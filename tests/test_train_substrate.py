"""Training substrate: optimizer, checkpointing, fault tolerance, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import ClassificationConfig, ClassificationStream, DataConfig, TokenStream
from repro.train import (
    FailureInjector,
    OptConfig,
    PreemptionError,
    RestartPolicy,
    StragglerDetector,
    Trainer,
    TrainerConfig,
    compressed_gradient,
    elastic_rescale_batch,
    init_opt_state,
    latest_step,
    lr_at,
    remesh_plan,
    restore,
    run_with_restarts,
    save,
)
from repro.train.optimizer import adamw_update, clip_by_global_norm

jax.config.update("jax_platform_name", "cpu")


class TestOptimizer:
    def _params(self):
        k = jax.random.PRNGKey(0)
        return {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,)),
                "sf": jnp.ones((3,))}

    def test_adamw_moves_params(self):
        p = self._params()
        g = jax.tree.map(jnp.ones_like, p)
        cfg = OptConfig(lr=1e-2, warmup_steps=0)
        p2, st, m = adamw_update(cfg, p, g, init_opt_state(p))
        assert float(jnp.abs(p2["w"] - p["w"]).max()) > 0
        assert int(st.step) == 1 and float(m["grad_norm"]) > 0

    def test_no_decay_on_quant_params(self):
        """LSQ state must not be weight-decayed (it is not a weight)."""
        p = {"sf": jnp.full((4,), 100.0), "w": jnp.full((4,), 100.0)}
        g = {"sf": jnp.zeros((4,)), "w": jnp.zeros((4,))}
        cfg = OptConfig(lr=1.0, weight_decay=0.5, warmup_steps=0,
                        quant_lr_mult=1.0)
        p2, _, _ = adamw_update(cfg, p, g, init_opt_state(p))
        np.testing.assert_array_equal(np.asarray(p2["sf"]), 100.0)
        assert float(p2["w"][0]) < 100.0  # decayed

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((10,), 10.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5

    def test_warmup_cosine_schedule(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110)
        assert float(lr_at(cfg, jnp.asarray(0))) == 0.0
        assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
        assert float(lr_at(cfg, jnp.asarray(110))) < 1e-6


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"params": {"w": jax.random.normal(k, (32, 16))},
                "opt": {"step": jnp.asarray(7, jnp.int32)}}

    def test_roundtrip_identity(self):
        t = self._tree()
        with tempfile.TemporaryDirectory() as d:
            save(d, 5, t)
            t2, step, _ = restore(d, t)
            assert step == 5
            np.testing.assert_array_equal(
                np.asarray(t["params"]["w"]), np.asarray(t2["params"]["w"])
            )

    def test_atomic_no_partial_checkpoint_visible(self):
        t = self._tree()
        with tempfile.TemporaryDirectory() as d:
            save(d, 1, t)
            # simulate a crashed write: stray tmp dir without commit marker
            os.makedirs(os.path.join(d, "step_00000009.tmp"))
            os.makedirs(os.path.join(d, "step_00000010"))  # no _COMMITTED
            assert latest_step(d) == 1

    def test_keep_last_gc(self):
        t = self._tree()
        with tempfile.TemporaryDirectory() as d:
            for s in range(6):
                save(d, s, t, keep_last=2)
            from repro.train.checkpoint import all_steps

            assert all_steps(d) == [4, 5]

    def test_restore_latest_by_default(self):
        with tempfile.TemporaryDirectory() as d:
            save(d, 1, self._tree(1))
            save(d, 9, self._tree(9))
            t9, step, _ = restore(d, self._tree())
            assert step == 9


class TestFaultTolerance:
    def test_run_with_restarts_resumes(self):
        calls = []

        def loop(start):
            calls.append(start)
            if len(calls) < 3:
                raise PreemptionError("boom")
            return 100

        steps = iter([0, 40, 80])
        assert run_with_restarts(loop, lambda: next(steps)) == 100
        assert calls == [0, 40, 80]

    def test_restart_policy_limits(self):
        pol = RestartPolicy(max_restarts=2)
        assert pol.should_restart(PreemptionError())
        assert pol.should_restart(PreemptionError())
        assert not pol.should_restart(PreemptionError())
        assert not pol.should_restart(ValueError())

    def test_straggler_detection(self):
        det = StragglerDetector(patience=2)
        flagged = []
        for step in range(8):
            times = {h: 1.0 for h in range(8)}
            times[3] = 5.0  # persistent straggler
            flagged += det.observe(times)
        assert 3 in flagged
        # healthy hosts never flagged
        assert set(flagged) == {3}

    def test_remesh_plan(self):
        assert remesh_plan(256, 16) == (16, 16)
        assert remesh_plan(240, 16) == (15, 16)
        with pytest.raises(ValueError):
            remesh_plan(8, 16)

    def test_elastic_batch_rescale(self):
        assert elastic_rescale_batch(256, 16, 15) == 240

    @given(seed=st.integers(0, 100))
    @settings(max_examples=10, deadline=None)
    def test_compression_error_feedback_bounded(self, seed):
        """int8 + error feedback: per-step residual stays bounded."""
        k = jax.random.PRNGKey(seed)
        g = {"w": jax.random.normal(k, (64,)) * 5.0}
        err = None
        for _ in range(4):
            deq, err = compressed_gradient(g, err)
        scale = float(jnp.max(jnp.abs(g["w"])) ) / 127.0
        assert float(jnp.max(jnp.abs(err["w"]))) <= scale * 1.01

    def test_trainer_recovers_from_injected_failure(self):
        from repro.configs import get_config

        cfg = get_config("tinyllama-1.1b").reduced()
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=2)
        stream = TokenStream(dc)
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(
                cfg, OptConfig(lr=1e-3, warmup_steps=2, total_steps=12),
                TrainerConfig(total_steps=12, ckpt_every=4, log_every=100,
                              ckpt_dir=d),
                data_fn=stream.batch_at,
                injector=FailureInjector(fail_at_steps=(6,)),
                log_fn=lambda s: None,
            )
            tr.train()
            assert tr.injector.raised == [6]
            assert latest_step(d) == 12


class TestData:
    def test_deterministic_and_seekable(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
        s1, s2 = TokenStream(cfg), TokenStream(cfg)
        b1, b2 = s1.batch_at(17), s2.batch_at(17)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_host_sharding_disjoint(self):
        base = dict(vocab_size=1000, seq_len=16, global_batch=8, n_hosts=2)
        h0 = TokenStream(DataConfig(host_id=0, **base)).batch_at(3)
        h1 = TokenStream(DataConfig(host_id=1, **base)).batch_at(3)
        assert h0["tokens"].shape == (4, 16)
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_targets_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
        b = TokenStream(cfg).batch_at(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])

    def test_markov_structure_is_learnable(self):
        """Structured tokens must have sub-uniform conditional entropy."""
        cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=8,
                         structure=1.0)
        b = TokenStream(cfg).batch_at(0)
        # each token has <= 8 successors -> bigram entropy <= log(8)
        from collections import defaultdict

        succ = defaultdict(set)
        for row in b["tokens"]:
            for a, c in zip(row[:-1], row[1:]):
                succ[int(a)].add(int(c))
        max_succ = max(len(v) for v in succ.values())
        assert max_succ <= 8

    def test_classification_stream_separable(self):
        cfg = ClassificationConfig(dim=64, train_noise=0.1)
        s = ClassificationStream(cfg)
        x, y = s.batch_at(0, 256)
        # nearest-prototype classification should be near-perfect
        pred = np.argmax(x @ s.protos.T, axis=1)
        assert (pred == y).mean() > 0.95
