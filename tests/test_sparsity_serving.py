"""Ternary-sparsity-aware serving: skip path bit-exactness + plumbing.

The sparsity-skipping path (docs/energy.md) may only ever change WHAT
work runs, never the numbers: a kernel given pack-time column-occupancy
metadata must return bit-identical outputs to its own dense execution,
on every registered backend, across the occupancy grid, both comparator
levels and the ADC baseline, ragged shapes included. This module pins
that invariant plus the metadata plumbing around it: pack-time
recording on :class:`PackedLayer`, pytree/mesh round-trips, the engine
greedy-parity with the skip toggled, and the benchmark-harness smoke
knobs (``benchmarks/run.py --smoke --sparsities --json``).
"""
import dataclasses
import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import QuantConfig
from repro.core.psq_linear import init_linear
from repro.kernels import registry
from repro.kernels.occupancy import (
    META_BLOCK, ColumnOccupancy, column_occupancy, kernel_block_flags,
    occupancy_for_kernel, shard_occupancy,
)
from repro.kernels.ref import psq_matmul_ref
from repro.serve.cache import PackedLayer, PackedModelCache, pack_tree_psq

from tests._hypothesis_compat import given, settings, st

jax.config.update("jax_platform_name", "cpu")

BACKENDS = registry.registered_backends()
OCCUPANCY_GRID = (0.0, 0.25, 0.5, 0.9, 1.0)

needs_devices = lambda n: pytest.mark.skipif(
    len(jax.devices()) < n,
    reason=f"needs >= {n} devices (tests/conftest.py forges 4 on CPU)",
)


def _backend_or_skip(name):
    try:
        return registry.get_backend(name)
    except RuntimeError as e:
        pytest.skip(str(e))


def _sparse_weight(K, O, zero_frac, block=META_BLOCK, seed=0, n_w=4):
    """Integer weight codes with ``round(zero_frac * n_blocks)`` whole
    ``block``-wide column blocks zeroed (the structure the pack-time
    metadata can actually exploit — scattered zero columns never empty
    a whole metadata block)."""
    rng = np.random.RandomState(seed)
    lo, hi = -(2 ** (n_w - 1)), 2 ** (n_w - 1) - 1
    w = rng.randint(lo, hi + 1, size=(K, O)).astype(np.float32)
    nb = math.ceil(O / block)
    for bi in range(int(round(zero_frac * nb))):
        w[:, bi * block:(bi + 1) * block] = 0.0
    return w


def _kernel_inputs(B, K, O, R, n_a=4, n_w=4, seed=0):
    T = math.ceil(K / R)
    rng = np.random.RandomState(seed + 1)
    lo_a, hi_a = -(2 ** (n_a - 1)), 2 ** (n_a - 1) - 1
    x = rng.randint(lo_a, hi_a + 1, size=(B, K)).astype(np.float32)
    sf = (rng.randint(0, 16, size=(T, n_a, n_w, O)) * 0.5).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(sf)


class TestOccupancyMetadata:
    def test_records_zero_blocks_per_tile(self):
        w = _sparse_weight(100, 96, 0.0, block=32)       # T=2 at R=64
        w[:, 32:64] = 0.0                                # block 1: all tiles
        w[:64, 0:32] = 0.0                               # block 0: tile 0 only
        occ = column_occupancy(w, xbar_rows=64, n_w=4, block=32)
        assert occ.n_tiles == 2 and occ.n_blocks == 3
        zb = occ.zero_blocks_np()
        assert zb.tolist() == [[True, True, False], [False, True, False]]
        assert occ.matches(96, 64, 100)
        assert not occ.matches(96, 128, 100)

    def test_mean_zero_fraction_is_column_weighted(self):
        # ragged last block (O=40, block=32): 32 zero cols of 40, per tile
        w = _sparse_weight(64, 40, 0.0, block=32)
        w[:, :32] = 0.0
        occ = column_occupancy(w, xbar_rows=64, n_w=4, block=32)
        assert occ.mean_zero_fraction == pytest.approx(32 / 40)
        assert occ.skippable_block_fraction == pytest.approx(0.5)

    def test_rejects_non_2d(self):
        with pytest.raises(ValueError):
            column_occupancy(np.zeros((2, 8, 8)), xbar_rows=64, n_w=4)

    def test_kernel_flags_conservative_and_padding(self):
        w = _sparse_weight(64, 96, 0.0, block=32)
        w[:, 0:32] = 0.0          # metadata block 0 zero, block 1 dense
        occ = column_occupancy(w, xbar_rows=64, n_w=4, block=32)
        # kernel block 0 covers metadata blocks 0+1 -> AND -> not skippable
        flags = kernel_block_flags(occ, block_o=64, o_pad=128)
        assert flags.shape == (1, 2)
        assert flags[0, 0] == 0
        # kernel block 1 covers cols 64..127: metadata block 2 is dense,
        # cols 96..127 are pure padding (skippable) -> AND -> 0
        assert flags[0, 1] == 0
        # padding-only kernel block is always skippable
        flags_wide = kernel_block_flags(occ, block_o=32, o_pad=128)
        assert flags_wide[0].tolist() == [1, 0, 0, 1]

    def test_for_kernel_guards(self):
        w = _sparse_weight(64, 64, 1.0, block=32)
        occ = column_occupancy(w, xbar_rows=64, n_w=4, block=32)
        assert occupancy_for_kernel(occ, 64, 64, 64) is occ
        assert occupancy_for_kernel(occ, 32, 64, 64) is None    # TP shard O
        assert occupancy_for_kernel(occ, 64, 128, 64) is None   # wrong K
        assert occupancy_for_kernel(None, 64, 64, 64) is None
        dense = column_occupancy(_sparse_weight(64, 64, 0.0, block=32),
                                 xbar_rows=64, n_w=4, block=32)
        assert occupancy_for_kernel(dense, 64, 64, 64) is None  # nothing to skip


class TestSkipBitExact:
    """Skip vs dense, same backend: must be bitwise identical."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("levels", ["ternary", "binary", "adc"])
    def test_occupancy_grid(self, backend, levels):
        impl = _backend_or_skip(backend)
        B, K, O, R = 5, 200, 4 * META_BLOCK, 64        # ragged K, 4 blocks
        x, sf = _kernel_inputs(B, K, O, R)
        alpha = jnp.array(5.0)
        kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=4, xbar_rows=R)
        for frac in OCCUPANCY_GRID:
            w = _sparse_weight(K, O, frac, seed=int(frac * 100))
            occ = column_occupancy(w, xbar_rows=R, n_w=4)
            wj = jnp.asarray(w)
            y_dense = impl.psq_matmul(x, wj, sf, alpha, **kw)
            y_skip = impl.psq_matmul(x, wj, sf, alpha, occupancy=occ, **kw)
            np.testing.assert_array_equal(
                np.asarray(y_dense), np.asarray(y_skip),
                err_msg=f"{backend}/{levels} differs at zero_frac={frac}")

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("levels", ["ternary", "binary"])
    def test_fused_planes_skip_exact(self, backend, levels):
        impl = _backend_or_skip(backend)
        B, K, O, R = 4, 128, 2 * META_BLOCK, 64
        w = _sparse_weight(K, O, 0.5, seed=7)
        occ = column_occupancy(w, xbar_rows=R, n_w=4)
        x, sf = _kernel_inputs(B, K, O, R)
        alpha = jnp.array(3.0)
        kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=4, xbar_rows=R,
                  fuse_planes=True)
        y_dense = impl.psq_matmul(x, jnp.asarray(w), sf, alpha, **kw)
        y_skip = impl.psq_matmul(x, jnp.asarray(w), sf, alpha,
                                 occupancy=occ, **kw)
        np.testing.assert_array_equal(np.asarray(y_dense), np.asarray(y_skip))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_all_zero_layer(self, backend):
        impl = _backend_or_skip(backend)
        B, K, O, R = 3, 96, META_BLOCK, 32
        w = np.zeros((K, O), np.float32)
        occ = column_occupancy(w, xbar_rows=R, n_w=4)
        assert occ.mean_zero_fraction == 1.0
        x, sf = _kernel_inputs(B, K, O, R)
        alpha = jnp.array(2.0)
        for levels in ("ternary", "binary", "adc"):
            kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=4, xbar_rows=R)
            y_dense = impl.psq_matmul(x, jnp.asarray(w), sf, alpha, **kw)
            y_skip = impl.psq_matmul(x, jnp.asarray(w), sf, alpha,
                                     occupancy=occ, **kw)
            np.testing.assert_array_equal(np.asarray(y_dense),
                                          np.asarray(y_skip))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_single_column_block_ragged(self, backend):
        impl = _backend_or_skip(backend)
        B, K, O, R = 2, 130, 40, 64          # one metadata block, O < 128
        w = _sparse_weight(K, O, 0.0, seed=3)
        w[:64, :] = 0.0                      # tile 0 fully zero, tile 1 dense
        occ = column_occupancy(w, xbar_rows=R, n_w=4)
        assert occ.zero_blocks_np().tolist() == [[True], [False], [False]]
        x, sf = _kernel_inputs(B, K, O, R)
        alpha = jnp.array(4.0)
        kw = dict(n_a=4, n_w=4, levels="ternary", adc_bits=4, xbar_rows=R)
        y_dense = impl.psq_matmul(x, jnp.asarray(w), sf, alpha, **kw)
        y_skip = impl.psq_matmul(x, jnp.asarray(w), sf, alpha,
                                 occupancy=occ, **kw)
        np.testing.assert_array_equal(np.asarray(y_dense), np.asarray(y_skip))

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 6),
        k=st.integers(33, 260),
        nb=st.integers(1, 4),
        r=st.sampled_from([32, 64, 128]),
        levels=st.sampled_from(["ternary", "binary", "adc"]),
        frac=st.sampled_from(OCCUPANCY_GRID),
        seed=st.integers(0, 2 ** 16),
    )
    def test_property_skip_invariance(self, b, k, nb, r, levels, frac, seed):
        """Random ragged shapes x occupancy grid, reference backend:
        pallas-interpret is exercised by the parametrized tests above
        (too slow per-example for hypothesis)."""
        O = nb * META_BLOCK - (seed % META_BLOCK)     # ragged last block
        w = _sparse_weight(k, O, frac, seed=seed)
        occ = column_occupancy(w, xbar_rows=r, n_w=4)
        x, sf = _kernel_inputs(b, k, O, r, seed=seed)
        alpha = jnp.array(float(1 + seed % 7))
        kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=4, xbar_rows=r)
        y_dense = psq_matmul_ref(x, jnp.asarray(w), sf, alpha, **kw)
        y_skip = psq_matmul_ref(x, jnp.asarray(w), sf, alpha,
                                occupancy=occ, **kw)
        np.testing.assert_array_equal(np.asarray(y_dense), np.asarray(y_skip))


def _sparse_packed_layer(zero_frac, k_in=96, n_out=2 * META_BLOCK,
                         seed=0, **qkw):
    cfg = QuantConfig(mode="psq", xbar_rows=32, kernel_backend="reference",
                      **qkw)
    params = init_linear(jax.random.PRNGKey(seed), k_in, n_out, cfg,
                         use_bias=True)
    w = np.asarray(params["w"]).copy()
    nb = math.ceil(n_out / META_BLOCK)
    for bi in range(int(round(zero_frac * nb))):
        w[:, bi * META_BLOCK:(bi + 1) * META_BLOCK] = 0.0
    params["w"] = jnp.asarray(w)
    return PackedLayer.pack(params, cfg), cfg


class TestPackedOccupancy:
    def test_pack_records_occupancy(self):
        layer, cfg = _sparse_packed_layer(0.5)
        occ = layer.occupancy
        assert isinstance(occ, ColumnOccupancy)
        k, o = layer.w_codes.shape
        assert occ.matches(o, cfg.xbar_rows, k)
        assert occ.mean_zero_fraction >= 0.5    # zeroed blocks stay zero codes
        assert occ.skippable_block_fraction >= 0.5

    def test_dense_pack_has_empty_occupancy(self):
        layer, _ = _sparse_packed_layer(0.0)
        assert layer.occupancy is not None
        assert layer.occupancy.skippable_block_fraction == 0.0

    def test_occupancy_survives_pytree_roundtrip(self):
        layer, _ = _sparse_packed_layer(0.5)
        leaves, treedef = jax.tree_util.tree_flatten(layer)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        assert rebuilt.occupancy == layer.occupancy
        mapped = jax.tree_util.tree_map(lambda a: a, layer)
        assert mapped.occupancy == layer.occupancy

    def test_occupancy_survives_pack_tree_and_cache_hit(self):
        cfg = QuantConfig(mode="psq", xbar_rows=32,
                          kernel_backend="reference")
        params = init_linear(jax.random.PRNGKey(0), 96, 2 * META_BLOCK, cfg)
        w = np.asarray(params["w"]).copy()
        w[:, :META_BLOCK] = 0.0
        params["w"] = jnp.asarray(w)
        tree = {"mlp": params}
        cache = PackedModelCache()
        packed = pack_tree_psq(tree, cfg, cache)
        assert packed["mlp"].occupancy.skippable_block_fraction == 0.5
        again = pack_tree_psq(tree, cfg, cache)      # cache hit path
        assert again["mlp"].occupancy == packed["mlp"].occupancy
        assert cache.stats()["hits"] >= 1

    @needs_devices(2)
    def test_occupancy_survives_mesh_placement(self):
        cfg = QuantConfig(mode="psq", xbar_rows=32,
                          kernel_backend="reference")
        params = init_linear(jax.random.PRNGKey(0), 96, 2 * META_BLOCK, cfg)
        w = np.asarray(params["w"]).copy()
        w[:, :META_BLOCK] = 0.0
        params["w"] = jnp.asarray(w)
        cache = PackedModelCache()
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        placed = pack_tree_psq({"mlp": params}, cfg, cache, mesh=mesh)
        assert placed["mlp"].occupancy is not None
        assert placed["mlp"].occupancy.skippable_block_fraction == 0.5

    @pytest.mark.parametrize("zero_frac", [0.5, 1.0])
    def test_apply_serving_skip_toggle_bit_exact(self, zero_frac):
        layer, cfg = _sparse_packed_layer(zero_frac)
        assert cfg.sparsity_skip                     # default on
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 96))
        y_skip, _ = layer.apply_serving(x)
        dense_layer = dataclasses.replace(
            layer, cfg=dataclasses.replace(cfg, sparsity_skip=False))
        y_dense, _ = dense_layer.apply_serving(x)
        np.testing.assert_array_equal(np.asarray(y_skip),
                                      np.asarray(y_dense))


def _block_sparsify_tree(node):
    """Zero the first META_BLOCK-wide column block of every 2-D linear
    weight wide enough to have one — structured sparsity the pack-time
    metadata can see, applied before packing."""
    if isinstance(node, dict):
        out = {}
        for k, v in node.items():
            if (k == "w" and hasattr(v, "ndim") and v.ndim in (2, 3)
                    and v.shape[-1] >= 2 * META_BLOCK):
                w = np.asarray(v).copy()
                w[..., :META_BLOCK] = 0.0       # all stacked layers: the
                out[k] = jnp.asarray(w)         # merged metadata sees it
            else:
                out[k] = _block_sparsify_tree(v)
        return out
    if isinstance(node, (list, tuple)):
        return type(node)(_block_sparsify_tree(v) for v in node)
    return node


class TestEngineSkipParity:
    def test_greedy_decode_parity_skip_on_off(self):
        """The served model's greedy tokens must not change when the
        sparsity skip is enabled — end-to-end over the packed engine."""
        from repro.configs import get_config
        from repro.core.config import PSQ_TERNARY
        from repro.models import init_model
        from repro.serve import EngineConfig, ServeEngine

        base = get_config("tinyllama-1.1b").reduced()
        outs = {}
        for skip in (True, False):
            qcfg = dataclasses.replace(PSQ_TERNARY,
                                       kernel_backend="reference",
                                       xbar_rows=64, sparsity_skip=skip)
            cfg = base.with_quant(qcfg)
            params = _block_sparsify_tree(
                init_model(jax.random.PRNGKey(0), cfg))
            packed = pack_tree_psq(params, qcfg, PackedModelCache())
            if skip:    # the structured zeros must be visible to the skip
                occs = [
                    lyr.occupancy.skippable_block_fraction
                    for lyr in jax.tree_util.tree_leaves(
                        packed, is_leaf=lambda n: hasattr(n, "w_codes"))
                    if hasattr(lyr, "w_codes") and lyr.occupancy is not None
                ]
                assert any(o > 0 for o in occs)
            eng = ServeEngine(params=packed, cfg=cfg,
                              ecfg=EngineConfig(max_batch=2, max_len=48))
            rng = np.random.RandomState(5)
            for _ in range(3):
                eng.submit(rng.randint(0, cfg.vocab_size, size=6),
                           max_new_tokens=5)
            outs[skip] = [r.output for r in eng.run()]
        assert outs[True] == outs[False]


class TestShardOccupancy:
    """Per-shard metadata re-slicing for tensor parallelism
    (:func:`repro.kernels.occupancy.shard_occupancy`)."""

    def test_reslice_and_conservative_merge(self):
        # O=4 blocks of 32; blocks 0 and 2 zero -> each 2-way shard
        # half has its FIRST local block zero -> merged local block 0
        # is skippable, local block 1 is not
        w = _sparse_weight(64, 128, 0.0, block=32)
        w[:, 0:32] = 0.0
        w[:, 64:96] = 0.0
        occ = column_occupancy(w, xbar_rows=64, n_w=4, block=32)
        s = shard_occupancy(occ, 2)
        assert s is not None and s.n_cols == 64 and s.n_blocks == 2
        assert s.zero_blocks_np().tolist() == [[True, False]]
        # the re-sliced metadata passes the kernel guard the global
        # metadata fails on a shard's local problem
        assert occupancy_for_kernel(occ, 64, 64, 64) is None
        assert occupancy_for_kernel(s, 64, 64, 64) is s

    def test_merge_drops_shard_disagreement(self):
        # only shard 0's half is zero -> AND across shards leaves
        # nothing skippable
        w = _sparse_weight(64, 128, 0.0, block=32)
        w[:, 0:64] = 0.0
        occ = column_occupancy(w, xbar_rows=64, n_w=4, block=32)
        s = shard_occupancy(occ, 2)
        assert s is not None
        assert not any(any(row) for row in s.zero_blocks)
        # fractions are the per-shard minimum, never an average
        assert s.zero_col_frac == ((0.0, 0.0),)

    def test_unrepresentable_splits_return_none(self):
        w = _sparse_weight(64, 96, 1.0, block=32)
        occ = column_occupancy(w, xbar_rows=64, n_w=4, block=32)
        assert shard_occupancy(occ, 5) is None      # 96 % 5 != 0
        # 96/2 = 48 puts a shard boundary inside a 32-wide block
        assert shard_occupancy(occ, 2) is None
        assert shard_occupancy(occ, 1) is occ
        assert shard_occupancy(None, 2) is None

    @needs_devices(2)
    def test_tp_skip_vs_dense_bit_exact(self):
        """2-way model mesh: shards must sparsity-skip (not fall back
        dense) and still match the dense single-device forward bit for
        bit."""
        from repro.core.psq_linear import apply_linear
        from repro.kernels.occupancy import shard_occupancy as shard_occ
        from repro.parallel.sharding import RULES_2D, axis_rules

        # zero the first block of EACH shard half so the conservative
        # cross-shard merge keeps a skippable block
        layer, qcfg = _sparse_packed_layer(0.0, n_out=4 * META_BLOCK)
        w = np.asarray(layer.w_codes).copy()
        w[:, :META_BLOCK] = 0
        w[:, 2 * META_BLOCK:3 * META_BLOCK] = 0
        layer = dataclasses.replace(
            layer, w_codes=jnp.asarray(w),
            occupancy=column_occupancy(w, xbar_rows=qcfg.xbar_rows,
                                       n_w=qcfg.spec.n_bits_w))
        s = shard_occ(layer.occupancy, 2)
        assert s is not None and s.skippable_block_fraction > 0

        dense_layer = dataclasses.replace(
            layer, cfg=dataclasses.replace(qcfg, sparsity_skip=False))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 96))
        y_ref, _ = dense_layer.apply_serving(x)     # single-device dense
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        with axis_rules(RULES_2D, mesh):
            y_skip, _ = apply_linear(layer, x, qcfg)
            y_dense, _ = apply_linear(dense_layer, x, dense_layer.cfg)
        np.testing.assert_array_equal(np.asarray(y_ref),
                                      np.asarray(y_skip))
        np.testing.assert_array_equal(np.asarray(y_ref),
                                      np.asarray(y_dense))

    @needs_devices(2)
    def test_engine_tp_skip_parity(self):
        """Served greedy tokens on a 2-way model mesh are identical with
        the sparsity skip on and off, with shard-aligned structured
        zeros that keep the re-sliced metadata skippable."""
        from repro.configs import get_config
        from repro.core.config import PSQ_TERNARY
        from repro.models import init_model
        from repro.serve import EngineConfig, ServeEngine

        def shard_aligned_sparsify(node):
            # zero the first META_BLOCK columns of each 2-way shard half
            if isinstance(node, dict):
                out = {}
                for k, v in node.items():
                    if (k == "w" and hasattr(v, "ndim") and v.ndim in (2, 3)
                            and v.shape[-1] >= 4 * META_BLOCK
                            and v.shape[-1] % (2 * META_BLOCK) == 0):
                        w = np.asarray(v).copy()
                        half = w.shape[-1] // 2
                        w[..., :META_BLOCK] = 0.0
                        w[..., half:half + META_BLOCK] = 0.0
                        out[k] = jnp.asarray(w)
                    else:
                        out[k] = shard_aligned_sparsify(v)
                return out
            if isinstance(node, (list, tuple)):
                return type(node)(shard_aligned_sparsify(v) for v in node)
            return node

        base = get_config("tinyllama-1.1b").reduced()
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        outs = {}
        for skip in (True, False):
            qcfg = dataclasses.replace(PSQ_TERNARY,
                                       kernel_backend="reference",
                                       xbar_rows=64, sparsity_skip=skip)
            cfg = base.with_quant(qcfg)
            params = shard_aligned_sparsify(
                init_model(jax.random.PRNGKey(0), cfg))
            packed = pack_tree_psq(params, qcfg, PackedModelCache(),
                                   mesh=mesh)
            eng = ServeEngine(params=packed, cfg=cfg,
                              ecfg=EngineConfig(max_batch=2, max_len=48),
                              mesh=mesh)
            rng = np.random.RandomState(5)
            for _ in range(3):
                eng.submit(rng.randint(0, cfg.vocab_size, size=6),
                           max_new_tokens=5)
            outs[skip] = [r.output for r in eng.run()]
        assert outs[True] == outs[False]


class TestBenchHarnessSmoke:
    def test_fig5a_sparsities_knob(self):
        from benchmarks.fig5a_sparsity import rows_to_json, run
        rows = run(sparsities=[0.0, 0.5])
        assert len(rows) == 2
        parsed = rows_to_json(rows)
        assert parsed[0]["reduction"] == 0.0
        assert parsed[1]["reduction"] > 0.2      # paper: 24% at 50%

    def test_run_py_smoke_emits_valid_json(self, tmp_path):
        out = tmp_path / "bench.json"
        env = dict(os.environ)
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(repo, "src"), repo,
             env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--smoke",
             "--only", "fig5a", "--json", str(out)],
            cwd=repo, env=env, capture_output=True, text=True, timeout=300)
        assert proc.returncode == 0, proc.stderr
        data = json.loads(out.read_text())
        assert data["failed"] == []
        names = [r["name"] for r in data["rows"]]
        # --smoke shrinks the grid to the three-point smoke grid
        assert names == ["fig5a/sparsity_00", "fig5a/sparsity_50",
                         "fig5a/sparsity_90"]
