"""Paged KV cache: pool/refcount/CoW invariants, radix index, engine
parity (paged vs contiguous, prefix reuse on vs off), kernel conformance,
jit stability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import registry
from repro.kernels.paged_attention import (
    paged_attention_kernel,
    paged_attention_ref,
)
from repro.models import init_model
from repro.serve import EngineConfig, ServeEngine
from repro.serve.paged_kv import (
    BlockPool,
    PagedKVManager,
    PoolExhausted,
    RadixPrefixIndex,
    TRASH_BLOCK,
)
from tests._hypothesis_compat import given, settings, st

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# host-side pool / index
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_alloc_release_cycle(self):
        pool = BlockPool(4)
        a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
        assert sorted([a, b, c]) == [1, 2, 3]       # page 0 reserved
        assert pool.free_blocks == 0
        with pytest.raises(PoolExhausted):
            pool.alloc()
        assert pool.release(b)
        assert pool.free_blocks == 1
        assert pool.alloc() == b                    # recycled
        pool.check_invariants()

    def test_refcounts_share_and_free(self):
        pool = BlockPool(3)
        a = pool.alloc()
        pool.retain(a)
        assert pool.refcount(a) == 2
        assert not pool.release(a)                  # still shared
        assert pool.release(a)                      # last ref frees
        assert pool.free_blocks == 2
        pool.check_invariants()

    def test_trash_block_never_allocated(self):
        pool = BlockPool(3)
        assert {pool.alloc(), pool.alloc()} == {1, 2}
        assert pool.refcount(TRASH_BLOCK) == 1


class TestRadixPrefixIndex:
    def _mk(self, num_blocks=16, bs=4):
        pool = BlockPool(num_blocks)
        return pool, RadixPrefixIndex(pool, bs)

    def test_longest_prefix_match(self):
        pool, idx = self._mk()
        blocks = [pool.alloc() for _ in range(3)]
        prompt = list(range(12))
        idx.insert(prompt, blocks)
        assert len(idx) == 3
        # full match, prefix match, diverging match, no match
        assert idx.lookup(prompt) == blocks
        assert idx.lookup(prompt[:9]) == blocks[:2]     # 9 // 4 = 2 pages
        assert idx.lookup(prompt[:8] + [99, 98, 97, 96]) == blocks[:2]
        assert idx.lookup([99] + prompt[1:]) == []
        pool.check_invariants()

    def test_lookup_limit_guards_full_match(self):
        pool, idx = self._mk()
        blocks = [pool.alloc() for _ in range(2)]
        prompt = list(range(8))
        idx.insert(prompt, blocks)
        # limit len-1: a fully-cached prompt still re-prefills one page
        assert idx.lookup(prompt, limit=len(prompt) - 1) == blocks[:1]

    def test_lookup_retains_for_caller(self):
        pool, idx = self._mk()
        blocks = [pool.alloc() for _ in range(2)]
        idx.insert(list(range(8)), blocks)
        got = idx.lookup(list(range(8)))
        assert [pool.refcount(b) for b in got] == [3, 3]  # alloc+index+caller

    def test_insert_keeps_existing_nodes(self):
        pool, idx = self._mk()
        blocks = [pool.alloc() for _ in range(2)]
        idx.insert(list(range(8)), blocks)
        dup = [pool.alloc() for _ in range(2)]
        assert idx.insert(list(range(8)), dup) == 0     # nothing new
        assert idx.lookup(list(range(8))) == blocks

    def test_eviction_is_lru_and_leaf_first(self):
        pool, idx = self._mk()
        b_old = [pool.alloc() for _ in range(2)]
        b_new = [pool.alloc()]
        idx.insert(list(range(8)), b_old)          # chain of 2 (leaf: page 2)
        idx.insert([50, 51, 52, 53], b_new)        # separate leaf
        # release the allocation refs: only the index holds the pages now
        for b in b_old + b_new:
            pool.release(b)
        idx.lookup([50, 51, 52, 53])               # touch -> most recent
        pool.release(b_new[0])                     # drop the lookup ref
        assert idx.evict(1) == 1
        # LRU leaf was the TAIL of the old chain, never its interior
        assert idx.lookup(list(range(8))) == b_old[:1]
        pool.release(b_old[0])                     # drop the lookup ref
        assert idx.evict(10) == 2                  # rest is evictable
        assert pool.free_blocks == pool.num_blocks - 1
        pool.check_invariants()


class TestPagedKVManager:
    def _mk(self, n_slots=2, bs=4, nb=12, mb=4, reuse=True):
        return PagedKVManager(n_slots, bs, nb, mb, prefix_reuse=reuse)

    def test_admit_register_reuse_retire(self):
        mgr = self._mk()
        p = [1, 2, 3, 4, 5, 6, 7, 8, 9]
        assert mgr.admit(0, p) == 0
        mgr.register(0, p)
        assert mgr.admit(1, p) == 8                # both full pages reused
        assert mgr.slot_blocks(1)[:2] == mgr.slot_blocks(0)[:2]
        assert mgr.slot_blocks(1)[2] != mgr.slot_blocks(0)[2]
        mgr.retire(0)
        mgr.retire(1)
        assert (mgr.tables == TRASH_BLOCK).all()
        assert mgr.stats()["indexed_blocks"] == 2  # prefix outlives slots
        mgr.check_invariants()

    def test_exactly_full_prompt_keeps_one_page_uncached(self):
        mgr = self._mk()
        p = list(range(8))                         # exactly 2 pages
        mgr.admit(0, p)
        mgr.register(0, p)
        mgr.retire(0)
        assert mgr.admit(1, p) == 4                # last page re-prefilled

    def test_prepare_append_allocates_at_boundary(self):
        mgr = self._mk()
        mgr.admit(0, [1, 2, 3])                    # 3 tokens in 1 page
        assert mgr.prepare_append(0) is None       # position 3: same page
        assert len(mgr.slot_blocks(0)) == 1
        assert mgr.prepare_append(0) is None       # position 4: new page
        assert len(mgr.slot_blocks(0)) == 2
        assert mgr.lengths[0] == 5

    def test_cow_on_shared_page_write(self):
        mgr = self._mk()
        mgr.admit(0, [1, 2, 3])
        mgr.fork(0, 1)
        src = mgr.slot_blocks(0)[0]
        assert mgr.pool.refcount(src) == 2
        cow = mgr.prepare_append(1)                # write into shared page
        assert cow is not None and cow[0] == src
        assert mgr.slot_blocks(1)[0] == cow[1] != src
        assert mgr.pool.refcount(src) == 1
        assert mgr.stats()["cow_copies"] == 1
        mgr.check_invariants()

    def test_mid_horizon_cow_probe(self):
        """Non-mutating probe for the device-loop engine: True iff a
        horizon position PAST the first would land in a shared page
        (only reachable via fork — the first position's CoW resolves on
        the host before the loop launches)."""
        mgr = self._mk(reuse=False)
        mgr.admit(0, [1, 2, 3, 4, 5, 6])           # pages [b0, b1], len 6
        mgr.fork(0, 1)
        free_before = mgr.pool.free_blocks
        # first write (pos 6) is host-resolvable: 1-step rounds are safe
        assert not mgr.mid_horizon_cow(1, 1)
        # but position 7 hits the still-shared second page mid-loop
        assert mgr.mid_horizon_cow(1, 2)
        assert mgr.pool.free_blocks == free_before     # probe mutated nothing
        cow = mgr.prepare_append(1)                # pos 6: CoW resolves now
        assert cow is not None
        assert not mgr.mid_horizon_cow(1, 4)       # all private/fresh ahead
        mgr.check_invariants()

    def test_failed_admit_rolls_back_all_page_refs(self):
        """PoolExhausted mid-admit must release lookup-retained prefix
        pages AND already-allocated private pages — no permanent leak."""
        mgr = self._mk(n_slots=1, bs=4, nb=2, mb=4)    # 1 usable page
        with pytest.raises(PoolExhausted):
            mgr.admit(0, list(range(9)))               # needs 3 pages
        assert mgr.pool.free_blocks == 1               # fully rolled back
        assert mgr.slot_blocks(0) == []
        mgr.check_invariants()
        assert mgr.admit(0, [1, 2, 3]) == 0            # pool still usable

    def test_pool_pressure_evicts_index(self):
        mgr = self._mk(n_slots=1, bs=4, nb=3, mb=2)   # 2 usable pages
        p1 = [1, 2, 3, 4, 5]
        mgr.admit(0, p1)
        mgr.register(0, p1)
        mgr.retire(0)                              # page [1..4] stays indexed
        mgr.admit(0, [9, 9, 9, 9, 9])              # needs both pages
        assert mgr.stats()["evictions"] == 1
        mgr.check_invariants()

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(1, 11),
                              st.integers(0, 6)),
                    min_size=1, max_size=40))
    def test_random_lifecycle_invariants(self, ops):
        """Random admit/append/fork/retire sequences keep every refcount,
        free-list and table entry consistent."""
        mgr = PagedKVManager(4, 4, 40, 4, prefix_reuse=True)
        rng = np.random.RandomState(0)
        live = [False] * 4
        for op, plen, slot_b in ops:
            slot = op % 4
            kind = slot_b % 3
            if not live[slot]:
                if kind == 2 and any(live):
                    src = next(i for i in range(4) if live[i])
                    mgr.fork(src, slot)
                else:
                    plen = min(plen, 4 * 4 - 4)    # leave decode headroom
                    p = rng.randint(0, 5, size=plen).tolist()
                    mgr.admit(slot, p)
                    mgr.register(slot, p)
                live[slot] = True
            elif kind == 0 and mgr.lengths[slot] < 4 * 4:
                mgr.prepare_append(slot)
            else:
                mgr.retire(slot)
                live[slot] = False
            mgr.check_invariants()
        for slot in range(4):
            if live[slot]:
                mgr.retire(slot)
        mgr.check_invariants()


# ---------------------------------------------------------------------------
# engine parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def shared_prompts(tiny):
    cfg, _ = tiny
    rng = np.random.RandomState(5)
    sys_prompt = rng.randint(0, cfg.vocab_size, size=24)
    return [np.concatenate([sys_prompt, rng.randint(0, cfg.vocab_size,
                                                    size=n)])
            for n in (3, 7, 5, 9, 4, 6)]


def _run_engine(params, cfg, prompts, mesh=None, max_new=6, **ecfg_kw):
    eng = ServeEngine(params, cfg,
                      EngineConfig(max_batch=4, max_len=64, block_size=8,
                                   **ecfg_kw),
                      mesh=mesh)
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    out = [r.output for r in sorted(eng.run(), key=lambda r: r.uid)]
    return out, eng


class TestPagedEngineParity:
    def test_paged_matches_contiguous_bit_exact(self, tiny, shared_prompts):
        """Same trace, paging on vs off (reuse disabled): token-for-token
        identical greedy outputs — the gathered page view is
        value-identical to the contiguous stripe."""
        cfg, params = tiny
        base, _ = _run_engine(params, cfg, shared_prompts)
        paged, _ = _run_engine(params, cfg, shared_prompts,
                               paged=True, prefix_reuse=False)
        assert paged == base

    def test_prefix_reuse_parity_and_prefill_reduction(self, tiny,
                                                       shared_prompts):
        """Prefix reuse on vs off: identical outputs, strictly fewer
        prefill tokens (the shared system prompt is served from pages)."""
        cfg, params = tiny
        off, e_off = _run_engine(params, cfg, shared_prompts,
                                 paged=True, prefix_reuse=False)
        on, e_on = _run_engine(params, cfg, shared_prompts,
                               paged=True, prefix_reuse=True)
        assert on == off
        s_on, s_off = e_on.stats(), e_off.stats()
        assert s_on["cached_prefix_tokens"] > 0
        assert s_on["prefill_tokens"] < s_off["prefill_tokens"]
        assert s_off["cached_prefix_tokens"] == 0
        assert s_on["paged"]["indexed_blocks"] > 0
        # cold admissions batch through the bucketed prefill like the
        # contiguous path — fewer prefill calls than requests
        assert s_off["prefill_calls"] < len(shared_prompts)

    def test_prefix_index_survives_runs(self, tiny, shared_prompts):
        """A second run on a warm engine serves (almost) every prompt
        from the index and still matches the cold outputs."""
        cfg, params = tiny
        base, _ = _run_engine(params, cfg, shared_prompts)
        _, eng = _run_engine(params, cfg, shared_prompts,
                             paged=True, prefix_reuse=True)
        eng.reset_stats()
        for p in shared_prompts:
            eng.submit(p, max_new_tokens=6)
        out2 = [r.output for r in sorted(eng.run(), key=lambda r: r.uid)]
        assert out2 == base
        s = eng.stats()
        assert s["cached_prefix_tokens"] > s["prefill_tokens"]

    def test_paged_attn_kernel_backend_parity(self, tiny, shared_prompts):
        """Engine decode routed through the registered pallas-interpret
        paged-attention kernel produces the same greedy tokens."""
        cfg, params = tiny
        base, _ = _run_engine(params, cfg, shared_prompts[:3], max_new=4)
        out, _ = _run_engine(params, cfg, shared_prompts[:3], max_new=4,
                             paged=True,
                             paged_attn_backend="pallas-interpret")
        assert out == base

    def test_paged_requires_continuous_family(self):
        cfg = get_config("xlstm-350m").reduced()
        with pytest.raises(ValueError, match="continuous"):
            ServeEngine(None, cfg, EngineConfig(paged=True))

    def test_paged_rejects_indivisible_block_size(self, tiny):
        cfg, params = tiny
        with pytest.raises(ValueError, match="block_size"):
            ServeEngine(params, cfg,
                        EngineConfig(max_len=60, paged=True, block_size=16))

    def test_no_recompile_after_warmup_paged(self, tiny, shared_prompts,
                                             compile_counts):
        """The paged decode step compiles once; a repeated workload adds
        zero compilations across decode/prefill/suffix/insert."""
        cfg, params = tiny
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=4, max_len=64, paged=True,
                                       block_size=8))
        fns = [eng._decode_multi_paged, eng._prefill_bucket,
               eng._prefill_suffix, eng._insert_paged]
        for p in shared_prompts:
            eng.submit(p, max_new_tokens=5)
        eng.run()
        warm = compile_counts(*fns)
        assert warm[0] == 1, "paged decode step must compile exactly once"
        for p in shared_prompts:
            eng.submit(p, max_new_tokens=5)
        eng.run()
        assert compile_counts(*fns) == warm, \
            "re-running an already-seen workload must not recompile"

    @pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
    def test_paged_sharded_parity(self, tiny, shared_prompts):
        """kv_blocks->data sharding of the page pool: mesh-sharded paged
        engine == single-device engine, token for token."""
        cfg, params = tiny
        base, _ = _run_engine(params, cfg, shared_prompts, max_new=4)
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        out, eng = _run_engine(params, cfg, shared_prompts, mesh=mesh,
                               max_new=4, paged=True, prefix_reuse=True)
        assert out == base
        assert eng.stats()["mesh"] == "data=2xmodel=1"


class TestPoolPressure:
    """A page pool smaller than the queue's concurrent demand must
    degrade to serialized serving, never hang or crash."""

    def _prompts(self, cfg, n=4, plen=12, seed=11):
        rng = np.random.RandomState(seed)
        return [rng.randint(0, cfg.vocab_size, size=plen) for _ in range(n)]

    @pytest.mark.parametrize("horizon", [1, 8])
    def test_tiny_pool_admission_stall_decodes_through(self, tiny, horizon):
        """Regression (busy-spin): with a deliberately tiny num_blocks
        pool, the admission loop used to spin forever once admit rolled
        back on PoolExhausted while free slots stayed open. The engine
        must instead break to decode — retirement frees pages — and
        still serve every request with the unconstrained outputs."""
        cfg, params = tiny
        prompts = self._prompts(cfg)    # each needs 3 pages (12+8 tok)
        base, _ = _run_engine(params, cfg, prompts, max_new=8)
        # 6 usable pages => at most two requests in flight; reuse off so
        # retired pages return to the free list immediately
        out, eng = _run_engine(params, cfg, prompts, max_new=8, paged=True,
                               num_blocks=7, prefix_reuse=False,
                               decode_horizon=horizon)
        assert out == base
        assert eng.stats()["paged"]["free_blocks"] == 6   # nothing leaked

    def test_pool_too_small_for_one_request_raises(self, tiny):
        """An admission stall with NO live slots to retire can never
        resolve — the engine must surface PoolExhausted instead of
        spinning on the queue head forever."""
        cfg, params = tiny
        rng = np.random.RandomState(12)
        prompt = rng.randint(0, cfg.vocab_size, size=20)  # needs 3 pages
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=2, max_len=64, paged=True,
                                       block_size=8, num_blocks=3,
                                       prefix_reuse=False))
        eng.submit(prompt, max_new_tokens=4)
        with pytest.raises(PoolExhausted, match="num_blocks"):
            eng.run()


# ---------------------------------------------------------------------------
# kernel conformance
# ---------------------------------------------------------------------------

class TestPagedAttentionKernel:
    def _case(self, b=3, heads=4, hk=2, d=8, nb=9, bs=4, mb=4, seed=0):
        rng = np.random.RandomState(seed)
        f = lambda *s: rng.randn(*s).astype(np.float32)
        q = f(b, heads, d)
        k_pool, v_pool = f(nb, bs, hk, d), f(nb, bs, hk, d)
        k_new, v_new = f(b, hk, d), f(b, hk, d)
        bt = rng.randint(1, nb, size=(b, mb)).astype(np.int32)
        lengths = np.array([0, 5, mb * bs], np.int32)[:b]
        return q, k_pool, v_pool, bt, lengths, k_new, v_new

    def test_interpret_kernel_matches_reference(self):
        args = self._case()
        ref = paged_attention_ref(*args)
        ker = paged_attention_kernel(*map(jnp.asarray, args), interpret=True)
        np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_gqa_grouping(self):
        args = self._case(b=2, heads=8, hk=2, d=4, seed=3)
        ref = paged_attention_ref(*args)
        ker = paged_attention_kernel(*map(jnp.asarray, args), interpret=True)
        np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_registry_exposes_paged_attention(self):
        for name in ("reference", "pallas-interpret", "pallas"):
            backend = registry._REGISTRY[name]
            assert backend.paged_attention is not None, name
        args = self._case(seed=7)
        ref = registry.get_backend("reference").paged_attention(*args)
        ker = registry.get_backend("pallas-interpret").paged_attention(
            *map(jnp.asarray, args))
        np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_reference_matches_contiguous_decode_semantics(self):
        """Zero-length slots attend only the new token; full slots attend
        everything — matching decode_attention's mask convention."""
        q, kp, vp, bt, lengths, kn, vn = self._case(seed=1)
        out = np.asarray(paged_attention_ref(q, kp, vp, bt, lengths, kn, vn))
        # length 0: softmax collapses onto the new-token column -> v_new
        g = q.shape[1] // kn.shape[1]
        expect = np.repeat(vn[0][:, None], g, axis=1).reshape(-1, vn.shape[-1])
        np.testing.assert_allclose(out[0], expect, atol=1e-5)
