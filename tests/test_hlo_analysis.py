"""Scan-aware HLO analyzer: validated against XLA cost_analysis where the
latter is correct (scan-free programs) and against ground truth on scans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze

jax.config.update("jax_platform_name", "cpu")
W = jax.ShapeDtypeStruct((512, 512), jnp.float32)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_cost(c):
    """cost_analysis() returns a per-device list on some JAX versions."""
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


class TestFlops:
    def test_plain_matmul_matches_xla(self):
        c = _compile(lambda a, b: a @ b, W, W)
        r = analyze(c.as_text())
        assert abs(r["flops"] - _xla_cost(c)["flops"]) < 1e6

    def test_scan_multiplies_trip_count(self):
        def f(x, ws):
            def body(c, s):
                return jnp.tanh(c @ s), None
            c, _ = jax.lax.scan(body, x, ws)
            return c

        ws = jax.ShapeDtypeStruct((10, 512, 512), jnp.float32)
        r = analyze(_compile(f, W, ws).as_text())
        expect = 2 * 512 ** 3 * 10
        assert abs(r["flops"] - expect) / expect < 0.05
        # XLA's cost_analysis undercounts by ~10x here (body counted once)

    def test_nested_scan(self):
        def f(x):
            def outer(c, _):
                def inner(ci, _):
                    return jnp.tanh(ci @ c), None
                ci, _ = jax.lax.scan(inner, c, None, length=5)
                return ci, None
            c, _ = jax.lax.scan(outer, x, None, length=4)
            return c

        r = analyze(_compile(f, W).as_text())
        expect = 2 * 512 ** 3 * 20
        assert abs(r["flops"] - expect) / expect < 0.05


class TestBytes:
    def test_matmul_io(self):
        c = _compile(lambda a, b: a @ b, W, W)
        r = analyze(c.as_text())
        expect = 3 * 512 * 512 * 4
        assert abs(r["bytes"] - expect) / expect < 0.01

    def test_scan_io_trip_multiplied_but_slice_aware(self):
        """Reading a (10,512,512) stack via scan must cost ~the stack once,
        not 10x the whole stack (dynamic-slice awareness)."""
        def f(x, ws):
            def body(c, s):
                return jnp.tanh(c @ s), None
            c, _ = jax.lax.scan(body, x, ws)
            return c

        ws = jax.ShapeDtypeStruct((10, 512, 512), jnp.float32)
        r = analyze(_compile(f, W, ws).as_text())
        stack_bytes = 10 * 512 * 512 * 4
        # lower bound: read stack once + carry traffic; upper: ~4x
        assert stack_bytes * 0.8 <= r["bytes"] <= stack_bytes * 8


class TestCollectives:
    def test_psum_counted(self):
        mesh = jax.make_mesh((1,), ("x",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        def f(a):
            return jax.lax.with_sharding_constraint(
                a, NamedSharding(mesh, P())
            ) * 2.0

        # single-device: no collectives expected
        with mesh:
            r = analyze(_compile(f, W).as_text())
        assert r["collectives"]["total"] == 0.0
