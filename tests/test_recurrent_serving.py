"""Recurrent-state families (SSM / xLSTM / hybrid) on the serve engine.

PR 2 left ssm/xlstm/hybrid on the static fallback because recurrent
prefill folded right-pad tokens into the state. Masked-length prefill
(``models/decode.prefill`` + per-layer ``lengths`` masking) makes padded
positions exact state no-ops, so these families now run the continuous
slot pool — this module pins bit-exact greedy parity across sequential /
static / continuous / sharded execution, slot-reuse state isolation, jit
stability, and the hoisted decode constants.

Three recurrent architectures cover the three state flavors:

* ``xlstm-350m`` — family "ssm": mLSTM matrix memory + sLSTM scalars,
* ``zamba2-7b`` — family "hybrid": Mamba2 SSD states + shared attention,
* a pure-Mamba variant (zamba2 layout with no attention slots) — SSD
  states only, no KV at all.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serve import EngineConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")

ARCHS = ("xlstm-350m", "zamba2-7b", "mamba-pure")


def _arch_cfg(name):
    if name == "mamba-pure":
        # hybrid layout with attn_every > n_layers: every layer lands in
        # the Mamba2 tail — a pure-SSM decoder with no attention block
        return dataclasses.replace(
            get_config("zamba2-7b").reduced(), n_layers=3, attn_every=4
        )
    return get_config(name).reduced()


@pytest.fixture(scope="module")
def models():
    return {
        a: (lambda c: (c, init_model(jax.random.PRNGKey(0), c)))(_arch_cfg(a))
        for a in ARCHS
    }


def _run(params, cfg, prompts, mode="auto", max_batch=4, max_new=6,
         mesh=None, max_len=64):
    eng = ServeEngine(
        params, cfg,
        EngineConfig(max_batch=max_batch, max_len=max_len, mode=mode),
        mesh=mesh,
    )
    for p in prompts:
        eng.submit(p, max_new_tokens=max_new)
    return {r.uid: r.output for r in eng.run()}, eng


def _prompts(cfg, sizes=(3, 9, 5, 14), seed=7):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, size=n) for n in sizes]


class TestContinuousParity:
    """Greedy decode is bit-exact across schedulers for every recurrent
    state flavor — the masked-length prefill contract, end to end."""

    @pytest.mark.parametrize("arch", ARCHS)
    def test_auto_resolves_continuous(self, models, arch):
        cfg, params = models[arch]
        _, eng = _run(params, cfg, _prompts(cfg, sizes=(4,)), max_new=2)
        assert eng.mode == "continuous"

    @pytest.mark.parametrize("arch", ARCHS)
    def test_continuous_vs_sequential(self, models, arch):
        """Mixed-length slot pool == one-at-a-time decoding, token for
        token (more requests than slots: retirement + re-admission)."""
        cfg, params = models[arch]
        prompts = _prompts(cfg, sizes=(3, 9, 5, 14, 7))
        batched, _ = _run(params, cfg, prompts, "continuous", max_batch=2)
        for uid, p in zip(sorted(batched), prompts):
            seq, _ = _run(params, cfg, [p], "static", max_batch=1)
            assert batched[uid] == seq[1], \
                f"{arch} request {uid} diverged from sequential decode"

    @pytest.mark.parametrize("arch", ARCHS)
    def test_static_vs_continuous(self, models, arch):
        """The static fallback right-pads with per-row lengths, so the
        two schedulers agree bit for bit on a mixed-length batch."""
        cfg, params = models[arch]
        prompts = _prompts(cfg)
        cont, _ = _run(params, cfg, prompts, "continuous")
        stat, _ = _run(params, cfg, prompts, "static")
        assert cont == stat, f"{arch}: static diverged from continuous"

    @pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
    @pytest.mark.parametrize("arch", ARCHS)
    def test_2way_data_mesh_parity(self, models, arch):
        """Recurrent state pools shard over the data axis
        (``recurrent_state`` rule) without changing a single token."""
        cfg, params = models[arch]
        prompts = _prompts(cfg)
        base, _ = _run(params, cfg, prompts, "continuous")
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        out, eng = _run(params, cfg, prompts, "continuous", mesh=mesh)
        assert out == base, f"{arch}: 2-way data mesh diverged"
        assert eng.stats()["mesh"] == "data=2xmodel=1"


class TestSlotReuse:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_retire_then_readmit_state_isolation(self, models, arch):
        """A retired slot's stale recurrent state must not bleed into
        the request re-admitted into it: with ONE slot, every request
        decodes in the previous retiree's slot."""
        cfg, params = models[arch]
        prompts = _prompts(cfg, sizes=(6, 11, 4), seed=3)
        pooled, eng = _run(params, cfg, prompts, "continuous", max_batch=1,
                           max_new=5)
        # every admission really went through the same slot
        assert {a["slot"] for a in eng.admissions} == {0}
        for uid, p in zip(sorted(pooled), prompts):
            seq, _ = _run(params, cfg, [p], "static", max_batch=1, max_new=5)
            assert pooled[uid] == seq[1], \
                f"{arch}: state bled through slot reuse (request {uid})"


class TestJitStability:
    @pytest.mark.parametrize("arch", ("xlstm-350m", "zamba2-7b"))
    def test_no_recompile_after_warmup(self, models, arch, compile_counts):
        cfg, params = models[arch]
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=4, max_len=64))
        fns = [eng._decode_multi, eng._prefill_bucket, eng._insert]
        rng = np.random.RandomState(1)
        trace = [(rng.randint(0, cfg.vocab_size, size=int(rng.randint(2, 17))),
                  int(rng.randint(2, 9))) for _ in range(8)]
        for p, mn in trace:
            eng.submit(p, max_new_tokens=mn)
        eng.run()
        warm = compile_counts(*fns)
        assert warm[0] == 1, "recurrent decode loop must compile exactly once"
        for p, mn in trace:
            eng.submit(p, max_new_tokens=mn)
        eng.run()
        assert compile_counts(*fns) == warm, \
            "re-running an already-seen workload must not recompile"

    def test_static_prefill_buckets_batch_and_length(self, models,
                                                     compile_counts):
        """The static path pow2-buckets the admitted batch dim (and, for
        recurrent right-pad, the prompt length), so uneven final batches
        reuse the full-batch compile instead of recompiling per size."""
        cfg, params = models["xlstm-350m"]
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=4, max_len=64,
                                       mode="static"))
        rng = np.random.RandomState(0)
        # 7 requests, prompt lengths all inside the 8-bucket: batches of
        # 4 and 3 — the 3-batch pads to 4 and hits the same compile
        for _ in range(7):
            eng.submit(rng.randint(0, cfg.vocab_size, size=6),
                       max_new_tokens=3)
        eng.run()
        assert compile_counts(eng._prefill_full) == [1], \
            "static prefill must compile once per (batch, length) bucket"


class TestDecodeConstantHoisting:
    """Satellite: decode_mamba2 stops re-deriving A = -exp(A_log) every
    token — the engine folds it into the served params at load."""

    def test_engine_hoists_mamba_constants(self, models):
        cfg, params = models["zamba2-7b"]
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=2, max_len=32))
        assert "A" in eng.params["mamba_groups"]["mamba"]
        np.testing.assert_array_equal(
            np.asarray(eng.params["mamba_groups"]["mamba"]["A"]),
            np.asarray(-jnp.exp(params["mamba_groups"]["mamba"]["A_log"])),
        )

    def test_hoisted_decode_step_drops_weight_exp_ops(self, models):
        """The compiled decode step contains strictly fewer exponential
        ops with hoisted params — and produces identical logits."""
        from repro.models import decode as D

        cfg, params = models["mamba-pure"]
        hoisted = D.hoist_decode_params(params, cfg)
        tok = jnp.zeros((2, 1), jnp.int32)

        def compiled(p):
            cache = D.cache_init(p, cfg, 2, 32, dtype=jnp.float32)
            fn = jax.jit(lambda pp, t, c: D.decode_step(pp, cfg, t, c))
            return fn.lower(p, tok, cache).compile(), cache

        raw_exe, raw_cache = compiled(params)
        hst_exe, hst_cache = compiled(hoisted)
        n_raw = raw_exe.as_text().count("exponential")
        n_hst = hst_exe.as_text().count("exponential")
        assert n_hst < n_raw, \
            f"hoisting must remove exp(A_log) from the step ({n_hst} vs {n_raw})"
        lg_raw, _ = raw_exe(params, tok, raw_cache)
        lg_hst, _ = hst_exe(hoisted, tok, hst_cache)
        np.testing.assert_array_equal(np.asarray(lg_raw), np.asarray(lg_hst))
