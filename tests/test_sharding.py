"""Sharding rules: logical axes, divisibility guards, param specs, serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    RULES_2D, axis_rules, constrain, logical_to_pspec,
    packed_layer_pspecs, shard_packed_layer, shard_packed_tree, tp_axes,
)

jax.config.update("jax_platform_name", "cpu")

needs_devices = lambda n: pytest.mark.skipif(
    len(jax.devices()) < n,
    reason=f"needs >= {n} devices (tests/conftest.py forges 4 on CPU)",
)


class TestLogicalRules:
    def test_noop_without_rules(self):
        x = jnp.ones((4, 8))
        y = constrain(x, "batch", "embed")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_pspec_mapping(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with axis_rules(RULES_2D, mesh):
            spec = logical_to_pspec(["batch", "seq", "ffn"], shape=(4, 8, 16))
        assert spec == P("data", None, "model")

    def test_divisibility_guard(self):
        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        with axis_rules(RULES_2D, mesh):
            # 7 not divisible by model=2 -> unsharded
            spec = logical_to_pspec(["batch", "ffn"], shape=(4, 7))
        assert spec == P("data")

    def test_duplicate_axis_dedup(self):
        """Two logical dims mapping to the same mesh axis: first wins."""
        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        with axis_rules(RULES_2D, mesh):
            spec = logical_to_pspec(
                ["experts", None, "expert_ffn"], shape=(4, 2, 8)
            )
        assert spec == P("model")  # expert_ffn dropped, no duplicates


class TestParamSpecs:
    def test_qkv_and_down_proj_rules(self):
        from repro.launch.specs import param_pspec

        mesh = jax.make_mesh((1, 1), ("data", "model"))

        class Leaf:
            def __init__(self, shape):
                self.shape = shape
                self.ndim = len(shape)

        class K:
            def __init__(self, key):
                self.key = key

        spec = param_pspec([K("blocks"), K("attn"), K("wq"), K("w")],
                           Leaf((22, 128, 64)), mesh)
        assert spec == P(None, None, "model")
        spec = param_pspec([K("blocks"), K("mlp"), K("down"), K("w")],
                           Leaf((22, 256, 128)), mesh)
        assert spec == P(None, "model")
        spec = param_pspec([K("blocks"), K("norm1"), K("scale")],
                           Leaf((128,)), mesh)
        assert spec == P()

    def test_moe_expert_parallel_vs_ffn_sharding(self):
        from repro.launch.specs import param_pspec

        class Leaf:
            def __init__(self, shape):
                self.shape = shape
                self.ndim = len(shape)

        class K:
            def __init__(self, key):
                self.key = key

        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        # 128 experts divisible by 2 -> EP
        spec = param_pspec([K("moe"), K("w_gate")], Leaf((35, 128, 64, 32)),
                           mesh)
        assert spec == P(None, "model")
        # 41 experts not divisible -> shard expert ffn dim
        spec = param_pspec([K("moe"), K("w_gate")], Leaf((35, 41, 64, 32)),
                           mesh)
        assert spec == P(None, None, None, "model")


def _packed_layer(k_in=64, n_out=8, use_bias=True, seed=0, **qkw):
    from repro.core.config import QuantConfig
    from repro.core.psq_linear import init_linear
    from repro.serve.cache import PackedLayer

    cfg = QuantConfig(mode="psq", xbar_rows=32, kernel_backend="reference",
                      **qkw)
    params = init_linear(jax.random.PRNGKey(seed), k_in, n_out, cfg,
                         use_bias=use_bias)
    return PackedLayer.pack(params, cfg), cfg


class TestPackedLayerSpecs:
    def test_column_dims_follow_sf_out_rule(self):
        layer, _ = _packed_layer()
        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        specs = packed_layer_pspecs(layer, rules=RULES_2D, mesh=mesh)
        assert specs.w_codes == P(None, "model")
        assert specs.w_packed == P(None, "model")
        assert specs.sf_q == P(None, None, None, "model")
        assert specs.bias == P("model")
        # scalars / bit-significance vectors replicate — even when their
        # length happens to equal a shardable size
        assert specs.alpha == P() and specs.step_x == P()
        assert specs.sigma == P() and specs.kappa == P()
        assert specs.s_w == P()          # per-layer LSQ step: scalar

    def test_reduced_granularity_sf_stays_replicated(self):
        layer, _ = _packed_layer(sf_granularity="per_tile")
        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        specs = packed_layer_pspecs(layer, rules=RULES_2D, mesh=mesh)
        assert layer.sf_q.shape[-1] == 1
        assert specs.sf_q == P()         # size-1 dim: divisibility guard

    def test_non_divisible_columns_fall_back_unsharded(self):
        layer, _ = _packed_layer(n_out=6)
        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 4)))
        specs = packed_layer_pspecs(layer, rules=RULES_2D, mesh=mesh)
        assert specs.w_codes == P()
        assert specs.bias == P()

    def test_stacked_layers_get_leading_layer_axis(self):
        from repro.core.config import QuantConfig
        from repro.core.psq_linear import init_linear
        from repro.serve.cache import PackedLayer

        cfg = QuantConfig(mode="psq", xbar_rows=32,
                          kernel_backend="reference")
        stacked = jax.vmap(
            lambda k: PackedLayer.pack(init_linear(k, 64, 8, cfg), cfg)
        )(jax.random.split(jax.random.PRNGKey(0), 3))
        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        specs = packed_layer_pspecs(stacked, rules=RULES_2D, mesh=mesh)
        assert stacked.w_codes.ndim == 3
        assert specs.w_codes == P(None, None, "model")
        assert specs.sf_q == P(None, None, None, None, "model")
        assert specs.s_w == P()          # (L,) stacked scalar: replicated

    def test_tp_axes_activation(self):
        assert tp_axes() is None                       # no rules active
        mesh1 = jax.make_mesh((1, 1), ("data", "model"))
        with axis_rules(RULES_2D, mesh1):
            assert tp_axes() is None                   # model axis size 1
        with axis_rules(RULES_2D, None):
            assert tp_axes() is None                   # rules without mesh
        amesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        with axis_rules(RULES_2D, amesh):
            assert tp_axes() is None                   # abstract: no shard_map

    @needs_devices(2)
    def test_tp_axes_on_real_mesh(self):
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        with axis_rules(RULES_2D, mesh):
            assert tp_axes() == (mesh, "model")


class TestTensorParallelPSQ:
    """Sharded-vs-single-device bit-exactness of the packed PSQ matmul."""

    @needs_devices(2)
    @pytest.mark.parametrize("model_parallel", [2, 4])
    def test_psq_linear_tp_bit_exact(self, model_parallel):
        if len(jax.devices()) < model_parallel:
            pytest.skip(f"needs {model_parallel} devices")
        from repro.core.psq_linear import apply_linear

        layer, qcfg = _packed_layer(k_in=64, n_out=8)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 3, 64))
        y_ref, _ = layer.apply_serving(x)

        mesh = jax.make_mesh((1, model_parallel), ("data", "model"))
        with axis_rules(RULES_2D, mesh):
            y_tp, _ = apply_linear(layer, x, qcfg)
        np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y_tp))

    @needs_devices(4)
    def test_tp_divisibility_fallback_still_exact(self):
        from repro.core.psq_linear import apply_linear

        layer, qcfg = _packed_layer(n_out=6)     # 6 % 4 != 0
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 64))
        y_ref, _ = layer.apply_serving(x)
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        with axis_rules(RULES_2D, mesh):
            y, _ = apply_linear(layer, x, qcfg)
        np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y))

    @needs_devices(4)
    def test_tp_under_jit_and_data_axis(self):
        from repro.core.psq_linear import apply_linear

        layer, qcfg = _packed_layer(k_in=64, n_out=16)
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 64))
        y_ref, _ = layer.apply_serving(x)
        mesh = jax.make_mesh((2, 2), ("data", "model"))

        def fwd(lyr, xx):
            with axis_rules(RULES_2D, mesh):
                return apply_linear(lyr, xx, qcfg)[0]

        y = jax.jit(fwd)(layer, x)
        np.testing.assert_array_equal(np.asarray(y_ref), np.asarray(y))

    @needs_devices(2)
    def test_shard_packed_layer_placement(self):
        from jax.sharding import NamedSharding

        layer, _ = _packed_layer(n_out=8)
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        placed = shard_packed_layer(layer, mesh)
        assert placed.w_codes.sharding == NamedSharding(
            mesh, P(None, "model"))
        assert placed.alpha.sharding == NamedSharding(mesh, P())
        np.testing.assert_array_equal(
            np.asarray(layer.w_codes), np.asarray(placed.w_codes))

    @needs_devices(2)
    def test_pack_cache_placement_is_per_call_not_sticky(self):
        """A meshed pack must not leak its sharding into later no-mesh
        packs of the same weights — the cache stores unplaced state and
        applies placement per call (fingerprint-stable: all hits)."""
        from repro.core.config import QuantConfig
        from repro.core.psq_linear import init_linear
        from repro.serve.cache import PackedModelCache, pack_tree_psq

        qcfg = QuantConfig(mode="psq", xbar_rows=32,
                           kernel_backend="reference")
        tree = {"mlp": init_linear(jax.random.PRNGKey(0), 64, 8, qcfg)}
        cache = PackedModelCache()
        mesh = jax.make_mesh((1, 2), ("data", "model"))

        sharded = pack_tree_psq(tree, qcfg, cache, mesh=mesh)
        assert sharded["mlp"].w_codes.sharding.spec == P(None, "model")
        plain = pack_tree_psq(tree, qcfg, cache)            # no mesh
        assert not isinstance(
            plain["mlp"].w_codes.sharding, jax.sharding.NamedSharding
        ) or plain["mlp"].w_codes.sharding.spec != P(None, "model")
        assert cache.stats() == {"layers": 1, "packs": 1, "hits": 1}

    @needs_devices(2)
    def test_shard_packed_tree_passes_non_packed_through(self):
        layer, _ = _packed_layer(n_out=8)
        norm = {"scale": jnp.ones((64,))}
        tree = {"mlp": layer, "norm": norm, "depth": [layer]}
        mesh = jax.make_mesh((1, 2), ("data", "model"))
        out = shard_packed_tree(tree, mesh)
        assert out["norm"]["scale"] is norm["scale"]   # leaf passes through
        assert out["mlp"].w_codes.sharding.spec == P(None, "model")
        assert out["depth"][0].w_codes.sharding.spec == P(None, "model")


class TestServeEngine:
    def test_batched_requests_complete(self):
        import numpy as np

        from repro.configs import get_config
        from repro.models import init_model
        from repro.serve import EngineConfig, ServeEngine, throughput_stats

        cfg = get_config("tinyllama-1.1b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=3, max_len=48))
        rng = np.random.RandomState(0)
        for _ in range(5):
            eng.submit(rng.randint(0, cfg.vocab_size, size=rng.randint(2, 8)),
                       max_new_tokens=6)
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.output) == 6 for r in done)
        stats = throughput_stats(done)
        assert stats["total_tokens"] == 30 and stats["tokens_per_s"] > 0

    def test_int4_serving_matches_greedy_mostly(self):
        import numpy as np

        from repro.configs import get_config
        from repro.core.psq_linear import pack_tree_for_serving
        from repro.models import init_model
        from repro.serve import EngineConfig, ServeEngine

        cfg = get_config("tinyllama-1.1b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        prompt = np.arange(5) % cfg.vocab_size
        outs = {}
        for name, p in [("fp", params), ("int4", pack_tree_for_serving(params))]:
            eng = ServeEngine(p, cfg, EngineConfig(max_batch=1, max_len=32))
            eng.submit(prompt, max_new_tokens=4)
            outs[name] = eng.run()[0].output
        assert len(outs["fp"]) == len(outs["int4"]) == 4
