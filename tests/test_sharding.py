"""Sharding rules: logical axes, divisibility guards, param specs, serve."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (
    RULES_2D, axis_rules, constrain, logical_to_pspec,
)

jax.config.update("jax_platform_name", "cpu")


class TestLogicalRules:
    def test_noop_without_rules(self):
        x = jnp.ones((4, 8))
        y = constrain(x, "batch", "embed")
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_pspec_mapping(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with axis_rules(RULES_2D, mesh):
            spec = logical_to_pspec(["batch", "seq", "ffn"], shape=(4, 8, 16))
        assert spec == P("data", None, "model")

    def test_divisibility_guard(self):
        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        with axis_rules(RULES_2D, mesh):
            # 7 not divisible by model=2 -> unsharded
            spec = logical_to_pspec(["batch", "ffn"], shape=(4, 7))
        assert spec == P("data")

    def test_duplicate_axis_dedup(self):
        """Two logical dims mapping to the same mesh axis: first wins."""
        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        with axis_rules(RULES_2D, mesh):
            spec = logical_to_pspec(
                ["experts", None, "expert_ffn"], shape=(4, 2, 8)
            )
        assert spec == P("model")  # expert_ffn dropped, no duplicates


class TestParamSpecs:
    def test_qkv_and_down_proj_rules(self):
        from repro.launch.specs import param_pspec

        mesh = jax.make_mesh((1, 1), ("data", "model"))

        class Leaf:
            def __init__(self, shape):
                self.shape = shape
                self.ndim = len(shape)

        class K:
            def __init__(self, key):
                self.key = key

        spec = param_pspec([K("blocks"), K("attn"), K("wq"), K("w")],
                           Leaf((22, 128, 64)), mesh)
        assert spec == P(None, None, "model")
        spec = param_pspec([K("blocks"), K("mlp"), K("down"), K("w")],
                           Leaf((22, 256, 128)), mesh)
        assert spec == P(None, "model")
        spec = param_pspec([K("blocks"), K("norm1"), K("scale")],
                           Leaf((128,)), mesh)
        assert spec == P()

    def test_moe_expert_parallel_vs_ffn_sharding(self):
        from repro.launch.specs import param_pspec

        class Leaf:
            def __init__(self, shape):
                self.shape = shape
                self.ndim = len(shape)

        class K:
            def __init__(self, key):
                self.key = key

        mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
        # 128 experts divisible by 2 -> EP
        spec = param_pspec([K("moe"), K("w_gate")], Leaf((35, 128, 64, 32)),
                           mesh)
        assert spec == P(None, "model")
        # 41 experts not divisible -> shard expert ffn dim
        spec = param_pspec([K("moe"), K("w_gate")], Leaf((35, 41, 64, 32)),
                           mesh)
        assert spec == P(None, None, None, "model")


class TestServeEngine:
    def test_batched_requests_complete(self):
        import numpy as np

        from repro.configs import get_config
        from repro.models import init_model
        from repro.serve import EngineConfig, ServeEngine, throughput_stats

        cfg = get_config("tinyllama-1.1b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=3, max_len=48))
        rng = np.random.RandomState(0)
        for _ in range(5):
            eng.submit(rng.randint(0, cfg.vocab_size, size=rng.randint(2, 8)),
                       max_new_tokens=6)
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.output) == 6 for r in done)
        stats = throughput_stats(done)
        assert stats["total_tokens"] == 30 and stats["tokens_per_s"] > 0

    def test_int4_serving_matches_greedy_mostly(self):
        import numpy as np

        from repro.configs import get_config
        from repro.core.psq_linear import pack_tree_for_serving
        from repro.models import init_model
        from repro.serve import EngineConfig, ServeEngine

        cfg = get_config("tinyllama-1.1b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        prompt = np.arange(5) % cfg.vocab_size
        outs = {}
        for name, p in [("fp", params), ("int4", pack_tree_for_serving(params))]:
            eng = ServeEngine(p, cfg, EngineConfig(max_batch=1, max_len=32))
            eng.submit(prompt, max_new_tokens=4)
            outs[name] = eng.run()[0].output
        assert len(outs["fp"]) == len(outs["int4"]) == 4
