"""On-device multi-step decode loop (models.decode.decode_multi_step):
greedy bit-parity vs the host loop across horizons, families, paged and
sharded layouts; mid-horizon retirement; host-sync accounting; jit
stability (one compile per horizon value)."""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serve import EngineConfig, ServeEngine, throughput_stats

jax.config.update("jax_platform_name", "cpu")

# one KV-cache family + both recurrent-state families: the loop's
# retirement mask must freeze KV writes AND recurrent state
ARCHS = ("tinyllama-1.1b", "xlstm-350m", "zamba2-7b")
HORIZONS = (1, 4, 32)


@pytest.fixture(scope="module")
def models():
    out = {}
    for a in ARCHS:
        cfg = get_config(a).reduced()
        out[a] = (cfg, init_model(jax.random.PRNGKey(0), cfg))
    return out


def _trace(cfg, n=6, seed=0):
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, size=int(rng.randint(3, 15))),
             int(rng.randint(3, 13))) for _ in range(n)]


def _run(cfg, params, trace, mesh=None, **kw):
    eng = ServeEngine(params, cfg,
                      EngineConfig(max_batch=2, max_len=64, **kw), mesh=mesh)
    for p, mn in trace:
        eng.submit(p, max_new_tokens=mn)
    return {r.uid: r.output for r in eng.run()}, eng


@pytest.fixture(scope="module")
def host_refs(models):
    """Per-arch reference outputs from the legacy per-token host loop
    (device_loop=False keeps greedy on the host-sampled path)."""
    refs = {}
    for a, (cfg, params) in models.items():
        refs[a], _ = _run(cfg, params, _trace(cfg), device_loop=False)
    return refs


class TestHorizonParity:
    @pytest.mark.parametrize("h", HORIZONS)
    @pytest.mark.parametrize("arch", ARCHS)
    def test_greedy_bit_parity_vs_host_loop(self, models, host_refs, arch, h):
        """decode_horizon ∈ {1, 4, 32} is token-for-token identical to
        the per-token host loop for KV and recurrent families."""
        cfg, params = models[arch]
        out, eng = _run(cfg, params, _trace(cfg), decode_horizon=h)
        assert out == host_refs[arch], f"{arch} diverged at horizon {h}"
        assert eng._use_device_loop

    @pytest.mark.parametrize("h", HORIZONS)
    def test_paged_horizon_parity(self, models, host_refs, h):
        """The paged loop (block tables pre-grown min(h, budget) steps via
        prepare_append) matches the host loop bit-for-bit too."""
        cfg, params = models["tinyllama-1.1b"]
        out, _ = _run(cfg, params, _trace(cfg), decode_horizon=h,
                      paged=True, block_size=8)
        assert out == host_refs["tinyllama-1.1b"]

    @pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
    @pytest.mark.parametrize("arch", ("tinyllama-1.1b", "zamba2-7b"))
    def test_two_way_mesh_parity(self, models, host_refs, arch):
        """The data-sharded slot pool (batch/recurrent_state -> data)
        decodes identically under the device loop on a 2-way mesh."""
        cfg, params = models[arch]
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        out, _ = _run(cfg, params, _trace(cfg), decode_horizon=4, mesh=mesh)
        assert out == host_refs[arch]


class TestRetirement:
    def _single_ref(self, cfg, params, prompt, max_new):
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=1, max_len=64,
                                       device_loop=False))
        eng.submit(prompt, max_new_tokens=max_new)
        return eng.run()[0].output

    def test_mid_horizon_eos_retirement(self, models):
        """A slot hitting EOS inside the horizon stops emitting there —
        the retirement mask keeps its later (masked) steps out of the
        output and the cache."""
        cfg, params = models["tinyllama-1.1b"]
        rng = np.random.RandomState(7)
        prompt = rng.randint(0, cfg.vocab_size, size=6)
        ref = self._single_ref(cfg, params, prompt, 12)
        eos, cut = None, None
        for k in range(1, len(ref)):
            if ref[k] not in ref[:k]:
                eos, cut = ref[k], k
                break
        if eos is None:
            pytest.skip("degenerate greedy output: no usable EOS token")
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=1, max_len=64,
                                       decode_horizon=32))
        eng.submit(prompt, max_new_tokens=12, eos_id=eos)
        out = eng.run()[0].output
        assert out == ref[:cut + 1]
        # EOS fell mid-horizon: the whole request took one boundary sync
        assert eng.host_syncs == 1

    def test_finish_exactly_at_horizon_boundary(self, models):
        """max_new_tokens = 1 (prefill) + horizon decode steps: the
        request retires exactly when the loop's step count hits h."""
        cfg, params = models["tinyllama-1.1b"]
        rng = np.random.RandomState(8)
        prompt = rng.randint(0, cfg.vocab_size, size=5)
        h = 4
        ref = self._single_ref(cfg, params, prompt, h + 1)
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=1, max_len=64,
                                       decode_horizon=h))
        eng.submit(prompt, max_new_tokens=h + 1)
        out = eng.run()[0].output
        assert out == ref and len(out) == h + 1
        assert eng.host_syncs == 1


class TestSyncAccounting:
    def test_host_syncs_drop_o_tokens_to_o_tokens_over_h(self, models):
        """stats()['host_syncs'] is the round-trip counter: per-token at
        h=1, ~tokens/h at larger horizons, same decode-token output."""
        cfg, params = models["tinyllama-1.1b"]
        rng = np.random.RandomState(9)
        prompt = rng.randint(0, cfg.vocab_size, size=4)
        n_decode = 32          # 33 output tokens = 1 prefill + 32 decode
        syncs = {}
        for h in (1, 8):
            eng = ServeEngine(params, cfg,
                              EngineConfig(max_batch=1, max_len=64,
                                           decode_horizon=h))
            eng.submit(prompt, max_new_tokens=n_decode + 1)
            out = eng.run()[0].output
            assert len(out) == n_decode + 1
            assert eng.stats()["host_syncs"] == eng.host_syncs
            syncs[h] = eng.host_syncs
        assert syncs[1] == n_decode
        assert syncs[8] == math.ceil(n_decode / 8)

    def test_stats_finite_and_monotone_with_horizon(self, models):
        """Timestamps come from real horizon boundaries, never fabricated
        per token: every request has t_enqueue <= t_first_token <= t_done
        and the aggregate latency stats stay finite at h > 1."""
        cfg, params = models["tinyllama-1.1b"]
        _, eng = _run(cfg, params, _trace(cfg, seed=2), decode_horizon=4)
        for r in eng.finished:
            assert r.t_enqueue <= r.t_first_token <= r.t_done
        ts = throughput_stats(eng.finished)
        for key in ("tokens_per_s", "mean_ttft_s", "mean_tpot_s"):
            assert np.isfinite(ts[key]) and ts[key] >= 0.0
        assert ts["mean_tpot_s"] > 0.0
        sched = eng.stats()
        assert np.isfinite(sched["decode_wall_s"])
        assert sched["decode_wall_s"] > 0.0
        assert 0 < sched["host_syncs"] <= sched["decode_steps"]


class TestCompileStability:
    def test_one_compile_per_horizon_value(self, models, compile_counts):
        """horizon is a static argnum: the loop compiles once per
        configured horizon and a repeated workload adds nothing."""
        cfg, params = models["tinyllama-1.1b"]
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=2, max_len=64,
                                       decode_horizon=8))
        trace = _trace(cfg, seed=3)
        for p, mn in trace:
            eng.submit(p, max_new_tokens=mn)
        eng.run()
        assert compile_counts(eng._decode_multi) == [1]
        for p, mn in trace:
            eng.submit(p, max_new_tokens=mn)
        eng.run()
        assert compile_counts(eng._decode_multi) == [1]

    def test_one_compile_per_horizon_value_paged(self, models,
                                                 compile_counts):
        cfg, params = models["tinyllama-1.1b"]
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=2, max_len=64,
                                       decode_horizon=8, paged=True,
                                       block_size=8))
        trace = _trace(cfg, seed=4)
        for p, mn in trace:
            eng.submit(p, max_new_tokens=mn)
        eng.run()
        assert compile_counts(eng._decode_multi_paged) == [1]
        for p, mn in trace:
            eng.submit(p, max_new_tokens=mn)
        eng.run()
        assert compile_counts(eng._decode_multi_paged) == [1]


class TestConfigValidation:
    def test_horizon_with_temperature_raises(self, models):
        cfg, params = models["tinyllama-1.1b"]
        with pytest.raises(ValueError, match="temperature"):
            ServeEngine(params, cfg,
                        EngineConfig(decode_horizon=4, temperature=0.7))

    def test_nonpositive_horizon_raises(self, models):
        cfg, params = models["tinyllama-1.1b"]
        with pytest.raises(ValueError, match="decode_horizon"):
            ServeEngine(params, cfg, EngineConfig(decode_horizon=0))

    def test_horizon_without_device_loop_raises(self, models):
        cfg, params = models["tinyllama-1.1b"]
        with pytest.raises(ValueError, match="device_loop"):
            ServeEngine(params, cfg,
                        EngineConfig(decode_horizon=4, device_loop=False))

    def test_temperature_falls_back_to_host_path(self, models):
        """temperature > 0 keeps the legacy host-sampled per-token loop
        (the device loop is greedy-only)."""
        cfg, params = models["tinyllama-1.1b"]
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=2, max_len=64,
                                       temperature=0.7))
        assert not eng._use_device_loop
