"""Scheduler layer: EngineConfig.validate messages, admission policies,
cost-aware serving, streaming step() deltas, energy-accounting hooks.

The validation tests pin the EXACT error text for every invalid knob
combination — ``EngineConfig.validate`` is the single home of engine
validation, and these messages are API (callers match on them)."""
import dataclasses
import re

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import PSQ_TERNARY
from repro.models import init_model
from repro.serve import (
    CostAwareEnergyBudget,
    EngineConfig,
    PackedModelCache,
    Pow2BucketFCFS,
    Request,
    ServeEngine,
    pack_tree_psq,
    resolve_admission_policy,
)
from repro.serve.scheduler import next_pow2

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _cfg(arch="tinyllama-1.1b"):
    return get_config(arch).reduced()


def _raises(ecfg, cfg, msg, **kw):
    with pytest.raises(ValueError, match=re.escape(msg)):
        ecfg.validate(cfg, **kw)


class TestEngineConfigValidate:
    """Every invalid combination raises from validate(), same text."""

    def test_unknown_mode(self):
        _raises(EngineConfig(mode="bogus"), _cfg(),
                "unknown engine mode 'bogus'")

    def test_horizon_below_one(self):
        _raises(EngineConfig(decode_horizon=0), _cfg(),
                "decode_horizon must be >= 1, got 0")

    def test_horizon_with_sampling(self):
        _raises(EngineConfig(decode_horizon=4, temperature=0.7), _cfg(),
                "decode_horizon > 1 runs the on-device greedy loop; "
                "temperature sampling needs the per-token host path "
                "(set decode_horizon=1)")

    def test_horizon_without_device_loop(self):
        _raises(EngineConfig(decode_horizon=4, device_loop=False), _cfg(),
                "decode_horizon > 1 requires device_loop=True")

    def test_spec_k_negative(self):
        _raises(EngineConfig(spec_k=-1), _cfg(),
                "spec_k must be >= 0, got -1")

    def test_spec_needs_draft(self):
        _raises(EngineConfig(spec_k=2), _cfg(),
                "speculative decoding (spec_k > 0) needs both "
                "EngineConfig.draft_config and a draft_params tree")

    def test_spec_needs_continuous(self):
        cfg = _cfg()
        dcfg = dataclasses.replace(cfg, n_layers=1)
        _raises(EngineConfig(spec_k=2, draft_config=dcfg, mode="static"),
                cfg, "speculative decoding requires the continuous "
                "scheduler; resolved mode is 'static'",
                has_draft_params=True)

    def test_spec_rejects_recurrent_family(self):
        cfg = _cfg("zamba2-7b")
        dcfg = dataclasses.replace(cfg, n_layers=1)
        _raises(EngineConfig(spec_k=2, draft_config=dcfg), cfg,
                "recurrent state folds every token and cannot roll "
                "back by a length edit", has_draft_params=True)

    def test_spec_greedy_only(self):
        cfg = _cfg()
        dcfg = dataclasses.replace(cfg, n_layers=1)
        _raises(EngineConfig(spec_k=2, draft_config=dcfg,
                             temperature=0.5), cfg,
                "speculative decoding is greedy-only (acceptance "
                "compares draft proposals with main-model argmaxes); "
                "set temperature=0", has_draft_params=True)

    def test_spec_replaces_horizon(self):
        cfg = _cfg()
        dcfg = dataclasses.replace(cfg, n_layers=1)
        _raises(EngineConfig(spec_k=2, draft_config=dcfg,
                             decode_horizon=4), cfg,
                "speculative decoding replaces the device horizon "
                "loop; set decode_horizon=1", has_draft_params=True)

    def test_spec_draft_family_mismatch(self):
        cfg = _cfg()
        dcfg = dataclasses.replace(_cfg("zamba2-7b"),
                                   vocab_size=cfg.vocab_size)
        _raises(EngineConfig(spec_k=2, draft_config=dcfg), cfg,
                f"draft family {dcfg.family!r} must match the target "
                f"family {cfg.family!r}", has_draft_params=True)

    def test_spec_vocab_mismatch(self):
        cfg = _cfg()
        dcfg = dataclasses.replace(cfg, vocab_size=cfg.vocab_size // 2)
        _raises(EngineConfig(spec_k=2, draft_config=dcfg), cfg,
                "draft and target models must share a vocabulary "
                f"({dcfg.vocab_size} != {cfg.vocab_size})",
                has_draft_params=True)

    def test_spec_side_input_d_model_mismatch(self):
        cfg = _cfg("whisper-large-v3")
        dcfg = dataclasses.replace(cfg, n_layers=1,
                                   d_model=cfg.d_model * 2)
        _raises(EngineConfig(spec_k=2, draft_config=dcfg), cfg,
                "side-input families need draft d_model == target "
                "d_model: enc_embeds/patch_embeds rows feed both "
                f"models ({dcfg.d_model} != {cfg.d_model})",
                has_draft_params=True)

    def test_unknown_energy_style(self):
        _raises(EngineConfig(energy_style="bogus"), _cfg(),
                "unknown energy_style 'bogus'")

    def test_paged_rejects_recurrent(self):
        _raises(EngineConfig(paged=True), _cfg("zamba2-7b"),
                "recurrent state has no sequence axis to page")

    def test_paged_rejects_cross_attention(self):
        _raises(EngineConfig(paged=True), _cfg("whisper-large-v3"),
                "cross-attention KV has no pages")

    def test_paged_rejects_patch_embeds(self):
        cfg = _cfg("llava-next-mistral-7b")
        _raises(EngineConfig(paged=True), cfg,
                "paged KV cache does not take per-request patch_embeds",
                extra={"patch_embeds": np.zeros((1, 4, cfg.d_model))})

    def test_paged_needs_continuous(self):
        _raises(EngineConfig(paged=True, mode="static"), _cfg(),
                "paged KV cache requires the continuous scheduler; "
                "resolved mode is 'static'")

    def test_paged_block_size_divisibility(self):
        _raises(EngineConfig(paged=True, max_len=100, block_size=16),
                _cfg(),
                "max_len (100) must be a multiple of block_size (16)")

    def test_unknown_admission_policy(self):
        _raises(EngineConfig(admission_policy="bogus"), _cfg(),
                "unknown admission_policy 'bogus'")

    def test_negative_energy_budget(self):
        _raises(EngineConfig(energy_budget_pj=-1.0), _cfg(),
                "energy_budget_pj must be >= 0, got -1.0")

    def test_cost_aware_needs_budget(self):
        _raises(EngineConfig(admission_policy="cost-aware"), _cfg(),
                "cost-aware admission needs a positive "
                "EngineConfig.energy_budget_pj cap")

    def test_check_order_is_fixed(self):
        """With several knobs invalid at once, the FIRST check in the
        documented order (mode, horizon, spec, ...) raises."""
        _raises(EngineConfig(decode_horizon=0, spec_k=-1,
                             energy_style="bogus",
                             admission_policy="bogus"), _cfg(),
                "decode_horizon must be >= 1, got 0")

    def test_valid_configs_resolve(self):
        assert EngineConfig().validate(_cfg()) == "continuous"
        assert EngineConfig(mode="static").validate(_cfg()) == "static"
        assert EngineConfig(admission_policy="cost-aware",
                            energy_budget_pj=1e6
                            ).validate(_cfg()) == "continuous"


def _req(uid, plen, mnew=8):
    return Request(uid, np.arange(plen, dtype=np.int32), mnew, None,
                   t_enqueue=0.0)


def _bucket(r):
    return max(8, next_pow2(len(r.prompt)))


class TestAdmissionPolicies:
    def test_fcfs_takes_head_bucket_in_fifo_order(self):
        q = [_req(1, 5), _req(2, 6), _req(3, 20), _req(4, 7)]
        take = Pow2BucketFCFS().take(q, 3, _bucket)
        assert [r.uid for r in take] == [1, 2, 4]   # 20 > bucket 8

    def test_fcfs_respects_limit_and_eligible(self):
        q = [_req(1, 5), _req(2, 6), _req(3, 7), _req(4, 5)]
        p = Pow2BucketFCFS()
        assert [r.uid for r in p.take(q, 2, _bucket)] == [1, 2]
        take = p.take(q, 4, _bucket, eligible=lambda r: r.uid != 2)
        assert [r.uid for r in take] == [1, 3, 4]
        assert p.admits_head(q[0], live=[_req(9, 5)])

    def test_cost_aware_rejects_nonpositive_budget(self):
        with pytest.raises(ValueError, match="positive budget_pj"):
            CostAwareEnergyBudget(0.0, lambda r: 1.0)

    def test_cost_aware_defers_over_budget(self):
        cost = lambda r: float(len(r.prompt))                 # noqa: E731
        p = CostAwareEnergyBudget(10.0, cost)
        q = [_req(1, 4), _req(2, 4), _req(3, 4)]
        take = p.take(q, 3, _bucket)
        assert [r.uid for r in take] == [1, 2]    # 4 + 4 <= 10 < 12
        assert p.deferrals == 1

    def test_cost_aware_forced_head_prevents_deadlock(self):
        """An over-budget head admits alone when nothing is live —
        deferring it forever would deadlock the engine."""
        p = CostAwareEnergyBudget(1.0, lambda r: 100.0)
        take = p.take([_req(1, 4)], 4, _bucket, live=())
        assert [r.uid for r in take] == [1]
        assert p.admits_head(_req(2, 4), live=())

    def test_cost_aware_head_waits_for_live_budget(self):
        cost = lambda r: float(len(r.prompt))                 # noqa: E731
        p = CostAwareEnergyBudget(10.0, cost)
        assert not p.admits_head(_req(2, 4), live=[_req(1, 9)])
        assert p.deferrals == 1
        assert p.admits_head(_req(2, 4), live=[_req(1, 5)])

    def test_resolver_maps_config_to_policy(self, tiny):
        cfg, params = tiny
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=2, max_len=64))
        assert isinstance(
            resolve_admission_policy(EngineConfig(), eng.energy),
            Pow2BucketFCFS)
        p = resolve_admission_policy(
            EngineConfig(admission_policy="cost-aware",
                         energy_budget_pj=5.0), eng.energy)
        assert isinstance(p, CostAwareEnergyBudget)
        assert p.budget_pj == 5.0


class TestCostAwareServing:
    def test_budgeted_engine_defers_but_matches_fcfs(self, tiny):
        """Under a cap of ~2 worst-case requests the engine defers
        admissions while slots are free, and still produces the exact
        greedy outputs of the unbudgeted run — admission order changes
        WHEN a request decodes, never WHAT."""
        cfg, params = tiny
        rng = np.random.RandomState(0)
        trace = [(rng.randint(0, cfg.vocab_size, size=6), 4)
                 for _ in range(5)]

        def serve(**kw):
            eng = ServeEngine(params, cfg,
                              EngineConfig(max_batch=4, max_len=64, **kw))
            for prompt, mnew in trace:
                eng.submit(prompt, max_new_tokens=mnew)
            done = eng.run()
            return eng, {r.uid: list(r.output) for r in done}

        eng_f, toks_f = serve()
        cost = max(eng_f.energy.request_cost_pj(r)
                   for r in eng_f.finished)
        assert cost > 0
        eng_c, toks_c = serve(admission_policy="cost-aware",
                              energy_budget_pj=2.0 * cost)
        assert toks_c == toks_f
        sched = eng_c.stats()
        assert sched["admission_policy"] == "cost-aware"
        assert sched["admission_deferrals"] > 0
        assert eng_f.stats()["admission_deferrals"] == 0

    def test_reset_stats_clears_deferrals(self, tiny):
        cfg, params = tiny
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=2, max_len=64,
                                       admission_policy="cost-aware",
                                       energy_budget_pj=1e-3))
        rng = np.random.RandomState(0)
        for _ in range(3):
            eng.submit(rng.randint(0, cfg.vocab_size, size=6),
                       max_new_tokens=2)
        eng.run()
        assert eng.policy.deferrals > 0
        eng.reset_stats()
        assert eng.policy.deferrals == 0
        assert eng.stats()["admission_deferrals"] == 0


class TestStreamingStep:
    def test_step_deltas_concatenate_to_run_outputs(self, tiny):
        cfg, params = tiny
        rng = np.random.RandomState(1)
        trace = [(rng.randint(0, cfg.vocab_size, size=n), m)
                 for n, m in ((5, 4), (6, 6), (9, 3))]

        ref = ServeEngine(params, cfg, EngineConfig(max_batch=2,
                                                    max_len=64))
        for prompt, mnew in trace:
            ref.submit(prompt, max_new_tokens=mnew)
        want = {r.uid: list(r.output) for r in ref.run()}

        eng = ServeEngine(params, cfg, EngineConfig(max_batch=2,
                                                    max_len=64))
        got = {}
        # submit mid-flight: two up front, the third after a round
        uids = [eng.submit(*trace[0]), eng.submit(*trace[1])]
        steps = 0
        while not eng.drained:
            if steps == 1:
                uids.append(eng.submit(*trace[2]))
            for uid, toks in eng.step().items():
                got.setdefault(uid, []).extend(toks)
            steps += 1
        # per-request outputs are independent of arrival time (greedy)
        assert {u: got[u] for u in uids} == want
        assert steps > 1
        assert eng.step() == {}          # drained: no-op

    def test_step_requires_continuous_mode(self, tiny):
        cfg, params = tiny
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=2, max_len=64,
                                       mode="static"))
        eng.submit(np.arange(4), max_new_tokens=2)
        with pytest.raises(ValueError, match="continuous scheduler"):
            eng.step()


class TestEnergyAccountingHooks:
    """The single account_prefill/account_decode boundary attributes
    exactly one energy token per true token, identically across every
    executor — the regression pin for the call-site dedupe."""

    @pytest.fixture(scope="class")
    def packed(self):
        cfg = get_config("tinyllama-1.1b").reduced()
        qcfg = dataclasses.replace(PSQ_TERNARY,
                                   kernel_backend="reference",
                                   xbar_rows=64)
        cfg = cfg.with_quant(qcfg)
        params = init_model(jax.random.PRNGKey(0), cfg)
        params = pack_tree_psq(params, qcfg, PackedModelCache())
        return cfg, params

    # the PR 7 energy-bench trace shape (serve_bench --smoke --energy)
    def _trace(self, cfg):
        rng = np.random.RandomState(0)
        return [(rng.randint(0, cfg.vocab_size,
                             size=int(rng.randint(4, 13))),
                 int(rng.randint(2, 5))) for _ in range(6)]

    def _serve(self, cfg, params, **kw):
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=3, max_len=32, **kw))
        for prompt, mnew in self._trace(cfg):
            eng.submit(prompt, max_new_tokens=mnew)
        eng.run()
        return eng

    def test_energy_tokens_equal_true_forward_tokens(self, packed):
        cfg, params = packed
        eng = self._serve(cfg, params)
        s = eng.stats()
        prompts = sum(len(p) for p, _ in self._trace(cfg))
        outputs = sum(len(r.output) for r in eng.finished)
        # each request's first token comes out of its prefill forward;
        # every later token is one decode forward
        assert s["prefill_tokens"] == prompts
        assert s["energy_tokens"] == prompts + outputs - len(eng.finished)
        assert s["energy_pj_total"] == pytest.approx(
            s["energy_pj_per_token"] * s["energy_tokens"])
        assert s["energy_pj_total"] > 0

    def test_counters_identical_across_executors(self, packed):
        """Host-loop, device-horizon and static executors attribute the
        same energy for the same trace (stats() unchanged by the
        accounting-hook dedupe)."""
        cfg, params = packed
        base = self._serve(cfg, params).stats()
        # prefill_calls is scheduling (horizon boundaries batch freed
        # slots into fewer admission waves); the attribution invariant
        # is the TOKEN counters every call site must agree on
        keys = ("prefill_tokens", "energy_tokens",
                "energy_pj_total", "edap_total")
        horizon = self._serve(cfg, params, decode_horizon=4).stats()
        static = self._serve(cfg, params, mode="static").stats()
        for k in keys:
            assert horizon[k] == base[k], k
            assert static[k] == base[k], k
