"""Property coverage of the serving-engine lifecycle.

Hypothesis drives randomized admission / retire / readmit traces
through real engines (tiny dense model, CPU) and checks the invariants
the example-based suites pin only at hand-picked points:

- every submitted uid comes back done exactly once, with exactly its
  requested decode budget — no request lost, duplicated, or truncated;
- outputs are never cross-wired between requests: each uid's tokens
  equal the single-slot sequential decode of ITS prompt, whatever slot
  (re)assignment the trace produced;
- admission bookkeeping stays sane: slots in range, one admission per
  uid;
- the paged engine's page pool stays conserved across waves of
  admission and retirement — every page free (ref 0) or live (ref > 0)
  exactly once, and with prefix reuse off a drained engine holds zero
  pages (with reuse on, only the radix index's references remain);
- speculative decoding under randomized accept/reject traces (a random
  1-layer draft makes acceptance data-dependent) keeps all of the
  above: outputs stay the exact sequential tokens (so every per-slot
  length rollback landed on the accepted count), no request is lost,
  duplicated or cross-wired, and the paged pool stays conserved with
  every rejected position's pages released.

Engines and the sequential-reference cache are module-level: jit
caches live on engine closures, so every hypothesis example after the
first replays compiled code (see docs/testing.md). Without hypothesis
installed these tests skip via tests/_hypothesis_compat.
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models import init_model
from repro.serve import EngineConfig, ServeEngine
from tests._hypothesis_compat import HealthCheck, given, settings, st

jax.config.update("jax_platform_name", "cpu")

MAX_LEN = 32
SLOTS = 2

_state = {}


def _models():
    if not _state:
        cfg = get_config("tinyllama-1.1b").reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        _state["cfg"], _state["params"] = cfg, params
        _state["eng"] = ServeEngine(
            params, cfg, EngineConfig(max_batch=SLOTS, max_len=MAX_LEN))
        _state["ref"] = ServeEngine(
            params, cfg, EngineConfig(max_batch=1, max_len=MAX_LEN))
        _state["paged"] = {
            reuse: ServeEngine(
                params, cfg,
                EngineConfig(max_batch=SLOTS, max_len=MAX_LEN, paged=True,
                             block_size=8, prefix_reuse=reuse))
            for reuse in (False, True)
        }
        # spec engines: a RANDOM 1-layer draft — proposals rarely match
        # the main argmax, so hypothesis traces exercise full rejection,
        # partial acceptance and the occasional full acceptance
        dcfg = dataclasses.replace(cfg, n_layers=1)
        dparams = init_model(jax.random.PRNGKey(1), dcfg)
        _state["spec"] = ServeEngine(
            params, cfg,
            EngineConfig(max_batch=SLOTS, max_len=MAX_LEN, spec_k=2,
                         draft_config=dcfg),
            draft_params=dparams)
        _state["spec_paged"] = ServeEngine(
            params, cfg,
            EngineConfig(max_batch=SLOTS, max_len=MAX_LEN, paged=True,
                         block_size=8, spec_k=2, draft_config=dcfg),
            draft_params=dparams)
        _state["ref_cache"] = {}
    return _state


def _sequential(prompt, mnew):
    """Single-slot reference outputs, memoized across examples."""
    s = _models()
    key = (tuple(int(t) for t in prompt), mnew)
    if key not in s["ref_cache"]:
        uid = s["ref"].submit(np.asarray(prompt, np.int32),
                              max_new_tokens=mnew)
        # run() returns the cumulative completed list — select by uid
        s["ref_cache"][key] = next(
            r.output for r in s["ref"].run() if r.uid == uid)
    return s["ref_cache"][key]


# a trace: 1..6 requests of (prompt-seed, prompt-len, decode-budget).
# Budgets stay under MAX_LEN - longest prompt so nothing truncates and
# the budget check below is exact.
TRACES = st.lists(
    st.tuples(st.integers(0, 3), st.integers(2, 10), st.integers(1, 5)),
    min_size=1, max_size=6,
)


def _prompts(trace, vocab):
    out = []
    for seed, plen, mnew in trace:
        rng = np.random.RandomState(seed)
        out.append((rng.randint(0, vocab, size=plen), mnew))
    return out


@settings(max_examples=12, deadline=None)
@given(trace=TRACES)
def test_lifecycle_conserves_requests_and_slots(trace):
    s = _models()
    eng, cfg = s["eng"], s["cfg"]
    reqs = _prompts(trace, cfg.vocab_size)
    uids = [eng.submit(p, max_new_tokens=mn) for p, mn in reqs]
    # run() returns the engine's cumulative completed list; a reused
    # engine (jit caches warm across examples) includes prior waves
    results = eng.run()
    returned = [r.uid for r in results]
    assert all(returned.count(uid) == 1 for uid in uids), \
        "requests lost or duplicated"
    done = {r.uid: r for r in results if r.uid in set(uids)}
    adm_uids = [a["uid"] for a in eng.admissions if a["uid"] in done]
    assert all(0 <= a["slot"] < SLOTS for a in eng.admissions)
    for uid, (_, mnew) in zip(uids, reqs):
        r = done[uid]
        assert r.done
        assert len(r.output) == mnew, \
            f"uid {uid}: budget {mnew}, got {len(r.output)} tokens"
        if mnew == 1:
            # the prefill token exhausts the budget: retired on the
            # spot, never occupies a slot, never recorded as admitted
            assert adm_uids.count(uid) == 0 and r.slot == -1
        else:
            assert adm_uids.count(uid) == 1, \
                f"uid {uid} admitted {adm_uids.count(uid)} times"
            assert 0 <= r.slot < SLOTS


@settings(max_examples=8, deadline=None)
@given(trace=TRACES)
def test_outputs_never_cross_wire(trace):
    s = _models()
    eng, cfg = s["eng"], s["cfg"]
    reqs = _prompts(trace, cfg.vocab_size)
    uids = [eng.submit(p, max_new_tokens=mn) for p, mn in reqs]
    done = {r.uid: r.output for r in eng.run() if r.uid in set(uids)}
    for uid, (p, mnew) in zip(uids, reqs):
        assert done[uid] == _sequential(p, mnew), \
            f"uid {uid} decoded another request's tokens"


@settings(max_examples=8, deadline=None)
@given(trace=TRACES, reuse=st.booleans())
def test_paged_pool_conserved_across_waves(trace, reuse):
    s = _models()
    eng, cfg = s["paged"][reuse], s["cfg"]
    reqs = _prompts(trace, cfg.vocab_size)
    for wave in range(2):                      # admission + readmission
        uids = [eng.submit(p, max_new_tokens=mn) for p, mn in reqs]
        done = {r.uid: r.output for r in eng.run() if r.uid in set(uids)}
        assert sorted(done) == sorted(uids)
        mgr = eng._mgr
        mgr.check_invariants()
        mgr.pool.check_invariants()
        if not reuse:
            assert mgr.pool.used_blocks == 0, \
                f"wave {wave}: drained engine leaked pages"
        else:
            # only the radix index may hold pages, one ref each from
            # the index itself (slots are all retired)
            for node in mgr.index._by_id.values():
                assert mgr.pool.refcount(node.block) == 1
            assert mgr.pool.used_blocks == len(mgr.index)
    for uid, (p, mnew) in zip(uids, reqs):
        assert done[uid] == _sequential(p, mnew), \
            "paged readmission cross-wired outputs"


@settings(max_examples=8, deadline=None)
@given(trace=TRACES)
def test_spec_rollback_matches_sequential(trace):
    """Speculative accept/reject/rollback is invisible in the outputs:
    whatever prefix of each round's proposals was accepted, every uid
    gets exactly its budget of exactly the sequential tokens — which
    can only happen if each rollback's per-slot length edit equals the
    accepted-token count, every round."""
    s = _models()
    eng, cfg = s["spec"], s["cfg"]
    reqs = _prompts(trace, cfg.vocab_size)
    uids = [eng.submit(p, max_new_tokens=mn) for p, mn in reqs]
    results = eng.run()
    returned = [r.uid for r in results]
    assert all(returned.count(uid) == 1 for uid in uids), \
        "spec decode lost or duplicated requests"
    done = {r.uid: r for r in results if r.uid in set(uids)}
    for uid, (p, mnew) in zip(uids, reqs):
        assert done[uid].done
        assert len(done[uid].output) == mnew, \
            f"uid {uid}: budget {mnew}, got {len(done[uid].output)}"
        assert done[uid].output == _sequential(p, mnew), \
            f"uid {uid}: spec rollback corrupted the decode state"
    st_ = eng.stats()
    assert 0 <= st_["spec_accepted"] <= st_["spec_proposed"]
    assert 0.0 <= st_["spec_accept_rate"] <= 1.0


@settings(max_examples=8, deadline=None)
@given(trace=TRACES)
def test_spec_paged_rollback_conserves_pool(trace):
    """Every rejected proposal's pre-reserved page slots are released
    by the truncate rollback: across admission waves the pool stays
    balanced (block tables consistent, refcounts exact), a drained
    engine holds only the radix index's pages, and outputs are still
    the sequential tokens."""
    s = _models()
    eng, cfg = s["spec_paged"], s["cfg"]
    reqs = _prompts(trace, cfg.vocab_size)
    for wave in range(2):                      # admission + readmission
        uids = [eng.submit(p, max_new_tokens=mn) for p, mn in reqs]
        done = {r.uid: r.output for r in eng.run() if r.uid in set(uids)}
        assert sorted(done) == sorted(uids)
        mgr = eng._mgr
        mgr.check_invariants()
        mgr.pool.check_invariants()
        assert np.all(np.asarray(mgr.lengths) == 0), \
            f"wave {wave}: a drained slot kept a nonzero length"
        # prefix reuse (the default) may keep index pages warm; each
        # holds exactly the index's own reference
        for node in mgr.index._by_id.values():
            assert mgr.pool.refcount(node.block) == 1
        assert mgr.pool.used_blocks == len(mgr.index), \
            f"wave {wave}: spec rollback leaked pages"
    for uid, (p, mnew) in zip(uids, reqs):
        assert done[uid] == _sequential(p, mnew), \
            "paged spec decode cross-wired outputs"
