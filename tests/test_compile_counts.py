"""One block compile per (family, phase) — the scan-over-layers pin.

Every forward stacks its layer params and runs them under ``lax.scan``
(models/transformer.layer_scan), so jit traces each transformer block
ONCE per engine phase regardless of depth. This suite pins the
consequence at the serving boundary: a single-bucket trace leaves every
phase closure (prefill / insert / decode) at jit cache size exactly 1
for each family, and the unrolled ``scan_layers=False`` oracle obeys
the same contract (it re-traces the block per layer inside ONE compile,
it does not compile per layer).

Two extensions of the same contract:

- side-input families (encdec cross-KV pools, VLM patch embeds) admit
  through the SAME bucketed prefill closure — the per-slot side-input
  scatter must not add a compile per admission wave;
- speculative decoding adds exactly TWO compiles on top of admission
  (the draft's scanned propose step and the masked verify forward),
  and a second admission wave replays both from cache.

The shared ``compile_counts`` fixture (tests/conftest.py) owns the
``_cache_size`` introspection guard; see docs/testing.md for the test
taxonomy this belongs to.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serve import EngineConfig, ServeEngine

import jax

# one arch per layer-stacked family without side inputs; encdec/vlm
# need per-request side-input rows, so they get their own suite below
ARCHS = ("tinyllama-1.1b", "granite-moe-3b-a800m", "zamba2-7b",
         "xlstm-350m")
SIDE_ARCHS = ("whisper-large-v3", "llava-next-mistral-7b")


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS + SIDE_ARCHS:
        cfg = get_config(arch).reduced()
        out[arch] = (cfg, init_model(jax.random.PRNGKey(0), cfg))
    return out


def _side_inputs(cfg, n=4, seed=7):
    rng = np.random.RandomState(seed)
    if cfg.family == "encdec":
        return {"enc_embeds": (rng.randn(n, 8, cfg.d_model)
                               * 0.1).astype(np.float32)}
    return {"patch_embeds": (rng.randn(n, cfg.frontend_len, cfg.d_model)
                             * 0.1).astype(np.float32)}


def _single_bucket_trace(cfg, n=4, seed=0):
    # one admission wave of one shape: n == slot-pool size, prompt
    # lengths 4..8 all land in the smallest (8-token) prefill bucket,
    # and equal decode budgets retire every slot together — so
    # prefill/insert/decode each see exactly one (bucket, batch) shape
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, size=int(rng.randint(4, 9))), 4)
            for _ in range(n)]


def _serve(eng, trace):
    for p, mn in trace:
        eng.submit(p, max_new_tokens=mn)
    return {r.uid: r.output for r in eng.run()}


class TestOneCompilePerFamilyPhase:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_scan_path_one_compile_per_phase(self, models, arch,
                                             compile_counts):
        cfg, params = models[arch]
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=4, max_len=48))
        _serve(eng, _single_bucket_trace(cfg))
        fns = [eng._prefill_bucket, eng._insert, eng._decode_multi]
        assert compile_counts(*fns) == [1, 1, 1], \
            f"{arch}: each engine phase must compile exactly one block"

    @pytest.mark.parametrize("arch", ("tinyllama-1.1b",
                                      "granite-moe-3b-a800m"))
    def test_unrolled_oracle_same_phase_counts(self, models, arch,
                                               compile_counts):
        """scan_layers=False swaps lax.scan for a Python loop over the
        same stacked params: slower to trace, but still ONE jit compile
        per phase — and token-identical to the scan engine (the full
        six-family parity matrix lives in tests/test_golden_parity.py).
        """
        cfg, params = models[arch]
        trace = _single_bucket_trace(cfg, seed=1)
        scan = _serve(ServeEngine(params, cfg,
                                  EngineConfig(max_batch=4, max_len=48)),
                      trace)
        loop_cfg = dataclasses.replace(cfg, scan_layers=False)
        eng = ServeEngine(params, loop_cfg,
                          EngineConfig(max_batch=4, max_len=48))
        assert _serve(eng, trace) == scan, \
            f"{arch}: unrolled oracle diverged from the scan path"
        fns = [eng._prefill_bucket, eng._insert, eng._decode_multi]
        assert compile_counts(*fns) == [1, 1, 1]

    @pytest.mark.parametrize("arch", SIDE_ARCHS)
    def test_side_input_admission_one_compile_per_phase(self, models,
                                                        arch,
                                                        compile_counts):
        """encdec/VLM continuous admission gathers per-request side
        inputs into the bucketed prefill batch and scatters them into
        per-slot pools on insert — still exactly one compile per phase
        for a single-bucket trace."""
        cfg, params = models[arch]
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=4, max_len=48),
                          extra_inputs=_side_inputs(cfg))
        assert eng.mode == "continuous"
        _serve(eng, _single_bucket_trace(cfg))
        fns = [eng._prefill_bucket, eng._insert, eng._decode_multi]
        assert compile_counts(*fns) == [1, 1, 1], \
            f"{arch}: side-input admission must not add compiles"

    def test_spec_decode_two_extra_compiles(self, models, compile_counts):
        """Speculative decoding compiles exactly two closures beyond
        admission — the draft's k-step scanned propose and the masked
        width-(k+1) verify forward — and a SECOND admission wave of the
        same shapes adds zero compilations anywhere (warm == rerun).
        The per-token decode-step closure stays cold: spec rounds
        replace it entirely."""
        cfg, params = models["tinyllama-1.1b"]
        dcfg = dataclasses.replace(cfg, n_layers=1)
        eng = ServeEngine(
            params, cfg,
            EngineConfig(max_batch=4, max_len=48, spec_k=2,
                         draft_config=dcfg),
            draft_params=init_model(jax.random.PRNGKey(1), dcfg))
        trace = _single_bucket_trace(cfg)
        _serve(eng, trace)
        spec_fns = [eng._draft_propose, eng._verify]
        assert compile_counts(*spec_fns) == [1, 1], \
            "spec decode must cost exactly two extra compiles"
        fns = spec_fns + [eng._prefill_bucket, eng._insert,
                          eng._draft_prefill, eng._draft_insert]
        warm = compile_counts(*fns)
        assert warm == [1, 1, 1, 1, 1, 1]
        assert compile_counts(eng._decode_multi) == [0], \
            "spec rounds must not fall back to the per-token step"
        _serve(eng, trace)                     # readmission wave
        assert compile_counts(*fns) == warm, \
            "a second admission wave re-traced a spec-engine phase"
