"""One block compile per (family, phase) — the scan-over-layers pin.

Every forward stacks its layer params and runs them under ``lax.scan``
(models/transformer.layer_scan), so jit traces each transformer block
ONCE per engine phase regardless of depth. This suite pins the
consequence at the serving boundary: a single-bucket trace leaves every
phase closure (prefill / insert / decode) at jit cache size exactly 1
for each family, and the unrolled ``scan_layers=False`` oracle obeys
the same contract (it re-traces the block per layer inside ONE compile,
it does not compile per layer).

The shared ``compile_counts`` fixture (tests/conftest.py) owns the
``_cache_size`` introspection guard; see docs/testing.md for the test
taxonomy this belongs to.
"""
import dataclasses

import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serve import EngineConfig, ServeEngine

import jax

# one arch per layer-stacked family (encdec/vlm serve through the same
# closures but need side inputs; their compile behavior is covered by
# their own suites)
ARCHS = ("tinyllama-1.1b", "granite-moe-3b-a800m", "zamba2-7b",
         "xlstm-350m")


@pytest.fixture(scope="module")
def models():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        out[arch] = (cfg, init_model(jax.random.PRNGKey(0), cfg))
    return out


def _single_bucket_trace(cfg, n=4, seed=0):
    # one admission wave of one shape: n == slot-pool size, prompt
    # lengths 4..8 all land in the smallest (8-token) prefill bucket,
    # and equal decode budgets retire every slot together — so
    # prefill/insert/decode each see exactly one (bucket, batch) shape
    rng = np.random.RandomState(seed)
    return [(rng.randint(0, cfg.vocab_size, size=int(rng.randint(4, 9))), 4)
            for _ in range(n)]


def _serve(eng, trace):
    for p, mn in trace:
        eng.submit(p, max_new_tokens=mn)
    return {r.uid: r.output for r in eng.run()}


class TestOneCompilePerFamilyPhase:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_scan_path_one_compile_per_phase(self, models, arch,
                                             compile_counts):
        cfg, params = models[arch]
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=4, max_len=48))
        _serve(eng, _single_bucket_trace(cfg))
        fns = [eng._prefill_bucket, eng._insert, eng._decode_multi]
        assert compile_counts(*fns) == [1, 1, 1], \
            f"{arch}: each engine phase must compile exactly one block"

    @pytest.mark.parametrize("arch", ("tinyllama-1.1b",
                                      "granite-moe-3b-a800m"))
    def test_unrolled_oracle_same_phase_counts(self, models, arch,
                                               compile_counts):
        """scan_layers=False swaps lax.scan for a Python loop over the
        same stacked params: slower to trace, but still ONE jit compile
        per phase — and token-identical to the scan engine (the full
        six-family parity matrix lives in tests/test_golden_parity.py).
        """
        cfg, params = models[arch]
        trace = _single_bucket_trace(cfg, seed=1)
        scan = _serve(ServeEngine(params, cfg,
                                  EngineConfig(max_batch=4, max_len=48)),
                      trace)
        loop_cfg = dataclasses.replace(cfg, scan_layers=False)
        eng = ServeEngine(params, loop_cfg,
                          EngineConfig(max_batch=4, max_len=48))
        assert _serve(eng, trace) == scan, \
            f"{arch}: unrolled oracle diverged from the scan path"
        fns = [eng._prefill_bucket, eng._insert, eng._decode_multi]
        assert compile_counts(*fns) == [1, 1, 1]
