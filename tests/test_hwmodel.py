"""Hardware-model tests: Table 3, Fig. 5(a), system-level paper claims."""
import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.hwmodel import (
    ADC_FLASH_4B,
    ADC_SAR_7B,
    CONFIG_A,
    CONFIG_B,
    DCIM_A,
    LayerShape,
    SystemConfig,
    WORKLOADS,
    cim_add_sub_row,
    dcim_column_energy_pj,
    dcim_latency_per_column_ns,
    evaluate_workload,
)
from repro.hwmodel.dcim import twos_complement_to_int
from repro.hwmodel.devices import DEFAULT_HW, scale_peripheral


class TestTable3:
    def test_dcim_per_column_latency_matches_table3(self):
        """Table 3: DCiM(A) 0.06 ns, DCiM(B) 0.10 ns per column (avg)."""
        assert abs(dcim_latency_per_column_ns(CONFIG_A) - 0.06) < 0.01
        assert abs(dcim_latency_per_column_ns(CONFIG_B) - 0.10) < 0.015

    def test_config_a_processes_2x_columns_of_b(self):
        """§5.3: config A has ~2x lower total latency per-column than B."""
        ratio = dcim_latency_per_column_ns(CONFIG_B) / dcim_latency_per_column_ns(
            CONFIG_A
        )
        assert 1.8 <= ratio <= 2.2

    def test_dcim_energy_vs_4bit_adc(self):
        """Table 3 / abstract: DCiM ~12x lower energy than the 4-bit ADC."""
        e_dcim = dcim_column_energy_pj(0.5)  # operating sparsity
        ratio = ADC_FLASH_4B.energy_pj / e_dcim
        assert 10.0 <= ratio <= 14.0, ratio

    def test_dcim_geometry_matches_table1(self):
        """Table 1: config A is a 24x128 array (4*4 SF bits + 8 PS bits)."""
        assert CONFIG_A.rows == 24 and CONFIG_A.columns == 128
        assert CONFIG_B.rows == 24 and CONFIG_B.columns == 64


class TestFig5aSparsity:
    def test_24pct_reduction_at_50pct_sparsity(self):
        e0, e50 = dcim_column_energy_pj(0.0), dcim_column_energy_pj(0.5)
        assert abs((1 - e50 / e0) - 0.24) < 0.01

    @given(s1=st.floats(0, 1), s2=st.floats(0, 1))
    @settings(max_examples=30, deadline=None)
    def test_energy_monotone_in_sparsity(self, s1, s2):
        if s1 > s2:
            s1, s2 = s2, s1
        assert dcim_column_energy_pj(s2) <= dcim_column_energy_pj(s1) + 1e-12

    def test_sparsity_does_not_change_latency(self):
        """§5.3: sparsity saves energy but not latency (columns parallel)."""
        layers = WORKLOADS["resnet20"]()
        lo = evaluate_workload(layers, SystemConfig(style="hcim", sparsity=0.1))
        hi = evaluate_workload(layers, SystemConfig(style="hcim", sparsity=0.9))
        assert lo.latency_ns == hi.latency_ns
        assert hi.energy_pj < lo.energy_pj


class TestSystemLevel:
    @pytest.fixture(scope="class")
    def tallies(self):
        layers = WORKLOADS["resnet20"]()
        mk = lambda **kw: evaluate_workload(layers, SystemConfig(**kw))
        return {
            "adc7": mk(style="adc", adc_bits=7),
            "adc6": mk(style="adc", adc_bits=6),
            "adc4": mk(style="adc", adc_bits=4),
            "hcim_t": mk(style="hcim", levels="ternary", sparsity=0.5),
            "hcim_b": mk(style="hcim", levels="binary"),
            "quarry": mk(style="quarry", levels="ternary", sparsity=0.5),
        }

    def test_fig1_15x_vs_7bit_system(self, tallies):
        r = tallies["adc7"].energy_pj / tallies["hcim_t"].energy_pj
        assert 12.0 <= r <= 19.0, r

    def test_at_least_3x_vs_all_baselines(self, tallies):
        """§5.3: >= ~3x lower energy than every ADC baseline."""
        for k in ["adc7", "adc6", "adc4"]:
            assert tallies[k].energy_pj / tallies["hcim_t"].energy_pj >= 2.8, k

    def test_ternary_beats_binary_by_15pct(self, tallies):
        """§5.3/abstract: ternary >= ~15% lower energy than binary."""
        r = tallies["hcim_b"].energy_pj / tallies["hcim_t"].energy_pj
        assert r >= 1.12, r

    def test_headline_column_path_ratios(self, tallies):
        """Abstract: up to 28x / 12x vs 7-/4-bit ADC on the column path."""
        a7 = tallies["adc7"].breakdown["adc"] + tallies["adc7"].breakdown["shift_add"]
        a4 = tallies["adc4"].breakdown["adc"] + tallies["adc4"].breakdown["shift_add"]
        h50 = tallies["hcim_t"].breakdown["dcim"] + tallies["hcim_t"].breakdown["comparators"]
        assert 20.0 <= a7 / h50 <= 30.0     # -> 28x at high-sparsity layers
        assert 9.0 <= a4 / h50 <= 14.0      # "12x"

    def test_flash4_latency_slightly_better_than_hcim(self, tallies):
        """§5.3: HCiM ~11% higher latency than the 4-bit flash baseline."""
        r = tallies["hcim_t"].latency_ns / tallies["adc4"].latency_ns
        assert 1.0 <= r <= 1.25, r

    def test_hcim_beats_sar_latency(self, tallies):
        """§5.3: 3-12x (we get more) lower latency than SAR baselines."""
        assert tallies["adc7"].latency_ns / tallies["hcim_t"].latency_ns >= 3.0

    def test_quarry_worse_than_hcim(self, tallies):
        """Fig 5(b): HCiM lower energy than Quarry-style SF processing."""
        assert tallies["quarry"].energy_pj > tallies["hcim_t"].energy_pj

    def test_config_b_keeps_2_5x_vs_baselines(self):
        """Fig 7: with 64x64 crossbars HCiM keeps >= 2.5x vs 6/4-bit ADC."""
        layers = WORKLOADS["resnet20"]()
        mk = lambda **kw: evaluate_workload(
            layers, SystemConfig(xbar_rows=64, **kw)
        )
        h = mk(style="hcim", levels="ternary", sparsity=0.5)
        for bits in [6, 4]:
            r = mk(style="adc", adc_bits=bits).energy_pj / h.energy_pj
            assert r >= 2.5, (bits, r)

    def test_tech_scaling_preserves_ratios(self):
        layers = WORKLOADS["resnet20"]()
        r65 = (
            evaluate_workload(layers, SystemConfig(style="adc", adc_bits=7)).energy_pj
            / evaluate_workload(layers, SystemConfig(style="hcim")).energy_pj
        )
        r32 = (
            evaluate_workload(
                layers, SystemConfig(style="adc", adc_bits=7, tech_scale=True)
            ).energy_pj
            / evaluate_workload(
                layers, SystemConfig(style="hcim", tech_scale=True)
            ).energy_pj
        )
        assert abs(r65 - r32) / r65 < 0.25


class TestInMemoryAddSub:
    """§4.2.1 — the CiM full adder/subtractor computes exact arithmetic."""

    @given(
        ps=st.integers(0, 255),
        sf=st.integers(0, 15),
        p=st.sampled_from([-1, 0, 1]),
    )
    @settings(max_examples=200, deadline=None)
    def test_add_sub_exact_mod_2n(self, ps, sf, p):
        out = cim_add_sub_row(ps, sf, p, ps_bits=8)
        assert out == (ps + p * sf) % 256

    def test_p_zero_is_gated(self):
        assert cim_add_sub_row(77, 13, 0, 8) == 77

    def test_subtraction_without_twos_complement_storage(self):
        # accumulating +s then -s returns to start (no 2x memory needed)
        ps = 100
        ps = cim_add_sub_row(ps, 9, +1, 8)
        ps = cim_add_sub_row(ps, 9, -1, 8)
        assert ps == 100

    @given(v=st.integers(-128, 127))
    @settings(max_examples=50, deadline=None)
    def test_twos_complement_roundtrip(self, v):
        assert twos_complement_to_int(v & 0xFF, 8) == v


class TestScaling:
    def test_scale_peripheral_shrinks_everything(self):
        s = scale_peripheral(ADC_SAR_7B)
        assert s.energy_pj < ADC_SAR_7B.energy_pj
        assert s.latency_ns < ADC_SAR_7B.latency_ns
        assert s.area_mm2 < ADC_SAR_7B.area_mm2

    def test_workload_counts_scale_with_depth(self):
        e20 = evaluate_workload(WORKLOADS["resnet20"](), SystemConfig(style="hcim"))
        e44 = evaluate_workload(WORKLOADS["resnet44"](), SystemConfig(style="hcim"))
        assert e44.energy_pj > 1.5 * e20.energy_pj


class TestServeEnergy:
    """serve_energy: the engine-facing wrapper over the Tally path."""

    def _shapes(self):
        return [(l.name, l.k, l.o, l.n_vec) for l in WORKLOADS["resnet20"]()]

    def test_hcim_energy_monotone_nonincreasing_in_sparsity(self):
        from repro.hwmodel import serve_energy

        for r in (64, 128):
            es = [
                serve_energy(self._shapes(), occupancy=sp, style="hcim",
                             xbar_rows=r)["energy_pj"]
                for sp in (0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0)
            ]
            assert all(a >= b - 1e-9 for a, b in zip(es, es[1:])), (r, es)

    def test_style_ordering_hcim_quarry_adc(self):
        """hcim <= quarry <= adc across the operating grid. Above
        occupancy ~0.88 quarry undercuts hcim (its SF cost gates fully
        with sparsity while hcim's DCiM keeps a fixed-cost floor), so
        the grid stops at 0.75 — the crossover is documented in
        docs/energy.md, not a modeling bug."""
        from repro.hwmodel import serve_energy

        for sp in (0.0, 0.25, 0.5, 0.75):
            for r in (64, 128):
                for lv in ("ternary", "binary"):
                    e = {
                        s: serve_energy(self._shapes(), occupancy=sp,
                                        style=s, xbar_rows=r,
                                        levels=lv)["energy_pj"]
                        for s in ("hcim", "quarry", "adc")
                    }
                    assert e["hcim"] <= e["quarry"] <= e["adc"], (sp, r, lv, e)

    def test_agrees_with_workload_tally(self):
        """serve_energy must be evaluate_workload in a serving coat: same
        energy, latency, area and EDAP on the fig5a/fig6 layer shapes."""
        from repro.hwmodel import serve_energy

        layers = WORKLOADS["resnet20"]()
        for style, sp in (("hcim", 0.5), ("quarry", 0.25), ("adc", 0.0)):
            t = evaluate_workload(
                layers, SystemConfig(style=style, sparsity=sp)
            )
            e = serve_energy([(l.name, l.k, l.o, l.n_vec) for l in layers],
                             occupancy=sp, style=style)
            assert e["energy_pj"] == pytest.approx(t.energy_pj)
            assert e["latency_ns"] == pytest.approx(t.latency_ns)
            assert e["area_mm2"] == pytest.approx(t.area_mm2)
            assert e["edap"] == pytest.approx(t.edap)
            assert e["breakdown"] == t.breakdown

    def test_per_layer_occupancy_map(self):
        from repro.hwmodel import serve_energy

        shapes = [("a", 128, 128, 1), ("b", 128, 128, 1)]
        uniform = serve_energy(shapes, occupancy=0.5, style="hcim")
        mapped = serve_energy(shapes, occupancy={"a": 0.5, "b": 0.5},
                              style="hcim")
        assert mapped["energy_pj"] == pytest.approx(uniform["energy_pj"])
        # a missing name falls back to dense (0.0) -> more energy
        partial = serve_energy(shapes, occupancy={"a": 0.5}, style="hcim")
        assert partial["energy_pj"] > mapped["energy_pj"]

    def test_unknown_style_raises(self):
        from repro.hwmodel import serve_energy

        with pytest.raises(ValueError, match="unknown energy style"):
            serve_energy([("fc", 64, 64, 1)], style="dram")
