"""Unit + property tests for the quantization primitives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import quant

jax.config.update("jax_platform_name", "cpu")


class TestRounding:
    def test_round_ste_forward(self):
        x = jnp.array([-1.5, -0.5, 0.5, 1.5, 2.4, 2.6])
        np.testing.assert_array_equal(
            quant.round_ste(x), jnp.round(x)
        )

    def test_round_ste_gradient_is_identity(self):
        g = jax.grad(lambda x: quant.round_ste(x).sum())(jnp.array([0.3, 1.7]))
        np.testing.assert_array_equal(g, jnp.ones(2))

    def test_round_comparator_ties_away(self):
        x = jnp.array([-1.5, -0.5, 0.5, 1.5])
        np.testing.assert_array_equal(
            quant.round_comparator(x), jnp.array([-2.0, -1.0, 1.0, 2.0])
        )

    def test_grad_scale(self):
        x = jnp.array(3.0)
        assert float(quant.grad_scale(x, 0.25)) == 3.0
        g = jax.grad(lambda v: quant.grad_scale(v, 0.25))(x)
        assert float(g) == 0.25


class TestLSQ:
    def test_quantize_levels(self):
        x = jnp.linspace(-3, 3, 100)
        y = quant.lsq_quantize(x, jnp.array(0.5), -8, 7)
        codes = np.unique(np.asarray(y) / 0.5)
        assert np.all(codes >= -8) and np.all(codes <= 7)
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-6)

    def test_clip_blocks_gradient(self):
        # outside-range inputs get zero gradient (LSQ clip behavior)
        g = jax.grad(
            lambda x: quant.lsq_quantize(x, jnp.array(0.5), -8, 7).sum()
        )(jnp.array([100.0, 0.2, -100.0]))
        np.testing.assert_array_equal(g, jnp.array([0.0, 1.0, 0.0]))

    def test_step_gradient_matches_lsq_formula(self):
        # d/ds [round(x/s)*s] = round(x/s) - x/s (in range), times grad scale g
        x, s, g = jnp.array([1.3]), jnp.array(0.5), 0.125
        grad_s = jax.grad(
            lambda s_: quant.lsq_quantize(x, s_, -8, 7, g=g).sum()
        )(s)
        v = 1.3 / 0.5
        expected = (np.round(v) - v) * g
        np.testing.assert_allclose(float(grad_s), expected, rtol=1e-5)


class TestBitSlicing:
    @given(
        n_bits=st.integers(2, 8),
        seed=st.integers(0, 2 ** 16),
    )
    @settings(max_examples=25, deadline=None)
    def test_twos_complement_roundtrip(self, n_bits, seed):
        rng = np.random.RandomState(seed)
        lo, hi = -(2 ** (n_bits - 1)), 2 ** (n_bits - 1) - 1
        x = jnp.asarray(rng.randint(lo, hi + 1, size=(4, 7)), jnp.float32)
        bits = quant.twos_complement_bits(x, n_bits)
        w = quant.bit_weights(n_bits)
        recon = jnp.einsum("k,k...->...", w, bits)
        np.testing.assert_array_equal(np.asarray(recon), np.asarray(x))
        assert set(np.unique(np.asarray(bits))) <= {0.0, 1.0}

    def test_unsigned_bits(self):
        x = jnp.asarray([[0, 1, 5, 15]], jnp.float32)
        bits = quant.unsigned_bits(x, 4)
        w = jnp.asarray([1.0, 2.0, 4.0, 8.0])
        recon = jnp.einsum("k,k...->...", w, bits)
        np.testing.assert_array_equal(np.asarray(recon), np.asarray(x))


class TestScaleFactorQuant:
    def test_codes_are_fixed_point(self):
        sf = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (3, 4, 4, 5))) * 10
        step = jnp.array(0.5)
        q = quant.quantize_scale_factors(sf, step, n_bits=4)
        codes = np.asarray(q) / 0.5
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-5)
        assert codes.max() <= 15 and codes.min() >= 0

    def test_nonnegative(self):
        sf = jnp.array([-1.0, 0.0, 3.0])
        q = quant.quantize_scale_factors(sf, jnp.array(1.0), n_bits=4)
        assert float(q.min()) >= 0.0


class TestADC:
    @given(bits=st.integers(1, 8), rows=st.sampled_from([32, 64, 128]))
    @settings(max_examples=30, deadline=None)
    def test_adc_error_bound(self, bits, rows):
        ps = jnp.arange(0, rows + 1, dtype=jnp.float32)
        q = quant.adc_quantize(ps, bits, rows)
        step = max(1.0, rows / 2 ** bits)
        # everything except top-code clipping is within half a step
        interior = np.asarray(ps) <= (2 ** bits - 1) * step
        err = np.abs(np.asarray(q - ps))
        assert err[interior].max() <= step / 2 + 1e-5

    def test_ideal_precision_is_exact_interior(self):
        ps = jnp.arange(0, 128, dtype=jnp.float32)  # below top code
        q = quant.adc_quantize(ps, 8, 128)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(ps))


class TestComparators:
    def test_ternary_thresholds_inclusive(self):
        alpha = jnp.array(2.0)
        a = jnp.array([-3.0, -2.0, -1.9, 0.0, 1.9, 2.0, 3.0])
        p = quant.ternary_comparator(a, alpha)
        np.testing.assert_array_equal(
            np.asarray(p), [-1.0, -1.0, 0.0, 0.0, 0.0, 1.0, 1.0]
        )

    def test_binary_sign_zero_positive(self):
        p = quant.binary_comparator(jnp.array([-0.1, 0.0, 0.1]), jnp.array(1.0))
        np.testing.assert_array_equal(np.asarray(p), [-1.0, 1.0, 1.0])

    def test_alpha_gradient_nonzero(self):
        a = jnp.linspace(-5, 5, 50)
        g = jax.grad(
            lambda al: (quant.ternary_comparator(a, al) ** 2).sum()
        )(jnp.array(2.0))
        assert np.isfinite(float(g))
