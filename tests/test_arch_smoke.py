"""Per-architecture smoke tests: reduced config, forward + train step.

Required deliverable (f): every assigned arch instantiates at reduced
size, runs one forward and one gradient step on CPU, and produces
finite outputs of the right shape. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, list_archs
from repro.core.config import QuantConfig
from repro.models import forward, init_model, loss_fn

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 16


def _batch(cfg, key=None):
    key = key or jax.random.PRNGKey(1)
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tok, "targets": jnp.roll(tok, -1, axis=1)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(key, (B, S, cfg.d_model)) * 0.1
    if cfg.family == "vlm":
        batch["patch_embeds"] = (
            jax.random.normal(key, (B, cfg.frontend_len, cfg.d_model)) * 0.1
        )
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    logits, _ = forward(params, cfg, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step_reduces_loss_direction(arch):
    """One SGD step along the gradient must not produce NaNs and the
    gradient must be non-trivial for every block family."""
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss0, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True
    )(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g ** 2) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(loss0)) and float(gnorm) > 0
    lr = 0.1 / max(float(gnorm), 1.0)
    new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    loss1, _ = loss_fn(new_params, cfg, batch)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0) + 0.5  # no blow-up


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "granite-moe-3b-a800m"])
def test_psq_mode_forward(arch):
    """The paper's technique engages on real archs (reduced size)."""
    cfg = get_config(arch).reduced()
    cfg = cfg.with_quant(
        QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=32,
                    collect_stats=True)
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    logits, stats = forward(params, cfg, _batch(cfg))
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert 0.0 < float(stats["p_zero_frac"]) < 1.0


def test_exact_assigned_configs_match_spec():
    """The full configs carry the exact published dimensions."""
    spec = {
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    }
    for name, (nl, d, h, kv, ff, v) in spec.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (nl, d, h, kv, ff, v), name


def test_param_counts_are_in_published_ballpark():
    """Analytic 6ND parameter counts should land near the model names."""
    expect = {
        "tinyllama-1.1b": (0.9e9, 1.4e9),
        "qwen3-14b": (12e9, 17e9),
        "starcoder2-3b": (2.5e9, 3.6e9),
        "arctic-480b": (380e9, 520e9),
        "xlstm-350m": (0.25e9, 0.50e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, (name, n / 1e9)


def test_moe_aux_loss_present():
    cfg = get_config("granite-moe-3b-a800m").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    _, stats = loss_fn(params, cfg, _batch(cfg))
    assert "moe_aux_loss" in stats


def test_zamba_shared_attention_is_shared():
    """zamba2: attention weights appear once, reused at every attn slot."""
    cfg = get_config("zamba2-7b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    assert "shared_attn" in params
    from repro.models.transformer import layer_kinds

    kinds = layer_kinds(cfg)
    assert kinds.count("shared_attn") >= 2
    # per-layer stacks contain only Mamba blocks; attention params exist
    # exactly once at model level (the shared block)
    assert "mamba_groups" in params and "blocks" not in params
