"""Optional-``hypothesis`` shim so the suite always collects.

Property-based tests import ``given``/``settings``/``st`` from here
instead of from ``hypothesis`` directly. With hypothesis installed
(CI: ``pip install -r requirements-dev.txt``) this re-exports the real
thing; without it, every ``@given`` test collects normally and skips
with an explanatory message, and the rest of the module's tests run.
"""
try:
    from hypothesis import HealthCheck, assume, given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised in bare containers
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Chainable stand-in: every attribute/call/composition returns
        itself, so module-level strategy expressions still evaluate."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()
    HealthCheck = _AnyStrategy()

    def assume(_condition=True):
        return True

    def given(*_args, **_kwargs):
        def deco(fn):
            # deliberately no functools.wraps: __wrapped__ would leak the
            # original signature and pytest would demand its argument
            # names as fixtures. A bare *args fn requests none.
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = getattr(fn, "__name__", "test")
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
