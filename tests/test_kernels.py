"""Pallas kernel vs pure-jnp oracle, swept over shapes/dtypes/modes.

Kernels run in interpret mode (CPU container); on TPU the same
pallas_call lowers to Mosaic with the documented BlockSpec tiling.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import QuantConfig, init_linear
from repro.core.psq import psq_matmul as psq_jnp
from repro.kernels import ops
from repro.kernels.int4_matmul import int4_matmul_kernel, pack_int4
from repro.kernels.psq_matmul import psq_matmul_kernel
from repro.kernels.ref import int4_matmul_ref, psq_matmul_ref

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


def _int_inputs(B, K, O, R, n_a=4, n_w=4, seed=0):
    T = math.ceil(K / R)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    lo_a, hi_a = -(2 ** (n_a - 1)), 2 ** (n_a - 1) - 1
    lo_w, hi_w = -(2 ** (n_w - 1)), 2 ** (n_w - 1) - 1
    x = jnp.round(jax.random.uniform(k1, (B, K), minval=lo_a, maxval=hi_a))
    w = jnp.round(jax.random.uniform(k2, (K, O), minval=lo_w, maxval=hi_w))
    sf = jnp.round(jax.random.uniform(k3, (T, n_a, n_w, O), maxval=15)) * 0.5
    return x, w, sf


SHAPES = [
    (4, 200, 17, 64),     # ragged everything
    (16, 256, 130, 128),  # multi-tile, ragged O
    (3, 64, 64, 64),      # single tile
    (1, 128, 256, 128),   # gemv-like
    (9, 300, 40, 32),     # small crossbar
]


class TestPsqKernel:
    @pytest.mark.parametrize("levels", ["ternary", "binary", "adc"])
    @pytest.mark.parametrize("shape", SHAPES)
    def test_matches_ref(self, levels, shape):
        B, K, O, R = shape
        x, w, sf = _int_inputs(B, K, O, R)
        alpha = jnp.array(5.0)
        kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=4, xbar_rows=R)
        yk = psq_matmul_kernel(x, w, sf, alpha, **kw)
        yr = psq_matmul_ref(x, w, sf, alpha, **kw)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-3)

    @pytest.mark.parametrize("levels", ["ternary", "binary"])
    def test_fused_planes_identical(self, levels):
        """Beyond-paper MXU fusion must be bit-identical to the loop."""
        B, K, O, R = 8, 256, 96, 128
        x, w, sf = _int_inputs(B, K, O, R)
        alpha = jnp.array(4.0)
        kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=4, xbar_rows=R)
        y0 = psq_matmul_kernel(x, w, sf, alpha, fuse_planes=False, **kw)
        y1 = psq_matmul_kernel(x, w, sf, alpha, fuse_planes=True, **kw)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))

    @pytest.mark.parametrize("n_a,n_w", [(2, 2), (3, 3), (4, 2), (8, 4)])
    def test_bitwidth_sweep(self, n_a, n_w):
        B, K, O, R = 4, 160, 24, 32
        x, w, sf = _int_inputs(B, K, O, R, n_a=n_a, n_w=n_w)
        alpha = jnp.array(3.0)
        kw = dict(n_a=n_a, n_w=n_w, levels="ternary", adc_bits=4, xbar_rows=R)
        yk = psq_matmul_kernel(x, w, sf, alpha, **kw)
        yr = psq_matmul_ref(x, w, sf, alpha, **kw)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-3)

    @given(
        b=st.integers(1, 12),
        k=st.integers(8, 280),
        o=st.integers(1, 150),
        r=st.sampled_from([32, 64, 128]),
        levels=st.sampled_from(["ternary", "binary", "adc"]),
        seed=st.integers(0, 999),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_kernel_matches_ref(self, b, k, o, r, levels, seed):
        x, w, sf = _int_inputs(b, k, o, r, seed=seed)
        alpha = jnp.array(4.0)
        kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=6, xbar_rows=r)
        yk = psq_matmul_kernel(x, w, sf, alpha, **kw)
        yr = psq_matmul_ref(x, w, sf, alpha, **kw)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-3)

    def test_block_size_invariance(self):
        B, K, O, R = 16, 256, 160, 64
        x, w, sf = _int_inputs(B, K, O, R)
        alpha = jnp.array(4.0)
        kw = dict(n_a=4, n_w=4, levels="ternary", adc_bits=4, xbar_rows=R)
        y0 = psq_matmul_kernel(x, w, sf, alpha, block_b=8, block_o=128, **kw)
        y1 = psq_matmul_kernel(x, w, sf, alpha, block_b=128, block_o=256, **kw)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


class TestQatWrapper:
    def test_kernel_forward_equals_jnp_forward(self):
        cfg = QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=64)
        p = init_linear(KEY, 200, 17, cfg)
        x = jax.random.normal(KEY, (5, 200))
        y1, _ = ops.psq_matmul(x, p["w"], p, cfg)
        y2, _ = psq_jnp(x, p["w"], p, cfg)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)

    def test_kernel_backward_equals_jnp_backward(self):
        cfg = QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=64)
        p = init_linear(KEY, 96, 12, cfg)
        x = jax.random.normal(KEY, (5, 96))
        g1 = jax.grad(lambda pp: jnp.sum(ops.psq_matmul(x, pp["w"], pp, cfg)[0] ** 2))(p)
        g2 = jax.grad(lambda pp: jnp.sum(psq_jnp(x, pp["w"], pp, cfg)[0] ** 2))(p)
        for k in g1:
            np.testing.assert_allclose(
                np.asarray(g1[k]), np.asarray(g2[k]), atol=1e-4, err_msg=k
            )


class TestInt4Kernel:
    @pytest.mark.parametrize("shape", [(7, 256, 96), (1, 512, 128), (33, 128, 300)])
    def test_matches_ref(self, shape):
        B, K, O = shape
        w_int = jnp.round(
            jax.random.uniform(KEY, (K, O), minval=-8, maxval=7)
        )
        wp = pack_int4(w_int)
        scale = jax.random.uniform(jax.random.fold_in(KEY, 1), (O,),
                                   minval=0.5, maxval=2.0)
        x = jnp.round(jax.random.normal(jax.random.fold_in(KEY, 2), (B, K)) * 4)
        yk = int4_matmul_kernel(x, wp, scale)
        yr = int4_matmul_ref(wp, scale, x)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=2e-2,
                                   atol=1e-2)

    def test_pack_roundtrip(self):
        w_int = jnp.round(jax.random.uniform(KEY, (64, 8), minval=-8, maxval=7))
        wp = pack_int4(w_int)
        assert wp.shape == (32, 8) and wp.dtype == jnp.int8
        # unpack via the reference and compare against direct dequant
        y = int4_matmul_ref(wp, jnp.ones(8), jnp.eye(64))
        np.testing.assert_array_equal(np.asarray(y), np.asarray(w_int))

    @given(
        b=st.integers(1, 8), k=st.sampled_from([64, 128, 256]),
        o=st.integers(8, 200), seed=st.integers(0, 99),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_int4(self, b, k, o, seed):
        kk = jax.random.PRNGKey(seed)
        w_int = jnp.round(jax.random.uniform(kk, (k, o), minval=-8, maxval=7))
        wp = pack_int4(w_int)
        scale = jnp.ones((o,))
        x = jnp.round(jax.random.normal(jax.random.fold_in(kk, 1), (b, k)) * 3)
        yk = int4_matmul_kernel(x, wp, scale)
        np.testing.assert_allclose(
            np.asarray(yk), np.asarray(x @ w_int), rtol=2e-2, atol=1e-2
        )


class TestFlashAttentionKernel:
    """Pallas flash kernel vs naive SDPA oracle (interpret mode)."""

    @pytest.mark.parametrize(
        "B,S,H,Hk,D,win",
        [(2, 64, 4, 2, 16, 0), (1, 128, 4, 4, 32, 0), (2, 64, 4, 2, 16, 24)],
    )
    def test_matches_sdpa(self, B, S, H, Hk, D, win):
        from repro.kernels.flash_attention import flash_attention_gqa
        from repro.models.attention import _sdpa

        q = jax.random.normal(KEY, (B, S, H, D))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hk, D))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hk, D))
        ref = _sdpa(q, k, v, True, win)
        out = flash_attention_gqa(q, k, v, causal=True, window=win,
                                  q_block=32, kv_block=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)

    def test_block_size_invariance(self):
        from repro.kernels.flash_attention import flash_attention_gqa

        q = jax.random.normal(KEY, (1, 64, 2, 16))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 2, 16))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64, 2, 16))
        y1 = flash_attention_gqa(q, k, v, q_block=16, kv_block=64)
        y2 = flash_attention_gqa(q, k, v, q_block=64, kv_block=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


class TestInt4Packing:
    def test_pack_tree_for_serving_roundtrip_quality(self):
        from repro.core.psq_linear import (
            _unpack_int4_matmul, pack_tree_for_serving,
        )

        w = jax.random.normal(KEY, (64, 32)) * 0.1
        tree = {"mlp": {"down": {"w": w}}, "norm": {"scale": jnp.ones(3)}}
        packed = pack_tree_for_serving(tree)
        assert "w_packed" in packed["mlp"]["down"]
        assert packed["norm"]["scale"].shape == (3,)
        x = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 64))
        y = _unpack_int4_matmul(
            x, packed["mlp"]["down"]["w_packed"],
            packed["mlp"]["down"]["w_scale"],
        )
        # int4 symmetric quantization: high correlation, bounded error
        ref = x @ w
        corr = np.corrcoef(np.asarray(y).ravel(), np.asarray(ref).ravel())[0, 1]
        assert corr > 0.99
