"""Continuous-batching engine: slot lifecycle, parity, telemetry, jit."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serve import EngineConfig, Request, ServeEngine, throughput_stats

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _greedy_outputs(cfg, params, prompt, max_new, **ecfg_kw):
    eng = ServeEngine(params, cfg,
                      EngineConfig(max_batch=1, max_len=64, **ecfg_kw))
    eng.submit(prompt, max_new_tokens=max_new)
    return eng.run()[0].output


class TestContinuousScheduling:
    def test_eos_retirement_frees_slot_for_queued_request(self, tiny):
        """A sequence hitting EOS retires at that decode step, and the
        freed slot is filled by a queued request while the other slot's
        sequence is still mid-flight."""
        cfg, params = tiny
        rng = np.random.RandomState(3)
        # find a prompt whose 2nd greedy token differs from its 1st, so
        # EOS fires at a decode step (not at prefill)
        for _ in range(10):
            prompt_a = rng.randint(0, cfg.vocab_size, size=6)
            probe = _greedy_outputs(cfg, params, prompt_a, 3)
            if probe[1] != probe[0]:
                break
        else:
            pytest.skip("no prompt with distinct first tokens found")
        eos = probe[1]

        eng = ServeEngine(params, cfg, EngineConfig(max_batch=2, max_len=64))
        assert eng.mode == "continuous"
        # all three share the length bucket, so admission is strictly
        # FIFO: a+b fill both slots, c queues until a slot frees
        uid_a = eng.submit(prompt_a, max_new_tokens=12, eos_id=eos)
        uid_b = eng.submit(rng.randint(0, cfg.vocab_size, size=7),
                           max_new_tokens=12)
        uid_c = eng.submit(rng.randint(0, cfg.vocab_size, size=5),
                           max_new_tokens=4)
        done = {r.uid: r for r in eng.run()}
        assert set(done) == {uid_a, uid_b, uid_c}

        # a retired via EOS, early
        assert done[uid_a].output[-1] == eos
        assert len(done[uid_a].output) == 2 < 12
        # b ran to its full budget, c to its own
        assert len(done[uid_b].output) == 12
        assert len(done[uid_c].output) == 4
        # c was admitted mid-flight into a freed slot (both slots were
        # taken at step 0), before b finished
        adm = {a["uid"]: a for a in eng.admissions}
        assert adm[uid_c]["step"] > 0
        assert adm[uid_c]["slot"] == adm[uid_a]["slot"]
        assert done[uid_c].t_first_token < done[uid_b].t_done

    def test_batched_vs_sequential_greedy_parity(self, tiny):
        """Per-slot lengths + right-padded bucketed prefill make the slot
        pool exact: batched greedy outputs match one-at-a-time decoding
        token for token."""
        cfg, params = tiny
        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, cfg.vocab_size, size=n)
                   for n in (3, 9, 5, 14)]

        eng = ServeEngine(params, cfg, EngineConfig(max_batch=4, max_len=64))
        for p in prompts:
            eng.submit(p, max_new_tokens=8)
        batched = {r.uid: r.output for r in eng.run()}

        for uid, p in zip(sorted(batched), prompts):
            assert batched[uid] == _greedy_outputs(cfg, params, p, 8), \
                f"request {uid} diverged from sequential decode"

    def test_no_recompile_after_warmup(self, tiny, compile_counts):
        """Fixed shapes: decode compiles once; prefill/insert compile per
        (bucket length, bucket batch) pair; a repeat of the same workload
        adds zero compilations."""
        cfg, params = tiny
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=4, max_len=64))
        fns = [eng._decode_multi, eng._prefill_bucket, eng._insert]

        rng = np.random.RandomState(1)
        trace = [(rng.randint(0, cfg.vocab_size, size=int(rng.randint(2, 17))),
                  int(rng.randint(2, 9))) for _ in range(8)]
        for p, mn in trace:
            eng.submit(p, max_new_tokens=mn)
        eng.run()
        warm = compile_counts(*fns)
        assert warm[0] == 1, "decode loop must compile exactly once"

        for p, mn in trace:
            eng.submit(p, max_new_tokens=mn)
        eng.run()
        assert compile_counts(*fns) == warm, \
            "re-running an already-seen workload must not recompile"

    def test_occupancy_and_scheduler_stats(self, tiny):
        cfg, params = tiny
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=2, max_len=48))
        rng = np.random.RandomState(2)
        for _ in range(6):
            eng.submit(rng.randint(0, cfg.vocab_size, size=4),
                       max_new_tokens=5)
        eng.run()
        s = eng.stats()
        assert s["mode"] == "continuous"
        assert s["admissions"] == 6
        assert s["decode_steps"] > 0 and s["prefill_calls"] > 0
        # equal-length equal-budget requests on a saturated queue keep
        # the pool essentially full
        assert s["mean_slot_occupancy"] > 0.8

    def test_static_right_pad_gives_short_prompt_full_budget(self, tiny):
        """The static path right-pads with per-row lengths, so each row's
        KV writes are bounded by its OWN prompt + budget (the historical
        left-pad layout shifted every row to the longest prompt and had
        to truncate the short one's decode budget)."""
        cfg, params = tiny
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=2, max_len=16,
                                       mode="static"))
        rng = np.random.RandomState(0)
        long_p = rng.randint(0, cfg.vocab_size, size=12)
        short_p = rng.randint(0, cfg.vocab_size, size=2)
        uid_a = eng.submit(long_p, max_new_tokens=2)
        uid_b = eng.submit(short_p, max_new_tokens=12)  # 2 + 12 <= 16
        done = {r.uid: r for r in eng.run()}
        assert len(done[uid_a].output) == 2
        assert len(done[uid_b].output) == 12
        assert all(r.done for r in done.values())
        # and the mixed-length batch is exact, not just full-length
        assert done[uid_a].output == _greedy_outputs(cfg, params, long_p, 2)
        assert done[uid_b].output == _greedy_outputs(cfg, params, short_p, 12)

    def test_submit_rejects_overlong_request(self, tiny):
        cfg, params = tiny
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=1, max_len=16))
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(np.arange(10), max_new_tokens=10)

    def test_engine_config_eos_id_is_live(self, tiny):
        """Regression: EngineConfig.eos_id used to be dead config —
        submit() hardcoded its own -1 default and never consulted it.
        The config value must now apply to submits without an explicit
        eos_id, and an explicit per-request value must win over it."""
        cfg, params = tiny
        rng = np.random.RandomState(5)
        prompt = rng.randint(0, cfg.vocab_size, size=6)
        ref = _greedy_outputs(cfg, params, prompt, 12)
        eos, cut = None, None
        for k in range(1, len(ref)):
            if ref[k] not in ref[:k]:
                eos, cut = ref[k], k
                break
        if eos is None:
            pytest.skip("degenerate greedy output: no usable EOS token")
        # config default reaches the request: output truncates at EOS
        assert _greedy_outputs(cfg, params, prompt, 12,
                               eos_id=eos) == ref[:cut + 1]
        # explicit per-request eos_id overrides the config
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=1, max_len=64, eos_id=eos))
        eng.submit(prompt, max_new_tokens=12, eos_id=-1)
        assert eng.run()[0].output == ref


class TestStaticScheduling:
    def test_static_prefill_buckets_the_batch_dim(self, tiny, compile_counts):
        """_prefill_full pow2-buckets the admitted batch size: a trailing
        batch of 3 pads to the 4-bucket and reuses the full-batch
        compile, and a repeat workload adds zero compilations."""
        cfg, params = tiny
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=4, max_len=64,
                                       mode="static"))
        rng = np.random.RandomState(0)
        for _ in range(7):                      # batches of 4 then 3
            eng.submit(rng.randint(0, cfg.vocab_size, size=6),
                       max_new_tokens=3)
        eng.run()
        assert compile_counts(eng._prefill_full) == [1], \
            "batches of 4 and 3 must share one (batch-bucket, len) compile"
        for _ in range(7):
            eng.submit(rng.randint(0, cfg.vocab_size, size=6),
                       max_new_tokens=3)
        eng.run()
        assert compile_counts(eng._prefill_full) == [1]

    def test_encdec_batches_get_their_own_side_inputs(self):
        """Side inputs are positional by submission order: request i must
        be prefilled against its OWN enc_embeds row, not batch-local row
        0 (the old head-slice handed every batch the first rows)."""
        from repro.models import init_model as _init

        cfg = get_config("whisper-large-v3").reduced()
        params = _init(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        prompt = rng.randint(0, cfg.vocab_size, size=5)
        enc = (rng.randn(2, 6, cfg.d_model) * 0.1).astype(np.float32)
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=1, max_len=32),
                          extra_inputs={"enc_embeds": enc})
        eng.submit(prompt, max_new_tokens=4)
        eng.submit(prompt, max_new_tokens=4)    # second single-req batch
        out = {r.uid: r.output for r in eng.run()}

        ref_eng = ServeEngine(params, cfg,
                              EngineConfig(max_batch=1, max_len=32),
                              extra_inputs={"enc_embeds": enc[1:]})
        ref_eng.submit(prompt, max_new_tokens=4)
        ref = ref_eng.run()[0].output
        assert out[2] == ref, \
            "request 2 must decode against enc_embeds row 1, not row 0"


class TestShardedServing:
    """Mesh-sharded engine == single-device engine, token for token."""

    @staticmethod
    def _run(params, cfg, prompts, mesh=None, max_new=6):
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=4, max_len=64), mesh=mesh)
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        return {r.uid: r.output for r in eng.run()}, eng

    @pytest.fixture(scope="class")
    def prompts(self, tiny):
        cfg, _ = tiny
        rng = np.random.RandomState(7)
        return [rng.randint(0, cfg.vocab_size, size=n) for n in (3, 9, 5, 14)]

    @pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
    @pytest.mark.parametrize("shape", [(2, 1), (1, 2)])
    def test_fp_decode_parity_2way(self, tiny, prompts, shape):
        cfg, params = tiny
        base, _ = self._run(params, cfg, prompts)
        mesh = jax.make_mesh(shape, ("data", "model"))
        out, eng = self._run(params, cfg, prompts, mesh=mesh)
        assert out == base, f"mesh {shape} diverged from single-device"
        assert eng.stats()["mesh"] == f"data={shape[0]}xmodel={shape[1]}"

    @pytest.mark.skipif(len(jax.devices()) < 4, reason="needs >= 4 devices")
    def test_psq_packed_decode_parity_4way(self, tiny, prompts):
        """The full HCiM datapath — packed codes, int4 planes, DCiM scale
        factors column-sharded over `model`, slots over `data` — decodes
        bit-identically to the single-device engine."""
        import dataclasses

        from repro.core.config import PSQ_TERNARY
        from repro.serve import PackedModelCache, pack_tree_psq

        cfg, _ = tiny
        qcfg = dataclasses.replace(PSQ_TERNARY, kernel_backend="reference",
                                   xbar_rows=64)
        qc = cfg.with_quant(qcfg)
        params = init_model(jax.random.PRNGKey(0), qc)
        cache = PackedModelCache()
        packed = pack_tree_psq(params, qcfg, cache)
        base, _ = self._run(packed, qc, prompts, max_new=4)

        mesh = jax.make_mesh((2, 2), ("data", "model"))
        packed_sh = pack_tree_psq(params, qcfg, cache, mesh=mesh)
        # sharded packing of identical weights is a pure cache hit
        assert cache.stats()["packs"] == cache.stats()["layers"]
        assert cache.stats()["hits"] == cache.stats()["layers"]
        out, _ = self._run(packed_sh, qc, prompts, mesh=mesh, max_new=4)
        assert out == base

    @pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
    def test_sharded_engine_stays_jit_stable(self, tiny, prompts,
                                             compile_counts):
        """The no-recompile contract survives sharding: decode compiles
        once, a repeated workload adds zero compilations."""
        cfg, params = tiny
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=4, max_len=64), mesh=mesh)
        fns = [eng._decode_multi, eng._prefill_bucket, eng._insert]
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        eng.run()
        warm = compile_counts(*fns)
        # sharded decode may compile twice at warm-up: the first step
        # canonicalizes the eagerly-placed cache's shardings (XLA drops
        # size-1 mesh-axis entries), the second traces the steady state
        assert warm[0] <= 2
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        eng.run()
        assert compile_counts(*fns) == warm, \
            "re-running an already-seen workload must not recompile"


class TestModeResolution:
    """mode="auto" across every family × (paged, mesh, prefix_reuse),
    including the error paths (nothing may silently fall through)."""

    # family -> expected auto resolution (no side inputs submitted)
    AUTO = {
        "tinyllama-1.1b": "continuous",        # dense
        "granite-moe-3b-a800m": "continuous",  # moe
        "llava-next-mistral-7b": "continuous",  # vlm without patch embeds
        "xlstm-350m": "continuous",            # ssm: mLSTM/sLSTM state
        "zamba2-7b": "continuous",             # hybrid: Mamba2 + attn
        "whisper-large-v3": "continuous",      # encdec: per-slot cross-KV
    }

    @pytest.mark.parametrize("arch,expect", sorted(AUTO.items()))
    def test_auto_resolution_by_family(self, arch, expect):
        cfg = get_config(arch).reduced()
        eng = ServeEngine(None, cfg, EngineConfig())
        assert eng.mode == expect, arch

    @pytest.mark.parametrize("arch,expect", sorted(AUTO.items()))
    @pytest.mark.parametrize("prefix_reuse", [False, True])
    @pytest.mark.parametrize("with_mesh", [False, True])
    def test_auto_matrix_paged_mesh_reuse(self, arch, expect, prefix_reuse,
                                          with_mesh):
        """paged/mesh/prefix_reuse flags never change what auto resolves
        to; the invalid paged combinations raise their specific message
        instead of falling through to a broken engine."""
        mesh = None
        if with_mesh:
            if len(jax.devices()) < 2:
                pytest.skip("needs >= 2 devices")
            mesh = jax.make_mesh((2, 1), ("data", "model"))
        cfg = get_config(arch).reduced()
        eng = ServeEngine(None, cfg,
                          EngineConfig(max_batch=2, max_len=32,
                                       prefix_reuse=prefix_reuse),
                          mesh=mesh)
        assert eng.mode == expect, arch

        paged_kw = dict(max_batch=2, max_len=32, paged=True, block_size=16,
                        prefix_reuse=prefix_reuse)
        if cfg.family in ("hybrid", "ssm", "encdec"):
            # recurrent state has nothing to page; encdec cross-KV has
            # no pages — both must say why (and name the contiguous
            # continuous scheduler as the way out)
            with pytest.raises(ValueError, match="paged KV cache"):
                ServeEngine(None, cfg, EngineConfig(**paged_kw), mesh=mesh)
        else:
            eng = ServeEngine(None, cfg, EngineConfig(**paged_kw), mesh=mesh)
            assert eng.mode == "continuous"

    def test_paged_on_recurrent_family_names_the_reason(self):
        cfg = get_config("xlstm-350m").reduced()
        with pytest.raises(ValueError, match="no sequence axis to page"):
            ServeEngine(None, cfg,
                        EngineConfig(paged=True, max_len=32, block_size=16))

    def test_paged_with_side_inputs_raises_scheduler_error(self):
        # vlm IS a paged family, but the radix prefix index keys on
        # token ids alone, so per-request patch embeds could alias a
        # reused prefix page — the engine must reject the combination,
        # not half-configure pages
        cfg = get_config("llava-next-mistral-7b").reduced()
        with pytest.raises(ValueError, match="continuous scheduler"):
            ServeEngine(None, cfg,
                        EngineConfig(paged=True, max_len=32, block_size=16),
                        extra_inputs={"patch_embeds": np.zeros((1, 2, 4))})

    def test_side_inputs_stay_continuous(self):
        # patch/enc side inputs ride per-slot pools now: they no longer
        # force (or even permit forcing back to) the static fallback
        cfg = get_config("llava-next-mistral-7b").reduced()
        eng = ServeEngine(None, cfg, EngineConfig(),
                          extra_inputs={"patch_embeds": np.zeros((1, 2, 4))})
        assert eng.mode == "continuous"

    def test_unknown_mode_raises(self):
        cfg = get_config("tinyllama-1.1b").reduced()
        with pytest.raises(ValueError, match="unknown engine mode"):
            ServeEngine(None, cfg, EngineConfig(mode="banana"))


class TestThroughputStats:
    def test_empty(self):
        assert throughput_stats([]) == {}

    def test_zero_output_request(self):
        r = Request(1, np.arange(3), t_enqueue=10.0)
        r.t_done = 11.0
        s = throughput_stats([r])
        assert s["total_tokens"] == 0
        assert s["tokens_per_s"] == 0.0
        assert s["started"] == 0
        assert s["mean_ttft_s"] == 0.0

    def test_tokens_without_finish_timestamps(self):
        # mid-flight inspection: tokens exist but nothing finished yet —
        # rate must be 0.0, not total_tokens / epsilon
        r = Request(1, np.arange(3), t_enqueue=10.0)
        r.output = [5, 6]
        r.t_first_token = 10.2
        s = throughput_stats([r])
        assert s["tokens_per_s"] == 0.0
        assert s["total_tokens"] == 2

    def test_never_started_request_mixed_with_finished(self):
        ok = Request(1, np.arange(3), t_enqueue=10.0)
        ok.output = [5, 6]
        ok.t_first_token, ok.t_done = 10.5, 11.0
        never = Request(2, np.arange(4), t_enqueue=10.0)   # no timestamps
        s = throughput_stats([ok, never])
        assert s["requests"] == 2 and s["started"] == 1
        assert s["total_tokens"] == 2
        assert s["mean_ttft_s"] == pytest.approx(0.5)
        assert np.isfinite(s["tokens_per_s"]) and s["tokens_per_s"] > 0


class TestEnergyTelemetry:
    """Modeled hwmodel energy attribution in stats() (docs/energy.md)."""

    ENERGY_KEYS = ("energy_pj_per_token", "energy_pj_total",
                   "energy_pj_per_request", "edap_total", "mean_occupancy")

    def test_counters_finite_and_monotone_across_runs(self, tiny):
        import math

        cfg, params = tiny
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=2, max_len=64))
        s0 = eng.stats()
        assert s0["energy_tokens"] == 0
        assert s0["energy_pj_total"] == 0.0 and s0["edap_total"] == 0.0

        rng = np.random.RandomState(11)
        eng.submit(rng.randint(0, cfg.vocab_size, size=6), max_new_tokens=4)
        eng.run()
        s1 = eng.stats()
        assert s1["energy_tokens"] > 0
        for k in self.ENERGY_KEYS:
            assert math.isfinite(s1[k]), k
        assert s1["energy_pj_per_token"] > 0.0
        assert s1["energy_pj_total"] > 0.0
        assert s1["energy_pj_per_request"] > 0.0
        assert s1["edap_total"] > 0.0

        eng.submit(rng.randint(0, cfg.vocab_size, size=5), max_new_tokens=3)
        eng.run()
        s2 = eng.stats()
        assert s2["energy_tokens"] > s1["energy_tokens"]
        assert s2["energy_pj_total"] > s1["energy_pj_total"]
        # per-token cost is a property of the served model, not the trace
        assert s2["energy_pj_per_token"] == s1["energy_pj_per_token"]

    def test_reset_counters_zeroes_energy(self, tiny):
        cfg, params = tiny
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=1, max_len=64))
        eng.submit(np.arange(4) % cfg.vocab_size, max_new_tokens=3)
        eng.run()
        before = eng.stats()
        assert before["energy_pj_total"] > 0.0
        eng.reset_counters()
        after = eng.stats()
        assert after["energy_tokens"] == 0
        assert after["energy_pj_total"] == 0.0
        assert after["energy_pj_per_request"] == 0.0
        assert after["edap_total"] == 0.0
        # the per-token model survives the reset (engine state, not trace)
        assert after["energy_pj_per_token"] == before["energy_pj_per_token"]

    def test_never_started_engine_reports_zeros(self, tiny):
        cfg, params = tiny
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=1, max_len=64))
        s = eng.stats()
        assert s["energy_tokens"] == 0
        assert s["energy_pj_total"] == 0.0
        assert s["energy_pj_per_request"] == 0.0
        assert s["edap_total"] == 0.0

    def test_zero_output_run_keeps_per_request_finite(self, tiny):
        """run() with no submissions: no division by an empty finished
        list, all totals stay zero."""
        cfg, params = tiny
        eng = ServeEngine(params, cfg, EngineConfig(max_batch=1, max_len=64))
        assert eng.run() == []
        s = eng.stats()
        assert s["energy_pj_per_request"] == 0.0 and s["energy_tokens"] == 0

    def test_energy_style_is_live(self, tiny):
        cfg, params = tiny
        pj = {}
        for style in ("hcim", "adc"):
            eng = ServeEngine(params, cfg,
                              EngineConfig(max_batch=1, max_len=64,
                                           energy_style=style))
            assert eng.stats()["energy_style"] == style
            pj[style] = eng.stats()["energy_pj_per_token"]
        assert pj["adc"] > pj["hcim"]

    def test_unknown_energy_style_raises(self, tiny):
        cfg, params = tiny
        with pytest.raises(ValueError, match="energy_style"):
            ServeEngine(params, cfg,
                        EngineConfig(max_batch=1, max_len=64,
                                     energy_style="dram"))
