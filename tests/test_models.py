"""Layer-level model tests: attention masks, SSD/mLSTM recurrence
equivalences, MoE routing, decode-vs-forward consistency."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import DENSE
from repro.models import decode_step, forward, init_cache, init_model, prefill
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models import moe as moe_mod
from repro.models.attention import (
    AttnConfig,
    apply_attention,
    decode_attention,
    init_attention,
    init_kv_cache,
)

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


class TestAttention:
    CFG = AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16)

    def test_causality(self):
        p = init_attention(KEY, self.CFG, DENSE)
        x = jax.random.normal(KEY, (1, 8, 64))
        y1, _ = apply_attention(p, x, self.CFG, DENSE)
        x2 = x.at[:, -1].set(99.0)  # perturb the future
        y2, _ = apply_attention(p, x2, self.CFG, DENSE)
        np.testing.assert_allclose(
            np.asarray(y1[:, :-1]), np.asarray(y2[:, :-1]), atol=1e-5
        )

    def test_sliding_window_blocks_distant_past(self):
        cfg = self.CFG._replace(sliding_window=3)
        p = init_attention(KEY, cfg, DENSE)
        x = jax.random.normal(KEY, (1, 10, 64))
        y1, _ = apply_attention(p, x, cfg, DENSE)
        x2 = x.at[:, 0].set(7.0)  # outside the window of position 9
        y2, _ = apply_attention(p, x2, cfg, DENSE)
        np.testing.assert_allclose(
            np.asarray(y1[:, -1]), np.asarray(y2[:, -1]), atol=1e-5
        )

    def test_decode_matches_forward(self):
        """Token-by-token decode == parallel causal attention."""
        p = init_attention(KEY, self.CFG, DENSE)
        S = 6
        x = jax.random.normal(KEY, (2, S, 64)) * 0.5
        y_par, _ = apply_attention(p, x, self.CFG, DENSE)
        cache = init_kv_cache(2, S, 2, 16, dtype=jnp.float32)
        outs = []
        for t in range(S):
            y_t, cache, _ = decode_attention(
                p, x[:, t : t + 1], cache, self.CFG, DENSE
            )
            outs.append(y_t)
        y_seq = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(y_par), np.asarray(y_seq), atol=1e-4
        )


class TestSSD:
    def test_chunked_matches_sequential(self):
        b, s, h, p, n = 2, 37, 3, 8, 4
        k1, k2, k3, k4 = jax.random.split(KEY, 4)
        xh = jax.random.normal(k1, (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(k2, (b, s, h)))
        A = -jnp.exp(jax.random.normal(k3, (h,)))
        Bm = jax.random.normal(k4, (b, s, n))
        Cm = jax.random.normal(jax.random.fold_in(KEY, 9), (b, s, n))
        y_seq = ssm_mod.ssd_sequential_reference(xh, dt, A, Bm, Cm)
        y_chk, _ = ssm_mod._ssd_chunked(xh, dt, A, Bm, Cm, chunk=8)
        np.testing.assert_allclose(
            np.asarray(y_seq), np.asarray(y_chk), rtol=1e-4, atol=1e-4
        )

    def test_mamba_decode_matches_parallel(self):
        cfg = ssm_mod.SSMConfig(d_model=32, d_state=8, head_dim=16)
        p = ssm_mod.init_mamba2(KEY, cfg, DENSE)
        x = jax.random.normal(KEY, (2, 9, 32)) * 0.5
        y_par, _ = ssm_mod.apply_mamba2(p, x, cfg, DENSE, chunk=4)
        cache = ssm_mod.init_mamba2_cache(2, cfg)
        outs = []
        for t in range(9):
            y_t, cache, _ = ssm_mod.decode_mamba2(
                p, x[:, t : t + 1], cache, cfg, DENSE
            )
            outs.append(y_t)
        y_seq = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(y_par), np.asarray(y_seq), rtol=1e-4, atol=1e-4
        )


class TestXLSTM:
    def test_chunked_matches_parallel_oracle(self):
        b, s, h, d = 2, 19, 2, 8
        ks = jax.random.split(KEY, 5)
        q, k, v = (jax.random.normal(ks[i], (b, s, h, d)) for i in range(3))
        i_pre = jax.random.normal(ks[3], (b, s, h))
        f_pre = jax.random.normal(ks[4], (b, s, h)) + 2.0
        y_par = xlstm_mod._mlstm_parallel(q, k / math.sqrt(d) * math.sqrt(d), v, i_pre, f_pre)
        y_chk, _ = xlstm_mod._mlstm_chunked(q, k, v, i_pre, f_pre, chunk=5)
        np.testing.assert_allclose(
            np.asarray(y_par), np.asarray(y_chk), rtol=1e-4, atol=1e-4
        )

    def test_mlstm_decode_matches_chunked(self):
        cfg = xlstm_mod.XLSTMConfig(d_model=16, n_heads=2)
        p = xlstm_mod.init_mlstm(KEY, cfg, DENSE)
        x = jax.random.normal(KEY, (2, 7, 16)) * 0.5
        y_par, _ = xlstm_mod.apply_mlstm(p, x, cfg, DENSE, chunk=3)
        cache = xlstm_mod.init_mlstm_cache(2, cfg)
        outs = []
        for t in range(7):
            y_t, cache, _ = xlstm_mod.decode_mlstm(
                p, x[:, t : t + 1], cache, cfg, DENSE
            )
            outs.append(y_t)
        y_seq = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(y_par), np.asarray(y_seq), rtol=1e-4, atol=1e-4
        )


class TestMoE:
    def test_routing_conservation(self):
        """With huge capacity nothing is dropped; outputs are a convex
        combination of expert outputs (gates sum to 1)."""
        p = moe_mod.init_moe(KEY, 16, 32, n_experts=4, top_k=2, quant=DENSE)
        x = jax.random.normal(KEY, (2, 8, 16))
        y, stats = moe_mod.apply_moe(
            p, x, 4, 2, DENSE, capacity_factor=8.0, chunk_size=16
        )
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))
        assert float(stats["moe_aux_loss"]) >= 1.0 - 1e-5  # >= 1 by Cauchy-Schwarz

    def test_capacity_drops_tokens(self):
        p = moe_mod.init_moe(KEY, 16, 32, n_experts=4, top_k=1, quant=DENSE)
        x = jax.random.normal(KEY, (1, 64, 16))
        y_small, _ = moe_mod.apply_moe(
            p, x, 4, 1, DENSE, capacity_factor=0.1, chunk_size=64
        )
        y_big, _ = moe_mod.apply_moe(
            p, x, 4, 1, DENSE, capacity_factor=8.0, chunk_size=64
        )
        # tight capacity zeroes some tokens' outputs
        dropped = jnp.sum(jnp.all(y_small == 0.0, axis=-1))
        assert int(dropped) > 0
        assert float(jnp.linalg.norm(y_big)) > float(jnp.linalg.norm(y_small))

    def test_chunk_invariance(self):
        """Same capacity-per-token => chunking must not change routing."""
        p = moe_mod.init_moe(KEY, 8, 16, n_experts=2, top_k=1, quant=DENSE)
        x = jax.random.normal(KEY, (1, 32, 8))
        y1, _ = moe_mod.apply_moe(p, x, 2, 1, DENSE, capacity_factor=16.0,
                                  chunk_size=32)
        y2, _ = moe_mod.apply_moe(p, x, 2, 1, DENSE, capacity_factor=16.0,
                                  chunk_size=8)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


class TestEndToEndDecode:
    @pytest.mark.parametrize(
        "arch", ["tinyllama-1.1b", "zamba2-7b", "xlstm-350m", "whisper-large-v3"]
    )
    def test_prefill_then_decode_matches_forward(self, arch):
        """prefill(t[:n]) + decode(t[n]) logits == forward(t[:n+1])[-1]."""
        cfg = get_config(arch).reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        S = 8
        key = jax.random.PRNGKey(3)
        tok = jax.random.randint(key, (1, S + 1), 0, cfg.vocab_size)
        batch = {"tokens": tok[:, : S + 1]}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jax.random.normal(key, (1, S, cfg.d_model)) * 0.1
        logits_full, _ = forward(params, cfg, batch)

        pre_batch = dict(batch, tokens=tok[:, :S])
        logits_pre, cache = prefill(params, cfg, pre_batch, max_len=S + 4,
                                    dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(logits_full[:, :S]), np.asarray(logits_pre),
            rtol=2e-2, atol=2e-2,
        )
        logits_dec, cache = decode_step(params, cfg, tok[:, S : S + 1], cache)
        np.testing.assert_allclose(
            np.asarray(logits_full[:, S]), np.asarray(logits_dec[:, 0]),
            rtol=2e-2, atol=2e-2,
        )


class TestMaskedLengthPrefill:
    """Per-row `lengths` make right-pad positions exact state no-ops.

    This is the contract that lets recurrent families ride the
    continuous engine's bucketed (right-padded) prefill: the final
    state/conv buffer of a padded row must equal the unpadded forward's,
    bit for bit — including when the true length lands mid-chunk.
    """

    B, SEQ = 3, 11
    LENS = (11, 5, 2)

    def _lens(self):
        return jnp.asarray(self.LENS, jnp.int32)

    def test_mamba2_state_matches_unpadded(self):
        cfg = ssm_mod.SSMConfig(d_model=32, d_state=8, head_dim=16)
        p = ssm_mod.init_mamba2(KEY, cfg, DENSE)
        x = jax.random.normal(jax.random.PRNGKey(1), (self.B, self.SEQ, 32))
        _, _, st = ssm_mod.apply_mamba2(p, x, cfg, DENSE, chunk=4,
                                        return_cache=True,
                                        lengths=self._lens())
        for b, l in enumerate(self.LENS):
            _, _, ref = ssm_mod.apply_mamba2(p, x[b:b + 1, :l], cfg, DENSE,
                                             chunk=4, return_cache=True)
            for k in ("state", "conv"):
                np.testing.assert_array_equal(
                    np.asarray(st[k][b]), np.asarray(ref[k][0]),
                    err_msg=f"mamba2 {k} row {b}")

    def test_mlstm_state_matches_unpadded(self):
        cfg = xlstm_mod.XLSTMConfig(d_model=16, n_heads=2)
        p = xlstm_mod.init_mlstm(KEY, cfg, DENSE)
        x = jax.random.normal(jax.random.PRNGKey(2), (self.B, self.SEQ, 16))
        _, _, st = xlstm_mod.apply_mlstm(p, x, cfg, DENSE, chunk=4,
                                         return_cache=True,
                                         lengths=self._lens())
        for b, l in enumerate(self.LENS):
            _, _, ref = xlstm_mod.apply_mlstm(p, x[b:b + 1, :l], cfg, DENSE,
                                              chunk=4, return_cache=True)
            for k in ("C", "n", "m", "conv"):
                np.testing.assert_array_equal(
                    np.asarray(st[k][b]), np.asarray(ref[k][0]),
                    err_msg=f"mlstm {k} row {b}")

    def test_slstm_state_matches_unpadded(self):
        cfg = xlstm_mod.XLSTMConfig(d_model=16, n_heads=2)
        p = xlstm_mod.init_slstm(KEY, cfg, DENSE)
        x = jax.random.normal(jax.random.PRNGKey(3), (self.B, self.SEQ, 16))
        _, _, st = xlstm_mod.apply_slstm(p, x, cfg, DENSE, return_cache=True,
                                         lengths=self._lens())
        for b, l in enumerate(self.LENS):
            _, _, ref = xlstm_mod.apply_slstm(p, x[b:b + 1, :l], cfg, DENSE,
                                              return_cache=True)
            for k in ("c", "n", "m", "h"):
                np.testing.assert_array_equal(
                    np.asarray(st[k][b]), np.asarray(ref[k][0]),
                    err_msg=f"slstm {k} row {b}")

    def test_zero_length_row_keeps_fresh_state(self):
        """A bucket-padding row (length 0) must come out exactly as a
        fresh cache — it may be scattered into a slot pool."""
        cfg = xlstm_mod.XLSTMConfig(d_model=16, n_heads=2)
        p = xlstm_mod.init_mlstm(KEY, cfg, DENSE)
        x = jax.random.normal(jax.random.PRNGKey(4), (1, 6, 16))
        _, _, st = xlstm_mod.apply_mlstm(p, x, cfg, DENSE, chunk=4,
                                         return_cache=True,
                                         lengths=jnp.asarray([0], jnp.int32))
        fresh = xlstm_mod.init_mlstm_cache(1, cfg)
        for k in ("C", "n", "m", "conv"):
            np.testing.assert_array_equal(
                np.asarray(st[k]), np.asarray(fresh[k]), err_msg=k)
