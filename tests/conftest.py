"""Tier-1 suite environment: 4 virtual CPU devices.

The sharded-serving tests (tests/test_sharding.py,
tests/test_serve_engine.py) need a multi-device mesh. On CPU, JAX forges
virtual devices via ``--xla_force_host_platform_device_count``, which is
only honored if set before the XLA backend initializes — hence this
conftest, which pytest imports before any test module. An explicit
``XLA_FLAGS`` in the environment (e.g. the CI ``mesh4`` job, or a
deliberate single-device run) wins; the multi-device tests skip
themselves when fewer devices exist than they need.
"""
import os

_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=4"
    )
