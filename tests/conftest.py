"""Tier-1 suite environment: 4 virtual CPU devices + shared fixtures.

The sharded-serving tests (tests/test_sharding.py,
tests/test_serve_engine.py) need a multi-device mesh. On CPU, JAX forges
virtual devices via ``--xla_force_host_platform_device_count``, which is
only honored if set before the XLA backend initializes — hence this
conftest, which pytest imports before any test module. An explicit
``XLA_FLAGS`` in the environment (e.g. the CI ``mesh4`` job, or a
deliberate single-device run) wins; the multi-device tests skip
themselves when fewer devices exist than they need.
"""
import os

_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=4"
    )

import pytest  # noqa: E402  (XLA_FLAGS must be set first)


@pytest.fixture
def compile_counts():
    """Shared jit compile counter for the no-recompile suites.

    Returns ``counts(*fns) -> List[int]``: the per-function jit cache
    sizes, read through the private ``_cache_size`` introspection hook.
    On a jax build without the hook the calling test skips (one message,
    one place) instead of every suite carrying its own hasattr guard.

    The canonical pins (see docs/testing.md):

    - one compile per (family, phase): a single-bucket trace leaves
      every engine phase closure (prefill / insert / decode) at cache
      size 1 — the scan-over-layers forwards trace the block once per
      phase, never per layer;
    - warm == rerun: repeating an already-served workload adds zero
      compilations.
    """
    def counts(*fns):
        if not all(hasattr(f, "_cache_size") for f in fns):
            pytest.skip("jax version without jit _cache_size introspection")
        return [f._cache_size() for f in fns]
    return counts
