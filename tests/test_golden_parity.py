"""Golden-token regression: every execution variant vs ONE pinned output.

Each family has a fixture under ``tests/golden/`` holding a tiny
deterministic case — init seed, prompts, side-input seed — plus the
greedy outputs it produced when the fixture was generated (CPU,
float32). Every execution variant of the same math must reproduce
those tokens EXACTLY:

- the scan-over-layers serving path (the default),
- the unrolled ``scan_layers=False`` oracle (Python loop over the same
  stacked params),
- the mesh-sharded engine (data axis; expert axis for MoE),
- the psq-packed engine with the ternary sparsity skip on AND off
  (pinned separately as ``outputs_psq`` — packed weights are a
  different model than fp32).

A variant comparing equal to the golden is a much stronger statement
than pairwise A==B checks: a regression in the SHARED path (e.g. the
block math itself) moves every variant together and pairwise parity
would still pass. See docs/testing.md.

Regenerate after an intentional numerics change:

    PYTHONPATH=src python tests/test_golden_parity.py --regen

and commit the diff — the review question becomes "should these tokens
have changed?".
"""
import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core.config import PSQ_TERNARY
from repro.models import init_model
from repro.serve import (
    EngineConfig, PackedModelCache, ServeEngine, pack_tree_psq,
)

GOLDEN_DIR = Path(__file__).parent / "golden"

# one arch per family; psq-packed goldens for the families the packed
# serving suites run end to end (dense + moe covers both FFN shapes)
ARCHS = ("tinyllama-1.1b", "granite-moe-3b-a800m", "zamba2-7b",
         "xlstm-350m", "whisper-large-v3", "llava-next-mistral-7b")
PSQ_ARCHS = ("tinyllama-1.1b", "granite-moe-3b-a800m")
# side-input families: continuous admission scatters per-slot
# enc-cross-KV / patch pools — pinned against the same golden as the
# static oracle and one-at-a-time decoding
SIDE_ARCHS = ("whisper-large-v3", "llava-next-mistral-7b")
# pure KV-cache families: speculative decoding must reproduce the
# vanilla golden token for token at every spec_k
SPEC_ARCHS = ("tinyllama-1.1b", "granite-moe-3b-a800m",
              "whisper-large-v3", "llava-next-mistral-7b")

MAX_LEN = 48
MAX_NEW = 6
N_REQ = 3


def _load(arch):
    path = GOLDEN_DIR / f"{arch}.json"
    with open(path) as f:
        return json.load(f)


def _case_prompts(case):
    return [np.asarray(p, dtype=np.int32) for p in case["prompts"]]


def _extra_inputs(cfg, case):
    """Side inputs regenerated from the pinned seed (not stored raw —
    a float tensor in JSON would dwarf the tokens it pins)."""
    rng = np.random.RandomState(case["extra_seed"])
    if cfg.family == "encdec":
        return {"enc_embeds": (rng.randn(N_REQ, 8, cfg.d_model)
                               * 0.1).astype(np.float32)}
    if cfg.family == "vlm":
        return {"patch_embeds": (rng.randn(N_REQ, cfg.frontend_len,
                                           cfg.d_model)
                                 * 0.1).astype(np.float32)}
    return {}


def _serve(cfg, params, case, mesh=None, mode="auto", max_batch=N_REQ,
           spec_k=0, draft=None):
    dcfg, dparams = draft if draft is not None else (None, None)
    eng = ServeEngine(params, cfg,
                      EngineConfig(max_batch=max_batch, max_len=MAX_LEN,
                                   mode=mode, spec_k=spec_k,
                                   draft_config=dcfg),
                      extra_inputs=_extra_inputs(cfg, case), mesh=mesh,
                      draft_params=dparams)
    for i, p in enumerate(_case_prompts(case)):
        eng.submit(p, max_new_tokens=MAX_NEW, extra_idx=i)
    done = {r.uid: r.output for r in eng.run()}
    return [done[uid] for uid in sorted(done)]


def _draft_model(cfg):
    """Tiny same-family draft: a 1-layer copy of the served config.

    Randomly initialized, so its proposals rarely match — which makes
    the golden check strict: acceptance, rejection and rollback all
    exercise on every trace, and the output STILL must be the vanilla
    golden."""
    dcfg = dataclasses.replace(cfg, n_layers=1)
    return dcfg, init_model(jax.random.PRNGKey(1), dcfg)


def _fp_model(arch):
    cfg = get_config(arch).reduced()
    return cfg, init_model(jax.random.PRNGKey(0), cfg)


def _psq_model(arch, sparsity_skip=True):
    cfg = get_config(arch).reduced()
    qcfg = dataclasses.replace(PSQ_TERNARY, kernel_backend="reference",
                               xbar_rows=64, sparsity_skip=sparsity_skip)
    cfg = cfg.with_quant(qcfg)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, pack_tree_psq(params, qcfg, PackedModelCache())


class TestGoldenParity:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_scan_path_matches_golden(self, arch):
        case = _load(arch)
        cfg, params = _fp_model(arch)
        assert _serve(cfg, params, case) == case["outputs"], \
            f"{arch}: scan-path greedy outputs drifted from the golden"

    @pytest.mark.parametrize("arch", ARCHS)
    def test_unrolled_loop_matches_golden(self, arch):
        """scan_layers=False: same stacked params, Python loop instead
        of lax.scan — bit-exact under jit, so the SAME golden."""
        case = _load(arch)
        cfg, params = _fp_model(arch)
        cfg = dataclasses.replace(cfg, scan_layers=False)
        assert _serve(cfg, params, case) == case["outputs"], \
            f"{arch}: unrolled layer loop diverged from the golden"

    @pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
    @pytest.mark.parametrize("arch", ARCHS)
    def test_data_sharded_matches_golden(self, arch):
        case = _load(arch)
        cfg, params = _fp_model(arch)
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        assert _serve(cfg, params, case, mesh=mesh) == case["outputs"], \
            f"{arch}: data-sharded engine diverged from the golden"

    @pytest.mark.skipif(len(jax.devices()) < 4, reason="needs >= 4 devices")
    def test_moe_expert_parallel_matches_golden(self):
        arch = "granite-moe-3b-a800m"
        case = _load(arch)
        cfg, params = _fp_model(arch)
        mesh = jax.make_mesh((1, 1, 4), ("data", "model", "expert"))
        assert _serve(cfg, params, case, mesh=mesh) == case["outputs"], \
            "expert-parallel MoE serving diverged from the golden"

    @pytest.mark.parametrize("arch", SIDE_ARCHS)
    @pytest.mark.parametrize("mode,mb", [("continuous", N_REQ),
                                         ("static", N_REQ),
                                         ("continuous", 1)],
                             ids=("continuous", "static", "sequential"))
    def test_side_input_modes_match_golden(self, arch, mode, mb):
        """encdec/VLM-with-patches on the continuous slot pool, the
        static oracle loop and one-at-a-time decoding all reproduce the
        same golden: per-slot side-input pools are bit-exact."""
        case = _load(arch)
        cfg, params = _fp_model(arch)
        assert _serve(cfg, params, case, mode=mode,
                      max_batch=mb) == case["outputs"], \
            f"{arch}: {mode} (batch {mb}) diverged from the golden"

    @pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
    @pytest.mark.parametrize("arch", SIDE_ARCHS)
    def test_side_input_continuous_2way_data_mesh(self, arch):
        """The per-slot side-input pools shard over ``data`` like every
        other cache leaf: 2-way data-parallel continuous serving stays
        on the golden."""
        case = _load(arch)
        cfg, params = _fp_model(arch)
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        assert _serve(cfg, params, case, mesh=mesh,
                      mode="continuous") == case["outputs"], \
            f"{arch}: 2-way data-sharded continuous diverged"

    @pytest.mark.parametrize("arch", SPEC_ARCHS)
    @pytest.mark.parametrize("spec_k", (2, 4))
    def test_spec_decode_matches_golden(self, arch, spec_k):
        """Speculative decoding is token-identical to vanilla greedy by
        construction — every emitted token is a main-model argmax at the
        same cache state — so the ONE golden pins it at every spec_k."""
        case = _load(arch)
        cfg, params = _fp_model(arch)
        assert _serve(cfg, params, case, spec_k=spec_k,
                      draft=_draft_model(cfg)) == case["outputs"], \
            f"{arch}: spec decode (k={spec_k}) diverged from the golden"

    @pytest.mark.parametrize("arch", PSQ_ARCHS)
    @pytest.mark.parametrize("skip", (True, False))
    def test_psq_sparsity_skip_matches_golden(self, arch, skip):
        """The ternary sparsity skip is an execution shortcut, not a
        numerics change: skip on and off both reproduce outputs_psq."""
        case = _load(arch)
        cfg, params = _psq_model(arch, sparsity_skip=skip)
        assert _serve(cfg, params, case) == case["outputs_psq"], \
            f"{arch}: psq serving (sparsity_skip={skip}) drifted"


def main():
    GOLDEN_DIR.mkdir(exist_ok=True)
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        rng = np.random.RandomState(11)
        case = {
            "arch": arch,
            "family": cfg.family,
            "init_seed": 0,
            "extra_seed": 7,
            "max_new_tokens": MAX_NEW,
            "prompts": [
                rng.randint(0, cfg.vocab_size,
                            size=int(rng.randint(4, 13))).tolist()
                for _ in range(N_REQ)
            ],
        }
        cfg, params = _fp_model(arch)
        case["outputs"] = _serve(cfg, params, case)
        if arch in PSQ_ARCHS:
            qcfg, qparams = _psq_model(arch)
            case["outputs_psq"] = _serve(qcfg, qparams, case)
        path = GOLDEN_DIR / f"{arch}.json"
        with open(path, "w") as f:
            json.dump(case, f, indent=1)
            f.write("\n")
        print(f"[golden] wrote {path}")


if __name__ == "__main__":
    import sys

    if "--regen" not in sys.argv:
        sys.exit("usage: PYTHONPATH=src python tests/test_golden_parity.py "
                 "--regen")
    main()
