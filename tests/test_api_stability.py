"""Public-API stability: imports, stats() keys, pinned jit closures.

The engine decomposition (scheduler / state / executor behind the
``ServeEngine`` facade) must not move or rename anything callers use:
every public import path resolves, ``stats()`` keeps its key set, and
the jit closures the compile-count suite introspects keep their names
and their per-instance ``_cache_size`` hook."""
import importlib

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.serve import EngineConfig, ServeEngine

jax.config.update("jax_platform_name", "cpu")

# every name importable from repro.serve before the decomposition,
# plus the scheduler/state layer names the decomposition added
PUBLIC_API = {
    "repro.serve": [
        "PackedLayer", "PackedModelCache", "pack_tree_psq",
        "ServeEngine", "throughput_stats",
        "BlockPool", "PagedKVManager", "PoolExhausted",
        "RadixPrefixIndex",
        "ADMISSION_POLICIES", "AdmissionPolicy", "CostAwareEnergyBudget",
        "EnergyModel", "EngineConfig", "Pow2BucketFCFS", "Request",
        "resolve_admission_policy",
        "ContiguousSlotState", "PagedSlotState", "SlotState",
    ],
    "repro.serve.engine": ["ServeEngine", "throughput_stats"],
    "repro.serve.scheduler": ["EngineConfig", "Request", "next_pow2"],
    "repro.serve.cache": ["PackedLayer", "pack_tree_psq"],
    "repro.serve.paged_kv": ["PagedKVManager", "PoolExhausted"],
    "repro.launch.serve": ["StreamingFrontend"],
}

# the stats() key set before the decomposition — supersets are fine,
# removals/renames are not
STATS_KEYS = {
    "mode", "decode_steps", "host_syncs", "decode_wall_s", "mean_step_s",
    "prefill_calls", "prefill_tokens", "cached_prefix_tokens",
    "mean_slot_occupancy", "admissions", "mesh",
    "energy_style", "energy_tokens", "energy_pj_per_token",
    "energy_pj_total", "energy_pj_per_request", "edap_total",
    "mean_occupancy",
}

# jit closures tests/benchmarks introspect by name (compile counts)
PINNED_CLOSURES = ["_prefill_full", "_prefill_bucket", "_decode",
                   "_insert", "_decode_multi"]
PINNED_PAGED = ["_decode_paged", "_insert_paged", "_prefill_suffix",
                "_copy_page", "_decode_multi_paged"]


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_public_imports_resolve():
    for module, names in PUBLIC_API.items():
        mod = importlib.import_module(module)
        for name in names:
            assert hasattr(mod, name), f"{module}.{name} is gone"


def test_engine_config_defaults_are_compatible():
    """New knobs must default to the old behavior."""
    ecfg = EngineConfig()
    assert ecfg.admission_policy == "fcfs"
    assert ecfg.energy_budget_pj == 0.0
    assert ecfg.mode == "auto"


def test_stats_keys_and_pinned_closures(tiny):
    cfg, params = tiny
    eng = ServeEngine(params, cfg, EngineConfig(max_batch=2, max_len=64))
    eng.submit(np.arange(4), max_new_tokens=2)
    eng.run()
    s = eng.stats()
    missing = STATS_KEYS - set(s)
    assert not missing, f"stats() lost keys: {sorted(missing)}"
    assert s["admission_policy"] == "fcfs"
    for name in PINNED_CLOSURES:
        fn = getattr(eng, name)
        assert callable(fn), name
        if hasattr(fn, "_cache_size"):
            assert fn._cache_size() >= 0


def test_paged_engine_pinned_closures(tiny):
    cfg, params = tiny
    eng = ServeEngine(params, cfg,
                      EngineConfig(max_batch=2, max_len=64, paged=True,
                                   block_size=16))
    for name in PINNED_CLOSURES + PINNED_PAGED:
        assert callable(getattr(eng, name)), name
    assert "paged" in eng.stats()


def test_engine_attributes_survive(tiny):
    """Non-closure attributes external code reads off the engine."""
    cfg, params = tiny
    eng = ServeEngine(params, cfg, EngineConfig(max_batch=2, max_len=64))
    for attr in ("mode", "queue", "finished", "energy", "policy",
                 "state", "admitter", "executor", "energy_tokens",
                 "drained", "mesh"):
        assert hasattr(eng, attr), attr
    assert eng.mode == "continuous"
    assert eng.drained
