"""Tests for the PSQ crossbar matmul (paper §4 semantics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    QuantConfig,
    adc_baseline,
    apply_linear,
    init_linear,
)
from repro.core.psq import (
    num_tiles,
    psq_matmul,
    psq_matmul_dequant_reference,
)

jax.config.update("jax_platform_name", "cpu")
KEY = jax.random.PRNGKey(0)


def _params_and_x(K, O, cfg, bsz=4, seed=0):
    key = jax.random.PRNGKey(seed)
    p = init_linear(key, K, O, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (bsz, K))
    return p, x


class TestExactness:
    @pytest.mark.parametrize("rows", [64, 128])
    def test_ideal_adc_equals_integer_matmul(self, rows):
        """A lossless ADC reduces the crossbar pipeline to plain x_q @ w_q."""
        cfg = adc_baseline(bits=8, xbar_rows=rows)
        p, x = _params_and_x(200, 33, cfg)
        y, _ = apply_linear(p, x, cfg)
        spec = cfg.spec
        xi = jnp.round(jnp.clip(x / p["step_x"], spec.a_qn, spec.a_qp))
        wi = jnp.round(jnp.clip(p["w"] / p["step_w"], spec.w_qn, spec.w_qp))
        y_true = (xi @ wi) * p["step_x"] * p["step_w"]
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_true), atol=1e-4)

    def test_adc_precision_ladder_monotone(self):
        """Lower ADC precision -> larger quantization error (Table 2 trend)."""
        errs = []
        for bits in [8, 7, 6, 4, 2]:
            cfg = adc_baseline(bits=bits, xbar_rows=128)
            p, x = _params_and_x(256, 64, cfg, bsz=16)
            y, _ = apply_linear(p, x, cfg)
            cfg_hi = adc_baseline(bits=10, xbar_rows=128)
            y_hi, _ = apply_linear(p, x, cfg_hi)
            errs.append(float(jnp.mean((y - y_hi) ** 2)))
        assert errs == sorted(errs), errs

    def test_smaller_crossbar_less_severe_quantization(self):
        """64-row crossbars quantize less severely than 128 (paper §5.2)."""
        mses = {}
        for rows in [64, 128]:
            cfg = QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=rows)
            p, x = _params_and_x(256, 64, cfg, bsz=16)
            y, _ = apply_linear(p, x, cfg)
            y_ref, _ = apply_linear(
                {k: v for k, v in p.items() if k in ("w", "step_x", "step_w")},
                x,
                adc_baseline(bits=10, xbar_rows=rows),
            )
            mses[rows] = float(jnp.mean((y - y_ref) ** 2))
        # with everything at init (untrained SFs) the trend still holds
        assert mses[64] < mses[128] * 1.5


class TestReferenceAgreement:
    @pytest.mark.parametrize("levels", ["ternary", "binary"])
    @pytest.mark.parametrize(
        "gran", ["column", "per_stream", "per_tile", "per_layer"]
    )
    def test_fast_path_matches_materialized_reference(self, levels, gran):
        cfg = QuantConfig(
            mode="psq", psq_levels=levels, xbar_rows=64, sf_granularity=gran
        )
        p, x = _params_and_x(200, 17, cfg)
        y1, _ = psq_matmul(x, p["w"], p, cfg)
        y2 = psq_matmul_dequant_reference(x, p["w"], p, cfg)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)

    @given(
        k=st.integers(10, 300),
        o=st.integers(1, 40),
        rows=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_fast_matches_reference(self, k, o, rows, seed):
        cfg = QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=rows)
        p, x = _params_and_x(k, o, cfg, bsz=2, seed=seed)
        y1, _ = psq_matmul(x, p["w"], p, cfg)
        y2 = psq_matmul_dequant_reference(x, p["w"], p, cfg)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-3)


class TestStructure:
    def test_num_tiles(self):
        assert num_tiles(128, 128) == 1
        assert num_tiles(129, 128) == 2
        assert num_tiles(4096, 128) == 32

    def test_sf_counts_match_eq2(self):
        """Eq. 2: #SF per crossbar = input_precision/bit_stream * #columns."""
        cfg = QuantConfig(mode="psq")
        # config A of Table 1: 128x128 crossbar, 4-bit w/a -> 4*128 SFs
        # per crossbar; a (128 x 32)-weight layer is exactly one crossbar.
        assert cfg.num_scale_factors(128, 32) == 4 * 128

    def test_batch_shape_preserved(self):
        cfg = QuantConfig(mode="psq")
        p = init_linear(KEY, 96, 24, cfg)
        x = jax.random.normal(KEY, (2, 3, 5, 96))
        y, _ = apply_linear(p, x, cfg)
        assert y.shape == (2, 3, 5, 24)

    def test_dense_mode_is_plain_matmul(self):
        cfg = QuantConfig(mode="none")
        p = init_linear(KEY, 64, 8, cfg, use_bias=True)
        x = jax.random.normal(KEY, (4, 64))
        y, _ = apply_linear(p, x, cfg)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(x @ p["w"] + p["b"]), rtol=1e-6
        )


class TestGradients:
    def test_all_params_get_finite_grads(self):
        cfg = QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=64)
        p, x = _params_and_x(200, 17, cfg)
        g = jax.grad(lambda pp: jnp.sum(apply_linear(pp, x, cfg)[0] ** 2))(p)
        for k, v in g.items():
            assert bool(jnp.all(jnp.isfinite(v))), k
        # weight + sf + alpha gradients must be non-trivial
        assert float(jnp.linalg.norm(g["w"])) > 0
        assert float(jnp.linalg.norm(g["sf"])) > 0

    def test_surrogate_gradient_matches_dense_direction(self):
        """STE gradient w.r.t. x should correlate with the dense gradient."""
        cfg = QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=64)
        p, x = _params_and_x(128, 32, cfg, bsz=8)
        tgt = jax.random.normal(KEY, (8, 32))

        def loss_q(x_):
            y, _ = apply_linear(p, x_, cfg)
            return jnp.mean((y - tgt) ** 2)

        def loss_d(x_):
            return jnp.mean((x_ @ p["w"] - tgt) ** 2)

        gq, gd = jax.grad(loss_q)(x), jax.grad(loss_d)(x)
        cos = jnp.sum(gq * gd) / (jnp.linalg.norm(gq) * jnp.linalg.norm(gd))
        # At init the scale factors are untrained so the residuals differ in
        # magnitude; we only require positive directional alignment here —
        # exact STE gradient agreement is covered by the kernel/reference
        # gradient tests.
        assert float(cos) > 0.05, float(cos)


class TestSparsityStats:
    def test_ternary_sparsity_at_init_matches_fig2c(self):
        """Fig 2(c): ~50% of ternary p values are zero at the operating
        point. At *init* (analytic alpha, untrained) the fraction lands
        0.25-0.6 depending on layer shape; QAT drives it toward ~0.5
        (examples/quickstart.py logs it converging to ~0.45)."""
        cfg = QuantConfig(
            mode="psq", psq_levels="ternary", xbar_rows=128, collect_stats=True
        )
        p, x = _params_and_x(512, 64, cfg, bsz=16)
        _, stats = apply_linear(p, x, cfg)
        assert 0.2 <= float(stats["p_zero_frac"]) <= 0.75

    def test_binary_has_no_zeros(self):
        cfg = QuantConfig(
            mode="psq", psq_levels="binary", xbar_rows=128, collect_stats=True
        )
        p, x = _params_and_x(256, 16, cfg)
        _, stats = apply_linear(p, x, cfg)
        assert stats == {} or float(stats.get("p_zero_frac", 0.0)) == 0.0

    def test_comparator_input_bounded_by_rows(self):
        cfg = QuantConfig(
            mode="psq", psq_levels="ternary", xbar_rows=64, collect_stats=True
        )
        p, x = _params_and_x(256, 16, cfg)
        _, stats = apply_linear(p, x, cfg)
        assert float(stats["comparator_in_max"]) <= 64.0
