"""Conformance suite: every registered kernel backend vs the core/psq.py
reference, plus the weight-stationary PackedLayer serving cache.

Accuracy in the HCiM pipeline hinges on exact scale-factor / partial-sum
arithmetic (see PAPERS.md: arXiv:2502.07842, arXiv:2505.07490), so
backends must stay bit-exact against the jnp reference while we optimize.
The grid deliberately includes K not divisible by ``xbar_rows`` and M/N
not divisible by the Pallas block sizes, both comparator levels, the ADC
baseline, and the fused-bit-plane MXU variant.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import psq
from repro.core.config import QuantConfig
from repro.core.psq_linear import apply_linear, init_linear
from repro.kernels import ops, registry
from repro.kernels.int4_matmul import pack_int4
from repro.kernels.ref import int4_matmul_ref, psq_matmul_ref
from repro.serve import cache as serve_cache

jax.config.update("jax_platform_name", "cpu")

BACKENDS = registry.registered_backends()

# (B, K, O, R): ragged K vs xbar_rows, ragged B vs block_b (8), ragged O
# vs block_o (128), single-tile, gemv-like, small crossbar.
SHAPES = [
    (4, 200, 17, 64),     # K % R != 0, O % 128 != 0
    (16, 256, 130, 128),  # multi-tile, O % 128 != 0
    (3, 64, 64, 64),      # single tile, B % 8 != 0
    (1, 128, 256, 128),   # gemv-like decode shape
    (9, 300, 40, 32),     # small crossbar, everything ragged
]


def _backend_or_skip(name):
    try:
        return registry.get_backend(name)
    except RuntimeError as e:
        pytest.skip(str(e))


def _int_inputs(B, K, O, R, n_a=4, n_w=4, seed=0):
    T = math.ceil(K / R)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    lo_a, hi_a = -(2 ** (n_a - 1)), 2 ** (n_a - 1) - 1
    lo_w, hi_w = -(2 ** (n_w - 1)), 2 ** (n_w - 1) - 1
    x = jnp.round(jax.random.uniform(k1, (B, K), minval=lo_a, maxval=hi_a))
    w = jnp.round(jax.random.uniform(k2, (K, O), minval=lo_w, maxval=hi_w))
    sf = jnp.round(jax.random.uniform(k3, (T, n_a, n_w, O), maxval=15)) * 0.5
    return x, w, sf


class TestIntegerLevelParity:
    """Backend contract vs the bit-exact jnp oracle, integer I/O."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("levels", ["ternary", "binary", "adc"])
    @pytest.mark.parametrize("shape", SHAPES)
    def test_psq_matmul(self, backend, levels, shape):
        impl = _backend_or_skip(backend)
        B, K, O, R = shape
        x, w, sf = _int_inputs(B, K, O, R)
        alpha = jnp.array(5.0)
        kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=4, xbar_rows=R)
        y = impl.psq_matmul(x, w, sf, alpha, **kw)
        y_ref = psq_matmul_ref(x, w, sf, alpha, **kw)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-3)

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("levels", ["ternary", "binary"])
    def test_fuse_planes_identical(self, backend, levels):
        impl = _backend_or_skip(backend)
        B, K, O, R = 8, 256, 96, 128
        x, w, sf = _int_inputs(B, K, O, R)
        alpha = jnp.array(4.0)
        kw = dict(n_a=4, n_w=4, levels=levels, adc_bits=4, xbar_rows=R)
        y_loop = impl.psq_matmul(x, w, sf, alpha, fuse_planes=False, **kw)
        y_fused = impl.psq_matmul(x, w, sf, alpha, fuse_planes=True, **kw)
        np.testing.assert_array_equal(np.asarray(y_loop), np.asarray(y_fused))

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("shape", [(4, 200, 17), (16, 256, 130),
                                       (1, 128, 256)])
    def test_int4_matmul(self, backend, shape):
        impl = _backend_or_skip(backend)
        B, K, O = shape
        # activations on a 1/16 grid with |x| < 8: exactly representable
        # in bf16, so the kernel's MXU dot is exact and parity is bitwise
        x = jnp.round(
            jax.random.uniform(jax.random.PRNGKey(1), (B, K),
                               minval=-8, maxval=8) * 16
        ) / 16
        w_int = jnp.round(
            jax.random.uniform(jax.random.PRNGKey(2), (K, O),
                               minval=-8, maxval=7)
        )
        packed = pack_int4(w_int)
        scale = jax.random.uniform(jax.random.PRNGKey(3), (O,)) + 0.1
        y = impl.int4_matmul(x, packed, scale)
        y_ref = int4_matmul_ref(packed, scale, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-4)


class TestQATLevelParity:
    """ops.psq_matmul (registry-dispatched) vs core/psq.py, LSQ included."""

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("levels", ["ternary", "binary"])
    @pytest.mark.parametrize("shape", [(5, 200, 17, 64), (3, 64, 33, 64)])
    def test_matches_jnp_reference(self, backend, levels, shape):
        _backend_or_skip(backend)
        B, K, O, R = shape
        cfg = QuantConfig(mode="psq", psq_levels=levels, xbar_rows=R,
                          kernel_backend=backend)
        p = init_linear(jax.random.PRNGKey(0), K, O, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, K))
        y_ref, _ = psq.psq_matmul(x, p["w"], p, cfg)
        y_kernel, _ = ops.psq_matmul(x, p["w"], p, cfg)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-5)
        y_oracle = psq.psq_matmul_dequant_reference(x, p["w"], p, cfg)
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_oracle),
                                   atol=1e-4, rtol=1e-5)

    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, backend, dtype):
        _backend_or_skip(backend)
        cfg = QuantConfig(mode="psq", xbar_rows=64, kernel_backend=backend)
        p = init_linear(jax.random.PRNGKey(0), 128, 48, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 128)).astype(dtype)
        y_ref, _ = psq.psq_matmul(x, p["w"], p, cfg)
        y_kernel, _ = ops.psq_matmul(x, p["w"], p, cfg)
        np.testing.assert_allclose(
            np.asarray(y_kernel, np.float32), np.asarray(y_ref, np.float32),
            atol=1e-3, rtol=1e-3,
        )


class TestRegistry:
    def test_unknown_backend_raises(self):
        with pytest.raises(KeyError, match="registered"):
            registry.get_backend("no-such-backend")

    def test_unavailable_backend_raises_or_resolves(self):
        impl = registry._REGISTRY["pallas"]
        if jax.default_backend() == "cpu":
            assert "pallas" not in registry.available_backends()
            with pytest.raises(RuntimeError, match="not.*available"):
                registry.get_backend("pallas")
        else:
            assert impl.is_available()

    def test_reference_always_available(self):
        avail = registry.available_backends()
        assert "reference" in avail and "pallas-interpret" in avail

    def test_env_var_overrides_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "reference")
        assert registry.default_backend() == "reference"
        assert registry.get_backend(None).name == "reference"

    def test_set_default_backend(self):
        old = registry.default_backend()
        try:
            registry.set_default_backend("reference")
            assert registry.default_backend() == "reference"
        finally:
            registry.set_default_backend(old)
        with pytest.raises(KeyError):
            registry.set_default_backend("no-such-backend")

    def test_config_kernel_path_property(self):
        assert not QuantConfig(mode="psq").kernel_path
        assert QuantConfig(mode="psq", use_kernel=True).kernel_path
        assert QuantConfig(mode="psq", kernel_backend="reference").kernel_path


class TestPackedLayerCache:
    CFG = QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=64,
                      kernel_backend="reference")

    def _layer(self, K=200, O=33, bias=True):
        return init_linear(jax.random.PRNGKey(0), K, O, self.CFG,
                           use_bias=bias)

    def test_identical_to_uncached_path(self):
        p = self._layer()
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 200))
        y_uncached, _ = apply_linear(p, x, self.CFG)
        packed = serve_cache.PackedLayer.pack(p, self.CFG)
        y_packed, _ = packed.apply_serving(x)
        np.testing.assert_array_equal(np.asarray(y_packed),
                                      np.asarray(y_uncached))
        # and through apply_linear's duck-typed dispatch
        y_dispatch, _ = apply_linear(packed, x, self.CFG)
        np.testing.assert_array_equal(np.asarray(y_dispatch),
                                      np.asarray(y_packed))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_identical_across_backends(self, backend):
        _backend_or_skip(backend)
        cfg = dataclasses.replace(self.CFG, kernel_backend=backend)
        p = self._layer(bias=False)
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 200))
        y_ref, _ = psq.psq_matmul(x, p["w"], p, cfg)
        y_packed, _ = serve_cache.PackedLayer.pack(p, cfg).apply_serving(x)
        np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-5)

    def test_not_repacked_across_calls(self):
        p = self._layer()
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 200))
        packed = serve_cache.PackedLayer.pack(p, self.CFG)
        before = serve_cache.PACK_EVENTS
        for _ in range(4):
            packed.apply_serving(x)
            packed.apply_int4(x)
        assert serve_cache.PACK_EVENTS == before, \
            "serving calls must not re-quantize/re-pack cached state"

    def test_model_cache_counts_packs_and_hits(self):
        p = self._layer()
        tree = {"blocks": [{"attn": {"wq": p}}, {"mlp": {"fc": p}}],
                "final_norm": {"scale": jnp.ones((8,))}}
        cache = serve_cache.PackedModelCache()
        t1 = serve_cache.pack_tree_psq(tree, self.CFG, cache)
        assert cache.stats() == {"layers": 2, "packs": 2, "hits": 0}
        t2 = serve_cache.pack_tree_psq(tree, self.CFG, cache)
        assert cache.stats() == {"layers": 2, "packs": 2, "hits": 2}
        # reused objects, not re-derived ones
        assert t1["blocks"][0]["attn"]["wq"] is t2["blocks"][0]["attn"]["wq"]
        # non-linear leaves untouched
        np.testing.assert_array_equal(
            np.asarray(t1["final_norm"]["scale"]), np.ones((8,)))

    def test_reloaded_weights_repack_not_stale(self):
        """Same path, different weights: the cache must re-pack, never
        serve the old model's packed state."""
        p1 = self._layer()
        p2 = {**p1, "w": p1["w"] + 1.0}
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 200))
        cache = serve_cache.PackedModelCache()
        tree1 = serve_cache.pack_tree_psq({"lin": p1}, self.CFG, cache)
        tree2 = serve_cache.pack_tree_psq({"lin": p2}, self.CFG, cache)
        assert cache.packs == 2 and cache.hits == 0
        y2, _ = tree2["lin"].apply_serving(x)
        y2_ref, _ = apply_linear(p2, x, self.CFG)
        np.testing.assert_array_equal(np.asarray(y2), np.asarray(y2_ref))
        # unchanged weights still hit
        serve_cache.pack_tree_psq({"lin": p2}, self.CFG, cache)
        assert cache.hits == 1

    def test_stacked_layers_pack_and_scan(self):
        """vmapped pack keeps the leading layer axis lax.scan slices."""
        n_layers, K = 3, 64
        cfg = self.CFG
        stacked = jax.vmap(
            lambda k: init_linear(k, K, K, cfg)
        )(jax.random.split(jax.random.PRNGKey(0), n_layers))
        packed = serve_cache.pack_tree_psq({"lin": stacked}, cfg)["lin"]
        assert packed.w_codes.shape == (n_layers, K, K)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, K))

        def body(x, layer):
            y, _ = apply_linear(layer, x, cfg)
            return jnp.tanh(y), None

        y_scan, _ = jax.lax.scan(body, x, packed)
        # reference: apply each layer's uncached path in sequence
        y_ref = x
        for i in range(n_layers):
            lp = jax.tree.map(lambda a: a[i], stacked)
            y, _ = apply_linear(lp, y_ref, cfg)
            y_ref = jnp.tanh(y)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_ref),
                                   atol=1e-5)

    def test_warm_calls_faster_than_packing(self):
        """Re-packing every call must cost more than cached serving."""
        import time

        K, O = 512, 256
        p = init_linear(jax.random.PRNGKey(0), K, O, self.CFG)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, K))
        packed = serve_cache.PackedLayer.pack(p, self.CFG)
        f = jax.jit(lambda layer, x: layer.apply_serving(x)[0])
        jax.block_until_ready(f(packed, x))  # warm-up: compile once

        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(packed, x))
        warm = time.perf_counter() - t0

        t0 = time.perf_counter()
        for _ in range(5):
            repacked = serve_cache.PackedLayer.pack(p, self.CFG)
            jax.block_until_ready(f(repacked, x))
        cold = time.perf_counter() - t0
        assert warm < cold, (warm, cold)
