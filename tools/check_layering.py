#!/usr/bin/env python
"""Layering check for the serving stack (CI docs job).

Three static guarantees, no imports executed (pure ``ast``):

1. **No import cycles** anywhere in ``repro`` — the module-level
   import graph must be a DAG. Deferred (function-body) imports are
   ignored: they cannot cycle at import time, and the serving layers
   use them deliberately (e.g. the bench imports the launcher's
   streaming front-end lazily).

2. **Serve-layer ordering** — the engine decomposition
   (docs/architecture.md) assigns each ``repro.serve`` module a layer:
   ``paged_kv``/``cache`` (leaves) < ``scheduler`` (decisions) <
   ``state`` (placement) < ``executor`` (execution) < ``engine``
   (facade) < ``__init__``. A module may only import serve modules
   from a strictly lower layer — so scheduling can never grow a
   dependency on execution, and the facade stays the only place the
   layers meet.

3. **Module-size budget** — no file under ``src/repro/serve/`` may
   exceed 900 lines, and the facade ``engine.py`` must stay at or
   under 500: growth has to land in the layer that owns it, not
   accrete back onto the engine.

    python tools/check_layering.py [--root src/repro]
"""
from __future__ import annotations

import argparse
import ast
import sys
from pathlib import Path
from typing import Dict, List, Optional, Set

SERVE_LAYERS: Dict[str, int] = {
    "repro.serve.paged_kv": 0,
    "repro.serve.cache": 0,
    "repro.serve.scheduler": 1,
    "repro.serve.state": 2,
    "repro.serve.executor": 3,
    "repro.serve.engine": 4,
    "repro.serve": 5,          # the package __init__ re-exports
}
SERVE_SIZE_BUDGET = 900        # lines, every src/repro/serve/*.py
ENGINE_SIZE_BUDGET = 500       # lines, the facade specifically


def module_name(py: Path, root: Path) -> str:
    rel = py.relative_to(root.parent).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def build_graph(root: Path) -> Dict[str, Set[str]]:
    """Module-level ``repro.*`` import graph (deferred imports excluded).

    ``from repro.x import y`` depends on the submodule ``repro.x.y``
    when one exists, else on the module ``repro.x`` itself — so a
    package ``__init__`` re-exporting its submodules is a parent of
    them, not a cycle with them.
    """
    mods: Dict[str, Path] = {module_name(p, root): p
                             for p in sorted(root.rglob("*.py"))}
    graph: Dict[str, Set[str]] = {}
    for name, path in mods.items():
        deps: Set[str] = set()
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in tree.body:
            if isinstance(node, ast.Import):
                deps.update(a.name for a in node.names
                            if a.name in mods)
            elif (isinstance(node, ast.ImportFrom) and node.module
                  and node.module.startswith("repro")):
                for a in node.names:
                    sub = f"{node.module}.{a.name}"
                    if sub in mods:
                        deps.add(sub)
                    elif node.module in mods:
                        deps.add(node.module)
        graph[name] = deps - {name}
    return graph


def find_cycle(graph: Dict[str, Set[str]]) -> Optional[List[str]]:
    """First cycle in the import graph (DFS three-color), or None."""
    WHITE, GREY, BLACK = 0, 1, 2
    color = {m: WHITE for m in graph}
    stack: List[str] = []

    def visit(m: str) -> Optional[List[str]]:
        color[m] = GREY
        stack.append(m)
        for dep in sorted(graph.get(m, ())):
            if color.get(dep, BLACK) == GREY:
                return stack[stack.index(dep):] + [dep]
            if color.get(dep, BLACK) == WHITE:
                cyc = visit(dep)
                if cyc:
                    return cyc
        stack.pop()
        color[m] = BLACK
        return None

    for m in sorted(graph):
        if color[m] == WHITE:
            cyc = visit(m)
            if cyc:
                return cyc
    return None


def check_serve_layers(graph: Dict[str, Set[str]]) -> List[str]:
    errs: List[str] = []
    for mod, deps in sorted(graph.items()):
        if mod not in SERVE_LAYERS:
            continue
        for dep in sorted(deps):
            if dep in SERVE_LAYERS and SERVE_LAYERS[dep] >= SERVE_LAYERS[mod]:
                errs.append(
                    f"{mod} (layer {SERVE_LAYERS[mod]}) imports {dep} "
                    f"(layer {SERVE_LAYERS[dep]}): serve modules may only "
                    f"import strictly lower layers"
                )
    return errs


def check_sizes(root: Path) -> List[str]:
    errs: List[str] = []
    for py in sorted((root / "serve").rglob("*.py")):
        n = len(py.read_text().splitlines())
        budget = (ENGINE_SIZE_BUDGET if py.name == "engine.py"
                  else SERVE_SIZE_BUDGET)
        if n > budget:
            errs.append(f"{py}: {n} lines exceeds the "
                        f"{budget}-line budget")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default="src/repro",
                    help="package root to scan")
    args = ap.parse_args()
    root = Path(args.root)
    if not root.is_dir():
        raise SystemExit(f"not a directory: {root}")

    graph = build_graph(root)
    errs: List[str] = []
    cyc = find_cycle(graph)
    if cyc:
        errs.append("import cycle: " + " -> ".join(cyc))
    errs.extend(check_serve_layers(graph))
    errs.extend(check_sizes(root))

    if errs:
        for e in errs:
            print(f"[check_layering] FAIL {e}")
        return 1
    n_serve = sum(1 for m in graph if m in SERVE_LAYERS)
    print(f"[check_layering] ok: {len(graph)} modules acyclic, "
          f"{n_serve} serve modules layered, sizes within budget")
    return 0


if __name__ == "__main__":
    sys.exit(main())
