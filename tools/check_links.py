#!/usr/bin/env python
"""Markdown link check (offline): every relative link target must exist.

Scans the given markdown files/directories for inline links and images
``[text](target)`` and verifies that relative targets resolve to a real
file or directory (anchors are stripped; ``http(s)``/``mailto`` targets
are skipped — CI has no network). Exits non-zero listing every broken
link, so docs can't silently rot as files move.

    python tools/check_links.py README.md docs ROADMAP.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import Iterable, List, Tuple

# inline [text](target) / ![alt](target); target up to the first
# unescaped ')' — good enough for the plain links this repo uses
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def iter_md_files(args: Iterable[str]) -> List[Path]:
    files: List[Path] = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            files.append(p)
        else:
            raise SystemExit(f"not a markdown file or directory: {a}")
    return files


def check_file(md: Path) -> Tuple[List[Tuple[int, str]], int]:
    """Returns (broken links, number of relative links checked)."""
    broken: List[Tuple[int, str]] = []
    checked = 0
    in_code = False
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_code = not in_code
            continue
        if in_code:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            checked += 1
            if not (md.parent / path).exists():
                broken.append((lineno, target))
    return broken, checked


def main(argv: List[str]) -> int:
    files = iter_md_files(argv or ["README.md", "docs"])
    n_links = 0
    failures = 0
    for md in files:
        broken, checked = check_file(md)
        n_links += checked
        for lineno, target in broken:
            print(f"BROKEN {md}:{lineno}: {target}")
            failures += 1
    print(f"[check_links] {len(files)} files, {n_links} relative links "
          f"checked, {failures} broken")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
