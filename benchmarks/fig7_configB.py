"""Fig 7: same comparison with HCiM configuration B (64x64 crossbars)."""
from benchmarks.fig6_system import run as _run


def run(fast: bool = False):
    return _run(fast=fast, xbar_rows=64)


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
