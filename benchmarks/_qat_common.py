"""Shared QAT harness for the accuracy benchmarks (Table 2 / Fig 2).

Trains a small MLP classifier on the synthetic CIFAR-shaped task with
every layer routed through the PSQ crossbar matmul — the same
quantization pipeline the paper trains ResNet-20 with (real CIFAR-10 is
not available offline; DESIGN.md records that accuracy claims are
validated as *relative* trends).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QuantConfig, apply_linear, init_linear
from repro.data import ClassificationConfig, ClassificationStream

# CIFAR-shaped but reduced input dim (4 crossbar tiles at R=128) so the
# full 11-config accuracy ladder runs in CI time on one CPU core; the
# quantization-severity trends are dimension-independent.
DIM, HIDDEN, CLASSES = 512, 128, 10


def init_mlp(key: jax.Array, quant: QuantConfig) -> Dict:
    k1, k2 = jax.random.split(key)
    return {
        "l1": init_linear(k1, DIM, HIDDEN, quant, use_bias=True),
        "l2": init_linear(k2, HIDDEN, CLASSES, quant, use_bias=True),
    }


def mlp_logits(params: Dict, x: jax.Array, quant: QuantConfig) -> jax.Array:
    h, _ = apply_linear(params["l1"], x, quant)
    h = jax.nn.relu(h)
    y, _ = apply_linear(params["l2"], h, quant)
    return y


def train_qat(
    quant: QuantConfig, steps: int = 250, batch: int = 128,
    lr: float = 3e-3, seed: int = 0, noise: float = 0.35,
) -> float:
    """Returns held-out accuracy after Adam-based QAT."""
    stream = ClassificationStream(
        ClassificationConfig(seed=seed, train_noise=noise, dim=DIM)
    )
    params = init_mlp(jax.random.PRNGKey(seed), quant)
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, x, y):
        logits = mlp_logits(p, x, quant)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

    @jax.jit
    def step(p, mu, nu, i, x, y):
        g = jax.grad(loss_fn)(p, x, y)
        mu = jax.tree.map(lambda m, gg: 0.9 * m + 0.1 * gg, mu, g)
        nu = jax.tree.map(lambda v, gg: 0.999 * v + 0.001 * gg * gg, nu, g)
        bc1 = 1 - 0.9 ** (i + 1.0)
        bc2 = 1 - 0.999 ** (i + 1.0)
        p = jax.tree.map(
            lambda pp, m, v: pp - lr * (m / bc1) / (jnp.sqrt(v / bc2) + 1e-8),
            p, mu, nu,
        )
        return p, mu, nu

    for i in range(steps):
        x, y = stream.batch_at(i, batch)
        params, mu, nu = step(
            params, mu, nu, jnp.asarray(float(i)), jnp.asarray(x), jnp.asarray(y)
        )

    # held-out eval
    xs, ys = stream.batch_at(10_000, 2048)
    pred = jnp.argmax(mlp_logits(params, jnp.asarray(xs), quant), axis=-1)
    return float(jnp.mean(pred == jnp.asarray(ys)))
