"""Table 3: per-column latency/energy/area — DCiM array vs ADCs."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.hwmodel import (
    ADC_FLASH_4B, ADC_SAR_6B, ADC_SAR_7B, CONFIG_A, CONFIG_B, DCIM_A, DCIM_B,
    dcim_column_energy_pj, dcim_latency_per_column_ns,
)


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    t0 = time.time()
    for p, paper_lat, paper_e in [
        (ADC_SAR_7B, 1.52, 4.10), (ADC_SAR_6B, 0.15, 0.59),
        (ADC_FLASH_4B, 0.05, 1.86),
    ]:
        rows.append((f"table3/{p.name}", 0.0,
                     f"lat_ns={p.latency_ns},e_pj={p.energy_pj},"
                     f"area_mm2={p.area_mm2}"))
    for cfgname, geo, per in [("dcim_a", CONFIG_A, DCIM_A),
                              ("dcim_b", CONFIG_B, DCIM_B)]:
        lat = dcim_latency_per_column_ns(geo)
        e50 = dcim_column_energy_pj(0.5, per)
        rows.append((
            f"table3/{cfgname}", (time.time() - t0) * 1e6,
            f"lat_ns={lat:.3f},e_pj_50sp={e50:.3f},area_mm2={per.area_mm2},"
            f"e_ratio_vs_adc4={ADC_FLASH_4B.energy_pj / e50:.1f}x",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
