"""Figs 1/6: system-level energy + latency*area, HCiM config A vs ADC
baselines, all CIFAR workloads (normalized to HCiM ternary)."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.hwmodel import SystemConfig, WORKLOADS, evaluate_workload

STYLES = [
    ("adc7", dict(style="adc", adc_bits=7)),
    ("adc6", dict(style="adc", adc_bits=6)),
    ("adc4", dict(style="adc", adc_bits=4)),
    ("hcim_binary", dict(style="hcim", levels="binary")),
    ("hcim_ternary", dict(style="hcim", levels="ternary", sparsity=0.5)),
]
CIFAR_WORKLOADS = ["resnet20", "resnet32", "resnet44", "wrn20", "vgg9", "vgg11"]


def run(fast: bool = False, xbar_rows: int = 128) -> List[Tuple[str, float, str]]:
    rows = []
    fig = "fig6" if xbar_rows == 128 else "fig7"
    for wl in CIFAR_WORKLOADS:
        layers = WORKLOADS[wl]()
        t0 = time.time()
        res = {
            name: evaluate_workload(
                layers, SystemConfig(xbar_rows=xbar_rows, **kw)
            )
            for name, kw in STYLES
        }
        base = res["hcim_ternary"]
        us = (time.time() - t0) * 1e6 / len(STYLES)
        for name, t in res.items():
            rows.append((
                f"{fig}/{wl}/{name}", us,
                f"E_rel={t.energy_pj / base.energy_pj:.2f},"
                f"latxarea_rel={t.latency_area / base.latency_area:.2f},"
                f"E_uJ={t.energy_pj / 1e6:.1f}",
            ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
