"""Fig 2(d): accuracy vs number of scale factors.

Fewer scale factors (coarser granularity) -> lower accuracy; the paper
uses this to motivate keeping per-(stream x column) granularity and
processing it in the DCiM array instead of shrinking it.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import QuantConfig
from benchmarks._qat_common import train_qat


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    steps = 120 if fast else 250
    rows = []
    for gran in ["column", "per_stream", "per_tile", "per_layer"]:
        qc = QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=128,
                         sf_granularity=gran)
        t0 = time.time()
        acc = train_qat(qc, steps=steps)
        nsf = qc.num_scale_factors(3 * 32 * 32, 256)
        rows.append((f"fig2d/{gran}", (time.time() - t0) * 1e6 / steps,
                     f"acc={acc:.3f},n_sf={nsf}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
