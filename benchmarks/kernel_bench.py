"""Pallas kernel micro-bench (interpret mode: correctness-path timing
only — TPU perf is assessed structurally via the §Roofline dry-run)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.psq_matmul import psq_matmul_kernel
from repro.kernels.int4_matmul import int4_matmul_kernel, pack_int4
from repro.kernels.ref import psq_matmul_ref


def _time(f, n=3):
    f()  # compile
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f())
    return (time.time() - t0) / n * 1e6


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    B, K, O, R = 64, 512, 256, 128
    key = jax.random.PRNGKey(0)
    x = jnp.round(jax.random.uniform(key, (B, K), minval=-8, maxval=7))
    w = jnp.round(jax.random.uniform(key, (K, O), minval=-8, maxval=7))
    import math
    T = math.ceil(K / R)
    sf = jnp.ones((T, 4, 4, O)) * 0.5
    alpha = jnp.asarray(5.0)
    kw = dict(n_a=4, n_w=4, levels="ternary", adc_bits=4, xbar_rows=R)
    rows = []
    us_k = _time(lambda: psq_matmul_kernel(x, w, sf, alpha, **kw))
    us_kf = _time(lambda: psq_matmul_kernel(x, w, sf, alpha, fuse_planes=True, **kw))
    us_r = _time(lambda: psq_matmul_ref(x, w, sf, alpha, **kw))
    rows.append(("kernel/psq_matmul_interp", us_k, f"ref_us={us_r:.0f}"))
    rows.append(("kernel/psq_matmul_fused", us_kf, f"loop_us={us_k:.0f}"))
    wp = pack_int4(w)
    scale = jnp.ones((O,))
    us_i = _time(lambda: int4_matmul_kernel(x, wp, scale))
    rows.append(("kernel/int4_matmul_interp", us_i,
                 f"bytes_ratio_vs_bf16={0.5 / 2.0}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
