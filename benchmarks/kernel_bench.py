"""Kernel micro-bench, swept over every registered backend.

Times the PSQ crossbar matmul (loop + fused-plane variants) and the int4
weight-stationary decode matmul through :mod:`repro.kernels.registry`, so
any newly registered backend is benchmarked side-by-side with zero
changes here, plus the PackedLayer serving cache cold (quantize + pack +
call) vs warm (cached) path.

Interpret-mode numbers are correctness-path timings only — TPU perf is
assessed structurally via the §Roofline dry-run.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--smoke] [--backend X]
"""
from __future__ import annotations

import argparse
import math
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import QuantConfig
from repro.core.psq_linear import init_linear
from repro.kernels import registry
from repro.kernels.int4_matmul import pack_int4
from repro.serve.cache import PackedLayer


def _time(f, n=3):
    jax.block_until_ready(f())  # compile + warm, fully retired
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f())
    return (time.time() - t0) / n * 1e6


def run(fast: bool = False,
        only_backend: Optional[str] = None) -> List[Tuple[str, float, str]]:
    if only_backend is not None:
        # fail fast with the registry's message (names the platform and
        # the available alternatives) instead of a silent empty sweep
        registry.get_backend(only_backend)
    if fast:
        B, K, O, R = 16, 256, 128, 128
        n_rep = 1
    else:
        B, K, O, R = 64, 512, 256, 128
        n_rep = 3
    key = jax.random.PRNGKey(0)
    x = jnp.round(jax.random.uniform(key, (B, K), minval=-8, maxval=7))
    w = jnp.round(jax.random.uniform(key, (K, O), minval=-8, maxval=7))
    T = math.ceil(K / R)
    sf = jnp.ones((T, 4, 4, O)) * 0.5
    alpha = jnp.asarray(5.0)
    kw = dict(n_a=4, n_w=4, levels="ternary", adc_bits=4, xbar_rows=R)
    wp = pack_int4(w)
    scale = jnp.ones((O,))

    backends = registry.available_backends()
    if only_backend:
        backends = [b for b in backends if b == only_backend]
    # only report platform-unavailable backends, not --backend filtering
    skipped = sorted(
        set(registry.registered_backends())
        - set(registry.available_backends())
    )

    rows: List[Tuple[str, float, str]] = []
    for name in backends:
        impl = registry.get_backend(name)
        us = _time(lambda: impl.psq_matmul(x, w, sf, alpha, **kw), n_rep)
        rows.append((f"kernel/psq_matmul[{name}]", us, f"B{B}xK{K}xO{O}"))
        us_f = _time(
            lambda: impl.psq_matmul(x, w, sf, alpha, fuse_planes=True, **kw),
            n_rep,
        )
        rows.append((f"kernel/psq_matmul_fused[{name}]", us_f,
                     f"loop_us={us:.0f}"))
        us_i = _time(lambda: impl.int4_matmul(x, wp, scale), n_rep)
        rows.append((f"kernel/int4_matmul[{name}]", us_i,
                     f"bytes_ratio_vs_bf16={0.5 / 2.0}"))

    # --- serving cache: per-call cost with vs without cached packing ---
    cfg = QuantConfig(mode="psq", xbar_rows=R,
                      kernel_backend=only_backend or "reference")
    params = init_linear(jax.random.PRNGKey(1), K, O, cfg)
    xf = jax.random.normal(jax.random.PRNGKey(2), (B, K))
    apply_packed = jax.jit(lambda layer, x: layer.apply_serving(x)[0])
    packed = PackedLayer.pack(params, cfg)
    us_warm = _time(lambda: apply_packed(packed, xf), n_rep)

    def cold_call():
        layer = PackedLayer.pack(params, cfg)  # re-derive every call
        return apply_packed(layer, xf)

    us_cold = _time(cold_call, n_rep)
    rows.append(("serve/packed_layer_warm", us_warm,
                 f"cold_us={us_cold:.0f},speedup={us_cold / us_warm:.2f}x"))
    if skipped:
        rows.append(("kernel/skipped_backends", 0.0,
                     f"unavailable_on_{jax.default_backend()}:"
                     + "|".join(skipped)))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes, single rep (CI mode)")
    ap.add_argument("--backend", default=None,
                    choices=registry.registered_backends(),
                    help="bench a single backend")
    args = ap.parse_args()
    for r in run(fast=args.smoke, only_backend=args.backend):
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
