"""Beyond-paper: HCiM vs ADC-CiM energy for the assigned LM architectures.

Maps every projection/FFN matmul of each LM arch onto the crossbar
system model (per generated token, batch 1) — showing the paper's
technique scales from CNNs to modern LM workloads.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.configs import ARCHS
from repro.hwmodel import LayerShape, SystemConfig, evaluate_workload


def lm_layers(cfg) -> List[LayerShape]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    out: List[LayerShape] = []
    L = cfg.n_layers
    out.append(LayerShape("qkv", d, hd * (cfg.n_heads + 2 * cfg.n_kv_heads), L))
    out.append(LayerShape("wo", cfg.n_heads * hd, d, L))
    if cfg.family == "moe":
        e_ff = cfg.moe_d_ff or cfg.d_ff
        out.append(LayerShape("moe_ffn", d, 3 * e_ff * cfg.moe_top_k, L))
    elif cfg.d_ff:
        n_ffn = 3 if cfg.act == "swiglu" else 2
        out.append(LayerShape("ffn", d, n_ffn * cfg.d_ff // 2, L))
    out.append(LayerShape("lm_head", d, cfg.vocab_size, 1))
    return out


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    for name, cfg in sorted(ARCHS.items()):
        layers = lm_layers(cfg)
        t0 = time.time()
        adc = evaluate_workload(layers, SystemConfig(style="adc", adc_bits=7))
        hcim = evaluate_workload(
            layers, SystemConfig(style="hcim", levels="ternary", sparsity=0.5)
        )
        rows.append((
            f"lm_hcim/{name}", (time.time() - t0) * 1e6,
            f"E_adc7_uJ={adc.energy_pj / 1e6:.1f},"
            f"E_hcim_uJ={hcim.energy_pj / 1e6:.1f},"
            f"ratio={adc.energy_pj / hcim.energy_pj:.1f}x",
        ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
