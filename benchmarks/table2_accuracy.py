"""Table 2: accuracy vs ADC precision / PSQ levels, crossbar 128 vs 64.

Reproduces the paper's accuracy *trends* on the synthetic task:
ternary (1.5-bit) within ~1.5 % of 4-bit ADC; binary ~2 % lower; the
64-row crossbar degrades less than the 128-row one.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import QuantConfig, adc_baseline
from benchmarks._qat_common import train_qat


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    steps = 120 if fast else 250
    rows = []
    t0 = time.time()
    acc_fp = train_qat(QuantConfig(mode="none"), steps=steps)
    rows.append(("table2/fp_baseline", (time.time() - t0) * 1e6 / steps,
                 f"acc={acc_fp:.3f}"))
    for rows_x in (128, 64):
        for label, qc in [
            ("adc7", adc_baseline(7, rows_x)),
            ("adc6", adc_baseline(6, rows_x)),
            ("adc4", adc_baseline(4, rows_x)),
            ("ternary", QuantConfig(mode="psq", psq_levels="ternary",
                                    xbar_rows=rows_x)),
            ("binary", QuantConfig(mode="psq", psq_levels="binary",
                                   xbar_rows=rows_x)),
        ]:
            t0 = time.time()
            acc = train_qat(qc, steps=steps)
            rows.append((
                f"table2/{label}_x{rows_x}",
                (time.time() - t0) * 1e6 / steps,
                f"acc={acc:.3f},delta_fp={acc - acc_fp:+.3f}",
            ))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
