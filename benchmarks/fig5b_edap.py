"""Fig 5(b): accuracy vs EDAP on ImageNet-scale layers (ResNet-18) —
HCiM vs Quarry-style (digital scale-factor mults) and a 4-bit baseline.

Accuracy points are the paper's reported numbers (we cannot train
ImageNet offline); EDAP comes from our system model.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.hwmodel import SystemConfig, WORKLOADS, evaluate_workload


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    layers = WORKLOADS["resnet18_imagenet"]()
    t0 = time.time()
    # ImageNet recipe: a3/w3, sf 8-bit (paper §5.1)
    mk = lambda **kw: evaluate_workload(
        layers, SystemConfig(n_bits_a=3, n_bits_w=3, n_bits_sf=8, **kw)
    )
    res = {
        "hcim_ternary": mk(style="hcim", levels="ternary", sparsity=0.5),
        "quarry_1b": mk(style="quarry", levels="binary"),
        "bitsplit": mk(style="quarry", levels="binary"),  # indep bit paths ~4x
    }
    # Quarry-4b = 4-bit ADC readout PLUS digital scale-factor multipliers
    # (the paper's Quarry baseline keeps SF mults at every precision)
    adc4 = mk(style="adc", adc_bits=4)
    q = res["quarry_1b"]
    sf_energy = q.breakdown.get("sf_mult", 0) + q.breakdown.get("sf_sram_fetch", 0)
    quarry4_edap = (adc4.energy_pj + sf_energy) * adc4.latency_ns * adc4.area_mm2
    us = (time.time() - t0) * 1e6 / (len(res) + 1)
    base = res["hcim_ternary"].edap
    edap = {k: v.edap / base for k, v in res.items()}
    edap["quarry_4b"] = quarry4_edap / base
    edap["bitsplit"] *= 4.0  # BitSplitNet scales 1-bit paths by 4 (paper §5.3)
    rows = [
        ("fig5b/hcim_ternary", us, f"edap_rel=1.00,acc_paper=66.9"),
        ("fig5b/quarry_1b", us, f"edap_rel={edap['quarry_1b']:.2f},acc_paper=64.4"),
        ("fig5b/quarry_4b", us, f"edap_rel={edap['quarry_4b']:.2f},acc_paper=69.2"),
        ("fig5b/bitsplit", us, f"edap_rel={edap['bitsplit']:.2f},acc_paper=62.7"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
