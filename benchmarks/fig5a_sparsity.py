"""Fig 5(a): DCiM energy vs ternary sparsity (24% saving at 50%).

    PYTHONPATH=src python benchmarks/fig5a_sparsity.py \
        [--smoke] [--sparsities 0.0,0.5,0.9] [--json OUT.json]

The sweep grid is parameterizable: ``--sparsities`` (or the
``sparsities`` argument to :func:`run`) overrides the default
seven-point grid, ``--smoke`` shrinks it to three points for CI, and
``--json`` writes the rows as valid JSON instead of CSV. The harness
(``benchmarks/run.py``) forwards its own ``--sparsities`` knob here.
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional, Sequence, Tuple

from repro.hwmodel import dcim_column_energy_pj

DEFAULT_GRID = (0.0, 0.1, 0.25, 0.5, 0.65, 0.75, 0.9)
SMOKE_GRID = (0.0, 0.5, 0.9)


def run(fast: bool = False,
        sparsities: Optional[Sequence[float]] = None,
        ) -> List[Tuple[str, float, str]]:
    grid = tuple(sparsities) if sparsities is not None else DEFAULT_GRID
    rows = []
    e0 = dcim_column_energy_pj(0.0)
    for sp in grid:
        e = dcim_column_energy_pj(sp)
        rows.append((f"fig5a/sparsity_{int(sp * 100):02d}", 0.0,
                     f"e_pj={e:.4f},reduction={1 - e / e0:.3f}"))
    return rows


def rows_to_json(rows: List[Tuple[str, float, str]]) -> List[dict]:
    """CSV rows -> JSON-friendly dicts (derived k=v pairs parsed out)."""
    out = []
    for name, us, derived in rows:
        entry = {"name": name, "us_per_call": us}
        for kv in derived.split(","):
            k, v = kv.split("=", 1)
            try:
                entry[k] = float(v)
            except ValueError:
                entry[k] = v
        out.append(entry)
    return out


def _parse_sparsities(text: str) -> List[float]:
    vals = [float(v) for v in text.split(",") if v.strip()]
    bad = [v for v in vals if not 0.0 <= v <= 1.0]
    if bad:
        raise SystemExit(f"--sparsities values must be in [0, 1], got {bad}")
    return vals


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help=f"three-point CI grid {SMOKE_GRID}")
    ap.add_argument("--sparsities", default=None,
                    help="comma-separated sparsity grid, e.g. 0.0,0.5,0.9 "
                         "(overrides --smoke)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as JSON instead of CSV on stdout")
    args = ap.parse_args()
    grid = (_parse_sparsities(args.sparsities) if args.sparsities
            else (SMOKE_GRID if args.smoke else None))
    rows = run(sparsities=grid)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows_to_json(rows), f, indent=2)
        print(f"[fig5a] wrote {args.json}")
    else:
        for r in rows:
            print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
