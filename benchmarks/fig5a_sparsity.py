"""Fig 5(a): DCiM energy vs ternary sparsity (24% saving at 50%)."""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.hwmodel import dcim_column_energy_pj


def run(fast: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    e0 = dcim_column_energy_pj(0.0)
    for sp in [0.0, 0.1, 0.25, 0.5, 0.65, 0.75, 0.9]:
        e = dcim_column_energy_pj(sp)
        rows.append((f"fig5a/sparsity_{int(sp*100):02d}", 0.0,
                     f"e_pj={e:.4f},reduction={1 - e / e0:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
