"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--fast`` shrinks QAT
step counts for CI-speed runs.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only fig6]
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "table2_accuracy",
    "fig2_granularity",
    "table3_dcim_vs_adc",
    "fig5a_sparsity",
    "fig6_system",
    "fig7_configB",
    "fig5b_edap",
    "lm_hcim_energy",
    "kernel_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            rows = mod.run(fast=args.fast)
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:
            failed.append((mod_name, repr(e)))
            print(f"{mod_name},-1,ERROR:{e!r}", flush=True)
        sys.stderr.write(f"[bench] {mod_name}: {time.time() - t0:.1f}s\n")
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
