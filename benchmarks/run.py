"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--fast`` shrinks QAT
step counts for CI-speed runs; ``--smoke`` additionally shrinks the
fig5a sparsity grid and implies ``--fast``. ``--sparsities`` forwards a
custom grid to the fig5a sweep (modules that take no such knob are
called without it). ``--json PATH`` writes the rows as valid JSON in
addition to the CSV on stdout.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--smoke] \
        [--only fig6] [--sparsities 0.0,0.5,0.9] [--json OUT.json]
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time

MODULES = [
    "table2_accuracy",
    "fig2_granularity",
    "table3_dcim_vs_adc",
    "fig5a_sparsity",
    "fig6_system",
    "fig7_configB",
    "fig5b_edap",
    "lm_hcim_energy",
    "kernel_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: implies --fast and shrinks the fig5a "
                         "sparsity grid to its three-point smoke grid")
    ap.add_argument("--only", default=None)
    ap.add_argument("--sparsities", default=None,
                    help="comma-separated sparsity grid forwarded to the "
                         "fig5a sweep, e.g. 0.0,0.5,0.9")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON to PATH")
    args = ap.parse_args()

    sparsities = None
    if args.sparsities:
        sparsities = [float(v) for v in args.sparsities.split(",")
                      if v.strip()]
    elif args.smoke:
        from benchmarks.fig5a_sparsity import SMOKE_GRID

        sparsities = list(SMOKE_GRID)
    fast = args.fast or args.smoke

    print("name,us_per_call,derived")
    all_rows = []
    failed = []
    for mod_name in MODULES:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            kw = {"fast": fast}
            # forward the sweep grid only to modules whose run() takes it
            if (sparsities is not None
                    and "sparsities" in inspect.signature(mod.run).parameters):
                kw["sparsities"] = sparsities
            rows = mod.run(**kw)
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
                all_rows.append(
                    {"name": name, "us_per_call": us, "derived": derived}
                )
        except Exception as e:
            failed.append((mod_name, repr(e)))
            print(f"{mod_name},-1,ERROR:{e!r}", flush=True)
        sys.stderr.write(f"[bench] {mod_name}: {time.time() - t0:.1f}s\n")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": all_rows,
                       "failed": [list(x) for x in failed]}, f, indent=2)
        sys.stderr.write(f"[bench] wrote {args.json}\n")
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
