"""Serving bench: continuous vs static batching under a mixed-length trace.

Generates a synthetic trace of requests with mixed prompt lengths and
mixed decode budgets — the regime where static batching collapses
(finished sequences hold their slot until the whole batch retires) and
continuous batching keeps the slot pool full. Both schedulers run the
SAME model, trace and slot count; each engine is warmed first so the
comparison measures steady-state scheduling, not compilation.

Reports tokens/s, mean TTFT and mean slot occupancy per mode plus the
continuous/static speedup, and writes the result as JSON
(``BENCH_serve.json``) so CI can archive the perf trajectory.

The ``recurrent_continuous`` section runs the recurrent-state families
(zamba2 hybrid, xlstm) through the same continuous-vs-static comparison
on their own mixed-length traces: masked-length prefill makes the slot
pool exact for recurrent state, so the delta is pure scheduling.
``--recurrent`` runs only this section.

The ``device_loop`` section sweeps the on-device multi-step decode loop
(``EngineConfig.decode_horizon`` 1 / 8 / 32) over the same mixed-length
trace: one ``lax.while_loop`` jit call per horizon instead of one jit
call per token, so ``host_syncs`` drops ~H-fold with bit-identical
greedy outputs. ``--device-loop`` runs only this section.

The ``paged_prefix`` section drives the PAGED engine with a
shared-system-prompt trace (every request = one long shared prefix + a
short unique tail — the chat-serving regime) with prefix reuse off vs
on: the radix index serves the shared pages from the pool, so steady
state prefills only the unique tails. Records true prefill tokens,
cached prefix tokens, the prefill-token reduction and the tokens/s
speedup (docs/memory.md). ``--paged`` runs only this section.

The ``moe`` section serves the reduced granite MoE config through the
continuous slot pool single-device and — when ``--devices`` forges
enough virtual devices — on a ``(1, 1, E)`` ("data", "model",
"expert") mesh with the expert FFN stacks sharded over the ``expert``
axis (router replicated, bit-exact dispatch; docs/parallelism.md),
asserting token-identical greedy outputs. ``--moe`` runs only this
section (``BENCH_serve_moe.json``).

The ``spec`` section (``--spec`` → ``BENCH_serve_spec.json``) benches
speculative decoding against vanilla continuous serving at ``spec_k``
in {2, 4} with two drafts — an untrained 1-layer copy (accept-rate
floor) and the served model itself (accept-rate ceiling) — plus the
side-input families (whisper encdec, llava VLM patches) continuous vs
static. Every entry records accept rate, host syncs and the tokens/s
ratio, and asserts greedy outputs token-identical across all paths.

The ``streaming`` section replays a seeded Poisson arrival schedule
through the incremental submit/poll front-end
(``repro.launch.serve.StreamingFrontend`` over ``ServeEngine.step()``):
requests arrive mid-flight at exponential inter-arrival gaps (measured
in scheduler rounds, so the schedule is exactly replayable), and the
entry records TTFT/TPOT/tokens-per-s UNDER LIVE ARRIVALS — admission
wait included — rather than the drain-the-queue figures above.
``--streaming`` runs only this section (plus ``admission``).

The ``admission`` section serves the same trace under both admission
policies (docs/scheduling.md): ``fcfs`` pow2-bucket waves vs
``cost-aware``, which prices every request through the engine's
hwmodel (``EnergyModel.request_cost_pj``) and defers admissions that
would push the modeled in-flight energy past a pJ cap (set here to
two worst-case requests, so deferrals are exercised). Greedy outputs
are asserted token-identical across policies — admission order never
changes what a request decodes, only when.

Every per-mode entry reports the engine's modeled hwmodel energy
attribution (``energy_pj``, ``energy_pj_per_request``, ``edap``,
``mean_occupancy`` — docs/energy.md). The ``--energy`` section serves
one psq-packed trace and sweeps ``energy_report`` across accounting
styles (adc / quarry / hcim) x an occupancy grid without re-serving,
recording the modeled hcim-vs-adc reduction; CI archives it as
``BENCH_serve_energy.json``.

``--devices N`` additionally sweeps tensor-parallel mesh sizes: N CPU
virtual devices are forged (``--xla_force_host_platform_device_count``,
so the flag must come before any other JAX use in the process) and the
psq-packed continuous engine runs once per ``model``-axis size in
{1, 2, ..., N} (powers of two), recording a per-mesh-size tokens/s
entry under ``"sharded"``. On CPU this measures dispatch overhead, not
speedup — the point is that CI exercises the 1/2/4-way sharded
datapath end to end (docs/parallelism.md).

    PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] \
        [--requests 32] [--slots 8] [--psq-packed] [--devices 4] \
        [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, List, Tuple

import numpy as np
import jax

from repro.configs import get_config
from repro.core.config import PSQ_TERNARY
from repro.kernels import registry
from repro.models import init_model
from repro.serve import (
    EngineConfig, PackedModelCache, ServeEngine, pack_tree_psq,
    throughput_stats,
)


def make_trace(n: int, prompt_rng: Tuple[int, int], new_rng: Tuple[int, int],
               vocab: int, seed: int = 0) -> List[Tuple[np.ndarray, int]]:
    """Mixed-length synthetic trace: (prompt, max_new_tokens) pairs."""
    rng = np.random.RandomState(seed)
    trace = []
    for _ in range(n):
        plen = int(rng.randint(prompt_rng[0], prompt_rng[1] + 1))
        mnew = int(rng.randint(new_rng[0], new_rng[1] + 1))
        trace.append((rng.randint(0, vocab, size=plen), mnew))
    return trace


def make_shared_prefix_trace(
    n: int, prefix_len: int, tail_rng: Tuple[int, int],
    new_rng: Tuple[int, int], vocab: int, seed: int = 0,
) -> List[Tuple[np.ndarray, int]]:
    """Chat-style trace: one shared system prompt + short unique tails."""
    rng = np.random.RandomState(seed)
    sys_prompt = rng.randint(0, vocab, size=prefix_len)
    trace = []
    for _ in range(n):
        tail = rng.randint(0, vocab,
                           size=int(rng.randint(tail_rng[0],
                                                tail_rng[1] + 1)))
        mnew = int(rng.randint(new_rng[0], new_rng[1] + 1))
        trace.append((np.concatenate([sys_prompt, tail]), mnew))
    return trace


def make_arrivals(n: int, mean_gap_rounds: float, seed: int = 0) -> List[int]:
    """Seeded, replayable Poisson arrival schedule.

    Returns the scheduler round at which each of ``n`` requests
    arrives: exponential inter-arrival gaps with the given mean,
    cumulated and floored to round indices, shifted so the first
    request arrives at round 0. Measuring arrivals in scheduler rounds
    (not wall time) makes the schedule exactly replayable — the same
    seed produces the same admission pattern on any machine.
    """
    rng = np.random.RandomState(seed)
    t = np.floor(np.cumsum(rng.exponential(mean_gap_rounds, size=n)))
    return [int(v - t[0]) for v in t]


def bench_mode(mode: str, params, cfg, trace, slots: int,
               max_len: int, mesh=None, repeats: int = 1,
               extra_inputs=None, draft_params=None,
               **ecfg_kw) -> Dict[str, float]:
    eng = ServeEngine(params, cfg,
                      EngineConfig(max_batch=slots, max_len=max_len,
                                   mode=mode, **ecfg_kw),
                      extra_inputs=extra_inputs, mesh=mesh,
                      draft_params=draft_params)
    # side-input rows are positional by uid, which drifts across the
    # warm-up + repeat runs below — pin each request to its trace row
    def submit_all():
        for i, (prompt, mnew) in enumerate(trace):
            eng.submit(prompt, max_new_tokens=mnew,
                       extra_idx=i if extra_inputs else None)

    # warm-up pass: compile every (bucket, batch) shape the trace needs
    # (and, for a paged engine, populate the prefix index — the measured
    # passes below are the steady state)
    submit_all()
    eng.run()

    # best-of-N: sub-second CPU runs are wall-clock noisy
    wall, done, sched = float("inf"), None, None
    for _ in range(max(repeats, 1)):
        eng.reset_stats()
        t0 = time.time()
        submit_all()
        reqs = eng.run()
        w = time.time() - t0
        if w < wall:
            wall, done, sched = w, reqs, eng.stats()
    stats = throughput_stats(done)
    out = {
        "mode": eng.mode,
        "wall_s": wall,
        "tokens_per_s": stats["tokens_per_s"],
        "total_tokens": stats["total_tokens"],
        "mean_ttft_s": stats["mean_ttft_s"],
        "mean_tpot_s": stats["mean_tpot_s"],
        "decode_steps": sched["decode_steps"],
        "host_syncs": sched["host_syncs"],
        "prefill_calls": sched["prefill_calls"],
        "prefill_tokens": sched["prefill_tokens"],
        "cached_prefix_tokens": sched["cached_prefix_tokens"],
        "mean_slot_occupancy": sched["mean_slot_occupancy"],
        # modeled hwmodel energy attribution (docs/energy.md): every
        # entry carries its style, total/per-request pJ, EDAP and the
        # measured ternary column occupancy of the served weights
        "energy_style": sched["energy_style"],
        "energy_pj": sched["energy_pj_total"],
        "energy_pj_per_request": sched["energy_pj_per_request"],
        "edap": sched["edap_total"],
        "mean_occupancy": sched["mean_occupancy"],
        "admission_policy": sched["admission_policy"],
        "admission_deferrals": sched["admission_deferrals"],
    }
    if "paged" in sched:
        out["paged"] = sched["paged"]
    if "spec_k" in sched:
        for k in ("spec_k", "spec_rounds", "spec_proposed",
                  "spec_accepted", "spec_accept_rate"):
            out[k] = sched[k]
    return out


def bench_streaming(params, cfg, trace, slots: int, max_len: int,
                    mean_gap_rounds: float, seed: int = 0) -> Dict:
    """Live-arrival serving through the incremental submit/poll API.

    Replays the seeded Poisson schedule from :func:`make_arrivals`
    through ``StreamingFrontend``: each scheduler round first submits
    every request whose arrival round has come, then advances the
    engine one ``step()`` and polls the per-request token deltas. TTFT
    here includes the admission wait a late arrival experiences behind
    a busy pool — the figure the drain-the-queue sections cannot show.
    The engine is warmed on the full trace first so the measured pass
    is steady-state scheduling, not compilation.
    """
    from repro.launch.serve import StreamingFrontend

    eng = ServeEngine(params, cfg,
                      EngineConfig(max_batch=slots, max_len=max_len,
                                   mode="continuous"))
    for prompt, mnew in trace:
        eng.submit(prompt, max_new_tokens=mnew)
    eng.run()
    eng.reset_stats()

    arrivals = make_arrivals(len(trace), mean_gap_rounds, seed)
    fe = StreamingFrontend(eng)
    uids: List[int] = []
    first_round: Dict[int, int] = {}
    rounds, nxt = 0, 0
    t0 = time.time()
    while nxt < len(trace) or not fe.drained:
        while nxt < len(trace) and arrivals[nxt] <= rounds:
            prompt, mnew = trace[nxt]
            uids.append(fe.submit(prompt, max_new_tokens=mnew))
            nxt += 1
        fe.step()          # no-op on idle rounds before the next arrival
        rounds += 1
        for uid in uids:
            toks, _ = fe.poll(uid)
            if toks and uid not in first_round:
                first_round[uid] = rounds
    wall = time.time() - t0
    stats = throughput_stats(eng.finished)
    sched = eng.stats()
    out = {
        "arrival_seed": seed,
        "arrival_mean_gap_rounds": mean_gap_rounds,
        "arrival_rounds": arrivals,
        "rounds": rounds,
        "mean_first_token_round": (
            float(np.mean([first_round[u] - arrivals[i]
                           for i, u in enumerate(uids)]))
            if first_round else 0.0
        ),
        "wall_s": wall,
        "tokens_per_s": stats["tokens_per_s"],
        "total_tokens": stats["total_tokens"],
        "mean_ttft_s": stats["mean_ttft_s"],
        "mean_tpot_s": stats["mean_tpot_s"],
        "decode_steps": sched["decode_steps"],
        "prefill_calls": sched["prefill_calls"],
        "mean_slot_occupancy": sched["mean_slot_occupancy"],
    }
    print(f"[serve_bench] streaming (Poisson gap {mean_gap_rounds:.1f} "
          f"rounds): {out['tokens_per_s']:8.1f} tok/s  "
          f"ttft {out['mean_ttft_s'] * 1e3:7.1f} ms  "
          f"rounds {rounds}  "
          f"first-token wait {out['mean_first_token_round']:.1f} rounds")
    return out


def bench_admission(params, cfg, trace, slots: int, max_len: int) -> Dict:
    """FCFS vs cost-aware admission under a pJ cap, same trace.

    The cap is set to two worst-case requests (priced through the same
    ``EnergyModel.request_cost_pj`` the policy consults at admission
    time), so the cost-aware run must defer admissions while slots are
    free — the budgeted regime. Greedy outputs are asserted identical:
    admission order changes WHEN a request decodes, never WHAT.
    """
    def serve(policy: str, budget: float):
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=slots, max_len=max_len,
                                       mode="continuous",
                                       admission_policy=policy,
                                       energy_budget_pj=budget))
        for prompt, mnew in trace:
            eng.submit(prompt, max_new_tokens=mnew)
        done = eng.run()
        return eng, {r.uid: list(r.output) for r in done}

    # price the trace through the engine's own hwmodel (no serving:
    # submit only populates the queue)
    pricer = ServeEngine(params, cfg,
                         EngineConfig(max_batch=slots, max_len=max_len,
                                      mode="continuous"))
    for prompt, mnew in trace:
        pricer.submit(prompt, max_new_tokens=mnew)
    costs = [pricer.energy.request_cost_pj(r) for r in pricer.queue]
    budget = 2.0 * max(costs) if costs else 0.0
    if budget <= 0.0:
        return {"skipped": "model prices every request at 0 pJ"}

    eng_f, toks_f = serve("fcfs", 0.0)
    eng_c, toks_c = serve("cost-aware", budget)
    match = toks_f == toks_c
    out = {
        "energy_budget_pj": budget,
        "request_cost_pj": {
            "min": min(costs), "max": max(costs),
            "mean": float(np.mean(costs)),
        },
        "tokens_match": match,
    }
    for name, eng in (("fcfs", eng_f), ("cost_aware", eng_c)):
        sched = eng.stats()
        stats = throughput_stats(eng.finished)
        out[name] = {
            "policy": sched["admission_policy"],
            "deferrals": sched["admission_deferrals"],
            "admissions": sched["admissions"],
            "tokens_per_s": stats["tokens_per_s"],
            "mean_ttft_s": stats["mean_ttft_s"],
            "energy_pj": sched["energy_pj_total"],
        }
        print(f"[serve_bench] admission {name:10s}: "
              f"{out[name]['tokens_per_s']:8.1f} tok/s  "
              f"deferrals {out[name]['deferrals']:3d}  "
              f"ttft {out[name]['mean_ttft_s'] * 1e3:7.1f} ms")
    print(f"[serve_bench] cost-aware cap {budget:.1f} pJ "
          f"(2x worst request): tokens_match={match}")
    if not match:
        raise SystemExit("[serve_bench] admission: cost-aware greedy "
                         "outputs diverged from fcfs")
    return out


def bench_paged_prefix(params, cfg, trace, slots: int, max_len: int,
                       block_size: int) -> Dict:
    """Paged engine, prefix reuse off vs on, same shared-prefix trace.

    Both engines are warmed on the full trace first (compiles every
    shape; for reuse=on it also populates the radix index), so the
    measured runs compare steady states: full re-prefill of every
    prompt vs prefilling only each request's unique tail.
    """
    out: Dict = {"block_size": block_size}
    for key, reuse in (("reuse_off", False), ("reuse_on", True)):
        out[key] = bench_mode("continuous", params, cfg, trace, slots,
                              max_len, repeats=5, paged=True,
                              block_size=block_size, prefix_reuse=reuse)
        r = out[key]
        print(f"[serve_bench] paged {key:9s}: "
              f"{r['tokens_per_s']:8.1f} tok/s  "
              f"prefill tokens {r['prefill_tokens']:5d}  "
              f"cached {r['cached_prefix_tokens']:5d}")
    off, on = out["reuse_off"], out["reuse_on"]
    out["prefill_token_reduction"] = (
        1.0 - on["prefill_tokens"] / max(off["prefill_tokens"], 1)
    )
    out["speedup_tokens_per_s"] = (
        on["tokens_per_s"] / max(off["tokens_per_s"], 1e-9)
    )
    print(f"[serve_bench] shared-prefix reuse: "
          f"{out['prefill_token_reduction'] * 100:.1f}% fewer prefill "
          f"tokens, {out['speedup_tokens_per_s']:.2f}x tokens/s")
    return out


def bench_recurrent(args) -> Dict:
    """Recurrent-state families on the continuous scheduler vs static.

    zamba2 (hybrid: Mamba2 groups + one shared attention block) and
    xlstm (mLSTM/sLSTM) run the same mixed-length trace through both
    schedulers. Masked-length prefill makes the continuous slot pool
    exact for recurrent state (models/decode.prefill), so the comparison
    is pure scheduling: static lockstep wastes steps on retired-but-held
    slots, the slot pool backfills them per step. Greedy outputs are
    bit-identical between the two modes (pinned by
    tests/test_recurrent_serving.py).
    """
    if args.smoke:
        n_req, prompt_rng, new_rng = 8, (4, 16), (2, 8)
        slots, max_len = 4, 48
    else:
        # decode-weighted budgets: recurrent decode steps are cheap
        # (no KV growth), so the trace keeps slots busy long enough for
        # scheduling — not prefill dispatch — to dominate the delta
        n_req, prompt_rng, new_rng = args.requests, (8, 64), (16, 64)
        slots, max_len = args.slots, 160
    out: Dict = {
        "requests": n_req, "prompt_len": list(prompt_rng),
        "max_new_tokens": list(new_rng), "slots": slots, "max_len": max_len,
    }
    for arch in ("zamba2-7b", "xlstm-350m"):
        cfg = get_config(arch).reduced()
        params = init_model(jax.random.PRNGKey(0), cfg)
        trace = make_trace(n_req, prompt_rng, new_rng, cfg.vocab_size)
        entry: Dict = {"family": cfg.family}
        for mode in ("static", "continuous"):
            entry[mode] = bench_mode(mode, params, cfg, trace, slots,
                                     max_len, repeats=5)
            r = entry[mode]
            print(f"[serve_bench] recurrent {arch} {mode:10s}: "
                  f"{r['tokens_per_s']:8.1f} tok/s  "
                  f"occupancy {r['mean_slot_occupancy']:.2f}  "
                  f"steps {r['decode_steps']}")
        entry["speedup_tokens_per_s"] = (
            entry["continuous"]["tokens_per_s"]
            / max(entry["static"]["tokens_per_s"], 1e-9)
        )
        entry["occupancy_gain"] = (
            entry["continuous"]["mean_slot_occupancy"]
            - entry["static"]["mean_slot_occupancy"]
        )
        print(f"[serve_bench] recurrent {arch}: "
              f"{entry['speedup_tokens_per_s']:.2f}x tokens/s, "
              f"occupancy +{entry['occupancy_gain']:.2f}")
        out[arch] = entry
    return out


def bench_device_loop(params, cfg, trace, slots: int, max_len: int) -> Dict:
    """Horizon sweep for the on-device multi-step decode loop.

    The same mixed-length trace runs the continuous greedy engine at
    ``decode_horizon`` 1 / 8 / 32: one jit call per horizon instead of
    per token, so ``host_syncs`` drops ~H-fold while ``decode_steps``
    (and greedy outputs — pinned by tests/test_device_loop.py) stay
    identical. Best-of-5 per horizon; the h=1 entry is the baseline the
    speedups compare against.
    """
    out: Dict = {"horizons": {}}
    base = None
    for h in (1, 8, 32):
        r = bench_mode("continuous", params, cfg, trace, slots, max_len,
                       repeats=5, decode_horizon=h)
        out["horizons"][str(h)] = r
        if base is None:
            base = r
        print(f"[serve_bench] device_loop h={h:2d}: "
              f"{r['tokens_per_s']:8.1f} tok/s  "
              f"syncs {r['host_syncs']:4d}  steps {r['decode_steps']:4d}  "
              f"tpot {r['mean_tpot_s'] * 1e3:6.2f} ms")
    h32 = out["horizons"]["32"]
    out["sync_reduction"] = 1.0 - h32["host_syncs"] / max(base["host_syncs"], 1)
    out["speedup_tokens_per_s"] = (
        h32["tokens_per_s"] / max(base["tokens_per_s"], 1e-9)
    )
    print(f"[serve_bench] device loop h=32 vs h=1: "
          f"{out['sync_reduction'] * 100:.1f}% fewer host syncs, "
          f"{out['speedup_tokens_per_s']:.2f}x tokens/s")
    return out


def bench_energy(args) -> Dict:
    """Modeled energy/EDAP section (``BENCH_serve_energy.json``).

    Serves one mixed-length trace from the psq-packed engine, then —
    without re-serving — sweeps ``eng.energy_report`` across accounting
    styles (adc / quarry / hcim) and an occupancy grid. The measured
    entry uses the pack-time ternary column occupancy of the served
    weights; the sweep entries override occupancy to show how the
    modeled hcim-vs-adc reduction scales with sparsity (docs/energy.md).
    """
    cfg = get_config(args.arch).reduced()
    qcfg = dataclasses.replace(PSQ_TERNARY, kernel_backend="reference",
                               xbar_rows=64)
    cfg = cfg.with_quant(qcfg)
    params = init_model(jax.random.PRNGKey(0), cfg)
    cache = PackedModelCache()
    params = pack_tree_psq(params, qcfg, cache)

    if args.smoke:
        n_req, prompt_rng, new_rng, slots, max_len = 6, (4, 12), (2, 4), 3, 32
    else:
        n_req, prompt_rng, new_rng = args.requests, (8, 64), (4, 32)
        slots, max_len = args.slots, 128
    trace = make_trace(n_req, prompt_rng, new_rng, cfg.vocab_size)

    eng = ServeEngine(params, cfg,
                      EngineConfig(max_batch=slots, max_len=max_len,
                                   mode="continuous"))
    for prompt, mnew in trace:
        eng.submit(prompt, max_new_tokens=mnew)
    eng.run()
    sched = eng.stats()

    out: Dict = {
        "requests": n_req, "slots": slots, "max_len": max_len,
        "energy_tokens": sched["energy_tokens"],
        "measured_occupancy": sched["mean_occupancy"],
        "measured": eng.energy_report(),
        "sweep": {},
    }
    for sp in (0.0, 0.25, 0.5, 0.75, 0.9):
        rep = eng.energy_report(occupancy=sp)
        rep["hcim_vs_adc_reduction"] = 1.0 - (
            rep["hcim"]["energy_pj_total"]
            / max(rep["adc"]["energy_pj_total"], 1e-12)
        )
        out["sweep"][f"{sp:.2f}"] = rep
        print(f"[serve_bench] energy occ={sp:.2f}: "
              + "  ".join(f"{s} {rep[s]['energy_pj_total']:12.1f} pJ"
                          for s in ("adc", "quarry", "hcim"))
              + f"  hcim/adc -{rep['hcim_vs_adc_reduction'] * 100:.1f}%")
    out["hcim_vs_adc_reduction_at_0.5"] = (
        out["sweep"]["0.50"]["hcim_vs_adc_reduction"]
    )
    print(f"[serve_bench] modeled hcim vs adc at occupancy 0.5: "
          f"{out['hcim_vs_adc_reduction_at_0.5'] * 100:.1f}% less energy "
          f"over {out['energy_tokens']} served tokens")
    return out


def bench_moe(args) -> Dict:
    """Expert-parallel MoE serving section (``BENCH_serve_moe.json``).

    Serves the reduced granite MoE config through the continuous slot
    pool twice over the same mixed-length trace: single-device, then on
    a ``(1, 1, E)`` ("data", "model", "expert") mesh with the expert
    FFN stacks sharded over the ``expert`` axis (router replicated —
    docs/parallelism.md). The dispatch reassembles the exact capacity
    tensor the single-device scatter consumes, so greedy outputs are
    bit-identical; the section records both throughputs and the
    token-level match. With one device (or a non-divisible expert
    count) only the single-device entry is emitted.
    """
    arch = "granite-moe-3b-a800m"
    cfg = get_config(arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    if args.smoke:
        n_req, prompt_rng, new_rng, slots, max_len = 6, (4, 12), (2, 6), 3, 32
    else:
        n_req, prompt_rng, new_rng = args.requests, (8, 64), (4, 32)
        slots, max_len = args.slots, 128
    trace = make_trace(n_req, prompt_rng, new_rng, cfg.vocab_size)

    def serve_tokens(mesh):
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=slots, max_len=max_len,
                                       mode="continuous"),
                          mesh=mesh)
        for prompt, mnew in trace:
            eng.submit(prompt, max_new_tokens=mnew)
        done = eng.run()
        return {r.uid: list(r.output) for r in done}

    out: Dict = {
        "arch": arch, "family": cfg.family, "n_experts": cfg.n_experts,
        "moe_top_k": cfg.moe_top_k, "requests": n_req, "slots": slots,
        "max_len": max_len,
    }
    out["single"] = bench_mode("continuous", params, cfg, trace, slots,
                               max_len, repeats=3)
    print(f"[serve_bench] moe single-device: "
          f"{out['single']['tokens_per_s']:8.1f} tok/s  "
          f"steps {out['single']['decode_steps']}")

    e = 1
    while e * 2 <= len(jax.devices()) and cfg.n_experts % (e * 2) == 0:
        e *= 2
    if e > 1:
        mesh = jax.make_mesh((1, 1, e), ("data", "model", "expert"))
        out["expert_parallel"] = dict(
            mesh=f"data=1,model=1,expert={e}",
            **bench_mode("continuous", params, cfg, trace, slots, max_len,
                         mesh=mesh, repeats=3),
        )
        out["tokens_match"] = serve_tokens(None) == serve_tokens(mesh)
        out["ep_vs_single_tokens_per_s"] = (
            out["expert_parallel"]["tokens_per_s"]
            / max(out["single"]["tokens_per_s"], 1e-9)
        )
        print(f"[serve_bench] moe expert={e}: "
              f"{out['expert_parallel']['tokens_per_s']:8.1f} tok/s  "
              f"tokens_match={out['tokens_match']}  "
              f"({out['ep_vs_single_tokens_per_s']:.2f}x vs single; CPU "
              f"measures dispatch overhead, not speedup)")
        if not out["tokens_match"]:
            raise SystemExit("[serve_bench] moe: expert-parallel greedy "
                             "outputs diverged from single-device")
    return out


def bench_spec(args) -> Dict:
    """Speculative decoding + side-input section (``BENCH_serve_spec.json``).

    Three comparisons on one mixed-length trace, all token-identical by
    construction (and asserted):

    * ``vanilla`` vs ``spec`` at ``spec_k`` in {2, 4} with a 1-layer
      random draft — the accept-rate floor (an untrained draft rarely
      matches the main argmax), so the entry measures pure verify-round
      overhead;
    * the same ``spec_k`` values with the served model as its own draft
      — the accept-rate ceiling (every proposal matches), showing the
      host-sync reduction speculative rounds buy when the draft is good;
    * ``side_input_continuous``: the encdec (whisper) and
      VLM-with-patches (llava) reduced configs served through the
      continuous slot pool vs the static oracle loop, tokens matched.
    """
    cfg = get_config(args.arch).reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    if args.smoke:
        n_req, prompt_rng, new_rng, slots, max_len = 8, (4, 16), (4, 10), 4, 48
    else:
        n_req, prompt_rng, new_rng = args.requests, (8, 32), (8, 32)
        slots, max_len = args.slots, 128
    trace = make_trace(n_req, prompt_rng, new_rng, cfg.vocab_size)

    def outputs(**kw):
        dp = kw.pop("draft_params", None)
        eng = ServeEngine(params, cfg,
                          EngineConfig(max_batch=slots, max_len=max_len,
                                       mode="continuous", **kw),
                          draft_params=dp)
        for prompt, mnew in trace:
            eng.submit(prompt, max_new_tokens=mnew)
        return {r.uid: list(r.output) for r in eng.run()}

    out: Dict = {"arch": args.arch, "requests": n_req, "slots": slots,
                 "max_len": max_len}
    base = bench_mode("continuous", params, cfg, trace, slots, max_len,
                      repeats=3)
    out["vanilla"] = base
    base_toks = outputs()
    print(f"[serve_bench] spec vanilla: {base['tokens_per_s']:8.1f} tok/s  "
          f"syncs {base['host_syncs']}")

    dcfg1 = dataclasses.replace(cfg, n_layers=1)
    drafts = {
        "draft_1layer": (dcfg1, init_model(jax.random.PRNGKey(1), dcfg1)),
        "draft_self": (cfg, params),
    }
    for name, (dcfg, dparams) in drafts.items():
        sec: Dict = {"draft_layers": dcfg.n_layers}
        for k in (2, 4):
            r = bench_mode("continuous", params, cfg, trace, slots,
                           max_len, repeats=3, spec_k=k, draft_config=dcfg,
                           draft_params=dparams)
            r["tokens_match"] = outputs(
                spec_k=k, draft_config=dcfg, draft_params=dparams
            ) == base_toks
            r["speedup_tokens_per_s"] = (
                r["tokens_per_s"] / max(base["tokens_per_s"], 1e-9)
            )
            sec[f"k{k}"] = r
            print(f"[serve_bench] spec {name} k={k}: "
                  f"{r['tokens_per_s']:8.1f} tok/s  "
                  f"accept {r['spec_accept_rate']:.3f}  "
                  f"syncs {r['host_syncs']}  "
                  f"({r['speedup_tokens_per_s']:.2f}x vs vanilla)  "
                  f"tokens_match={r['tokens_match']}")
            if not r["tokens_match"]:
                raise SystemExit(f"[serve_bench] spec {name} k={k}: greedy "
                                 f"outputs diverged from vanilla decode")
        out[name] = sec

    side: Dict = {}
    rng = np.random.RandomState(0)
    for arch in ("whisper-large-v3", "llava-next-mistral-7b"):
        scfg = get_config(arch).reduced()
        sparams = init_model(jax.random.PRNGKey(0), scfg)
        strace = make_trace(n_req, (4, 10), new_rng, scfg.vocab_size)
        extra = {}
        key = "enc_embeds" if scfg.family == "encdec" else "patch_embeds"
        extra[key] = (rng.randn(n_req, 8, scfg.d_model) * 0.1
                      ).astype(np.float32)
        entry: Dict = {"family": scfg.family, "side_input": key}
        for mode in ("static", "continuous"):
            entry[mode] = bench_mode(mode, sparams, scfg, strace, slots,
                                     max_len, repeats=3,
                                     extra_inputs=extra)
        entry["tokens_match"] = True
        for mode in ("static", "continuous"):
            eng = ServeEngine(sparams, scfg,
                              EngineConfig(max_batch=slots, max_len=max_len,
                                           mode=mode),
                              extra_inputs=extra)
            for i, (prompt, mnew) in enumerate(strace):
                eng.submit(prompt, max_new_tokens=mnew, extra_idx=i)
            toks = {r.uid: list(r.output) for r in eng.run()}
            if mode == "static":
                ref = toks
            else:
                entry["tokens_match"] = toks == ref
        entry["speedup_tokens_per_s"] = (
            entry["continuous"]["tokens_per_s"]
            / max(entry["static"]["tokens_per_s"], 1e-9)
        )
        print(f"[serve_bench] side-input {arch} ({scfg.family}): "
              f"continuous {entry['continuous']['tokens_per_s']:8.1f} tok/s "
              f"({entry['speedup_tokens_per_s']:.2f}x vs static)  "
              f"tokens_match={entry['tokens_match']}")
        if not entry["tokens_match"]:
            raise SystemExit(f"[serve_bench] side-input {arch}: continuous "
                             f"outputs diverged from static")
        side[arch] = entry
    out["side_input_continuous"] = side
    return out


def run(args) -> Dict:
    if args.spec:
        return {
            "bench": "serve_spec",
            "platform": jax.default_backend(),
            "spec": bench_spec(args),
        }
    if args.energy:
        return {
            "bench": "serve_energy",
            "arch": args.arch,
            "platform": jax.default_backend(),
            "energy": bench_energy(args),
        }
    if args.moe:
        return {
            "bench": "serve_moe",
            "platform": jax.default_backend(),
            "devices": len(jax.devices()),
            "moe": bench_moe(args),
        }
    cfg = get_config(args.arch).reduced()
    if not args.recurrent:
        # the recurrent section builds its own zamba2/xlstm models —
        # don't init (or pack) an args.arch model it never serves
        if args.psq_packed:
            qcfg = dataclasses.replace(PSQ_TERNARY,
                                       kernel_backend="reference",
                                       xbar_rows=64)
            cfg = cfg.with_quant(qcfg)
            params = init_model(jax.random.PRNGKey(0), cfg)
            cache = PackedModelCache()
            params = pack_tree_psq(params, qcfg, cache)
            print(f"[serve_bench] packed once at load: {cache.stats()}")
        else:
            params = init_model(jax.random.PRNGKey(0), cfg)

    if args.smoke:
        n_req, prompt_rng, new_rng = 8, (4, 16), (2, 8)
        slots, max_len = 4, 32
    else:
        n_req, prompt_rng, new_rng = args.requests, (8, 64), (4, 64)
        slots, max_len = args.slots, 160
    trace = make_trace(n_req, prompt_rng, new_rng, cfg.vocab_size)

    result: Dict = {
        "bench": "serve",
        "arch": args.arch,
        "weights": "psq-packed" if args.psq_packed else "fp32",
        "requests": n_req,
        "prompt_len": list(prompt_rng),
        "max_new_tokens": list(new_rng),
        "slots": slots,
        "max_len": max_len,
        "platform": jax.default_backend(),
        "devices": len(jax.devices()),
    }
    only_section = (args.paged or args.recurrent or args.device_loop
                    or args.streaming)
    if not only_section:
        for mode in ("static", "continuous"):
            result[mode] = bench_mode(mode, params, cfg, trace, slots,
                                      max_len)
            r = result[mode]
            print(f"[serve_bench] {mode:10s}: "
                  f"{r['tokens_per_s']:8.1f} tok/s  "
                  f"ttft {r['mean_ttft_s'] * 1e3:7.1f} ms  "
                  f"occupancy {r['mean_slot_occupancy']:.2f}  "
                  f"steps {r['decode_steps']}")
        result["speedup_tokens_per_s"] = (
            result["continuous"]["tokens_per_s"]
            / max(result["static"]["tokens_per_s"], 1e-9)
        )
        print(f"[serve_bench] continuous/static speedup: "
              f"{result['speedup_tokens_per_s']:.2f}x")

    # live-arrival streaming + admission-policy comparison on the same
    # trace: TTFT/TPOT under a replayable Poisson schedule through the
    # submit/poll front-end, and fcfs vs cost-aware under a pJ cap
    if args.streaming or not only_section:
        mean_gap = 1.0 if args.smoke else 2.0
        result["streaming"] = dict(
            requests=n_req, slots=slots, max_len=max_len,
            **bench_streaming(params, cfg, trace, slots, max_len, mean_gap),
        )
        result["admission"] = bench_admission(params, cfg, trace, slots,
                                              max_len)

    # horizon sweep for the on-device decode loop: same trace, same
    # greedy outputs, host syncs cut ~H-fold (docs/serving.md)
    if not args.paged and not args.recurrent and not args.streaming:
        result["device_loop"] = dict(
            requests=n_req, slots=slots, max_len=max_len,
            **bench_device_loop(params, cfg, trace, slots, max_len),
        )

    # shared-system-prompt trace on the paged engine: a prefill-heavy
    # regime (long shared prefix, short tails and decode budgets) where
    # radix prefix reuse pays directly in admission latency
    if not args.recurrent and not args.device_loop and not args.streaming:
        if args.smoke:
            pn, pfx, tails, pnew = 8, 24, (2, 6), (2, 4)
            pslots, pmax, pbs = 4, 64, 8
        else:
            pn, pfx, tails, pnew = 48, 64, (4, 12), (4, 8)
            pslots, pmax, pbs = args.slots, 128, 16
        ptrace = make_shared_prefix_trace(pn, pfx, tails, pnew,
                                          cfg.vocab_size)
        result["paged_prefix"] = dict(
            requests=pn, shared_prefix_len=pfx, tail_len=list(tails),
            max_new_tokens=list(pnew), slots=pslots, max_len=pmax,
            **bench_paged_prefix(params, cfg, ptrace, pslots, pmax, pbs),
        )

    # recurrent-state families (hybrid zamba2, xlstm) through the
    # continuous slot pool vs the static fallback — same mixed-length
    # trace per arch, bit-identical outputs, scheduling-only delta
    if not args.paged and not args.device_loop and not args.streaming:
        result["recurrent_continuous"] = bench_recurrent(args)

    # tiny MoE entry in the default section: single-device continuous
    # serve of the reduced granite MoE (the full expert-parallel
    # comparison is the --moe section / BENCH_serve_moe.json)
    if not only_section:
        result["moe"] = bench_moe(args)

    if not only_section and args.devices > 1:
        result["sharded"] = run_sharded_sweep(args)
    return result


def run_sharded_sweep(args) -> List[Dict]:
    """Per-mesh-size tokens/s for the tensor-parallel PSQ datapath.

    The same mixed-length trace drives the psq-packed continuous engine
    under a ``(1, m)`` ("data", "model") mesh for every power-of-two
    ``m`` up to ``--devices``. ``m=1`` is the single-device baseline
    the sharded entries compare against.
    """
    n_dev = len(jax.devices())
    if n_dev < args.devices:
        raise SystemExit(
            f"--devices {args.devices} but only {n_dev} JAX devices exist "
            f"— the flag must be the first JAX use in the process"
        )
    cfg = get_config(args.arch).reduced()
    qcfg = dataclasses.replace(PSQ_TERNARY, kernel_backend="reference",
                               xbar_rows=64)
    cfg = cfg.with_quant(qcfg)
    params = init_model(jax.random.PRNGKey(0), cfg)
    cache = PackedModelCache()

    if args.smoke:
        n_req, prompt_rng, new_rng, slots, max_len = 4, (4, 12), (2, 4), 2, 32
    else:
        n_req, prompt_rng, new_rng = args.requests, (8, 64), (4, 32)
        slots, max_len = args.slots, 128
    trace = make_trace(n_req, prompt_rng, new_rng, cfg.vocab_size)

    sizes = []
    m = 1
    while m <= args.devices:
        sizes.append(m)
        m *= 2
    entries: List[Dict] = []
    for m in sizes:
        mesh = jax.make_mesh((1, m), ("data", "model"))
        packed = pack_tree_psq(params, qcfg, cache, mesh=mesh)
        r = bench_mode("continuous", packed, cfg, trace, slots, max_len,
                       mesh=mesh)
        entry = {"devices": m, "mesh": f"data=1,model={m}",
                 "pack_stats": cache.stats(), **r}
        entries.append(entry)
        print(f"[serve_bench] sharded model={m}: "
              f"{r['tokens_per_s']:8.1f} tok/s  "
              f"occupancy {r['mean_slot_occupancy']:.2f}")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--psq-packed", action="store_true",
                    help="serve from the weight-stationary PackedLayer cache")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace + model (CI mode)")
    ap.add_argument("--paged", action="store_true",
                    help="run only the paged shared-prefix section")
    ap.add_argument("--recurrent", action="store_true",
                    help="run only the recurrent-family (zamba2/xlstm) "
                         "continuous-vs-static section")
    ap.add_argument("--device-loop", action="store_true",
                    help="run only the device-loop horizon sweep "
                         "(decode_horizon 1/8/32)")
    ap.add_argument("--streaming", action="store_true",
                    help="run only the live-arrival streaming section "
                         "(seeded Poisson schedule through the "
                         "submit/poll front-end) plus the fcfs vs "
                         "cost-aware admission comparison")
    ap.add_argument("--moe", action="store_true",
                    help="run only the MoE serving section: continuous "
                         "granite-moe single-device vs expert-parallel "
                         "(with --devices N) with a bit-exact token "
                         "check (BENCH_serve_moe.json)")
    ap.add_argument("--spec", action="store_true",
                    help="run only the speculative-decoding + side-input "
                         "section: vanilla vs spec_k in {2,4} with floor/"
                         "ceiling drafts plus whisper/llava continuous-vs-"
                         "static, all token-matched "
                         "(BENCH_serve_spec.json)")
    ap.add_argument("--energy", action="store_true",
                    help="run only the modeled energy/EDAP section: "
                         "styles x occupancy-grid sweep on one "
                         "psq-packed engine run (BENCH_serve_energy.json)")
    ap.add_argument("--devices", type=int, default=0,
                    help="CPU virtual devices for the tensor-parallel mesh "
                         "sweep (must be the first JAX use in the process)")
    ap.add_argument("--out", default="BENCH_serve.json",
                    help="JSON output path")
    args = ap.parse_args()
    if args.devices:
        # safe despite the module-level jax import: the flag is read at
        # backend INIT, and nothing above touches devices before run()
        from repro.launch.mesh import force_host_device_count

        force_host_device_count(args.devices)
    result = run(args)
    result["kernel_backends"] = registry.describe()
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[serve_bench] wrote {args.out}")


if __name__ == "__main__":
    main()
