"""Executor layer: compiled step functions + one ``run_round()`` per
serving strategy.

The scheduler (``serve/scheduler.py``) decides WHAT is admitted; the
slot state (``serve/state.py``) owns WHERE it lives; this module owns
HOW a decode round actually executes. Three continuous-mode executors
share one interface — ``run_round()`` advances every live slot at least
one token, drains device results, stamps boundary timestamps and
retires finished slots:

:class:`DeviceHorizonExecutor`
    greedy serving's default: one jit call takes up to
    ``decode_horizon`` on-device steps (``models.decode
    .decode_multi_step[_paged]``) with on-device argmax and per-slot
    EOS/budget flags — the host syncs once per horizon.

:class:`HostLoopExecutor`
    the legacy per-token round-trip (temperature sampling, or
    ``device_loop=False``): one decode step, host-side sampling,
    EOS/budget checks and retirement.

:class:`SpecRoundExecutor`
    speculative decoding: the draft proposes ``spec_k`` tokens, the
    main model verifies them in one masked forward, the longest
    argmax-matching prefix plus a bonus token is emitted, and the
    rollback is a per-slot length stamp through the slot-state
    interface (paged: plus page truncation).

:class:`StaticBatchExecutor`
    the static oracle mode: a fixed batch prefills together and
    decodes in lockstep until every member finishes.

Executors never touch the queue or the admission policy, which is what
makes prefill/decode disaggregation a scheduler-level change: two
engines running different executors can pass paged blocks without
either one learning new step logic.

:func:`build_compiled` is the single factory for every jitted closure
(prefill, insert, decode, horizon loop, paged and speculative
variants) — fresh closures per engine so compile-cache accounting
(``_cache_size``) is per-instance, and donation/static-argnum choices
live in exactly one place.
"""
from __future__ import annotations

import time
from types import SimpleNamespace
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import decode as D
from repro.serve.scheduler import next_pow2, right_pad


def build_compiled(eng) -> SimpleNamespace:
    """Build every jitted closure the engine's executors use.

    The cache-donating jits update the slot pool in place (the same
    trick as launch/dryrun.py's decode cells) — donation survives
    sharding because in/out slot-pool leaves keep the same
    NamedSharding. Horizon/propose step counts are static argnums: one
    compile per value.
    """
    cfg, ecfg = eng.cfg, eng.ecfg
    fns = SimpleNamespace()

    if ecfg.paged:
        def _decode_paged(p, tok, cache, bt):
            with eng._ctx():
                return D.decode_step_paged(
                    p, cfg, tok, cache, bt,
                    attn_backend=ecfg.paged_attn_backend,
                )

        def _insert_paged(cache, src_kv, row, slot, slot_row, start,
                          total):
            with eng._ctx():
                return D.paged_cache_insert(
                    cache, src_kv, row, slot, slot_row, start, total
                )

        def _prefill_suffix(p, toks, cache, slot_row, plen):
            with eng._ctx():
                return D.prefill_paged_suffix(
                    p, cfg, toks, cache, slot_row, plen
                )

        def _copy_page(cache, src, dst):
            # copy-on-write: duplicate one page across all layers
            kv = cache["kv"]
            return {**cache, "kv": {
                "k": kv["k"].at[:, dst].set(kv["k"][:, src]),
                "v": kv["v"].at[:, dst].set(kv["v"][:, src]),
            }}

        def _decode_multi_paged(p, cache, bt, last, live, eos, budget,
                                horizon):
            with eng._ctx():
                return D.decode_multi_step_paged(
                    p, cfg, cache, bt, last, live, eos, budget,
                    horizon, attn_backend=ecfg.paged_attn_backend,
                )

        fns.decode_paged = jax.jit(_decode_paged, donate_argnums=(2,))
        fns.insert_paged = jax.jit(_insert_paged, donate_argnums=(0,))
        fns.prefill_suffix = jax.jit(_prefill_suffix)
        fns.copy_page = jax.jit(_copy_page, donate_argnums=(0,))
        # horizon is static: one compile per horizon value
        fns.decode_multi_paged = jax.jit(
            _decode_multi_paged, donate_argnums=(1,), static_argnums=(7,))

    # static path: prefill allocates the full decode-capacity cache
    def _prefill_full(p, b):
        with eng._ctx():
            return D.prefill(p, cfg, b, ecfg.max_len, dtype=jnp.float32)

    # continuous path: prefill only covers the prompt bucket — the
    # rows are scattered into the long-lived slot cache afterwards.
    # Per-row true lengths ride along so recurrent-state families
    # return exact final states under right-padding (attention
    # families need only the causal mask and ignore them). The batch
    # dict may carry side inputs (enc_embeds/patch_embeds rows
    # gathered per request): one compile per (bucket shapes, side
    # keys) combination, both fixed per engine.
    def _prefill_bucket(p, b):
        with eng._ctx():
            return D.prefill(
                p, cfg, b, b["tokens"].shape[1], dtype=jnp.float32
            )

    def _decode(p, tok, cache):
        with eng._ctx():
            return D.decode_step(p, cfg, tok, cache)

    def _insert(dst, src, row, slot, ln):
        with eng._ctx():
            return D.cache_insert(dst, src, row, slot, ln)

    # the on-device horizon loop: up to `horizon` greedy steps per
    # call, cache donated across the whole loop
    def _decode_multi(p, cache, last, live, eos, budget, horizon):
        with eng._ctx():
            return D.decode_multi_step(
                p, cfg, cache, last, live, eos, budget, horizon
            )

    fns.prefill_full = jax.jit(_prefill_full)
    fns.prefill_bucket = jax.jit(_prefill_bucket)
    fns.decode = jax.jit(_decode, donate_argnums=(2,))
    fns.insert = jax.jit(_insert, donate_argnums=(0,))
    # horizon is static: one compile per horizon value
    fns.decode_multi = jax.jit(
        _decode_multi, donate_argnums=(1,), static_argnums=(6,))

    # speculative decoding: draft prefill/propose + main-model verify,
    # plus the tiny length-edit that IS the rollback
    if eng._spec_k:
        dcfg = ecfg.draft_config

        def _draft_prefill(p, b):
            with eng._ctx():
                return D.prefill(p, dcfg, b, b["tokens"].shape[1],
                                 dtype=jnp.float32)

        def _draft_insert(dst, src, row, slot, ln):
            with eng._ctx():
                return D.cache_insert(dst, src, row, slot, ln)

        def _draft_propose(p, cache, last, live, k_steps):
            with eng._ctx():
                return D.decode_propose(p, dcfg, cache, last, live,
                                        k_steps)

        # verify tokens are [pending, d1 .. d_{k-1}]: the last draft
        # proposal exists only to keep the draft cache one position
        # ahead (decode_propose), so props[:, :-1] drops it
        def _verify(p, cache, last, props):
            with eng._ctx():
                toks = jnp.concatenate(
                    [last[:, None], props[:, :-1]], axis=1)
                return D.decode_verify(p, cfg, toks, cache)

        def _set_len(cache, lens):
            return {**cache, "length": lens}

        fns.draft_prefill = jax.jit(_draft_prefill)
        fns.draft_insert = jax.jit(_draft_insert, donate_argnums=(0,))
        fns.draft_propose = jax.jit(
            _draft_propose, donate_argnums=(1,), static_argnums=(4,))
        fns.verify = jax.jit(_verify, donate_argnums=(1,))
        fns.set_len = jax.jit(_set_len, donate_argnums=(0,))
        if ecfg.paged:
            def _verify_paged(p, cache, bt, live, last, props):
                with eng._ctx():
                    toks = jnp.concatenate(
                        [last[:, None], props[:, :-1]], axis=1)
                    logits, kv_new = D.prefill_paged_suffix(
                        p, cfg, toks, cache, bt, cache["length"],
                        per_token_ffn=True)
                    kv = D.paged_verify_commit(
                        cache["kv"], kv_new, cache["length"], bt, live)
                    return logits, {**cache, "kv": kv}

            fns.verify_paged = jax.jit(_verify_paged, donate_argnums=(1,))
    return fns


class _Executor:
    """Shared executor plumbing: engine/slot-state handles and the
    boundary retirement that every strategy performs the same way."""

    def __init__(self, eng):
        self.eng = eng

    @property
    def state(self):
        return self.eng.state

    def retire(self, slot: int, request, now: float) -> None:
        self.eng._finish(request, now)
        self.state.retire(slot)     # paged: releases page refcounts

    def run_round(self) -> None:
        raise NotImplementedError


class DeviceHorizonExecutor(_Executor):
    """One host round-trip: up to ``decode_horizon`` decode steps on
    device (``models.decode.decode_multi_step[_paged]``), then drain
    the returned token buffer, stamp ONE boundary timestamp, and
    retire finished slots. The loop exits early on device once every
    live slot is done, so short tails don't burn horizon steps."""

    def run_round(self) -> None:
        eng = self.eng
        slots = self.state.slots
        n = eng.ecfg.max_batch
        h = eng.ecfg.decode_horizon
        paged = eng.ecfg.paged
        live = self.state.live_flags()
        budget = np.zeros((n,), np.int32)
        eos = np.full((n,), -1, np.int32)
        for i, r in enumerate(slots):
            if r is None:
                continue
            budget[i] = r.max_new_tokens - len(r.output)
            eos[i] = r.eos_id
        t0 = time.time()
        if paged:
            mgr = self.state.mgr
            # a CoW valve can only resolve on the host; if one would
            # trigger past the first position (reachable via fork()
            # only — full-page publishing keeps shared pages full),
            # fall back to a single-step round
            if any(mgr.mid_horizon_cow(i, min(h, int(budget[i])))
                   for i, s in enumerate(slots) if s is not None):
                h = 1

            # never pre-reserve past the pool: shrink this round's
            # horizon until the worst-case fresh-page demand fits the
            # free list (halving keeps the static-horizon compile set
            # at O(log H) entries under sustained pressure)
            bs = eng.ecfg.block_size

            def _new_pages(hh: int) -> int:
                need = 0
                for i, s in enumerate(slots):
                    if s is None:
                        continue
                    end = int(mgr.lengths[i]) + min(hh, int(budget[i]))
                    need += max(0, -(-end // bs)
                                - len(mgr.slot_blocks(i)))
                return need

            while h > 1 and _new_pages(h) > mgr.pool.free_blocks:
                h //= 2
            # pre-reserve the whole horizon: grow each live slot's
            # table min(h, budget) tokens ahead (fresh pages at block
            # boundaries, eager copy-on-write when shared) so the
            # device loop never needs the host mid-horizon
            for i, s in enumerate(slots):
                if s is None:
                    continue
                for _ in range(min(h, int(budget[i]))):
                    self.state.prepare_append(i)
            buf, emitted, done, last, cache, steps = eng._decode_multi_paged(
                eng.params, eng._cache, jnp.asarray(mgr.tables),
                jnp.asarray(self.state.last_tok), jnp.asarray(live),
                jnp.asarray(eos), jnp.asarray(budget), h)
        else:
            buf, emitted, done, last, cache, steps = eng._decode_multi(
                eng.params, eng._cache, jnp.asarray(self.state.last_tok),
                jnp.asarray(live), jnp.asarray(eos), jnp.asarray(budget), h)
        eng._cache = cache
        buf, emitted = np.asarray(buf), np.asarray(emitted)
        done, last, steps = np.asarray(done), np.asarray(last), int(steps)
        now = time.time()
        eng.host_syncs += 1
        eng.decode_wall_s += now - t0
        eng.decode_steps += steps
        # occupancy per DEVICE step: slot i was live at step s of the
        # horizon iff it emitted more than s tokens
        for s in range(steps):
            eng.step_occupancy.append(float(np.sum(emitted > s)) / n)
        new_tokens = 0
        for i, r in enumerate(slots):
            if r is None:
                continue
            r.output.extend(int(t) for t in buf[i, :emitted[i]])
            # energy: only tokens a live slot actually emitted (retired
            # rows keep stepping under the no-op mask — burned compute on
            # the TPU, but no modeled crossbar work is attributed)
            new_tokens += int(emitted[i])
            self.state.last_tok[i] = int(last[i])
            if done[i]:
                self.retire(i, r, now)       # freed at THIS boundary
        eng.account_decode(new_tokens)


class HostLoopExecutor(_Executor):
    """Legacy per-token round-trip (temperature sampling, or
    ``device_loop=False``): one decode step, host-side sampling,
    EOS/budget checks and retirement."""

    def run_round(self) -> None:
        eng = self.eng
        slots = self.state.slots
        n = eng.ecfg.max_batch
        paged = eng.ecfg.paged
        eng.step_occupancy.append(sum(s is not None for s in slots) / n)
        t0 = time.time()
        if paged:
            # grow each live slot's table by one token (a fresh
            # page at block boundaries, copy-on-write if shared)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                self.state.prepare_append(i)
            logits, cache = eng._decode_paged(
                eng.params, jnp.asarray(self.state.last_tok)[:, None],
                eng._cache, jnp.asarray(self.state.mgr.tables))
        else:
            logits, cache = eng._decode(
                eng.params, jnp.asarray(self.state.last_tok)[:, None],
                eng._cache)
        eng._cache = cache
        nxt = np.asarray(eng._sample(logits[:, 0]))
        eng.decode_steps += 1
        eng.host_syncs += 1
        now = time.time()
        eng.decode_wall_s += now - t0
        new_tokens = 0
        for i, r in enumerate(slots):
            if r is None:
                continue
            t = int(nxt[i])
            r.output.append(t)
            new_tokens += 1
            self.state.last_tok[i] = t
            if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                self.retire(i, r, now)       # freed THIS step
        eng.account_decode(new_tokens)


class SpecRoundExecutor(_Executor):
    """One speculative round: draft proposes, the main model verifies,
    the longest argmax-matching proposal prefix plus one bonus token is
    emitted, and both caches roll back to the accepted length.

    The draft runs k+1 masked steps so its cache holds every position a
    full acceptance needs (``decode_propose``); the verify commits k+1
    K/V positions but leaves lengths untouched, so the rollback is the
    single set-lengths stamp at the end (paged: plus
    ``PagedKVManager.truncate`` page releases). Paged rounds pre-reserve
    all k+1 positions per live slot BEFORE the verify; if the fresh-page
    demand exceeds the free list the round runs at width 1 — exactly a
    vanilla decode step (the admission headroom invariant guarantees one
    position always fits) — which keeps the draft cache in lockstep
    under pool pressure. Every emitted token is a main-model argmax at
    the same cache state vanilla decode would have, so outputs are
    token-identical to vanilla greedy serving.
    """

    def run_round(self) -> None:
        eng = self.eng
        slots = self.state.slots
        n = eng.ecfg.max_batch
        k = eng._spec_k
        paged = eng.ecfg.paged
        live = self.state.live_flags()
        n_live = int(live.sum())
        t0 = time.time()
        k_round = k
        base_len = None
        if paged:
            mgr = self.state.mgr
            bs = eng.ecfg.block_size
            base_len = [int(mgr.lengths[i]) for i in range(n)]
            need = 0
            for i, s in enumerate(slots):
                if s is None:
                    continue
                end = base_len[i] + k + 1
                need += max(0, -(-end // bs)
                            - len(mgr.slot_blocks(i)))
            if need > mgr.pool.free_blocks:
                k_round = 0
            for i, s in enumerate(slots):
                if s is None:
                    continue
                for _ in range(k_round + 1):
                    self.state.prepare_append(i)
        live_dev = jnp.asarray(live)
        last_dev = jnp.asarray(self.state.last_tok)
        props, eng._draft_cache = eng._draft_propose(
            eng.draft_params, eng._draft_cache, last_dev, live_dev,
            k_round + 1)
        if paged:
            logits, eng._cache = eng._verify_paged(
                eng.params, eng._cache,
                jnp.asarray(self.state.mgr.tables),
                live_dev, last_dev, props)
        else:
            logits, eng._cache = eng._verify(eng.params, eng._cache,
                                             last_dev, props)
        # one host sync per round: the proposals and the verify argmaxes
        # land together (async dispatch keeps the draft/verify pipelined)
        m = np.asarray(jnp.argmax(logits, axis=-1))     # (n, k_round+1)
        props = np.asarray(props)
        now = time.time()
        eng.host_syncs += 1
        eng.decode_wall_s += now - t0
        eng.decode_steps += 1
        eng.spec_rounds += 1
        eng.step_occupancy.append(n_live / n)
        new_tokens = 0
        for i in range(n):
            r = slots[i]
            if r is None:
                continue
            a = 0
            while a < k_round and props[i, a] == m[i, a]:
                a += 1
            eng.spec_proposed += k_round
            eng.spec_accepted += a
            for t in m[i, :a + 1]:
                t = int(t)
                r.output.append(t)
                new_tokens += 1
                self.state.last_tok[i] = t
                if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                    self.retire(i, r, now)
                    break
            if paged and slots[i] is not None:
                self.state.truncate(i, base_len[i] + a + 1)
        eng.account_decode(new_tokens)
        # the rollback: both caches' lengths snap to the accepted
        # position (free slots to 0); junk K/V above the watermark is
        # never attended and the next round overwrites it in place
        lens = np.zeros((n,), np.int32)
        for i, r in enumerate(slots):
            if r is not None:
                lens[i] = (eng._patch_len + len(r.prompt)
                           + len(r.output) - 1)
        self.state.set_lengths(lens)
        eng._draft_cache = eng._set_len(eng._draft_cache,
                                        jnp.asarray(lens))


class StaticBatchExecutor(_Executor):
    """The static oracle mode: one batch prefills together (batch dim
    pow2-bucketed so compiles stay enumerable) and decodes in lockstep
    until every member finishes."""

    def run_batch(self, reqs: List) -> None:
        eng = self.eng
        nreq = len(reqs)
        # pow2-bucket the batch dim: _prefill_full compiles once per
        # (batch bucket, padded length) pair instead of once per exact
        # admitted batch size (batch rows are independent everywhere in
        # the model, so padding rows are inert)
        bp = min(next_pow2(nreq), eng.ecfg.max_batch)
        # RIGHT-pad every family to a pow2 length bucket + per-row true
        # lengths: the causal mask keeps pad columns out of attention,
        # the lengths make recurrent prefill exact, and decode advances
        # each row at its own position (vector cache lengths) — so
        # mixed-length static batches decode bit-exactly with the
        # sequential and continuous paths. (The historical left-pad
        # variant was NOT exact for mixed lengths: pad positions sat
        # inside the causal window and leaked into attention.)
        w = eng._bucket(max(len(r.prompt) for r in reqs))
        toks, lens = right_pad(reqs, bp, w)
        b = eng._prefill_batch(reqs, bp, toks, lens)
        logits, cache = eng._prefill_full(eng.params, b)
        eng.account_prefill(sum(len(r.prompt) for r in reqs))
        # each row's first token comes from its true last prompt position
        nxt = eng._sample(
            logits[jnp.arange(bp), jnp.maximum(b["lengths"] - 1, 0)])
        first = np.asarray(nxt)
        t_first = time.time()
        for i, r in enumerate(reqs):
            t = int(first[i])
            r.output.append(t)
            r.t_first_token = t_first
            if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                r.done, r.t_done = True, t_first
        # submit() bounds every request's own writes (side/spec overhead
        # included), so live rows never clamp; a finished row that keeps
        # stepping only touches its own junk tail — batch rows are
        # independent and the cache dies with the batch
        max_new = max(r.max_new_tokens for r in reqs)
        for _ in range(max_new - 1):
            # occupancy relative to the slot pool a continuous scheduler
            # would have: retired-but-held and unfilled slots count as idle
            n_alive = sum(
                not r.done and len(r.output) < r.max_new_tokens for r in reqs
            )
            if n_alive == 0:
                break
            eng.step_occupancy.append(n_alive / eng.ecfg.max_batch)
            logits, cache = eng._decode(
                eng.params, jnp.asarray(nxt)[:, None], cache
            )
            eng.decode_steps += 1
            nxt = eng._sample(logits[:, 0])
            arr = np.asarray(nxt)
            now = time.time()
            new_tokens = 0
            for i, r in enumerate(reqs):
                if r.done or len(r.output) >= r.max_new_tokens:
                    continue
                t = int(arr[i])
                r.output.append(t)
                new_tokens += 1
                if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                    r.done, r.t_done = True, now
            eng.account_decode(new_tokens)
        now = time.time()
        for r in reqs:
            r.done = True
            r.t_done = r.t_done or now
            eng.finished.append(r)
