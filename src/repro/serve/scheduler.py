"""Scheduling layer: requests, engine config, admission policies.

This module owns every *decision* about which request runs where —
the :class:`ServeEngine` facade (``serve/engine.py``) only wires the
layers together, and the executors (``serve/executor.py``) only run
what admission already placed.

Three pieces:

``Request`` / ``EngineConfig``
    the public request record and engine knob set.
    :meth:`EngineConfig.validate` is the ONE place every invalid knob
    combination raises — the engine calls it once at construction,
    and standalone callers (launchers, tests) can call it directly.

``AdmissionPolicy``
    the protocol behind mid-flight admission. Two implementations:

    * :class:`Pow2BucketFCFS` (default) — the queue head plus any
      later requests sharing its pow2 prompt-length bucket, FIFO
      otherwise, capped by free slots and ``prefill_batch``. This is
      byte-identical to the policy historically inlined in the engine.
    * :class:`CostAwareEnergyBudget` — the same bucket selection,
      additionally budgeted against the modeled per-request serve
      energy (:class:`EnergyModel`, pJ): a request is admitted only
      while the summed worst-case energy of in-flight requests stays
      under ``EngineConfig.energy_budget_pj``. The queue head is
      always admitted when nothing is in flight, so the engine can
      never deadlock on an over-budget head. HCiM's scale-factor
      array makes the energy signal cheap and static (pack-time
      occupancy metadata), which is what makes admission-time pricing
      practical — the cost-model-driven CiM design loop of Andrulis
      et al. (2024) applied to scheduling.

``ContiguousAdmitter`` / ``PagedAdmitter``
    the admission *mechanism*: bucketed prefill batches, slot
    scatter, radix prefix reuse and page-pool headroom math. They
    consult the policy for the take decision and the engine for
    compiled functions and telemetry.
"""
from __future__ import annotations

import dataclasses
import time
from typing import (
    Any, Callable, Dict, List, Optional, Protocol, Sequence, Tuple,
)

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import decode as D
from repro.serve.paged_kv import PoolExhausted


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    slot: int = -1                # decode slot served in (continuous mode)
    extra_idx: int = -1           # side-input row (-1: positional by uid)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # decode slot-pool size (static: batch size)
    max_len: int = 256            # KV capacity per slot
    temperature: float = 0.0      # 0 => greedy
    seed: int = 0
    mode: str = "auto"            # auto | continuous | static
    prefill_batch: int = 4        # max requests per bucketed prefill call
    min_bucket: int = 8           # smallest prompt-length bucket
    eos_id: int = -1              # default EOS for submit() (-1: never)
    # on-device multi-step decode (continuous greedy serving only):
    # one jit call advances every slot up to decode_horizon steps
    # (models.decode.decode_multi_step) — host syncs per horizon, not
    # per token. device_loop=False forces the legacy per-token path.
    decode_horizon: int = 1
    device_loop: bool = True
    # paged KV layout (continuous scheduler only; see docs/memory.md)
    paged: bool = False           # page pool + block tables vs stripes
    block_size: int = 16          # tokens per KV page (divides max_len)
    num_blocks: int = 0           # pool pages; 0 => auto (2x slot capacity)
    prefix_reuse: bool = True     # radix-index shared-prefix reuse
    paged_attn_backend: Optional[str] = None  # None => inline gather path
    # hwmodel accounting style for stats()["energy_pj_total"] etc.
    # (repro.hwmodel.system.serve_energy): adc | quarry | hcim
    energy_style: str = "hcim"
    # speculative decoding (continuous greedy serving only): a draft
    # model proposes spec_k tokens per slot, decode_verify scores them
    # in one forward, rollback is a per-slot length edit. 0 => off.
    # draft_params ride in as a ServeEngine constructor argument.
    spec_k: int = 0
    draft_config: Optional[ArchConfig] = None
    # admission policy (docs/scheduling.md): "fcfs" is the pow2-bucket
    # FIFO wave; "cost-aware" budgets in-flight requests against the
    # modeled serve energy cap below (pJ, worst-case per request).
    admission_policy: str = "fcfs"
    energy_budget_pj: float = 0.0

    def resolve_mode(self) -> str:
        mode = self.mode
        if mode == "auto":
            # every family serves continuously — side inputs included
            # (admission gathers per-request rows; the slot pool carries
            # cross-KV / patch-offset state). "auto" always resolves
            # continuous; "static" remains as an explicit oracle mode.
            return "continuous"
        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown engine mode {mode!r}")
        return mode

    def validate(self, cfg: ArchConfig, *, mode: Optional[str] = None,
                 has_draft_params: bool = False,
                 extra: Optional[Dict[str, Any]] = None) -> str:
        """Raise on every invalid knob combination; returns the resolved
        mode. The single home of engine-config validation — the checks
        run in a fixed order (mode, horizon, spec, energy style, paged
        layout, admission policy) so each invalid combination raises
        the same message regardless of which other knobs are also set.
        """
        extra = extra or {}
        if mode is None:
            mode = self.resolve_mode()
        if self.decode_horizon < 1:
            raise ValueError(
                f"decode_horizon must be >= 1, got {self.decode_horizon}"
            )
        if self.decode_horizon > 1 and self.temperature > 0.0:
            raise ValueError(
                "decode_horizon > 1 runs the on-device greedy loop; "
                "temperature sampling needs the per-token host path "
                "(set decode_horizon=1)"
            )
        if self.decode_horizon > 1 and not self.device_loop:
            raise ValueError(
                "decode_horizon > 1 requires device_loop=True"
            )
        if self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {self.spec_k}")
        if self.spec_k:
            dcfg = self.draft_config
            if dcfg is None or not has_draft_params:
                raise ValueError(
                    "speculative decoding (spec_k > 0) needs both "
                    "EngineConfig.draft_config and a draft_params tree"
                )
            if mode != "continuous":
                raise ValueError(
                    f"speculative decoding requires the continuous "
                    f"scheduler; resolved mode is {mode!r}"
                )
            if cfg.family not in D._SPEC_FAMILIES:
                raise ValueError(
                    f"speculative decoding supports the pure KV-cache "
                    f"families {D._SPEC_FAMILIES}, got {cfg.family!r}: "
                    f"recurrent state folds every token and cannot roll "
                    f"back by a length edit"
                )
            if self.temperature > 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only (acceptance "
                    "compares draft proposals with main-model argmaxes); "
                    "set temperature=0"
                )
            if self.decode_horizon != 1:
                raise ValueError(
                    "speculative decoding replaces the device horizon "
                    "loop; set decode_horizon=1"
                )
            if dcfg.family != cfg.family:
                raise ValueError(
                    f"draft family {dcfg.family!r} must match the target "
                    f"family {cfg.family!r}"
                )
            if dcfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    "draft and target models must share a vocabulary "
                    f"({dcfg.vocab_size} != {cfg.vocab_size})"
                )
            if cfg.family in ("encdec", "vlm") and dcfg.d_model != cfg.d_model:
                raise ValueError(
                    "side-input families need draft d_model == target "
                    "d_model: enc_embeds/patch_embeds rows feed both "
                    f"models ({dcfg.d_model} != {cfg.d_model})"
                )
        from repro.hwmodel.system import SERVE_STYLES
        if self.energy_style not in SERVE_STYLES:
            raise ValueError(
                f"unknown energy_style {self.energy_style!r}; "
                f"choose from {SERVE_STYLES}"
            )
        if self.paged:
            if cfg.family not in D._PAGED_FAMILIES:
                reason = (
                    "recurrent state has no sequence axis to page — serve "
                    "it through the contiguous continuous scheduler "
                    "(paged=False)"
                    if cfg.family in ("hybrid", "ssm") else
                    "cross-attention KV has no pages — serve it through "
                    "the contiguous continuous scheduler (paged=False)"
                )
                raise ValueError(
                    f"paged KV cache supports attention-KV families "
                    f"{D._PAGED_FAMILIES}, got {cfg.family!r}: {reason}"
                )
            if cfg.family == "vlm" and "patch_embeds" in extra:
                raise ValueError(
                    "paged KV cache does not take per-request "
                    "patch_embeds: the radix prefix index keys on token "
                    "ids alone, so a reused prefix page could alias "
                    "another request's patch context; serve through the "
                    "contiguous continuous scheduler (paged=False)"
                )
            if mode != "continuous":
                raise ValueError(
                    f"paged KV cache requires the continuous scheduler; "
                    f"resolved mode is {mode!r}"
                )
            if self.max_len % self.block_size:
                raise ValueError(
                    f"max_len ({self.max_len}) must be a multiple of "
                    f"block_size ({self.block_size})"
                )
        if self.admission_policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"unknown admission_policy {self.admission_policy!r}; "
                f"choose from {tuple(ADMISSION_POLICIES)}"
            )
        if self.energy_budget_pj < 0:
            raise ValueError(
                f"energy_budget_pj must be >= 0, got "
                f"{self.energy_budget_pj}"
            )
        if self.admission_policy == "cost-aware" and self.energy_budget_pj <= 0:
            raise ValueError(
                "cost-aware admission needs a positive "
                "EngineConfig.energy_budget_pj cap (pJ of modeled "
                "in-flight serve energy; see docs/scheduling.md)"
            )
        return mode


# -- energy pricing ---------------------------------------------------------

def collect_mvm_layers(node, path: str = "") -> List[tuple]:
    """Walk a served param tree and list its MVM layers for the hwmodel.

    Returns ``(name, k, o, occupancy_or_None, quant_cfg_or_None)`` per
    linear — PackedLayer nodes carry their pack-time occupancy metadata
    and QuantConfig; raw param dicts (fp / QAT trees, key ``"w"`` of rank
    2 or 3) are modeled dense. Embedding tables (key ``"table"``) are
    lookups, not MVMs, and are skipped. Stacked rank-3 weights count one
    layer per leading index (scan-over-layers packs; MoE expert banks are
    modeled as all-experts-resident, the PUMA weight-stationary story).
    """
    out: List[tuple] = []
    if node is None:
        return out
    if hasattr(node, "w_codes"):             # PackedLayer (2-D or stacked)
        w = node.w_codes
        if w.ndim == 3:
            for l in range(int(w.shape[0])):
                out.append((f"{path}[{l}]", int(w.shape[1]),
                            int(w.shape[2]), None, node.cfg))
        else:
            out.append((path, int(w.shape[0]), int(w.shape[1]),
                        node.occupancy, node.cfg))
        return out
    if isinstance(node, dict):
        w = node.get("w")
        if getattr(w, "ndim", 0) in (2, 3) and "table" not in node:
            if w.ndim == 3:
                for l in range(int(w.shape[0])):
                    out.append((f"{path}[{l}]", int(w.shape[1]),
                                int(w.shape[2]), None, None))
            else:
                out.append((path, int(w.shape[0]), int(w.shape[1]),
                            None, None))
            return out
        for k in sorted(node):
            out.extend(collect_mvm_layers(node[k], f"{path}/{k}"))
        return out
    if isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            out.extend(collect_mvm_layers(v, f"{path}[{i}]"))
        return out
    return out


class EnergyModel:
    """hwmodel-in-the-loop energy pricing for one served param tree.

    One pass over the tree at construction collects every MVM shape plus
    its pack-time occupancy metadata; the per-token modeled cost is
    evaluated once (all hwmodel energy terms are linear in ``n_vec``)
    and scaled by the true forward-pass token count. This object is the
    SINGLE energy-accounting hook: admission and the executors call
    :meth:`add` at their prefill/decode boundaries, nothing else touches
    the token counter. It also prices requests for the cost-aware
    admission policy (:meth:`request_cost_pj`).
    """

    def __init__(self, params, style: str):
        from repro.hwmodel.system import serve_energy

        self.style = style
        self.tokens = 0              # true tokens through the model
        self.shapes: List[tuple] = []
        self.occ: Dict[str, float] = {}
        self.kw: Dict[str, Any] = {}
        self.per_token: Optional[Dict[str, Any]] = None
        mvms = collect_mvm_layers(params)
        if not mvms:
            return
        self.shapes = [(name, k, o, 1) for name, k, o, _, _ in mvms]
        self.occ = {
            name: (occ.mean_zero_fraction if occ is not None else 0.0)
            for name, _, _, occ, _ in mvms
        }
        qcfg = next((c for _, _, _, _, c in mvms if c is not None), None)
        if qcfg is not None:
            self.kw = dict(
                xbar_rows=qcfg.xbar_rows,
                n_bits_a=qcfg.spec.n_bits_a,
                n_bits_w=qcfg.spec.n_bits_w,
                n_bits_sf=qcfg.spec.n_bits_sf,
                adc_bits=qcfg.adc_bits,
                levels=qcfg.psq_levels,
            )
        self.per_token = serve_energy(
            self.shapes, occupancy=self.occ, style=style, **self.kw,
        )

    def add(self, n_tokens: int) -> None:
        """Attribute ``n_tokens`` true forward-pass tokens (prefill or
        decode) — the one accounting call site."""
        self.tokens += int(n_tokens)

    def reset(self) -> None:
        self.tokens = 0

    def request_cost_pj(self, r: Request) -> float:
        """Worst-case modeled serve energy of one request: every prompt
        token prefills and the full decode budget is spent. Prefix reuse
        and early EOS only lower the realized figure, so budgeting on
        this keeps the cost-aware cap conservative."""
        if self.per_token is None:
            return 0.0
        return self.per_token["energy_pj"] * (len(r.prompt)
                                              + r.max_new_tokens)

    def summary(self, n_finished: int) -> Dict[str, float]:
        """The ``stats()`` energy fragment (zeros before any token is
        served, and for trees with no MVM layers)."""
        e, tok = self.per_token, self.tokens
        total = e["energy_pj"] * tok if e is not None else 0.0
        return {
            "energy_style": self.style,
            "energy_tokens": tok,
            "energy_pj_per_token": e["energy_pj"] if e is not None else 0.0,
            "energy_pj_total": total,
            "energy_pj_per_request": (total / n_finished
                                      if n_finished else 0.0),
            "edap_total": (total * (e["latency_ns"] * tok) * e["area_mm2"]
                           if e is not None else 0.0),
            "mean_occupancy": e["occupancy"] if e is not None else 0.0,
        }

    def report(self, styles=None, occupancy=None) -> Dict[str, Dict]:
        """Modeled per-style totals for the tokens served so far."""
        from repro.hwmodel.system import SERVE_STYLES, serve_energy

        if not self.shapes:
            return {}
        occ = self.occ if occupancy is None else occupancy
        tok = self.tokens
        rep: Dict[str, Dict] = {}
        for s in (styles or SERVE_STYLES):
            e = serve_energy(self.shapes, occupancy=occ, style=s, **self.kw)
            rep[s] = {
                "energy_pj_per_token": e["energy_pj"],
                "energy_pj_total": e["energy_pj"] * tok,
                "edap_total": (e["energy_pj"] * tok) * (e["latency_ns"] * tok)
                              * e["area_mm2"],
                "occupancy": e["occupancy"],
            }
        return rep


# -- admission policies -----------------------------------------------------

class AdmissionPolicy(Protocol):
    """The admission decision: which queued requests join this wave.

    ``take`` sees the queue in FIFO order, the wave size cap, a
    ``bucket_of`` callable (pow2 prompt-length bucket) and the list of
    in-flight requests; it returns the selected requests in queue order
    (possibly empty — the engine then decodes instead of admitting).
    ``admits_head`` is the single-admission variant used by the paged
    shared-prefix path, which admits the head alone.
    """
    name: str

    def take(self, queue: Sequence[Request], limit: int,
             bucket_of: Callable[[Request], int],
             eligible: Optional[Callable[[Request], bool]] = None,
             live: Sequence[Request] = ()) -> List[Request]: ...

    def admits_head(self, head: Request,
                    live: Sequence[Request]) -> bool: ...


class Pow2BucketFCFS:
    """Default policy: the queue head plus any later requests sharing
    its pow2 prompt-length bucket, FIFO otherwise — one prefill compile
    per (bucket length, bucket batch) pair."""

    name = "fcfs"

    def take(self, queue, limit, bucket_of, eligible=None, live=()):
        head = queue[0]
        w = bucket_of(head)
        take = [head]
        for r in queue[1:]:
            if len(take) >= limit:
                break
            if bucket_of(r) == w and (eligible is None or eligible(r)):
                take.append(r)
        return take

    def admits_head(self, head, live):
        return True


class CostAwareEnergyBudget(Pow2BucketFCFS):
    """FCFS bucket selection gated by a modeled-energy budget.

    In-flight requests hold their worst-case serve energy
    (:meth:`EnergyModel.request_cost_pj`) against ``budget_pj``; a
    candidate joins the wave only while the total stays under the cap.
    Retirement returns a request's share, so deferred requests admit on
    later waves. The queue head is always admitted when nothing is in
    flight and nothing was selected — an over-budget head must not
    deadlock the engine (it simply serves alone).
    """

    name = "cost-aware"

    def __init__(self, budget_pj: float,
                 cost_fn: Callable[[Request], float]):
        if budget_pj <= 0:
            raise ValueError(
                f"cost-aware admission needs a positive budget_pj, "
                f"got {budget_pj}"
            )
        self.budget_pj = float(budget_pj)
        self.cost_fn = cost_fn
        self.deferrals = 0           # requests bumped to a later wave

    def _inflight_pj(self, live) -> float:
        return sum(self.cost_fn(r) for r in live)

    def take(self, queue, limit, bucket_of, eligible=None, live=()):
        base = super().take(queue, limit, bucket_of, eligible, live)
        spent = self._inflight_pj(live)
        out: List[Request] = []
        for r in base:
            c = self.cost_fn(r)
            if spent + c <= self.budget_pj or (not out and not live):
                out.append(r)
                spent += c
            else:
                self.deferrals += 1
        return out

    def admits_head(self, head, live):
        if not live:
            return True
        if self._inflight_pj(live) + self.cost_fn(head) <= self.budget_pj:
            return True
        self.deferrals += 1
        return False


ADMISSION_POLICIES = ("fcfs", "cost-aware")


def resolve_admission_policy(ecfg: EngineConfig,
                             energy: EnergyModel) -> AdmissionPolicy:
    if ecfg.admission_policy == "cost-aware":
        return CostAwareEnergyBudget(ecfg.energy_budget_pj,
                                     energy.request_cost_pj)
    return Pow2BucketFCFS()


# -- admission mechanism ----------------------------------------------------

class ContiguousAdmitter:
    """Fill free slots from the queue with one bucketed prefill call.

    The policy picks the wave (queue head plus bucket-mates under the
    default FCFS); prompts are right-padded to (pow2 batch, pow2 length)
    so prefill shapes stay enumerable, each row's first token is sampled
    from its TRUE last-prompt position, and each row's prefilled state —
    KV, recurrent rows, cross-attention KV — scatters into its slot via
    the :class:`~repro.serve.state.SlotState` insert interface. With
    speculative decoding on, the draft model prefills the SAME batch and
    its rows scatter into the draft pool in lockstep.
    """

    def __init__(self, eng):
        self.eng = eng

    def admit(self, free: List[int]) -> bool:
        eng = self.eng
        queue = eng.queue
        limit = min(len(free), eng.ecfg.prefill_batch)
        live = [s for s in eng.state.slots if s is not None]
        take = eng.policy.take(queue, limit, eng._bucket_of, live=live)
        if not take:
            return False
        for r in take:
            queue.remove(r)

        m = len(take)
        mp = min(next_pow2(m), eng.ecfg.prefill_batch)
        w = eng._bucket_of(take[0])
        toks, lens = right_pad(take, mp, w)
        b = eng._prefill_batch(take, mp, toks, lens)
        logits, pcache = eng._prefill_bucket(eng.params, b)
        dcache = None
        if eng._spec_k:
            _, dcache = eng._draft_prefill(eng.draft_params, b)
        eng.account_prefill(sum(len(r.prompt) for r in take))
        # each row's next token comes from its true last prompt position
        idx = jnp.asarray([len(r.prompt) - 1 for r in take]
                          + [0] * (mp - m))
        first = np.asarray(eng._sample(logits[jnp.arange(mp), idx]))
        now = time.time()
        for i, r in enumerate(take):
            r.t_first_token = now
            t = int(first[i])
            r.output.append(t)
            if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                eng._finish(r, now)                  # never occupies a slot
                continue
            slot = free.pop(0)
            ln = eng._patch_len + len(r.prompt)
            eng.state.insert(pcache, i, slot, ln)
            if dcache is not None:
                eng._draft_cache = eng._draft_insert(
                    eng._draft_cache, dcache, i, slot, ln)
            eng.state.bind(r, slot, t)
            eng.admissions.append(
                {"step": eng.decode_steps, "uid": r.uid, "slot": slot})
        return True


class PagedAdmitter:
    """Admit from the queue into free slots through the radix index.

    A queue head with a cached shared prefix admits alone: the reused
    pages are ref-bumped into its block table and ONLY the un-cached
    suffix is prefilled against them
    (``models.decode.prefill_paged_suffix``). Cold requests batch
    through the same pow2-bucketed prefill as the contiguous path, then
    scatter into their private pages. Either way, the prompt's full
    pages are published to the index for later requests.

    ``admit`` returns ``progressed``. ``False`` means the page pool (or
    the energy budget) could not hold the queue head: nothing was
    admitted, and the caller must STOP admitting and decode instead —
    retirement frees pages and budget — rather than spin on the head.
    """

    def __init__(self, eng):
        self.eng = eng

    @property
    def mgr(self):
        return self.eng.state.mgr

    def admit(self, free: List[int]) -> bool:
        eng = self.eng
        if self.mgr.match_tokens([int(t) for t in eng.queue[0].prompt]):
            return self._admit_suffix(free)
        return self._admit_cold(free)

    def worst_case_pages(self, r: Request) -> int:
        """Pages ``r`` occupies if it decodes to its full budget: the
        cache length peaks at len(prompt) + max_new_tokens - 1 (the last
        sampled token is never appended). A speculative verify round can
        additionally write spec_k proposal positions past that peak
        before rolling back, so spec engines budget those pages too."""
        end = len(r.prompt) + r.max_new_tokens - 1 + self.eng._spec_k
        return -(-end // self.eng.ecfg.block_size)

    def headroom(self) -> int:
        """Free pages minus the growth still owed to live slots.

        Admission must budget for decode growth, not just the prompt:
        admitting on prompt pages alone can deadlock mid-decode when
        every live slot needs its next page and nothing is retirable.
        Gating on this headroom keeps the invariant that owed growth
        always fits the free list, so ``prepare_append`` cannot exhaust
        the pool between horizon boundaries.
        """
        owed = 0
        for i, s in enumerate(self.eng.state.slots):
            if s is None:
                continue
            owed += max(0, self.worst_case_pages(s)
                        - len(self.mgr.slot_blocks(i)))
        return self.mgr.pool.free_blocks - owed

    def _place(self, r: Request, slot: int, token: int,
               now: float) -> None:
        """Record a freshly-admitted request in its slot (or retire it on
        the spot when the prefill token already finishes it)."""
        eng = self.eng
        r.t_first_token = now
        r.output.append(token)
        if token == r.eos_id or len(r.output) >= r.max_new_tokens:
            eng._finish(r, now)
            self.mgr.retire(slot)  # pages freed; the prefix stays indexed
            return
        eng.state.bind(r, slot, token)
        eng.admissions.append(
            {"step": eng.decode_steps, "uid": r.uid, "slot": slot})

    def _admit_suffix(self, free: List[int]) -> bool:
        # peek, don't pop: if the pool can't hold the head's pages the
        # request must stay queued (admit() rolls its allocation back)
        eng = self.eng
        r = eng.queue[0]
        slot = free[0]
        prompt = [int(t) for t in r.prompt]
        live = [s for s in eng.state.slots if s is not None]
        if not eng.policy.admits_head(r, live):
            return False
        # full shared prefix pages are reused; everything else — the
        # prompt tail AND the decode growth — must fit the headroom
        cached_probe = self.mgr.match_tokens(prompt)
        need = (self.worst_case_pages(r)
                - cached_probe // eng.ecfg.block_size)
        if need > self.headroom():
            return False
        try:
            cached = self.mgr.admit(slot, prompt)
        except PoolExhausted:
            return False
        eng.queue.pop(0)
        free.pop(0)
        suffix = r.prompt[cached:]
        w = eng._bucket(len(suffix))
        toks = np.zeros((1, w), np.int32)
        toks[0, :len(suffix)] = suffix
        # gather only a pow2 bucket of prefix pages, not the whole
        # table — suffix attention width scales with the prefix, and
        # compile count stays one per (suffix, prefix) bucket pair
        bs = eng.ecfg.block_size
        pb = min(next_pow2(-(-cached // bs)), len(self.mgr.tables[slot]))
        logits, src = eng._prefill_suffix(
            eng.params, jnp.asarray(toks), eng._cache,
            jnp.asarray(self.mgr.tables[slot][:pb])[None],
            np.int32(cached),
        )
        # reused prefix costs nothing — only the suffix runs the model
        eng.account_prefill(len(suffix))
        eng.cached_prefix_tokens += cached
        eng._cache = eng._insert_paged(
            eng._cache, src, 0, slot, jnp.asarray(self.mgr.tables[slot]),
            np.int32(cached), len(prompt))
        self.mgr.register(slot, prompt)
        first = np.asarray(eng._sample(logits[:, len(suffix) - 1]))
        self._place(r, slot, int(first[0]), time.time())
        if eng._spec_k and eng.state.slots[slot] is r:
            # the draft pool is contiguous and reuses no prefixes: it
            # prefills the FULL prompt even when the main model only
            # ran the suffix
            wf = eng._bucket(len(prompt))
            dt = np.zeros((1, wf), np.int32)
            dt[0, :len(prompt)] = prompt
            db = {"tokens": jnp.asarray(dt),
                  "lengths": jnp.asarray(np.array([len(prompt)], np.int32))}
            _, dc = eng._draft_prefill(eng.draft_params, db)
            eng._draft_cache = eng._draft_insert(
                eng._draft_cache, dc, 0, slot, len(prompt))
        return True

    def _admit_cold(self, free: List[int]) -> bool:
        # same take policy as the contiguous admitter: the queue head
        # plus FIFO-later requests sharing its length bucket — but only
        # other index misses (a hit admits alone through the suffix path)
        eng = self.eng
        limit = min(len(free), eng.ecfg.prefill_batch)
        live = [s for s in eng.state.slots if s is not None]
        take = eng.policy.take(
            eng.queue, limit, eng._bucket_of,
            eligible=lambda r: not self.mgr.match_tokens(
                [int(t) for t in r.prompt]),
            live=live)
        if not take:
            return False
        w = eng._bucket_of(take[0])

        # claim pages first so nothing registers mid-batch: identical
        # prompts inside one cold batch each prefill privately (the
        # second one hits the index only on a LATER admission). A
        # PoolExhausted admit rolls itself back and stops the batch
        # there — only successfully-placed requests leave the queue,
        # the rest wait for retirement to free pages.
        placed = []
        headroom = self.headroom()
        for r in take:
            slot = free[0]
            prompt = [int(t) for t in r.prompt]
            # gate on the full worst case (prompt + decode growth), not
            # just the prompt pages admit() allocates now — earlier
            # batch members' growth stays owed against the same free
            # list until they retire
            need = self.worst_case_pages(r)
            if need > headroom:
                break
            try:
                self.mgr.admit(slot, prompt)
            except PoolExhausted:
                break
            headroom -= need         # prompt pages taken + growth owed
            free.pop(0)
            placed.append((r, slot, prompt))
        if not placed:
            return False
        for r, _, _ in placed:
            eng.queue.remove(r)

        m = len(placed)
        mp = min(next_pow2(m), eng.ecfg.prefill_batch)
        toks, lens = right_pad([r for r, _, _ in placed], mp, w)
        b = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens)}
        logits, pcache = eng._prefill_bucket(eng.params, b)
        dcache = None
        if eng._spec_k:
            _, dcache = eng._draft_prefill(eng.draft_params, b)
        eng.account_prefill(sum(len(r.prompt) for r, _, _ in placed))
        idx = jnp.asarray([len(r.prompt) - 1 for r, _, _ in placed]
                          + [0] * (mp - m))
        first = np.asarray(eng._sample(logits[jnp.arange(mp), idx]))
        now = time.time()
        for i, (r, slot, prompt) in enumerate(placed):
            eng._cache = eng._insert_paged(
                eng._cache, pcache["kv"], i, slot,
                jnp.asarray(self.mgr.tables[slot]), np.int32(0),
                len(prompt))
            self.mgr.register(slot, prompt)
            self._place(r, slot, int(first[i]), now)
            if dcache is not None and eng.state.slots[slot] is r:
                eng._draft_cache = eng._draft_insert(
                    eng._draft_cache, dcache, i, slot, len(prompt))
        return True


def right_pad(reqs: List[Request], rows: int,
              width: int) -> Tuple[np.ndarray, np.ndarray]:
    """RIGHT-padded token block + true-length vector for a prefill
    batch: the causal mask keeps pad columns out of attention, the
    lengths keep them out of recurrent state (models/decode.prefill).
    Rows beyond ``len(reqs)`` are batch-bucket padding (length 0)."""
    toks = np.zeros((rows, width), np.int32)
    lens = np.zeros((rows,), np.int32)
    for i, r in enumerate(reqs):
        toks[i, : len(r.prompt)] = r.prompt
        lens[i] = len(r.prompt)
    return toks, lens
