"""Paged KV cache: fixed block pool + shared-prefix radix index.

The continuous-batching engine (PR 2/3) allocates one contiguous
``max_len`` KV stripe per decode slot and re-prefills identical system
prompts for every request. This module replaces that stripe with the
classic paged layout: the device holds ONE pool of fixed-size KV pages
(``block_size`` tokens each) per layer stack, and every slot owns a
*block table* — a row of page indices mapping sequence position
``t`` to ``(table[t // block_size], t % block_size)``.

Three host-side pieces cooperate (all device work stays in
``models/decode.py`` / ``serve/engine.py``):

``BlockPool``
  A ref-counted allocator over page ids. Page 0 is the reserved *trash*
  page: free slots' table rows point at it, so the fixed-shape decode
  scatter always has somewhere harmless to write. A page is returned to
  the free list exactly when its refcount reaches zero.

``RadixPrefixIndex``
  A token-prefix-hash chain over FULL pages of prefilled prompts: page
  ``i`` of a prompt is keyed by ``(parent_node, tokens[i*bs:(i+1)*bs])``,
  so ``lookup`` walks the longest already-prefilled prefix page by page.
  The index holds one reference on every registered page; eviction is
  LRU over *leaf* nodes whose page nobody else references (so a cached
  chain never loses an interior page).

``PagedKVManager``
  The engine-facing facade: ``admit`` reuses cached prefix pages and
  allocates private pages for the rest of the prompt, ``register``
  publishes a prompt's full pages to the index, ``prepare_append``
  grows a slot's table one token at a time during decode (allocating a
  fresh page at every ``block_size`` boundary, copy-on-write if the
  target page is shared), and ``retire`` drops all of a slot's
  references. Shared pages are immutable by construction — only full
  pages are ever published, and decode/suffix writes always land in
  private pages — so copy-on-write is a safety valve, not a hot path.

Example — two prompts sharing one full page:

    >>> mgr = PagedKVManager(n_slots=2, block_size=4, num_blocks=8,
    ...                      max_blocks=4)
    >>> mgr.admit(0, [1, 2, 3, 4, 9])       # cold: nothing cached yet
    0
    >>> mgr.register(0, [1, 2, 3, 4, 9])    # publish page [1,2,3,4]
    >>> mgr.admit(1, [1, 2, 3, 4, 7, 8])    # warm: first page reused
    4
    >>> int(mgr.pool.refcount(mgr.tables[1][0]))  # slot 0 + slot 1 + index
    3
    >>> mgr.retire(0); mgr.retire(1)
    >>> mgr.stats()["cached_tokens"]
    4

See docs/memory.md for the full layout and eviction rules.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "BlockPool",
    "PagedKVManager",
    "PoolExhausted",
    "RadixPrefixIndex",
]

TRASH_BLOCK = 0


class PoolExhausted(RuntimeError):
    """No free page and nothing evictable — the pool is undersized."""


class BlockPool:
    """Ref-counted allocator over ``num_blocks`` fixed-size KV pages.

    Page 0 (:data:`TRASH_BLOCK`) is reserved forever — its refcount is
    pinned so it can never be handed out, and free slots' block tables
    point at it so masked decode writes stay in-bounds.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the trash page)")
        self.num_blocks = num_blocks
        self._ref = np.zeros(num_blocks, np.int32)
        self._ref[TRASH_BLOCK] = 1
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))

    def alloc(self) -> int:
        """Hand out a free page with refcount 1; raises :class:`PoolExhausted`."""
        if not self._free:
            raise PoolExhausted(
                f"no free KV page ({self.num_blocks} total)"
            )
        bid = self._free.pop()
        self._ref[bid] = 1
        return bid

    def retain(self, bid: int) -> None:
        assert self._ref[bid] > 0, f"retain of free page {bid}"
        self._ref[bid] += 1

    def release(self, bid: int) -> bool:
        """Drop one reference; returns True when the page went free."""
        assert bid != TRASH_BLOCK, "release of the trash page"
        assert self._ref[bid] > 0, f"double free of page {bid}"
        self._ref[bid] -= 1
        if self._ref[bid] == 0:
            self._free.append(bid)
            return True
        return False

    def refcount(self, bid: int) -> int:
        return int(self._ref[bid])

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        # excludes the trash page
        return self.num_blocks - 1 - len(self._free)

    def check_invariants(self) -> None:
        """Every page is either free (ref 0) or live (ref > 0), exactly once."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page on the free list"
        assert TRASH_BLOCK not in free
        for bid in range(self.num_blocks):
            if bid == TRASH_BLOCK:
                assert self._ref[bid] >= 1
            elif bid in free:
                assert self._ref[bid] == 0, f"free page {bid} has refs"
            else:
                assert self._ref[bid] > 0, f"live page {bid} has no refs"


@dataclasses.dataclass
class _Node:
    nid: int
    parent: int                    # parent node id (0 = root)
    tokens: Tuple[int, ...]        # the page's block_size tokens
    block: int                     # pool page id holding the prefilled KV
    children: int = 0
    tick: int = 0                  # LRU stamp


class RadixPrefixIndex:
    """Token-prefix-hash chain over full prefilled pages.

    Each node is one FULL page of some prompt, keyed by
    ``(parent_node_id, page_tokens)`` — the chain of keys from the root
    is exactly the token prefix, so lookups cannot alias two different
    prefixes (keys compare the actual tokens, the hash is only the dict
    bucket). The index owns one pool reference per node.
    """

    _ROOT = 0

    def __init__(self, pool: BlockPool, block_size: int):
        self.pool = pool
        self.block_size = block_size
        self._by_key: Dict[Tuple[int, Tuple[int, ...]], _Node] = {}
        self._by_id: Dict[int, _Node] = {}
        self._next_id = 1
        self._tick = 0

    def __len__(self) -> int:
        return len(self._by_key)

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.tick = self._tick

    def _chain(self, tokens: Sequence[int], limit: Optional[int]):
        """Yield the cached nodes covering ``tokens``, root outward."""
        bs = self.block_size
        n = len(tokens) if limit is None else min(limit, len(tokens))
        parent = self._ROOT
        for i in range(n // bs):
            key = (parent, tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            node = self._by_key.get(key)
            if node is None:
                return
            yield node
            parent = node.nid

    def match_len(self, tokens: Sequence[int],
                  limit: Optional[int] = None) -> int:
        """Pages a :meth:`lookup` would return — no refs, no LRU touch."""
        return sum(1 for _ in self._chain(tokens, limit))

    def lookup(self, tokens: Sequence[int], limit: Optional[int] = None
               ) -> List[int]:
        """Longest cached full-page prefix of ``tokens``, as pool page ids.

        Walks at most ``limit`` tokens (default: all). Every returned
        page is RETAINED on behalf of the caller — the caller owns one
        reference per page and must release them (slot retirement).
        """
        out: List[int] = []
        for node in self._chain(tokens, limit):
            self.pool.retain(node.block)
            self._touch(node)
            out.append(node.block)
        return out

    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Publish the full pages of ``tokens`` (held in ``blocks``).

        Pages already present keep their existing node (a duplicate
        prefilled privately stays private); new nodes retain their page
        on behalf of the index. Returns the number of nodes added.
        """
        bs = self.block_size
        added = 0
        parent = self._ROOT
        for i in range(len(tokens) // bs):
            key = (parent, tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            node = self._by_key.get(key)
            if node is None:
                node = _Node(self._next_id, parent, key[1], int(blocks[i]))
                self._next_id += 1
                self._by_key[key] = node
                self._by_id[node.nid] = node
                if parent != self._ROOT:
                    self._by_id[parent].children += 1
                self.pool.retain(node.block)
                added += 1
            self._touch(node)
            parent = node.nid
        return added

    def _evict_one(self) -> bool:
        """Drop the LRU leaf whose page only the index still references."""
        best: Optional[_Node] = None
        for node in self._by_key.values():
            if node.children:
                continue
            if self.pool.refcount(node.block) != 1:
                continue          # a live slot still reads this page
            if best is None or node.tick < best.tick:
                best = node
        if best is None:
            return False
        del self._by_key[(best.parent, best.tokens)]
        del self._by_id[best.nid]
        if best.parent != self._ROOT:
            self._by_id[best.parent].children -= 1
        self.pool.release(best.block)
        return True

    def evict(self, n_blocks: int) -> int:
        """Free up to ``n_blocks`` pages, LRU-leaf-first; returns #freed."""
        freed = 0
        while freed < n_blocks and self._evict_one():
            freed += 1
        return freed


class PagedKVManager:
    """Host-side paged-KV bookkeeping for one decode slot pool.

    Device state (the page pool tensors) lives in the engine; this class
    owns the allocator, the block tables (a ``(n_slots, max_blocks)``
    int32 array whose rows feed the gather-based paged decode step) and
    the shared-prefix index. ``prefix_reuse=False`` keeps the paged
    layout but never consults or fills the index.
    """

    def __init__(self, n_slots: int, block_size: int, num_blocks: int,
                 max_blocks: int, prefix_reuse: bool = True):
        self.n_slots = n_slots
        self.block_size = block_size
        self.max_blocks = max_blocks
        self.prefix_reuse = prefix_reuse
        self.pool = BlockPool(num_blocks)
        self.index = RadixPrefixIndex(self.pool, block_size)
        self.tables = np.zeros((n_slots, max_blocks), np.int32)
        self.lengths = np.zeros(n_slots, np.int64)
        self._slot_blocks: List[List[int]] = [[] for _ in range(n_slots)]
        # telemetry
        self.cached_tokens = 0      # prompt tokens served from the index
        self.cow_copies = 0
        self.evictions = 0

    # -- allocation ---------------------------------------------------------
    def _alloc(self) -> int:
        try:
            return self.pool.alloc()
        except PoolExhausted:
            self.evictions += self.index.evict(1)
            return self.pool.alloc()   # raises again if eviction found nothing

    # -- request lifecycle --------------------------------------------------
    def match_tokens(self, prompt: Sequence[int]) -> int:
        """Prompt tokens :meth:`admit` would serve from the index — a
        non-mutating probe (no refs, no LRU touch) with the same
        last-token re-prefill guard, so schedulers can route cold
        requests to batched prefill without touching index state."""
        if not self.prefix_reuse or len(prompt) < 2:
            return 0
        return (self.index.match_len(prompt, limit=len(prompt) - 1)
                * self.block_size)

    def admit(self, slot: int, prompt: Sequence[int]) -> int:
        """Install ``prompt``'s block table into ``slot``.

        Reuses cached prefix pages (full pages only, and never the whole
        prompt — at least one token is always re-prefilled so admission
        has logits to sample the first output from) and allocates
        private pages for the rest. Returns the number of prompt tokens
        whose KV is already in the pool — the engine prefills only
        ``prompt[cached:]``.
        """
        assert not self._slot_blocks[slot], f"slot {slot} already occupied"
        plen = len(prompt)
        assert plen >= 1
        cached: List[int] = []
        if self.prefix_reuse:
            # limit = plen - 1: the last token is always recomputed
            cached = self.index.lookup(prompt, limit=plen - 1)
        n_cached_tok = len(cached) * self.block_size
        n_total = -(-plen // self.block_size)      # ceil
        fresh: List[int] = []
        try:
            for _ in range(n_total - len(cached)):
                fresh.append(self._alloc())
        except PoolExhausted:
            # undo the partial claim: an undersized pool must not leak
            # the refs lookup() took or the pages already allocated
            for bid in cached + fresh:
                self.pool.release(bid)
            raise
        blocks = cached + fresh
        self._slot_blocks[slot] = blocks
        self.tables[slot, :] = TRASH_BLOCK
        self.tables[slot, :len(blocks)] = blocks
        self.lengths[slot] = plen
        self.cached_tokens += n_cached_tok
        return n_cached_tok

    def register(self, slot: int, prompt: Sequence[int]) -> None:
        """Publish the slot's full prompt pages to the prefix index."""
        if self.prefix_reuse:
            self.index.insert(prompt, self._slot_blocks[slot])

    def prepare_append(self, slot: int) -> Optional[Tuple[int, int]]:
        """Make position ``lengths[slot]`` writable; advance the length.

        Called once per live slot before every decode step. Allocates a
        fresh page at each ``block_size`` boundary. If the target page
        is shared (refcount > 1 — cannot happen under the full-page
        publishing rule, but kept as the copy-on-write safety valve),
        replaces it with a private copy and returns ``(src, dst)`` page
        ids so the engine copies the device contents; otherwise None.
        """
        pos = int(self.lengths[slot])
        bi = pos // self.block_size
        assert bi < self.max_blocks, f"slot {slot} grew past its table"
        blocks = self._slot_blocks[slot]
        cow: Optional[Tuple[int, int]] = None
        if bi == len(blocks):
            bid = self._alloc()
            blocks.append(bid)
            self.tables[slot, bi] = bid
        elif self.pool.refcount(blocks[bi]) > 1:
            src = blocks[bi]
            dst = self._alloc()
            self.pool.release(src)
            blocks[bi] = dst
            self.tables[slot, bi] = dst
            self.cow_copies += 1
            cow = (src, dst)
        self.lengths[slot] = pos + 1
        return cow

    def mid_horizon_cow(self, slot: int, steps: int) -> bool:
        """Would a copy-on-write valve trigger *mid*-horizon for this slot?

        Non-mutating probe for the device-loop engine: before running
        ``steps`` decode steps on device, positions ``lengths[slot] + 1
        .. lengths[slot] + steps - 1`` must not land in a *shared* page
        — the engine can eagerly resolve a CoW at the first position
        (it copies the page before launching the loop) but not at later
        ones, because the device loop never returns to the host between
        steps. Returns True if any later position's page is shared
        (refcount > 1), in which case the engine falls back to
        horizon=1 for this round. Under the full-page publishing rule
        shared pages are always full, so this is only reachable via
        :meth:`fork`; the probe is conservative and cheap either way.
        """
        pos0 = int(self.lengths[slot])
        bs = self.block_size
        blocks = self._slot_blocks[slot]
        for j in range(1, steps):
            bi = (pos0 + j) // bs
            if bi < len(blocks) and self.pool.refcount(blocks[bi]) > 1:
                return True
        return False

    def fork(self, src_slot: int, dst_slot: int) -> None:
        """Share ``src_slot``'s whole table with ``dst_slot`` (ref-bumped).

        The copy-on-write path in :meth:`prepare_append` keeps both
        slots correct once either starts writing. Exercised by the
        property tests; the greedy engine itself never forks.
        """
        assert not self._slot_blocks[dst_slot]
        blocks = list(self._slot_blocks[src_slot])
        for bid in blocks:
            self.pool.retain(bid)
        self._slot_blocks[dst_slot] = blocks
        self.tables[dst_slot, :] = self.tables[src_slot, :]
        self.lengths[dst_slot] = self.lengths[src_slot]

    def truncate(self, slot: int, new_len: int) -> None:
        """Roll the slot back to ``new_len`` tokens, releasing surplus pages.

        The speculative-decode rollback: verify pre-reserves ``k + 1``
        positions via :meth:`prepare_append`; rejected proposals shrink
        the slot to the accepted length by keeping only the first
        ``ceil(new_len / block_size)`` pages. Released pages are always
        the freshly-reserved private tail — rollback targets include the
        full prompt, and shared prefix pages are full pages *within* the
        prompt — so this never releases an index-published page out from
        under another slot (``release`` still balances refcounts if a
        forked table shares the tail). Partially-filled kept pages hold
        rejected-token junk above ``new_len``; the next round's writes
        land exactly there before any read can see it.
        """
        if not 0 <= new_len <= int(self.lengths[slot]):
            raise ValueError(
                f"truncate target {new_len} outside "
                f"[0, {int(self.lengths[slot])}] for slot {slot}"
            )
        keep = -(-new_len // self.block_size)      # ceil
        blocks = self._slot_blocks[slot]
        while len(blocks) > keep:
            bid = blocks.pop()
            self.tables[slot, len(blocks)] = TRASH_BLOCK
            self.pool.release(bid)
        self.lengths[slot] = new_len

    def retire(self, slot: int) -> None:
        """Release every page the slot references; clear its table row."""
        for bid in self._slot_blocks[slot]:
            self.pool.release(bid)
        self._slot_blocks[slot] = []
        self.tables[slot, :] = TRASH_BLOCK
        self.lengths[slot] = 0

    # -- introspection ------------------------------------------------------
    def reset_counters(self) -> None:
        """Zero the telemetry counters (cached/CoW/eviction tallies).

        Pool and index STATE — live pages, tables, cached chains — is
        untouched: resetting telemetry must not drop the prefix cache.
        """
        self.cached_tokens = 0
        self.cow_copies = 0
        self.evictions = 0

    def slot_blocks(self, slot: int) -> List[int]:
        return list(self._slot_blocks[slot])

    def check_invariants(self) -> None:
        self.pool.check_invariants()
        for s, blocks in enumerate(self._slot_blocks):
            for i, bid in enumerate(blocks):
                assert self.tables[s, i] == bid
                assert self.pool.refcount(bid) >= 1
            for i in range(len(blocks), self.max_blocks):
                assert self.tables[s, i] == TRASH_BLOCK

    def stats(self) -> Dict[str, int]:
        return {
            "num_blocks": self.pool.num_blocks,
            "used_blocks": self.pool.used_blocks,
            "free_blocks": self.pool.free_blocks,
            "indexed_blocks": len(self.index),
            "cached_tokens": self.cached_tokens,
            "cow_copies": self.cow_copies,
            "evictions": self.evictions,
        }
