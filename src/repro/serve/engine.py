"""Continuous-batching serving engine — the thin facade over three layers.

The stack is layered (docs/architecture.md, docs/scheduling.md):
``serve/scheduler.py`` owns decisions (Request/EngineConfig + the one
``validate()`` home, the AdmissionPolicy protocol with its FCFS and
cost-aware energy-budget policies, EnergyModel pricing, admitters);
``serve/state.py`` owns placement (SlotState over the contiguous
stripe, paged block pool and recurrent leaves); ``serve/executor.py``
owns execution (``build_compiled`` makes every jitted closure, and the
host-loop / device-horizon / spec-round / static executors advance the
pool behind one ``run_round()``). This module wires them together and
preserves the public API: ``submit()`` then ``run()`` (drain) or
``step()`` (one round — the streaming front-end in ``launch/serve.py``
polls incremental tokens between steps), plus ``stats()`` /
``energy_report()`` / ``throughput_stats``.

Both scheduling modes (continuous slot pool; static drain-the-queue
oracle) are bit-exact with sequential decoding — right-padded pow2
prefill buckets + per-row true lengths — and all shapes are fixed
after warm-up so nothing recompiles (asserted by the tier-1 suite).
Side-input families, speculative decoding, the paged KV layout and
multi-device meshes all serve through the same facade; see
docs/serving.md for the matrix.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import decode as D
from repro.parallel.sharding import (
    axis_rules,
    rules_for_mesh,
    shard_expert_params,
)
from repro.serve.executor import (
    DeviceHorizonExecutor,
    HostLoopExecutor,
    SpecRoundExecutor,
    StaticBatchExecutor,
    build_compiled,
)
from repro.serve.paged_kv import PagedKVManager, PoolExhausted
from repro.serve.scheduler import (
    ContiguousAdmitter,
    EngineConfig,
    EnergyModel,
    PagedAdmitter,
    Request,
    next_pow2,
    resolve_admission_policy,
    right_pad,
)
from repro.serve.state import ContiguousSlotState, PagedSlotState

PyTree = Any

# families the continuous scheduler admits mid-flight — all of them
_CONTINUOUS_FAMILIES = ("dense", "moe", "vlm", "hybrid", "ssm", "encdec")

# encoder width for encdec engines built WITHOUT enc_embeds (zero rows
# at a fixed width, so both schedulers agree on the cross-KV pool shape)
_DEFAULT_ENC_LEN = 8

# recurrent-state families: admission scatters state rows, not KV stripes
_RECURRENT_FAMILIES = ("hybrid", "ssm")


class ServeEngine:
    """Submit prompts, then :meth:`run` to completion (or :meth:`step`
    one scheduling round at a time for streaming callers);
    ``stats()`` exposes scheduler counters on top of throughput."""

    def __init__(self, params: PyTree, cfg: ArchConfig, ecfg: EngineConfig,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None,
                 mesh: Optional[Mesh] = None, rules=None,
                 draft_params: Optional[PyTree] = None):
        if params is not None:
            # per-token-invariant decode constants (e.g. Mamba2's
            # A = -exp(A_log)) fold into the served tree once at load
            params = D.hoist_decode_params(params, cfg)
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.extra = extra_inputs or {}
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._uid = 0
        self._key = jax.random.PRNGKey(ecfg.seed)
        # ONE validation pass raises on every invalid knob combination
        self.mode = ecfg.validate(cfg, has_draft_params=draft_params
                                  is not None, extra=self.extra)

        # side-input geometry is fixed per engine so the pools compile once
        enc = self.extra.get("enc_embeds")
        self._enc_len = (int(np.asarray(enc).shape[1])
                         if enc is not None and np.asarray(enc).size
                         else _DEFAULT_ENC_LEN)
        pe = self.extra.get("patch_embeds")
        self._patch_len = (int(np.asarray(pe).shape[1])
                           if cfg.family == "vlm" and pe is not None
                           and np.asarray(pe).size else 0)

        # the device loop is greedy-only (on-device argmax, no RNG carry);
        # sampling stays host-side and spec decode has its own round loop
        self._use_device_loop = (
            self.mode == "continuous"
            and ecfg.device_loop
            and ecfg.temperature <= 0.0
            and not ecfg.spec_k
        )
        self._spec_k = int(ecfg.spec_k)
        self.draft_params = (D.hoist_decode_params(draft_params,
                                                   ecfg.draft_config)
                             if self._spec_k else None)

        # multi-device serving: rules activate around every traced
        # function (cache slots shard over "data", packed PSQ layers go
        # tensor-parallel over "model"; mesh=None = no-op annotations).
        # An "expert" axis places MoE expert stacks at load.
        self.mesh = mesh
        self._rules = rules if rules is not None else rules_for_mesh(mesh)
        if (mesh is not None and params is not None
                and "expert" in getattr(mesh, "axis_names", ())):
            self.params = params = shard_expert_params(
                params, mesh, self._rules
            )

        # scheduler telemetry (continuous mode)
        self.decode_steps = 0
        self.host_syncs = 0              # decode round-trips (jit + drain)
        self.decode_wall_s = 0.0         # wall time inside decode syncs
        self.prefill_calls = 0
        self.prefill_tokens = 0          # true (unpadded) tokens prefilled
        self.cached_prefix_tokens = 0    # prompt tokens served from pages
        self.step_occupancy: List[float] = []
        self.admissions: List[Dict[str, int]] = []   # {step, uid, slot}
        # speculative decoding telemetry
        self.spec_rounds = 0
        self.spec_proposed = 0           # draft tokens put up for verify
        self.spec_accepted = 0           # draft tokens the verify kept

        # hwmodel energy pricing: admission/executors account through
        # this ONE hook; the cost-aware policy prices via the same model
        self.energy = EnergyModel(self.params, ecfg.energy_style)
        self.policy = resolve_admission_policy(ecfg, self.energy)

        # slot-state layer: contiguous stripes or the paged block pool
        # (PERSISTENT — prefix pages indexed in one run() feed the next)
        self._mgr = None
        self._cache = None
        self._draft_cache = None
        if ecfg.paged:
            mb = ecfg.max_len // ecfg.block_size
            nb = ecfg.num_blocks or (1 + 2 * ecfg.max_batch * mb)
            if mesh is not None:
                dsz = mesh.shape.get("data", 1)    # divisibility for the
                nb = -(-nb // dsz) * dsz           # kv_blocks->data rule
            self._mgr = PagedKVManager(
                ecfg.max_batch, ecfg.block_size, nb, mb,
                prefix_reuse=ecfg.prefix_reuse,
            )
            self.state = PagedSlotState(self, self._mgr)
            self._cache = self.state.init_pool()
            self.admitter = PagedAdmitter(self)
        else:
            self.state = ContiguousSlotState(self)
            self.admitter = ContiguousAdmitter(self)

        # executor layer: every jitted closure in one builder, assigned
        # to the attribute names the compile-count suite introspects
        fns = build_compiled(self)
        self._prefill_full = fns.prefill_full
        self._prefill_bucket = fns.prefill_bucket
        self._decode = fns.decode
        self._insert = fns.insert
        self._decode_multi = fns.decode_multi
        if ecfg.paged:
            self._decode_paged = fns.decode_paged
            self._insert_paged = fns.insert_paged
            self._prefill_suffix = fns.prefill_suffix
            self._copy_page = fns.copy_page
            self._decode_multi_paged = fns.decode_multi_paged
        if self._spec_k:
            self._draft_prefill = fns.draft_prefill
            self._draft_insert = fns.draft_insert
            self._draft_propose = fns.draft_propose
            self._verify = fns.verify
            self._set_len = fns.set_len
            if ecfg.paged:
                self._verify_paged = fns.verify_paged

        if self.mode == "static":
            self.executor = StaticBatchExecutor(self)
        elif self._spec_k:
            self.executor = SpecRoundExecutor(self)
        elif self._use_device_loop:
            self.executor = DeviceHorizonExecutor(self)
        else:
            self.executor = HostLoopExecutor(self)

    def _ctx(self):
        """Rules-activation context entered at trace time (and for the
        eager slot-pool construction)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return axis_rules(self._rules, self.mesh)

    # -- API ---------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               extra_idx: Optional[int] = None) -> int:
        """Enqueue a prompt; returns its uid. ``eos_id=None`` resolves
        to ``EngineConfig.eos_id``; ``extra_idx`` picks the request's
        side-input row (default: positional by submission order)."""
        if eos_id is None:
            eos_id = self.ecfg.eos_id
        prompt = np.asarray(prompt, np.int32)
        # patch rows sit below the prompt and a verify can write spec_k
        # junk positions — both must fit so no KV write is ever clamped
        overhead = self._patch_len + self._spec_k
        if overhead + len(prompt) + max_new_tokens > self.ecfg.max_len:
            extra = (f" + side/spec overhead ({overhead})"
                     if overhead else "")
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f"{extra} exceeds max_len ({self.ecfg.max_len})"
            )
        self._uid += 1
        r = Request(self._uid, prompt, max_new_tokens, eos_id,
                    t_enqueue=time.time(),
                    extra_idx=-1 if extra_idx is None else int(extra_idx))
        self.queue.append(r)
        return r.uid

    @property
    def drained(self) -> bool:
        """True when nothing is queued or in flight — the streaming
        front-end's idle signal."""
        return not self.queue and not self.state.any_live

    def step(self) -> Dict[int, List[int]]:
        """One continuous scheduling round: admit at the boundary, run
        one executor round, return the tokens each touched request
        gained (``{uid: [new tokens...]}``) for streaming pollers.
        A no-op (empty dict) when nothing is queued or live."""
        if self.mode != "continuous":
            raise ValueError("step() requires the continuous scheduler; "
                             "static mode only drains through run()")
        if self.drained:
            return {}
        self._start()                    # idempotent pool allocation
        before = {r.uid: (r, len(r.output)) for r in self.queue}
        for r in self.state.slots:
            if r is not None:
                before[r.uid] = (r, len(r.output))
        # admission at the round boundary. `stalled` breaks when the
        # pool/budget can't take the queue head — retirement frees both,
        # so fall through to the executor rather than spin here.
        stalled = False
        while self.queue and not stalled:
            free = self.state.free()
            if not free:
                break
            stalled = not self.admitter.admit(free)
        if self.state.any_live:
            self.executor.run_round()
        elif stalled:
            # nothing live to retire: the pool can never hold the
            # queue head — surface it instead of spinning forever
            raise PoolExhausted(
                f"page pool ({self._mgr.pool.num_blocks} "
                f"blocks) cannot hold the queue head's "
                f"prompt plus its decode budget with no "
                f"live slots left to retire; raise "
                f"num_blocks"
            )
        # else: all admits retired at t=1 — their first tokens are the
        # round's only deltas
        return {uid: r.output[n:] for uid, (r, n) in before.items()
                if len(r.output) > n}

    def run(self) -> List[Request]:
        """Serve every queued request to completion; returns them with
        outputs (continuous: per-step retirement + mid-flight admission;
        static: fixed batches decoded in lockstep)."""
        if self.mode == "continuous":
            while not self.drained:
                self.step()
        else:
            while self.queue:
                batch = self.queue[: self.ecfg.max_batch]
                self.queue = self.queue[self.ecfg.max_batch:]
                self.executor.run_batch(batch)
        return self.finished

    def _start(self) -> None:
        """Allocate the contiguous pools lazily (the paged pool lives in
        ``__init__``); junk above the length watermark is never read, so
        one pool serves every run. Under a mesh a drained pool is
        re-placed eagerly: donated decode outputs carry XLA-canonicalized
        shardings that would retrace the warm insert closures."""
        fresh = self.mesh is not None and not self.state.any_live
        if self._cache is None or (fresh and not self.ecfg.paged):
            self._cache = self.state.init_pool()
        if self._spec_k and (self._draft_cache is None or fresh):
            # draft pool: always contiguous, mirrors slot assignment 1:1
            enc_len = self._enc_len if self.cfg.family == "encdec" else 0
            with self._ctx():
                self._draft_cache = D.cache_init(
                    self.draft_params, self.ecfg.draft_config,
                    self.ecfg.max_batch, self.ecfg.max_len,
                    dtype=jnp.float32, enc_len=enc_len)

    def reset_stats(self) -> None:
        """Clear finished requests + telemetry, keeping compiled fns
        warm and the paged prefix index populated (post-warm-up runs)."""
        self.finished = []
        self.decode_steps = 0
        self.host_syncs = 0
        self.decode_wall_s = 0.0
        self.prefill_calls = 0
        self.prefill_tokens = 0
        self.cached_prefix_tokens = 0
        self.energy.reset()
        self.step_occupancy = []
        self.admissions = []
        self.spec_rounds = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        if hasattr(self.policy, "deferrals"):
            self.policy.deferrals = 0
        if self._mgr is not None:
            self._mgr.reset_counters()   # telemetry only; pages/index kept

    def reset_counters(self) -> None:
        """Alias for :meth:`reset_stats` (paged-KV manager naming)."""
        self.reset_stats()

    # -- accounting hooks (the single energy/prefill attribution sites) ----
    def account_prefill(self, n_tokens: int) -> None:
        """One prefill call ran ``n_tokens`` TRUE prompt tokens (reused
        prefix pages cost nothing and are not reported here)."""
        self.prefill_calls += 1
        self.prefill_tokens += n_tokens
        self.energy.add(n_tokens)

    def account_decode(self, n_tokens: int) -> None:
        """A decode round emitted ``n_tokens`` true tokens (masked
        no-op steps of retired rows excluded)."""
        self.energy.add(n_tokens)

    @property
    def energy_tokens(self) -> int:
        return self.energy.tokens

    def energy_report(self, styles=None, occupancy=None) -> Dict[str, Dict]:
        """Modeled per-style totals for the tokens served so far;
        ``occupancy`` overrides the pack-time figure for what-if sweeps
        without re-serving the trace (the serve_bench energy grid)."""
        return self.energy.report(styles=styles, occupancy=occupancy)

    def stats(self) -> Dict[str, float]:
        occ = float(np.mean(self.step_occupancy)) if self.step_occupancy else 0.0
        out = {
            "mode": self.mode,
            "decode_steps": self.decode_steps,
            "host_syncs": self.host_syncs,
            "decode_wall_s": self.decode_wall_s,
            "mean_step_s": (self.decode_wall_s / self.decode_steps
                            if self.decode_steps else 0.0),
            "prefill_calls": self.prefill_calls,
            "prefill_tokens": self.prefill_tokens,
            "cached_prefix_tokens": self.cached_prefix_tokens,
            "mean_slot_occupancy": occ,
            "admissions": len(self.admissions),
            "mesh": (None if self.mesh is None else
                     "x".join(f"{k}={v}" for k, v in self.mesh.shape.items())),
            "admission_policy": self.policy.name,
            "admission_deferrals": getattr(self.policy, "deferrals", 0),
        }
        # hwmodel energy attribution (zeros before any token is served,
        # and for trees with no MVM layers)
        out.update(self.energy.summary(len(self.finished)))
        if self._spec_k:
            out.update({
                "spec_k": self._spec_k,
                "spec_rounds": self.spec_rounds,
                "spec_proposed": self.spec_proposed,
                "spec_accepted": self.spec_accepted,
                "spec_accept_rate": (self.spec_accepted / self.spec_proposed
                                     if self.spec_proposed else 0.0),
            })
        if self._mgr is not None:
            out["paged"] = self._mgr.stats()
        return out

    # -- shared helpers used by the scheduler / executor layers -------------
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / self.ecfg.temperature)

    def _bucket(self, n: int) -> int:
        return min(max(self.ecfg.min_bucket, next_pow2(n)),
                   self.ecfg.max_len)

    def _bucket_of(self, r: Request) -> int:
        return self._bucket(len(r.prompt))

    def _finish(self, r: Request, now: float) -> None:
        r.done, r.t_done = True, now
        self.finished.append(r)

    def _prefill_batch(self, reqs: List[Request], rows: int,
                       toks: np.ndarray, lens: np.ndarray) -> Dict:
        """Build a prefill batch dict with each request's side-input
        rows; shapes depend only on (rows, width, side keys), so
        prefill compiles stay enumerable."""
        b = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens)}
        if self.cfg.family == "encdec":
            b["enc_embeds"] = jnp.asarray(self._extra_rows(
                "enc_embeds", reqs, rows,
                (self._enc_len, self.cfg.d_model)))
        if self.cfg.family == "vlm" and "patch_embeds" in self.extra:
            b["patch_embeds"] = jnp.asarray(
                self._extra_rows("patch_embeds", reqs, rows, None))
        return b

    def _extra_rows(self, key: str, reqs: List[Request], bp: int,
                    default_shape) -> np.ndarray:
        """Per-request side-input rows for a prefill batch: gathered by
        ``Request.extra_idx`` (positional by submission order when
        unset) so every batch gets its OWN rows; padding rows are
        zeros (their outputs are ignored)."""
        arr = self.extra.get(key)
        if arr is None:
            arr = np.zeros((0,) + tuple(default_shape), np.float32)
        arr = np.asarray(arr)
        out = np.zeros((bp,) + arr.shape[1:], arr.dtype)
        for i, r in enumerate(reqs):
            if arr.shape[0] == 0:
                continue                     # no side inputs: zeros rows
            idx = r.extra_idx if r.extra_idx >= 0 else r.uid - 1
            if idx >= arr.shape[0]:
                raise ValueError(
                    f"request uid {r.uid} has no {key} row {idx}: "
                    f"{arr.shape[0]} rows were supplied at engine "
                    f"construction (side inputs are positional by "
                    f"submission order unless submit(extra_idx=...) "
                    f"picks a row)"
                )
            out[i] = arr[idx]
        return out

    @staticmethod
    def _right_pad(reqs: List[Request], rows: int, width: int):
        return right_pad(reqs, rows, width)


def throughput_stats(reqs: List[Request]) -> Dict[str, float]:
    """Aggregate request metrics; robust to empty/never-started requests.

    Never-started requests count toward ``requests`` but not TTFT.
    ``mean_tpot_s`` divides the two REAL timestamps each request has
    (first token at admission, completion at retirement) by its decode
    count — honest at every ``decode_horizon`` (no per-token wall times
    are fabricated inside a device horizon), and equal to true
    per-token latency at horizon 1.
    """
    if not reqs:
        return {}
    total_tokens = sum(len(r.output) for r in reqs)
    t0 = min(r.t_enqueue for r in reqs)
    finished = [r.t_done for r in reqs if r.t_done]
    elapsed = (max(finished) - t0) if finished else 0.0
    started = [r for r in reqs if r.t_first_token > 0.0]
    ttft = [r.t_first_token - r.t_enqueue for r in started]
    tpot = [
        (r.t_done - r.t_first_token) / max(len(r.output) - 1, 1)
        for r in reqs
        if r.t_done and r.t_first_token and len(r.output) > 1
    ]
    return {
        "requests": len(reqs),
        "started": len(started),
        "total_tokens": total_tokens,
        "tokens_per_s": total_tokens / elapsed if elapsed > 0 else 0.0,
        "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
        "mean_tpot_s": float(np.mean(tpot)) if tpot else 0.0,
    }
