"""Continuous-batching serving engine: slot pool + bucketed prefill.

Small-scale-runnable (CPU) but structured like a real engine. Two
scheduling modes share one API:

``continuous`` (default for KV-cache AND recurrent-state families)
  * a fixed pool of ``max_batch`` decode slots advances over the WHOLE
    pool — per-slot lengths in the stacked cache
    (``models.decode.cache_init``) keep every slot at its own position.
    Greedy serving runs the on-device horizon loop
    (``models.decode.decode_multi_step``): ONE jit call takes up to
    ``decode_horizon`` steps with on-device argmax and per-slot
    EOS/budget flags, so the host syncs once per horizon instead of
    once per token (``temperature > 0`` keeps the per-token
    host-sampled path),
  * finished sequences (EOS or max tokens) retire at every horizon
    boundary — mid-horizon they keep executing under a retirement mask
    that makes their steps cache no-ops — freeing their slot
    immediately,
  * queued requests are admitted into free slots at decode-step
    boundaries: prompts are right-padded to a power-of-two length bucket,
    prefilled as a batch, and each row's prefilled cache is scattered
    into its slot (``models.decode.cache_insert``). Attention K/V is
    exact under right-padding by the causal mask; recurrent state
    (SSM/xLSTM/hybrid) is exact because prefill threads per-row true
    lengths into the state scans — pad positions are state no-ops and
    each row's final state/conv buffer is taken at its true length,
  * all shapes are fixed after warm-up — the decode step compiles once,
    prefill/insert compile once per (bucket length, bucket batch) pair,
    and nothing recompiles afterwards (asserted by the tier-1 suite).

``static`` (fallback for side-input families, available everywhere)
  * the classic drain-the-queue loop: one batch prefills together
    (batch dim pow2-bucketed so compiles stay enumerable) and decodes
    in lockstep until every member finishes. Attention families
    left-pad to the longest prompt; recurrent families right-pad with
    per-row lengths (masked prefill), so their mixed-length static
    batches are bit-exact with sequential and continuous decoding.
    Required for per-request side inputs (encdec ``enc_embeds``, VLM
    ``patch_embeds``), which are batch-positional.

The continuous scheduler supports two KV layouts
(``EngineConfig.paged``): the default contiguous per-slot stripe, and
the paged block pool (``serve/paged_kv.py`` + ``models/decode.py``'s
``decode_step_paged``) — fixed-size KV pages reached through per-slot
block tables, with a token-prefix radix index that lets admission reuse
already-prefilled shared-prefix pages and prefill only the un-cached
suffix. Retirement releases page refcounts instead of abandoning a
stripe; reused prefixes cut prefill work without changing greedy
outputs (docs/memory.md).

PSQ-trained models serve through either mode from the weight-stationary
``PackedLayer`` cache (``serve.cache.pack_tree_psq``) — quantize + pack
once at load, stream activations past the packed state on every step:
the HCiM deployment story on TPU.

Multi-device serving: pass a ``("data", "model")`` mesh and the engine
activates the logical-axis rules around every traced function — the
decode slot pool and stacked KV cache shard over ``data`` (per-slot
state is independent, so slot parallelism is free), packed PSQ layers
execute tensor-parallel over ``model`` (column split + one psum; see
``core.psq_linear.serve_linear_tp``), and cache donation is kept across
shardings so the slot pool still updates in place. Outputs are
bit-identical to the single-device engine (tested: greedy decode parity
on 2- and 4-way meshes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import decode as D
from repro.parallel.sharding import (
    axis_rules,
    rules_for_mesh,
    shard_expert_params,
)
from repro.serve.paged_kv import PagedKVManager, PoolExhausted

PyTree = Any

# families the continuous scheduler admits mid-flight. KV-cache families
# are exact under right-padded prefill (causal mask); recurrent-state
# families (ssm/xlstm/hybrid) are exact because masked prefill makes pad
# positions state no-ops and returns each row's final state at its TRUE
# length (models/decode.prefill + per-layer `lengths` masking). Only
# side-input families (encdec enc_embeds, VLM patch_embeds) still serve
# static: their per-request inputs are batch-positional.
_CONTINUOUS_FAMILIES = ("dense", "moe", "vlm", "hybrid", "ssm")

# families whose decode state is carried recurrently (no KV sequence
# axis): slot admission scatters state rows instead of KV stripes, and
# the static fallback right-pads + tracks per-row lengths so recurrent
# prefill stays exact under mixed prompt lengths
_RECURRENT_FAMILIES = ("hybrid", "ssm")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    slot: int = -1                # decode slot served in (continuous mode)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # decode slot-pool size (static: batch size)
    max_len: int = 256            # KV capacity per slot
    temperature: float = 0.0      # 0 => greedy
    seed: int = 0
    mode: str = "auto"            # auto | continuous | static
    prefill_batch: int = 4        # max requests per bucketed prefill call
    min_bucket: int = 8           # smallest prompt-length bucket
    eos_id: int = -1              # default EOS for submit() (-1: never)
    # on-device multi-step decode (continuous greedy serving only):
    # one jit call advances every slot up to decode_horizon steps
    # (models.decode.decode_multi_step) — host syncs per horizon, not
    # per token. device_loop=False forces the legacy per-token path.
    decode_horizon: int = 1
    device_loop: bool = True
    # paged KV layout (continuous scheduler only; see docs/memory.md)
    paged: bool = False           # page pool + block tables vs stripes
    block_size: int = 16          # tokens per KV page (divides max_len)
    num_blocks: int = 0           # pool pages; 0 => auto (2x slot capacity)
    prefix_reuse: bool = True     # radix-index shared-prefix reuse
    paged_attn_backend: Optional[str] = None  # None => inline gather path
    # hwmodel accounting style for stats()["energy_pj_total"] etc.
    # (repro.hwmodel.system.serve_energy): adc | quarry | hcim
    energy_style: str = "hcim"


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _collect_mvm_layers(node, path: str = "") -> List[tuple]:
    """Walk a served param tree and list its MVM layers for the hwmodel.

    Returns ``(name, k, o, occupancy_or_None, quant_cfg_or_None)`` per
    linear — PackedLayer nodes carry their pack-time occupancy metadata
    and QuantConfig; raw param dicts (fp / QAT trees, key ``"w"`` of rank
    2 or 3) are modeled dense. Embedding tables (key ``"table"``) are
    lookups, not MVMs, and are skipped. Stacked rank-3 weights count one
    layer per leading index (scan-over-layers packs; MoE expert banks are
    modeled as all-experts-resident, the PUMA weight-stationary story).
    """
    out: List[tuple] = []
    if node is None:
        return out
    if hasattr(node, "w_codes"):             # PackedLayer (2-D or stacked)
        w = node.w_codes
        if w.ndim == 3:
            for l in range(int(w.shape[0])):
                out.append((f"{path}[{l}]", int(w.shape[1]),
                            int(w.shape[2]), None, node.cfg))
        else:
            out.append((path, int(w.shape[0]), int(w.shape[1]),
                        node.occupancy, node.cfg))
        return out
    if isinstance(node, dict):
        w = node.get("w")
        if getattr(w, "ndim", 0) in (2, 3) and "table" not in node:
            if w.ndim == 3:
                for l in range(int(w.shape[0])):
                    out.append((f"{path}[{l}]", int(w.shape[1]),
                                int(w.shape[2]), None, None))
            else:
                out.append((path, int(w.shape[0]), int(w.shape[1]),
                            None, None))
            return out
        for k in sorted(node):
            out.extend(_collect_mvm_layers(node[k], f"{path}/{k}"))
        return out
    if isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            out.extend(_collect_mvm_layers(v, f"{path}[{i}]"))
        return out
    return out


class ServeEngine:
    """Submit prompts, then :meth:`run` to completion.

    ``stats()`` exposes scheduler counters (decode steps, prefill calls,
    mean slot occupancy) on top of :func:`throughput_stats`.
    """

    def __init__(self, params: PyTree, cfg: ArchConfig, ecfg: EngineConfig,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None,
                 mesh: Optional[Mesh] = None, rules=None):
        if params is not None:
            # per-token-invariant decode constants (e.g. Mamba2's
            # A = -exp(A_log)) fold into the served tree once at load
            params = D.hoist_decode_params(params, cfg)
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.extra = extra_inputs or {}
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._uid = 0
        self._key = jax.random.PRNGKey(ecfg.seed)
        self.mode = self._resolve_mode()

        if ecfg.decode_horizon < 1:
            raise ValueError(
                f"decode_horizon must be >= 1, got {ecfg.decode_horizon}"
            )
        if ecfg.decode_horizon > 1 and ecfg.temperature > 0.0:
            raise ValueError(
                "decode_horizon > 1 runs the on-device greedy loop; "
                "temperature sampling needs the per-token host path "
                "(set decode_horizon=1)"
            )
        if ecfg.decode_horizon > 1 and not ecfg.device_loop:
            raise ValueError(
                "decode_horizon > 1 requires device_loop=True"
            )
        # the device loop is greedy-only (on-device argmax, no RNG
        # carry); temperature > 0 stays on the host-sampled path
        self._use_device_loop = (
            self.mode == "continuous"
            and ecfg.device_loop
            and ecfg.temperature <= 0.0
        )

        # multi-device serving: the rules activate around every traced
        # function, so cache slots shard over "data" (via the model's
        # constrain() annotations) and packed PSQ layers go tensor-
        # parallel over "model" (core.psq_linear.serve_linear_tp). With
        # mesh=None every annotation is a no-op — single-device engine.
        # A mesh carrying an "expert" axis defaults to the expert-
        # parallel table (RULES_EXPERT): MoE expert FFN stacks place
        # over "expert" at load and apply_moe picks its shard_map path.
        self.mesh = mesh
        self._rules = rules if rules is not None else rules_for_mesh(mesh)
        if (mesh is not None and params is not None
                and "expert" in getattr(mesh, "axis_names", ())):
            self.params = params = shard_expert_params(
                params, mesh, self._rules
            )

        # scheduler telemetry (continuous mode)
        self.decode_steps = 0
        self.host_syncs = 0              # decode round-trips (jit + drain)
        self.decode_wall_s = 0.0         # wall time inside decode syncs
        self.prefill_calls = 0
        self.prefill_tokens = 0          # true (unpadded) tokens prefilled
        self.cached_prefix_tokens = 0    # prompt tokens served from pages
        self.step_occupancy: List[float] = []
        self.admissions: List[Dict[str, int]] = []   # {step, uid, slot}

        # hwmodel-in-the-loop energy accounting: one pass over the served
        # tree at construction collects every MVM shape + its pack-time
        # occupancy metadata; per-token modeled cost is evaluated once
        # (all hwmodel energy terms are linear in n_vec) and scaled by
        # the true forward-pass token count at stats() time
        from repro.hwmodel.system import SERVE_STYLES
        if ecfg.energy_style not in SERVE_STYLES:
            raise ValueError(
                f"unknown energy_style {ecfg.energy_style!r}; "
                f"choose from {SERVE_STYLES}"
            )
        self.energy_tokens = 0           # true tokens through the model
        self._energy_shapes: List[tuple] = []
        self._energy_occ: Dict[str, float] = {}
        self._energy_kw: Dict[str, Any] = {}
        self._energy_per_token: Optional[Dict[str, Any]] = None
        self._init_energy_model()

        # paged KV layout: host-side pool/table/index bookkeeping plus a
        # PERSISTENT device page pool — prefix pages indexed in one run
        # are reused by the next, so the cache must outlive run()
        self._mgr = None
        self._kv_cache = None
        if ecfg.paged:
            if cfg.family not in D._PAGED_FAMILIES:
                reason = (
                    "recurrent state has no sequence axis to page — serve "
                    "it through the contiguous continuous scheduler "
                    "(paged=False)"
                    if cfg.family in _RECURRENT_FAMILIES else
                    "per-request side inputs force the static scheduler"
                )
                raise ValueError(
                    f"paged KV cache supports attention-KV families "
                    f"{D._PAGED_FAMILIES}, got {cfg.family!r}: {reason}"
                )
            if self.mode != "continuous":
                raise ValueError(
                    f"paged KV cache requires the continuous scheduler; "
                    f"resolved mode is {self.mode!r}"
                )
            if ecfg.max_len % ecfg.block_size:
                raise ValueError(
                    f"max_len ({ecfg.max_len}) must be a multiple of "
                    f"block_size ({ecfg.block_size})"
                )
            mb = ecfg.max_len // ecfg.block_size
            nb = ecfg.num_blocks or (1 + 2 * ecfg.max_batch * mb)
            if mesh is not None:
                dsz = mesh.shape.get("data", 1)    # divisibility for the
                nb = -(-nb // dsz) * dsz           # kv_blocks->data rule
            self._mgr = PagedKVManager(
                ecfg.max_batch, ecfg.block_size, nb, mb,
                prefix_reuse=ecfg.prefix_reuse,
            )
            with self._ctx():
                self._kv_cache = D.paged_cache_init(
                    params, cfg, ecfg.max_batch, ecfg.max_len,
                    ecfg.block_size, nb, dtype=jnp.float32,
                )

            def _decode_paged(p, tok, cache, bt):
                with self._ctx():
                    return D.decode_step_paged(
                        p, cfg, tok, cache, bt,
                        attn_backend=ecfg.paged_attn_backend,
                    )

            def _insert_paged(cache, src_kv, row, slot, slot_row, start,
                              total):
                with self._ctx():
                    return D.paged_cache_insert(
                        cache, src_kv, row, slot, slot_row, start, total
                    )

            def _prefill_suffix(p, toks, cache, slot_row, plen):
                with self._ctx():
                    return D.prefill_paged_suffix(
                        p, cfg, toks, cache, slot_row, plen
                    )

            def _copy_page(cache, src, dst):
                # copy-on-write: duplicate one page across all layers
                kv = cache["kv"]
                return {**cache, "kv": {
                    "k": kv["k"].at[:, dst].set(kv["k"][:, src]),
                    "v": kv["v"].at[:, dst].set(kv["v"][:, src]),
                }}

            def _decode_multi_paged(p, cache, bt, last, live, eos, budget,
                                    horizon):
                with self._ctx():
                    return D.decode_multi_step_paged(
                        p, cfg, cache, bt, last, live, eos, budget,
                        horizon, attn_backend=ecfg.paged_attn_backend,
                    )

            self._decode_paged = jax.jit(_decode_paged, donate_argnums=(2,))
            self._insert_paged = jax.jit(_insert_paged, donate_argnums=(0,))
            self._prefill_suffix = jax.jit(_prefill_suffix)
            self._copy_page = jax.jit(_copy_page, donate_argnums=(0,))
            # horizon is static: one compile per horizon value
            self._decode_multi_paged = jax.jit(
                _decode_multi_paged, donate_argnums=(1,), static_argnums=(7,))

        # static path: prefill allocates the full decode-capacity cache
        def _prefill_full(p, b):
            with self._ctx():
                return D.prefill(p, cfg, b, ecfg.max_len, dtype=jnp.float32)

        # continuous path: prefill only covers the prompt bucket — the
        # rows are scattered into the long-lived slot cache afterwards.
        # Per-row true lengths ride along so recurrent-state families
        # return exact final states under right-padding (attention
        # families need only the causal mask and ignore them).
        def _prefill_bucket(p, toks, lens):
            with self._ctx():
                return D.prefill(
                    p, cfg, {"tokens": toks, "lengths": lens},
                    toks.shape[1], dtype=jnp.float32
                )

        # donate the cache: in-place dynamic-update-slice instead of a
        # full slot-pool copy per decode step / admission (same trick as
        # launch/dryrun.py's decode cells) — donation survives sharding
        # because in/out slot-pool leaves keep the same NamedSharding
        def _decode(p, tok, cache):
            with self._ctx():
                return D.decode_step(p, cfg, tok, cache)

        def _insert(dst, src, row, slot, ln):
            with self._ctx():
                return D.cache_insert(dst, src, row, slot, ln)

        # the on-device horizon loop: up to `horizon` greedy steps per
        # call, cache donated across the whole loop
        def _decode_multi(p, cache, last, live, eos, budget, horizon):
            with self._ctx():
                return D.decode_multi_step(
                    p, cfg, cache, last, live, eos, budget, horizon
                )

        # fresh closures per engine so compile-cache accounting
        # (_cache_size) is per-instance, not shared module-level state
        self._prefill_full = jax.jit(_prefill_full)
        self._prefill_bucket = jax.jit(_prefill_bucket)
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._insert = jax.jit(_insert, donate_argnums=(0,))
        # horizon is static: one compile per horizon value
        self._decode_multi = jax.jit(
            _decode_multi, donate_argnums=(1,), static_argnums=(6,))

    def _ctx(self):
        """Rules-activation context entered at trace time (and for the
        eager slot-pool construction)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return axis_rules(self._rules, self.mesh)

    def _resolve_mode(self) -> str:
        mode = self.ecfg.mode
        if mode == "auto":
            if (self.cfg.family in _CONTINUOUS_FAMILIES
                    and "patch_embeds" not in self.extra
                    and "enc_embeds" not in self.extra):
                return "continuous"
            return "static"
        if mode == "continuous":
            if self.cfg.family not in _CONTINUOUS_FAMILIES:
                raise ValueError(
                    f"continuous batching supports {_CONTINUOUS_FAMILIES}, "
                    f"got {self.cfg.family!r} (per-request side inputs are "
                    f"batch-positional); use mode='static'"
                )
            if self.extra:
                raise ValueError(
                    "continuous batching does not take per-request side "
                    "inputs (enc_embeds/patch_embeds); use mode='static'"
                )
            return mode
        if mode != "static":
            raise ValueError(f"unknown engine mode {mode!r}")
        return mode

    # -- API ---------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None) -> int:
        """Enqueue a prompt; returns its uid.

        ``eos_id=None`` (the default) resolves to
        ``EngineConfig.eos_id``; an explicit per-request value always
        wins over the config.
        """
        if eos_id is None:
            eos_id = self.ecfg.eos_id
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) + max_new_tokens > self.ecfg.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds max_len ({self.ecfg.max_len})"
            )
        self._uid += 1
        r = Request(self._uid, prompt, max_new_tokens, eos_id,
                    t_enqueue=time.time())
        self.queue.append(r)
        return r.uid

    def run(self) -> List[Request]:
        """Serve every queued request to completion; returns them with
        outputs (continuous: per-step retirement + mid-flight admission;
        static: fixed batches decoded in lockstep)."""
        if self.mode == "continuous":
            self._run_continuous()
        else:
            while self.queue:
                batch = self.queue[: self.ecfg.max_batch]
                self.queue = self.queue[self.ecfg.max_batch:]
                self._run_batch(batch)
        return self.finished

    def reset_stats(self) -> None:
        """Clear finished requests + scheduler telemetry (keeps compiled
        functions warm AND the paged prefix index populated) — so
        benchmarks can measure a post-warm-up run."""
        self.finished = []
        self.decode_steps = 0
        self.host_syncs = 0
        self.decode_wall_s = 0.0
        self.prefill_calls = 0
        self.prefill_tokens = 0
        self.cached_prefix_tokens = 0
        self.energy_tokens = 0
        self.step_occupancy = []
        self.admissions = []
        if self._mgr is not None:
            self._mgr.reset_counters()   # telemetry only; pages/index kept

    def reset_counters(self) -> None:
        """Alias for :meth:`reset_stats` — matches the paged-KV manager's
        counter-reset naming so callers can treat engine and manager
        telemetry uniformly."""
        self.reset_stats()

    def _init_energy_model(self) -> None:
        from repro.hwmodel.system import serve_energy

        mvms = _collect_mvm_layers(self.params)
        if not mvms:
            return
        self._energy_shapes = [(name, k, o, 1) for name, k, o, _, _ in mvms]
        self._energy_occ = {
            name: (occ.mean_zero_fraction if occ is not None else 0.0)
            for name, _, _, occ, _ in mvms
        }
        qcfg = next((c for _, _, _, _, c in mvms if c is not None), None)
        if qcfg is not None:
            self._energy_kw = dict(
                xbar_rows=qcfg.xbar_rows,
                n_bits_a=qcfg.spec.n_bits_a,
                n_bits_w=qcfg.spec.n_bits_w,
                n_bits_sf=qcfg.spec.n_bits_sf,
                adc_bits=qcfg.adc_bits,
                levels=qcfg.psq_levels,
            )
        self._energy_per_token = serve_energy(
            self._energy_shapes, occupancy=self._energy_occ,
            style=self.ecfg.energy_style, **self._energy_kw,
        )

    def energy_report(self, styles=None, occupancy=None) -> Dict[str, Dict]:
        """Modeled per-style totals for the tokens served so far.

        ``styles`` defaults to all of adc/quarry/hcim; ``occupancy``
        overrides the measured pack-time occupancy (scalar or
        ``{layer: fraction}``) for what-if sweeps — the serve_bench
        energy section uses this to show the hcim-vs-adc reduction
        across an occupancy grid without re-serving the trace.
        """
        from repro.hwmodel.system import SERVE_STYLES, serve_energy

        if not self._energy_shapes:
            return {}
        occ = self._energy_occ if occupancy is None else occupancy
        tok = self.energy_tokens
        rep: Dict[str, Dict] = {}
        for s in (styles or SERVE_STYLES):
            e = serve_energy(self._energy_shapes, occupancy=occ, style=s,
                             **self._energy_kw)
            rep[s] = {
                "energy_pj_per_token": e["energy_pj"],
                "energy_pj_total": e["energy_pj"] * tok,
                "edap_total": (e["energy_pj"] * tok) * (e["latency_ns"] * tok)
                              * e["area_mm2"],
                "occupancy": e["occupancy"],
            }
        return rep

    def stats(self) -> Dict[str, float]:
        occ = float(np.mean(self.step_occupancy)) if self.step_occupancy else 0.0
        out = {
            "mode": self.mode,
            "decode_steps": self.decode_steps,
            "host_syncs": self.host_syncs,
            "decode_wall_s": self.decode_wall_s,
            "mean_step_s": (self.decode_wall_s / self.decode_steps
                            if self.decode_steps else 0.0),
            "prefill_calls": self.prefill_calls,
            "prefill_tokens": self.prefill_tokens,
            "cached_prefix_tokens": self.cached_prefix_tokens,
            "mean_slot_occupancy": occ,
            "admissions": len(self.admissions),
            "mesh": (None if self.mesh is None else
                     "x".join(f"{k}={v}" for k, v in self.mesh.shape.items())),
        }
        # hwmodel energy attribution (zeros before any token is served,
        # and for trees with no MVM layers)
        e = self._energy_per_token
        tok = self.energy_tokens
        total = e["energy_pj"] * tok if e is not None else 0.0
        out.update({
            "energy_style": self.ecfg.energy_style,
            "energy_tokens": tok,
            "energy_pj_per_token": e["energy_pj"] if e is not None else 0.0,
            "energy_pj_total": total,
            "energy_pj_per_request": (total / len(self.finished)
                                      if self.finished else 0.0),
            "edap_total": (total * (e["latency_ns"] * tok) * e["area_mm2"]
                           if e is not None else 0.0),
            "mean_occupancy": e["occupancy"] if e is not None else 0.0,
        })
        if self._mgr is not None:
            out["paged"] = self._mgr.stats()
        return out

    # -- shared -------------------------------------------------------------
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / self.ecfg.temperature)

    # -- continuous batching --------------------------------------------------
    def _bucket(self, n: int) -> int:
        return min(max(self.ecfg.min_bucket, _next_pow2(n)),
                   self.ecfg.max_len)

    def _retire(self, r: Request, now: float):
        r.done, r.t_done = True, now
        self.finished.append(r)

    @staticmethod
    def _right_pad(reqs: List[Request], rows: int, width: int):
        """RIGHT-padded token block + true-length vector for a prefill
        batch: the causal mask keeps pad columns out of attention, the
        lengths keep them out of recurrent state (models/decode.prefill).
        Rows beyond ``len(reqs)`` are batch-bucket padding (length 0)."""
        toks = np.zeros((rows, width), np.int32)
        lens = np.zeros((rows,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        return toks, lens

    def _admit(self, cache, slots: List[Optional[Request]],
               last_tok: np.ndarray, free: List[int]):
        """Fill free slots from the queue with one bucketed prefill call.

        Takes the queue head plus any later requests sharing its length
        bucket (FIFO otherwise), right-pads to (pow2 batch, pow2 length)
        so prefill shapes stay enumerable, samples each row's first token
        from its TRUE last-prompt position, and scatters each row's
        prefilled KV into its slot.
        """
        head = self.queue[0]
        w = self._bucket(len(head.prompt))
        limit = min(len(free), self.ecfg.prefill_batch)
        take = [head]
        for r in self.queue[1:]:
            if len(take) >= limit:
                break
            if self._bucket(len(r.prompt)) == w:
                take.append(r)
        for r in take:
            self.queue.remove(r)

        m = len(take)
        mp = min(_next_pow2(m), self.ecfg.prefill_batch)
        toks, lens = self._right_pad(take, mp, w)
        logits, pcache = self._prefill_bucket(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        self.prefill_calls += 1
        self.prefill_tokens += sum(len(r.prompt) for r in take)
        self.energy_tokens += sum(len(r.prompt) for r in take)
        # each row's next token comes from its true last prompt position
        idx = jnp.asarray([len(r.prompt) - 1 for r in take]
                          + [0] * (mp - m))
        first = np.asarray(self._sample(logits[jnp.arange(mp), idx]))
        now = time.time()
        for i, r in enumerate(take):
            r.t_first_token = now
            t = int(first[i])
            r.output.append(t)
            if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                self._retire(r, now)                 # never occupies a slot
                continue
            slot = free.pop(0)
            cache = self._insert(cache, pcache, i, slot, len(r.prompt))
            slots[slot] = r
            r.slot = slot
            last_tok[slot] = t
            self.admissions.append(
                {"step": self.decode_steps, "uid": r.uid, "slot": slot})
        return cache

    def _place_admitted(self, r: Request, slot: int, token: int,
                        slots: List[Optional[Request]],
                        last_tok: np.ndarray, now: float) -> None:
        """Record a freshly-admitted request in its slot (or retire it on
        the spot when the prefill token already finishes it)."""
        r.t_first_token = now
        r.output.append(token)
        if token == r.eos_id or len(r.output) >= r.max_new_tokens:
            self._retire(r, now)
            self._mgr.retire(slot)     # pages freed; the prefix stays indexed
            return
        slots[slot] = r
        r.slot = slot
        last_tok[slot] = token
        self.admissions.append(
            {"step": self.decode_steps, "uid": r.uid, "slot": slot})

    def _admit_paged(self, cache, slots: List[Optional[Request]],
                     last_tok: np.ndarray, free: List[int]):
        """Admit from the queue into free slots through the radix index.

        A queue head with a cached shared prefix admits alone: the
        reused pages are ref-bumped into its block table and ONLY the
        un-cached suffix is prefilled against them
        (``models.decode.prefill_paged_suffix``). Cold requests batch
        through the same pow2-bucketed prefill as the contiguous path,
        then scatter into their private pages. Either way, the prompt's
        full pages are published to the index for later requests.

        Returns ``(cache, progressed)``. ``progressed=False`` means the
        page pool could not hold the queue head (``PoolExhausted``
        rolled the partial allocation back): nothing was admitted, and
        the caller must STOP admitting and decode instead — retirement
        frees pages — rather than spin on the same head.
        """
        if self._mgr.match_tokens([int(t) for t in self.queue[0].prompt]):
            return self._admit_paged_suffix(cache, slots, last_tok, free)
        return self._admit_paged_cold(cache, slots, last_tok, free)

    def _worst_case_pages(self, r: Request) -> int:
        """Pages ``r`` occupies if it decodes to its full budget: the
        cache length peaks at len(prompt) + max_new_tokens - 1 (the last
        sampled token is never appended)."""
        end = len(r.prompt) + r.max_new_tokens - 1
        return -(-end // self.ecfg.block_size)

    def _paged_headroom(self, slots: List[Optional[Request]]) -> int:
        """Free pages minus the growth still owed to live slots.

        Admission must budget for decode growth, not just the prompt:
        admitting on prompt pages alone can deadlock mid-decode when
        every live slot needs its next page and nothing is retirable.
        Gating on this headroom keeps the invariant that owed growth
        always fits the free list, so ``prepare_append`` cannot exhaust
        the pool between horizon boundaries.
        """
        owed = 0
        for i, s in enumerate(slots):
            if s is None:
                continue
            owed += max(0, self._worst_case_pages(s)
                        - len(self._mgr.slot_blocks(i)))
        return self._mgr.pool.free_blocks - owed

    def _admit_paged_suffix(self, cache, slots, last_tok, free):
        # peek, don't pop: if the pool can't hold the head's pages the
        # request must stay queued (admit() rolls its allocation back)
        r = self.queue[0]
        slot = free[0]
        prompt = [int(t) for t in r.prompt]
        # full shared prefix pages are reused; everything else — the
        # prompt tail AND the decode growth — must fit the headroom
        cached_probe = self._mgr.match_tokens(prompt)
        need = (self._worst_case_pages(r)
                - cached_probe // self.ecfg.block_size)
        if need > self._paged_headroom(slots):
            return cache, False
        try:
            cached = self._mgr.admit(slot, prompt)
        except PoolExhausted:
            return cache, False
        self.queue.pop(0)
        free.pop(0)
        suffix = r.prompt[cached:]
        w = self._bucket(len(suffix))
        toks = np.zeros((1, w), np.int32)
        toks[0, :len(suffix)] = suffix
        # gather only a pow2 bucket of prefix pages, not the whole
        # table — suffix attention width scales with the prefix, and
        # compile count stays one per (suffix, prefix) bucket pair
        bs = self.ecfg.block_size
        pb = min(_next_pow2(-(-cached // bs)), len(self._mgr.tables[slot]))
        logits, src = self._prefill_suffix(
            self.params, jnp.asarray(toks), cache,
            jnp.asarray(self._mgr.tables[slot][:pb])[None],
            np.int32(cached),
        )
        self.prefill_calls += 1
        self.prefill_tokens += len(suffix)
        self.energy_tokens += len(suffix)   # reused prefix costs nothing
        self.cached_prefix_tokens += cached
        cache = self._insert_paged(
            cache, src, 0, slot, jnp.asarray(self._mgr.tables[slot]),
            np.int32(cached), len(prompt))
        self._mgr.register(slot, prompt)
        first = np.asarray(self._sample(logits[:, len(suffix) - 1]))
        self._place_admitted(r, slot, int(first[0]), slots, last_tok,
                             time.time())
        return cache, True

    def _admit_paged_cold(self, cache, slots, last_tok, free):
        # same take policy as the contiguous _admit: the queue head plus
        # FIFO-later requests sharing its length bucket — but only other
        # index misses (a hit admits alone through the suffix path)
        head = self.queue[0]
        w = self._bucket(len(head.prompt))
        limit = min(len(free), self.ecfg.prefill_batch)
        take = [head]
        for r in self.queue[1:]:
            if len(take) >= limit:
                break
            if (self._bucket(len(r.prompt)) == w
                    and not self._mgr.match_tokens(
                        [int(t) for t in r.prompt])):
                take.append(r)

        # claim pages first so nothing registers mid-batch: identical
        # prompts inside one cold batch each prefill privately (the
        # second one hits the index only on a LATER admission). A
        # PoolExhausted admit rolls itself back and stops the batch
        # there — only successfully-placed requests leave the queue,
        # the rest wait for retirement to free pages.
        placed = []
        headroom = self._paged_headroom(slots)
        for r in take:
            slot = free[0]
            prompt = [int(t) for t in r.prompt]
            # gate on the full worst case (prompt + decode growth), not
            # just the prompt pages admit() allocates now — earlier
            # batch members' growth stays owed against the same free
            # list until they retire
            need = self._worst_case_pages(r)
            if need > headroom:
                break
            try:
                self._mgr.admit(slot, prompt)
            except PoolExhausted:
                break
            headroom -= need         # prompt pages taken + growth owed
            free.pop(0)
            placed.append((r, slot, prompt))
        if not placed:
            return cache, False
        for r, _, _ in placed:
            self.queue.remove(r)

        m = len(placed)
        mp = min(_next_pow2(m), self.ecfg.prefill_batch)
        toks, lens = self._right_pad([r for r, _, _ in placed], mp, w)
        logits, pcache = self._prefill_bucket(
            self.params, jnp.asarray(toks), jnp.asarray(lens))
        self.prefill_calls += 1
        self.prefill_tokens += sum(len(r.prompt) for r, _, _ in placed)
        self.energy_tokens += sum(len(r.prompt) for r, _, _ in placed)
        idx = jnp.asarray([len(r.prompt) - 1 for r, _, _ in placed]
                          + [0] * (mp - m))
        first = np.asarray(self._sample(logits[jnp.arange(mp), idx]))
        now = time.time()
        for i, (r, slot, prompt) in enumerate(placed):
            cache = self._insert_paged(
                cache, pcache["kv"], i, slot,
                jnp.asarray(self._mgr.tables[slot]), np.int32(0),
                len(prompt))
            self._mgr.register(slot, prompt)
            self._place_admitted(r, slot, int(first[i]), slots, last_tok,
                                 now)
        return cache, True

    def _run_continuous(self):
        n = self.ecfg.max_batch
        paged = self.ecfg.paged
        if paged:
            # persistent pool: pages indexed in an earlier run() still
            # hold their prefilled KV, so the cache outlives the run
            cache = self._kv_cache
        else:
            # under a mesh, constrain() shards the slot axis over "data"
            # eagerly here, so decode-step donation reuses placed buffers
            with self._ctx():
                cache = D.cache_init(self.params, self.cfg, n,
                                     self.ecfg.max_len, dtype=jnp.float32)
        slots: List[Optional[Request]] = [None] * n
        last_tok = np.zeros((n,), np.int32)
        try:
            while self.queue or any(s is not None for s in slots):
                # admission at the horizon boundary. `stalled` breaks
                # the loop when the paged pool can't hold the queue
                # head (admit rolled back) — decoding frees pages via
                # retirement, so we must fall through, NOT spin here.
                stalled = False
                while (self.queue and any(s is None for s in slots)
                       and not stalled):
                    free = [i for i, s in enumerate(slots) if s is None]
                    if paged:
                        cache, progressed = self._admit_paged(
                            cache, slots, last_tok, free)
                        stalled = not progressed
                    else:
                        cache = self._admit(cache, slots, last_tok, free)
                if not any(s is not None for s in slots):
                    if stalled:
                        # nothing live to retire: the pool can never
                        # hold the queue head — surface it instead of
                        # spinning forever
                        raise PoolExhausted(
                            f"page pool ({self._mgr.pool.num_blocks} "
                            f"blocks) cannot hold the queue head's "
                            f"prompt plus its decode budget with no "
                            f"live slots left to retire; raise "
                            f"num_blocks"
                        )
                    continue                         # all admits retired at t=1
                if self._use_device_loop:
                    cache = self._horizon_step(cache, slots, last_tok, paged)
                else:
                    cache = self._host_step(cache, slots, last_tok, paged)
        finally:
            if paged:
                self._kv_cache = cache               # donated: keep the live
                # handle so the next run() reuses indexed prefix pages

    def _horizon_step(self, cache, slots: List[Optional[Request]],
                      last_tok: np.ndarray, paged: bool):
        """One host round-trip: up to ``decode_horizon`` decode steps on
        device (``models.decode.decode_multi_step[_paged]``), then drain
        the returned token buffer, stamp ONE boundary timestamp, and
        retire finished slots. The loop exits early on device once every
        live slot is done, so short tails don't burn horizon steps."""
        n = self.ecfg.max_batch
        h = self.ecfg.decode_horizon
        live = np.array([s is not None for s in slots])
        budget = np.zeros((n,), np.int32)
        eos = np.full((n,), -1, np.int32)
        for i, r in enumerate(slots):
            if r is None:
                continue
            budget[i] = r.max_new_tokens - len(r.output)
            eos[i] = r.eos_id
        t0 = time.time()
        if paged:
            # a CoW valve can only resolve on the host; if one would
            # trigger past the first position (reachable via fork()
            # only — full-page publishing keeps shared pages full),
            # fall back to a single-step round
            if any(self._mgr.mid_horizon_cow(i, min(h, int(budget[i])))
                   for i, s in enumerate(slots) if s is not None):
                h = 1

            # never pre-reserve past the pool: shrink this round's
            # horizon until the worst-case fresh-page demand fits the
            # free list (halving keeps the static-horizon compile set
            # at O(log H) entries under sustained pressure)
            bs = self.ecfg.block_size

            def _new_pages(hh: int) -> int:
                need = 0
                for i, s in enumerate(slots):
                    if s is None:
                        continue
                    end = int(self._mgr.lengths[i]) + min(hh, int(budget[i]))
                    need += max(0, -(-end // bs)
                                - len(self._mgr.slot_blocks(i)))
                return need

            while h > 1 and _new_pages(h) > self._mgr.pool.free_blocks:
                h //= 2
            # pre-reserve the whole horizon: grow each live slot's
            # table min(h, budget) tokens ahead (fresh pages at block
            # boundaries, eager copy-on-write when shared) so the
            # device loop never needs the host mid-horizon
            for i, s in enumerate(slots):
                if s is None:
                    continue
                for _ in range(min(h, int(budget[i]))):
                    cow = self._mgr.prepare_append(i)
                    if cow is not None:
                        cache = self._copy_page(cache, *cow)
            buf, emitted, done, last, cache, steps = self._decode_multi_paged(
                self.params, cache, jnp.asarray(self._mgr.tables),
                jnp.asarray(last_tok), jnp.asarray(live),
                jnp.asarray(eos), jnp.asarray(budget), h)
        else:
            buf, emitted, done, last, cache, steps = self._decode_multi(
                self.params, cache, jnp.asarray(last_tok),
                jnp.asarray(live), jnp.asarray(eos), jnp.asarray(budget), h)
        buf, emitted = np.asarray(buf), np.asarray(emitted)
        done, last, steps = np.asarray(done), np.asarray(last), int(steps)
        now = time.time()
        self.host_syncs += 1
        self.decode_wall_s += now - t0
        self.decode_steps += steps
        # occupancy per DEVICE step: slot i was live at step s of the
        # horizon iff it emitted more than s tokens
        for s in range(steps):
            self.step_occupancy.append(float(np.sum(emitted > s)) / n)
        for i, r in enumerate(slots):
            if r is None:
                continue
            r.output.extend(int(t) for t in buf[i, :emitted[i]])
            # energy: only tokens a live slot actually emitted (retired
            # rows keep stepping under the no-op mask — burned compute on
            # the TPU, but no modeled crossbar work is attributed)
            self.energy_tokens += int(emitted[i])
            last_tok[i] = int(last[i])
            if done[i]:
                self._retire(r, now)
                slots[i] = None              # freed at THIS boundary
                if paged:
                    self._mgr.retire(i)
        return cache

    def _host_step(self, cache, slots: List[Optional[Request]],
                   last_tok: np.ndarray, paged: bool):
        """Legacy per-token round-trip (temperature sampling, or
        ``device_loop=False``): one decode step, host-side sampling,
        EOS/budget checks and retirement."""
        n = self.ecfg.max_batch
        self.step_occupancy.append(sum(s is not None for s in slots) / n)
        t0 = time.time()
        if paged:
            # grow each live slot's table by one token (a fresh
            # page at block boundaries, copy-on-write if shared)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                cow = self._mgr.prepare_append(i)
                if cow is not None:
                    cache = self._copy_page(cache, *cow)
            logits, cache = self._decode_paged(
                self.params, jnp.asarray(last_tok)[:, None], cache,
                jnp.asarray(self._mgr.tables))
        else:
            logits, cache = self._decode(
                self.params, jnp.asarray(last_tok)[:, None], cache)
        nxt = np.asarray(self._sample(logits[:, 0]))
        self.decode_steps += 1
        self.host_syncs += 1
        now = time.time()
        self.decode_wall_s += now - t0
        for i, r in enumerate(slots):
            if r is None:
                continue
            t = int(nxt[i])
            r.output.append(t)
            self.energy_tokens += 1
            last_tok[i] = t
            if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                self._retire(r, now)
                slots[i] = None              # freed THIS step
                if paged:
                    self._mgr.retire(i)
        return cache

    # -- static batching ------------------------------------------------------
    def _pad_prompts(self, reqs: List[Request]) -> np.ndarray:
        # left-pad to the longest prompt so last position is the newest token
        s = max(len(r.prompt) for r in reqs)
        out = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            out[i, s - len(r.prompt):] = r.prompt
        return out

    def _extra_rows(self, key: str, reqs: List[Request], bp: int,
                    default_shape) -> np.ndarray:
        """Per-request side-input rows for a static batch.

        Side inputs are positional by submission order (request uid 1 is
        row 0, ...). Slicing the head of the array — the old behavior —
        handed EVERY batch the first batch's rows; gathering per request
        keeps later batches on their own inputs. Batch-bucket padding
        rows are zeros (their outputs are ignored).
        """
        arr = self.extra.get(key)
        if arr is None:
            arr = np.zeros((0,) + tuple(default_shape), np.float32)
        arr = np.asarray(arr)
        out = np.zeros((bp,) + arr.shape[1:], arr.dtype)
        for i, r in enumerate(reqs):
            if arr.shape[0] == 0:
                continue                     # no side inputs: zeros rows
            if r.uid - 1 >= arr.shape[0]:
                raise ValueError(
                    f"request uid {r.uid} has no {key} row: "
                    f"{arr.shape[0]} rows were supplied at engine "
                    f"construction (side inputs are positional by "
                    f"submission order)"
                )
            out[i] = arr[r.uid - 1]
        return out

    def _run_batch(self, reqs: List[Request]):
        nreq = len(reqs)
        # pow2-bucket the batch dim: _prefill_full compiles once per
        # (batch bucket, padded length) pair instead of once per exact
        # admitted batch size (batch rows are independent everywhere in
        # the model, so padding rows are inert)
        bp = min(_next_pow2(nreq), self.ecfg.max_batch)
        recurrent = self.cfg.family in _RECURRENT_FAMILIES
        if recurrent:
            # RIGHT-pad to a pow2 length bucket + per-row true lengths:
            # masked recurrent prefill is exact under right-padding
            # (models/decode.prefill) and decode advances each row at
            # its own position (vector lengths) — mixed-length static
            # batches decode bit-exactly with sequential and continuous
            w = self._bucket(max(len(r.prompt) for r in reqs))
            tokens, lens = self._right_pad(reqs, bp, w)
            b = {"tokens": jnp.asarray(tokens), "lengths": jnp.asarray(lens)}
        else:
            # attention families keep the classic left-pad: the newest
            # token sits at the last position for every row
            tokens = self._pad_prompts(reqs)
            if bp > nreq:
                tokens = np.concatenate(
                    [tokens, np.zeros((bp - nreq, tokens.shape[1]),
                                      np.int32)]
                )
            b = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "encdec":
            b["enc_embeds"] = jnp.asarray(self._extra_rows(
                "enc_embeds", reqs, bp, (tokens.shape[1], self.cfg.d_model)))
        if self.cfg.family == "vlm" and "patch_embeds" in self.extra:
            b["patch_embeds"] = jnp.asarray(
                self._extra_rows("patch_embeds", reqs, bp, None))
        logits, cache = self._prefill_full(self.params, b)
        self.prefill_calls += 1
        self.prefill_tokens += sum(len(r.prompt) for r in reqs)
        self.energy_tokens += sum(len(r.prompt) for r in reqs)
        if recurrent:
            # each row's first token comes from its true last position
            nxt = self._sample(
                logits[jnp.arange(bp), jnp.maximum(b["lengths"] - 1, 0)])
        else:
            nxt = self._sample(logits[:, -1])
        t_first = time.time()
        for r, t in zip(reqs, np.asarray(nxt)):
            r.output.append(int(t))
            r.t_first_token = t_first
        # attention-family static batches pad to the LONGEST prompt
        # (VLM: plus patch embeds), so a short prompt's decode budget can
        # push KV writes past max_len even when every request
        # individually fits (submit() checks per-request). Cap steps at
        # remaining cache capacity: truncated output for the over-budget
        # request, never a clamped write corrupting the cache. Pure
        # recurrent state has no sequence axis to overflow.
        max_new = max(r.max_new_tokens for r in reqs)
        if self.cfg.family != "ssm":
            capacity = self.ecfg.max_len - int(np.max(np.asarray(cache["length"])))
            max_new = min(max_new, capacity + 1)
        for _ in range(max_new - 1):
            # occupancy relative to the slot pool a continuous scheduler
            # would have: retired-but-held and unfilled slots count as idle
            n_alive = sum(
                not r.done and len(r.output) < r.max_new_tokens for r in reqs
            )
            self.step_occupancy.append(n_alive / self.ecfg.max_batch)
            logits, cache = self._decode(
                self.params, jnp.asarray(nxt)[:, None], cache
            )
            self.decode_steps += 1
            nxt = self._sample(logits[:, 0])
            now = time.time()
            alive = False
            for i, r in enumerate(reqs):
                if r.done or len(r.output) >= r.max_new_tokens:
                    continue
                t = int(np.asarray(nxt)[i])
                r.output.append(t)
                self.energy_tokens += 1
                if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                    r.done, r.t_done = True, now
                else:
                    alive = True
            if not alive:
                break
        now = time.time()
        for r in reqs:
            r.done = True
            r.t_done = r.t_done or now
            self.finished.append(r)


def throughput_stats(reqs: List[Request]) -> Dict[str, float]:
    """Aggregate request metrics; robust to empty/never-started requests.

    Requests that never produced a token contribute to ``requests`` but
    not to TTFT (no first token to time); a request list with no finish
    timestamps falls back to enqueue time so ``tokens_per_s`` is 0 rather
    than garbage.

    Per-token latency (``mean_tpot_s``) is derived from the two REAL
    timestamps each request has — first token at admission, completion
    at its retirement boundary — divided by its decode-token count.
    Under the device horizon loop the engine only touches the host at
    horizon boundaries, so there are no per-token wall times to average
    (and none are fabricated): the boundary-to-boundary quotient is the
    honest figure at every ``decode_horizon``, and degrades gracefully
    to true per-token latency at horizon 1.
    """
    if not reqs:
        return {}
    total_tokens = sum(len(r.output) for r in reqs)
    t0 = min(r.t_enqueue for r in reqs)
    finished = [r.t_done for r in reqs if r.t_done]
    elapsed = (max(finished) - t0) if finished else 0.0
    started = [r for r in reqs if r.t_first_token > 0.0]
    ttft = [r.t_first_token - r.t_enqueue for r in started]
    tpot = [
        (r.t_done - r.t_first_token) / max(len(r.output) - 1, 1)
        for r in reqs
        if r.t_done and r.t_first_token and len(r.output) > 1
    ]
    return {
        "requests": len(reqs),
        "started": len(started),
        "total_tokens": total_tokens,
        "tokens_per_s": total_tokens / elapsed if elapsed > 0 else 0.0,
        "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
        "mean_tpot_s": float(np.mean(tpot)) if tpot else 0.0,
    }
