"""Continuous-batching serving engine: slot pool + bucketed prefill.

Small-scale-runnable (CPU) but structured like a real engine. Two
scheduling modes share one API:

``continuous`` (default for KV-cache AND recurrent-state families)
  * a fixed pool of ``max_batch`` decode slots advances over the WHOLE
    pool — per-slot lengths in the stacked cache
    (``models.decode.cache_init``) keep every slot at its own position.
    Greedy serving runs the on-device horizon loop
    (``models.decode.decode_multi_step``): ONE jit call takes up to
    ``decode_horizon`` steps with on-device argmax and per-slot
    EOS/budget flags, so the host syncs once per horizon instead of
    once per token (``temperature > 0`` keeps the per-token
    host-sampled path),
  * finished sequences (EOS or max tokens) retire at every horizon
    boundary — mid-horizon they keep executing under a retirement mask
    that makes their steps cache no-ops — freeing their slot
    immediately,
  * queued requests are admitted into free slots at decode-step
    boundaries: prompts are right-padded to a power-of-two length bucket,
    prefilled as a batch, and each row's prefilled cache is scattered
    into its slot (``models.decode.cache_insert``). Attention K/V is
    exact under right-padding by the causal mask; recurrent state
    (SSM/xLSTM/hybrid) is exact because prefill threads per-row true
    lengths into the state scans — pad positions are state no-ops and
    each row's final state/conv buffer is taken at its true length,
  * all shapes are fixed after warm-up — the decode step compiles once,
    prefill/insert compile once per (bucket length, bucket batch) pair,
    and nothing recompiles afterwards (asserted by the tier-1 suite).

``static`` (an oracle/debug mode, available everywhere)
  * the classic drain-the-queue loop: one batch prefills together
    (batch dim pow2-bucketed so compiles stay enumerable) and decodes
    in lockstep until every member finishes. EVERY family right-pads
    to a pow2 length bucket with per-row true lengths — the causal
    mask keeps pad columns out of attention, masked prefill keeps
    them out of recurrent state — so mixed-length static batches are
    bit-exact with sequential and continuous decoding.

Per-request side inputs (encdec ``enc_embeds``, VLM ``patch_embeds``)
serve through BOTH modes: continuous admission gathers each request's
rows (positional by uid) into the bucketed prefill batch, and the slot
pool carries an encoder-output cross-KV stripe per slot
(``models.decode.cache_init(enc_len=...)``) scattered at admission
exactly like self-attention KV; patch KV is baked into the prompt
prefill with a per-slot ``patches + prompt`` length offset. Under a
mesh the side-input pools shard over ``data`` with the other per-slot
leaves.

Speculative decoding (``EngineConfig.spec_k`` + ``draft_config`` +
``draft_params``) accelerates greedy continuous serving: a small
same-family draft model proposes K tokens per slot
(``models.decode.decode_propose``), the main model scores all K+1
positions in one masked forward (``models.decode.decode_verify``), and
the engine accepts the longest proposal prefix matching the main
model's argmaxes plus one bonus token. Rollback is a per-slot length
edit on both caches (plus ``PagedKVManager.truncate`` page releases on
the paged path) — outputs are token-identical to vanilla greedy decode
by construction, because every emitted token IS a main-model argmax at
the same cache state.

The continuous scheduler supports two KV layouts
(``EngineConfig.paged``): the default contiguous per-slot stripe, and
the paged block pool (``serve/paged_kv.py`` + ``models/decode.py``'s
``decode_step_paged``) — fixed-size KV pages reached through per-slot
block tables, with a token-prefix radix index that lets admission reuse
already-prefilled shared-prefix pages and prefill only the un-cached
suffix. Retirement releases page refcounts instead of abandoning a
stripe; reused prefixes cut prefill work without changing greedy
outputs (docs/memory.md).

PSQ-trained models serve through either mode from the weight-stationary
``PackedLayer`` cache (``serve.cache.pack_tree_psq``) — quantize + pack
once at load, stream activations past the packed state on every step:
the HCiM deployment story on TPU.

Multi-device serving: pass a ``("data", "model")`` mesh and the engine
activates the logical-axis rules around every traced function — the
decode slot pool and stacked KV cache shard over ``data`` (per-slot
state is independent, so slot parallelism is free), packed PSQ layers
execute tensor-parallel over ``model`` (column split + one psum; see
``core.psq_linear.serve_linear_tp``), and cache donation is kept across
shardings so the slot pool still updates in place. Outputs are
bit-identical to the single-device engine (tested: greedy decode parity
on 2- and 4-way meshes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ArchConfig
from repro.models import decode as D
from repro.parallel.sharding import (
    axis_rules,
    rules_for_mesh,
    shard_expert_params,
)
from repro.serve.paged_kv import PagedKVManager, PoolExhausted

PyTree = Any

# families the continuous scheduler admits mid-flight — all of them.
# KV-cache families are exact under right-padded prefill (causal mask);
# recurrent-state families (ssm/xlstm/hybrid) are exact because masked
# prefill makes pad positions state no-ops and returns each row's final
# state at its TRUE length (models/decode.prefill + per-layer `lengths`
# masking); side-input families (encdec enc_embeds, VLM patch_embeds)
# are exact because admission gathers each request's rows (positional
# by uid) into the prefill batch and scatters the resulting per-request
# state — cross-attention KV, patch-offset lengths — into the slot pool
# like any other cache leaf.
_CONTINUOUS_FAMILIES = ("dense", "moe", "vlm", "hybrid", "ssm", "encdec")

# encoder width used for encdec engines constructed WITHOUT
# extra_inputs["enc_embeds"] (zero encoder rows at a fixed width, so
# both schedulers agree on the cross-KV pool shape)
_DEFAULT_ENC_LEN = 8

# families whose decode state is carried recurrently (no KV sequence
# axis): slot admission scatters state rows instead of KV stripes, and
# the static fallback right-pads + tracks per-row lengths so recurrent
# prefill stays exact under mixed prompt lengths
_RECURRENT_FAMILIES = ("hybrid", "ssm")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    slot: int = -1                # decode slot served in (continuous mode)
    extra_idx: int = -1           # side-input row (-1: positional by uid)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # decode slot-pool size (static: batch size)
    max_len: int = 256            # KV capacity per slot
    temperature: float = 0.0      # 0 => greedy
    seed: int = 0
    mode: str = "auto"            # auto | continuous | static
    prefill_batch: int = 4        # max requests per bucketed prefill call
    min_bucket: int = 8           # smallest prompt-length bucket
    eos_id: int = -1              # default EOS for submit() (-1: never)
    # on-device multi-step decode (continuous greedy serving only):
    # one jit call advances every slot up to decode_horizon steps
    # (models.decode.decode_multi_step) — host syncs per horizon, not
    # per token. device_loop=False forces the legacy per-token path.
    decode_horizon: int = 1
    device_loop: bool = True
    # paged KV layout (continuous scheduler only; see docs/memory.md)
    paged: bool = False           # page pool + block tables vs stripes
    block_size: int = 16          # tokens per KV page (divides max_len)
    num_blocks: int = 0           # pool pages; 0 => auto (2x slot capacity)
    prefix_reuse: bool = True     # radix-index shared-prefix reuse
    paged_attn_backend: Optional[str] = None  # None => inline gather path
    # hwmodel accounting style for stats()["energy_pj_total"] etc.
    # (repro.hwmodel.system.serve_energy): adc | quarry | hcim
    energy_style: str = "hcim"
    # speculative decoding (continuous greedy serving only): a draft
    # model proposes spec_k tokens per slot, decode_verify scores them
    # in one forward, rollback is a per-slot length edit. 0 => off.
    # draft_params ride in as a ServeEngine constructor argument.
    spec_k: int = 0
    draft_config: Optional[ArchConfig] = None


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _collect_mvm_layers(node, path: str = "") -> List[tuple]:
    """Walk a served param tree and list its MVM layers for the hwmodel.

    Returns ``(name, k, o, occupancy_or_None, quant_cfg_or_None)`` per
    linear — PackedLayer nodes carry their pack-time occupancy metadata
    and QuantConfig; raw param dicts (fp / QAT trees, key ``"w"`` of rank
    2 or 3) are modeled dense. Embedding tables (key ``"table"``) are
    lookups, not MVMs, and are skipped. Stacked rank-3 weights count one
    layer per leading index (scan-over-layers packs; MoE expert banks are
    modeled as all-experts-resident, the PUMA weight-stationary story).
    """
    out: List[tuple] = []
    if node is None:
        return out
    if hasattr(node, "w_codes"):             # PackedLayer (2-D or stacked)
        w = node.w_codes
        if w.ndim == 3:
            for l in range(int(w.shape[0])):
                out.append((f"{path}[{l}]", int(w.shape[1]),
                            int(w.shape[2]), None, node.cfg))
        else:
            out.append((path, int(w.shape[0]), int(w.shape[1]),
                        node.occupancy, node.cfg))
        return out
    if isinstance(node, dict):
        w = node.get("w")
        if getattr(w, "ndim", 0) in (2, 3) and "table" not in node:
            if w.ndim == 3:
                for l in range(int(w.shape[0])):
                    out.append((f"{path}[{l}]", int(w.shape[1]),
                                int(w.shape[2]), None, None))
            else:
                out.append((path, int(w.shape[0]), int(w.shape[1]),
                            None, None))
            return out
        for k in sorted(node):
            out.extend(_collect_mvm_layers(node[k], f"{path}/{k}"))
        return out
    if isinstance(node, (list, tuple)):
        for i, v in enumerate(node):
            out.extend(_collect_mvm_layers(v, f"{path}[{i}]"))
        return out
    return out


class ServeEngine:
    """Submit prompts, then :meth:`run` to completion.

    ``stats()`` exposes scheduler counters (decode steps, prefill calls,
    mean slot occupancy) on top of :func:`throughput_stats`.
    """

    def __init__(self, params: PyTree, cfg: ArchConfig, ecfg: EngineConfig,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None,
                 mesh: Optional[Mesh] = None, rules=None,
                 draft_params: Optional[PyTree] = None):
        if params is not None:
            # per-token-invariant decode constants (e.g. Mamba2's
            # A = -exp(A_log)) fold into the served tree once at load
            params = D.hoist_decode_params(params, cfg)
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.extra = extra_inputs or {}
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._uid = 0
        self._key = jax.random.PRNGKey(ecfg.seed)
        self.mode = self._resolve_mode()

        # side-input geometry is fixed per engine so admission batches
        # and the slot pools compile once: encdec engines without
        # supplied enc_embeds run zero encoder rows at a default width
        enc = self.extra.get("enc_embeds")
        self._enc_len = (int(np.asarray(enc).shape[1])
                         if enc is not None and np.asarray(enc).size
                         else _DEFAULT_ENC_LEN)
        pe = self.extra.get("patch_embeds")
        self._patch_len = (int(np.asarray(pe).shape[1])
                           if cfg.family == "vlm" and pe is not None
                           and np.asarray(pe).size else 0)

        if ecfg.decode_horizon < 1:
            raise ValueError(
                f"decode_horizon must be >= 1, got {ecfg.decode_horizon}"
            )
        if ecfg.decode_horizon > 1 and ecfg.temperature > 0.0:
            raise ValueError(
                "decode_horizon > 1 runs the on-device greedy loop; "
                "temperature sampling needs the per-token host path "
                "(set decode_horizon=1)"
            )
        if ecfg.decode_horizon > 1 and not ecfg.device_loop:
            raise ValueError(
                "decode_horizon > 1 requires device_loop=True"
            )
        # the device loop is greedy-only (on-device argmax, no RNG
        # carry); temperature > 0 stays on the host-sampled path, and
        # speculative decoding has its own draft/verify round loop
        self._use_device_loop = (
            self.mode == "continuous"
            and ecfg.device_loop
            and ecfg.temperature <= 0.0
            and not ecfg.spec_k
        )

        if ecfg.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {ecfg.spec_k}")
        self._spec_k = int(ecfg.spec_k)
        self.draft_params = None
        if self._spec_k:
            dcfg = ecfg.draft_config
            if dcfg is None or draft_params is None:
                raise ValueError(
                    "speculative decoding (spec_k > 0) needs both "
                    "EngineConfig.draft_config and a draft_params tree"
                )
            if self.mode != "continuous":
                raise ValueError(
                    f"speculative decoding requires the continuous "
                    f"scheduler; resolved mode is {self.mode!r}"
                )
            if cfg.family not in D._SPEC_FAMILIES:
                raise ValueError(
                    f"speculative decoding supports the pure KV-cache "
                    f"families {D._SPEC_FAMILIES}, got {cfg.family!r}: "
                    f"recurrent state folds every token and cannot roll "
                    f"back by a length edit"
                )
            if ecfg.temperature > 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only (acceptance "
                    "compares draft proposals with main-model argmaxes); "
                    "set temperature=0"
                )
            if ecfg.decode_horizon != 1:
                raise ValueError(
                    "speculative decoding replaces the device horizon "
                    "loop; set decode_horizon=1"
                )
            if dcfg.family != cfg.family:
                raise ValueError(
                    f"draft family {dcfg.family!r} must match the target "
                    f"family {cfg.family!r}"
                )
            if dcfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    "draft and target models must share a vocabulary "
                    f"({dcfg.vocab_size} != {cfg.vocab_size})"
                )
            if cfg.family in ("encdec", "vlm") and dcfg.d_model != cfg.d_model:
                raise ValueError(
                    "side-input families need draft d_model == target "
                    "d_model: enc_embeds/patch_embeds rows feed both "
                    f"models ({dcfg.d_model} != {cfg.d_model})"
                )
            self.draft_params = D.hoist_decode_params(draft_params, dcfg)

        # multi-device serving: the rules activate around every traced
        # function, so cache slots shard over "data" (via the model's
        # constrain() annotations) and packed PSQ layers go tensor-
        # parallel over "model" (core.psq_linear.serve_linear_tp). With
        # mesh=None every annotation is a no-op — single-device engine.
        # A mesh carrying an "expert" axis defaults to the expert-
        # parallel table (RULES_EXPERT): MoE expert FFN stacks place
        # over "expert" at load and apply_moe picks its shard_map path.
        self.mesh = mesh
        self._rules = rules if rules is not None else rules_for_mesh(mesh)
        if (mesh is not None and params is not None
                and "expert" in getattr(mesh, "axis_names", ())):
            self.params = params = shard_expert_params(
                params, mesh, self._rules
            )

        # scheduler telemetry (continuous mode)
        self.decode_steps = 0
        self.host_syncs = 0              # decode round-trips (jit + drain)
        self.decode_wall_s = 0.0         # wall time inside decode syncs
        self.prefill_calls = 0
        self.prefill_tokens = 0          # true (unpadded) tokens prefilled
        self.cached_prefix_tokens = 0    # prompt tokens served from pages
        self.step_occupancy: List[float] = []
        self.admissions: List[Dict[str, int]] = []   # {step, uid, slot}
        # speculative decoding telemetry
        self.spec_rounds = 0
        self.spec_proposed = 0           # draft tokens put up for verify
        self.spec_accepted = 0           # draft tokens the verify kept

        # hwmodel-in-the-loop energy accounting: one pass over the served
        # tree at construction collects every MVM shape + its pack-time
        # occupancy metadata; per-token modeled cost is evaluated once
        # (all hwmodel energy terms are linear in n_vec) and scaled by
        # the true forward-pass token count at stats() time
        from repro.hwmodel.system import SERVE_STYLES
        if ecfg.energy_style not in SERVE_STYLES:
            raise ValueError(
                f"unknown energy_style {ecfg.energy_style!r}; "
                f"choose from {SERVE_STYLES}"
            )
        self.energy_tokens = 0           # true tokens through the model
        self._energy_shapes: List[tuple] = []
        self._energy_occ: Dict[str, float] = {}
        self._energy_kw: Dict[str, Any] = {}
        self._energy_per_token: Optional[Dict[str, Any]] = None
        self._init_energy_model()

        # paged KV layout: host-side pool/table/index bookkeeping plus a
        # PERSISTENT device page pool — prefix pages indexed in one run
        # are reused by the next, so the cache must outlive run()
        self._mgr = None
        self._kv_cache = None
        if ecfg.paged:
            if cfg.family not in D._PAGED_FAMILIES:
                reason = (
                    "recurrent state has no sequence axis to page — serve "
                    "it through the contiguous continuous scheduler "
                    "(paged=False)"
                    if cfg.family in _RECURRENT_FAMILIES else
                    "cross-attention KV has no pages — serve it through "
                    "the contiguous continuous scheduler (paged=False)"
                )
                raise ValueError(
                    f"paged KV cache supports attention-KV families "
                    f"{D._PAGED_FAMILIES}, got {cfg.family!r}: {reason}"
                )
            if cfg.family == "vlm" and "patch_embeds" in self.extra:
                raise ValueError(
                    "paged KV cache does not take per-request "
                    "patch_embeds: the radix prefix index keys on token "
                    "ids alone, so a reused prefix page could alias "
                    "another request's patch context; serve through the "
                    "contiguous continuous scheduler (paged=False)"
                )
            if self.mode != "continuous":
                raise ValueError(
                    f"paged KV cache requires the continuous scheduler; "
                    f"resolved mode is {self.mode!r}"
                )
            if ecfg.max_len % ecfg.block_size:
                raise ValueError(
                    f"max_len ({ecfg.max_len}) must be a multiple of "
                    f"block_size ({ecfg.block_size})"
                )
            mb = ecfg.max_len // ecfg.block_size
            nb = ecfg.num_blocks or (1 + 2 * ecfg.max_batch * mb)
            if mesh is not None:
                dsz = mesh.shape.get("data", 1)    # divisibility for the
                nb = -(-nb // dsz) * dsz           # kv_blocks->data rule
            self._mgr = PagedKVManager(
                ecfg.max_batch, ecfg.block_size, nb, mb,
                prefix_reuse=ecfg.prefix_reuse,
            )
            with self._ctx():
                self._kv_cache = D.paged_cache_init(
                    params, cfg, ecfg.max_batch, ecfg.max_len,
                    ecfg.block_size, nb, dtype=jnp.float32,
                )

            def _decode_paged(p, tok, cache, bt):
                with self._ctx():
                    return D.decode_step_paged(
                        p, cfg, tok, cache, bt,
                        attn_backend=ecfg.paged_attn_backend,
                    )

            def _insert_paged(cache, src_kv, row, slot, slot_row, start,
                              total):
                with self._ctx():
                    return D.paged_cache_insert(
                        cache, src_kv, row, slot, slot_row, start, total
                    )

            def _prefill_suffix(p, toks, cache, slot_row, plen):
                with self._ctx():
                    return D.prefill_paged_suffix(
                        p, cfg, toks, cache, slot_row, plen
                    )

            def _copy_page(cache, src, dst):
                # copy-on-write: duplicate one page across all layers
                kv = cache["kv"]
                return {**cache, "kv": {
                    "k": kv["k"].at[:, dst].set(kv["k"][:, src]),
                    "v": kv["v"].at[:, dst].set(kv["v"][:, src]),
                }}

            def _decode_multi_paged(p, cache, bt, last, live, eos, budget,
                                    horizon):
                with self._ctx():
                    return D.decode_multi_step_paged(
                        p, cfg, cache, bt, last, live, eos, budget,
                        horizon, attn_backend=ecfg.paged_attn_backend,
                    )

            self._decode_paged = jax.jit(_decode_paged, donate_argnums=(2,))
            self._insert_paged = jax.jit(_insert_paged, donate_argnums=(0,))
            self._prefill_suffix = jax.jit(_prefill_suffix)
            self._copy_page = jax.jit(_copy_page, donate_argnums=(0,))
            # horizon is static: one compile per horizon value
            self._decode_multi_paged = jax.jit(
                _decode_multi_paged, donate_argnums=(1,), static_argnums=(7,))

        # static path: prefill allocates the full decode-capacity cache
        def _prefill_full(p, b):
            with self._ctx():
                return D.prefill(p, cfg, b, ecfg.max_len, dtype=jnp.float32)

        # continuous path: prefill only covers the prompt bucket — the
        # rows are scattered into the long-lived slot cache afterwards.
        # Per-row true lengths ride along so recurrent-state families
        # return exact final states under right-padding (attention
        # families need only the causal mask and ignore them). The batch
        # dict may carry side inputs (enc_embeds/patch_embeds rows
        # gathered per request): one compile per (bucket shapes, side
        # keys) combination, both fixed per engine.
        def _prefill_bucket(p, b):
            with self._ctx():
                return D.prefill(
                    p, cfg, b, b["tokens"].shape[1], dtype=jnp.float32
                )

        # donate the cache: in-place dynamic-update-slice instead of a
        # full slot-pool copy per decode step / admission (same trick as
        # launch/dryrun.py's decode cells) — donation survives sharding
        # because in/out slot-pool leaves keep the same NamedSharding
        def _decode(p, tok, cache):
            with self._ctx():
                return D.decode_step(p, cfg, tok, cache)

        def _insert(dst, src, row, slot, ln):
            with self._ctx():
                return D.cache_insert(dst, src, row, slot, ln)

        # the on-device horizon loop: up to `horizon` greedy steps per
        # call, cache donated across the whole loop
        def _decode_multi(p, cache, last, live, eos, budget, horizon):
            with self._ctx():
                return D.decode_multi_step(
                    p, cfg, cache, last, live, eos, budget, horizon
                )

        # fresh closures per engine so compile-cache accounting
        # (_cache_size) is per-instance, not shared module-level state
        self._prefill_full = jax.jit(_prefill_full)
        self._prefill_bucket = jax.jit(_prefill_bucket)
        self._decode = jax.jit(_decode, donate_argnums=(2,))
        self._insert = jax.jit(_insert, donate_argnums=(0,))
        # horizon is static: one compile per horizon value
        self._decode_multi = jax.jit(
            _decode_multi, donate_argnums=(1,), static_argnums=(6,))

        # speculative decoding: draft prefill/propose + main-model
        # verify, plus the tiny length-edit that IS the rollback
        self._draft_cache = None
        if self._spec_k:
            dcfg = ecfg.draft_config

            def _draft_prefill(p, b):
                with self._ctx():
                    return D.prefill(p, dcfg, b, b["tokens"].shape[1],
                                     dtype=jnp.float32)

            def _draft_insert(dst, src, row, slot, ln):
                with self._ctx():
                    return D.cache_insert(dst, src, row, slot, ln)

            def _draft_propose(p, cache, last, live, k_steps):
                with self._ctx():
                    return D.decode_propose(p, dcfg, cache, last, live,
                                            k_steps)

            # verify tokens are [pending, d1 .. d_{k-1}]: the last draft
            # proposal exists only to keep the draft cache one position
            # ahead (decode_propose), so props[:, :-1] drops it
            def _verify(p, cache, last, props):
                with self._ctx():
                    toks = jnp.concatenate(
                        [last[:, None], props[:, :-1]], axis=1)
                    return D.decode_verify(p, cfg, toks, cache)

            def _set_len(cache, lens):
                return {**cache, "length": lens}

            self._draft_prefill = jax.jit(_draft_prefill)
            self._draft_insert = jax.jit(_draft_insert, donate_argnums=(0,))
            self._draft_propose = jax.jit(
                _draft_propose, donate_argnums=(1,), static_argnums=(4,))
            self._verify = jax.jit(_verify, donate_argnums=(1,))
            self._set_len = jax.jit(_set_len, donate_argnums=(0,))
            if ecfg.paged:
                def _verify_paged(p, cache, bt, live, last, props):
                    with self._ctx():
                        toks = jnp.concatenate(
                            [last[:, None], props[:, :-1]], axis=1)
                        logits, kv_new = D.prefill_paged_suffix(
                            p, cfg, toks, cache, bt, cache["length"],
                            per_token_ffn=True)
                        kv = D.paged_verify_commit(
                            cache["kv"], kv_new, cache["length"], bt, live)
                        return logits, {**cache, "kv": kv}

                self._verify_paged = jax.jit(
                    _verify_paged, donate_argnums=(1,))

    def _ctx(self):
        """Rules-activation context entered at trace time (and for the
        eager slot-pool construction)."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return axis_rules(self._rules, self.mesh)

    def _resolve_mode(self) -> str:
        mode = self.ecfg.mode
        if mode == "auto":
            # every family serves continuously — side inputs included
            # (admission gathers per-request rows; the slot pool carries
            # cross-KV / patch-offset state). "auto" always resolves
            # continuous; "static" remains as an explicit oracle mode.
            return "continuous"
        if mode not in ("continuous", "static"):
            raise ValueError(f"unknown engine mode {mode!r}")
        return mode

    # -- API ---------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               extra_idx: Optional[int] = None) -> int:
        """Enqueue a prompt; returns its uid.

        ``eos_id=None`` (the default) resolves to
        ``EngineConfig.eos_id``; an explicit per-request value always
        wins over the config. ``extra_idx`` picks this request's
        side-input row (enc_embeds/patch_embeds) explicitly; by default
        rows are positional by submission order (uid 1 -> row 0, ...),
        which only works when the engine serves at most one row per
        submit over its lifetime.
        """
        if eos_id is None:
            eos_id = self.ecfg.eos_id
        prompt = np.asarray(prompt, np.int32)
        # patch positions occupy cache slots below the prompt, and a
        # speculative verify can write spec_k junk positions past the
        # final accepted token — both must fit the per-slot capacity so
        # no KV write is ever clamped
        overhead = self._patch_len + self._spec_k
        if overhead + len(prompt) + max_new_tokens > self.ecfg.max_len:
            extra = (f" + side/spec overhead ({overhead})"
                     if overhead else "")
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f"{extra} exceeds max_len ({self.ecfg.max_len})"
            )
        self._uid += 1
        r = Request(self._uid, prompt, max_new_tokens, eos_id,
                    t_enqueue=time.time(),
                    extra_idx=-1 if extra_idx is None else int(extra_idx))
        self.queue.append(r)
        return r.uid

    def run(self) -> List[Request]:
        """Serve every queued request to completion; returns them with
        outputs (continuous: per-step retirement + mid-flight admission;
        static: fixed batches decoded in lockstep)."""
        if self.mode == "continuous":
            self._run_continuous()
        else:
            while self.queue:
                batch = self.queue[: self.ecfg.max_batch]
                self.queue = self.queue[self.ecfg.max_batch:]
                self._run_batch(batch)
        return self.finished

    def reset_stats(self) -> None:
        """Clear finished requests + scheduler telemetry (keeps compiled
        functions warm AND the paged prefix index populated) — so
        benchmarks can measure a post-warm-up run."""
        self.finished = []
        self.decode_steps = 0
        self.host_syncs = 0
        self.decode_wall_s = 0.0
        self.prefill_calls = 0
        self.prefill_tokens = 0
        self.cached_prefix_tokens = 0
        self.energy_tokens = 0
        self.step_occupancy = []
        self.admissions = []
        self.spec_rounds = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        if self._mgr is not None:
            self._mgr.reset_counters()   # telemetry only; pages/index kept

    def reset_counters(self) -> None:
        """Alias for :meth:`reset_stats` — matches the paged-KV manager's
        counter-reset naming so callers can treat engine and manager
        telemetry uniformly."""
        self.reset_stats()

    def _init_energy_model(self) -> None:
        from repro.hwmodel.system import serve_energy

        mvms = _collect_mvm_layers(self.params)
        if not mvms:
            return
        self._energy_shapes = [(name, k, o, 1) for name, k, o, _, _ in mvms]
        self._energy_occ = {
            name: (occ.mean_zero_fraction if occ is not None else 0.0)
            for name, _, _, occ, _ in mvms
        }
        qcfg = next((c for _, _, _, _, c in mvms if c is not None), None)
        if qcfg is not None:
            self._energy_kw = dict(
                xbar_rows=qcfg.xbar_rows,
                n_bits_a=qcfg.spec.n_bits_a,
                n_bits_w=qcfg.spec.n_bits_w,
                n_bits_sf=qcfg.spec.n_bits_sf,
                adc_bits=qcfg.adc_bits,
                levels=qcfg.psq_levels,
            )
        self._energy_per_token = serve_energy(
            self._energy_shapes, occupancy=self._energy_occ,
            style=self.ecfg.energy_style, **self._energy_kw,
        )

    def energy_report(self, styles=None, occupancy=None) -> Dict[str, Dict]:
        """Modeled per-style totals for the tokens served so far.

        ``styles`` defaults to all of adc/quarry/hcim; ``occupancy``
        overrides the measured pack-time occupancy (scalar or
        ``{layer: fraction}``) for what-if sweeps — the serve_bench
        energy section uses this to show the hcim-vs-adc reduction
        across an occupancy grid without re-serving the trace.
        """
        from repro.hwmodel.system import SERVE_STYLES, serve_energy

        if not self._energy_shapes:
            return {}
        occ = self._energy_occ if occupancy is None else occupancy
        tok = self.energy_tokens
        rep: Dict[str, Dict] = {}
        for s in (styles or SERVE_STYLES):
            e = serve_energy(self._energy_shapes, occupancy=occ, style=s,
                             **self._energy_kw)
            rep[s] = {
                "energy_pj_per_token": e["energy_pj"],
                "energy_pj_total": e["energy_pj"] * tok,
                "edap_total": (e["energy_pj"] * tok) * (e["latency_ns"] * tok)
                              * e["area_mm2"],
                "occupancy": e["occupancy"],
            }
        return rep

    def stats(self) -> Dict[str, float]:
        occ = float(np.mean(self.step_occupancy)) if self.step_occupancy else 0.0
        out = {
            "mode": self.mode,
            "decode_steps": self.decode_steps,
            "host_syncs": self.host_syncs,
            "decode_wall_s": self.decode_wall_s,
            "mean_step_s": (self.decode_wall_s / self.decode_steps
                            if self.decode_steps else 0.0),
            "prefill_calls": self.prefill_calls,
            "prefill_tokens": self.prefill_tokens,
            "cached_prefix_tokens": self.cached_prefix_tokens,
            "mean_slot_occupancy": occ,
            "admissions": len(self.admissions),
            "mesh": (None if self.mesh is None else
                     "x".join(f"{k}={v}" for k, v in self.mesh.shape.items())),
        }
        # hwmodel energy attribution (zeros before any token is served,
        # and for trees with no MVM layers)
        e = self._energy_per_token
        tok = self.energy_tokens
        total = e["energy_pj"] * tok if e is not None else 0.0
        out.update({
            "energy_style": self.ecfg.energy_style,
            "energy_tokens": tok,
            "energy_pj_per_token": e["energy_pj"] if e is not None else 0.0,
            "energy_pj_total": total,
            "energy_pj_per_request": (total / len(self.finished)
                                      if self.finished else 0.0),
            "edap_total": (total * (e["latency_ns"] * tok) * e["area_mm2"]
                           if e is not None else 0.0),
            "mean_occupancy": e["occupancy"] if e is not None else 0.0,
        })
        if self._spec_k:
            out.update({
                "spec_k": self._spec_k,
                "spec_rounds": self.spec_rounds,
                "spec_proposed": self.spec_proposed,
                "spec_accepted": self.spec_accepted,
                "spec_accept_rate": (self.spec_accepted / self.spec_proposed
                                     if self.spec_proposed else 0.0),
            })
        if self._mgr is not None:
            out["paged"] = self._mgr.stats()
        return out

    # -- shared -------------------------------------------------------------
    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / self.ecfg.temperature)

    # -- continuous batching --------------------------------------------------
    def _bucket(self, n: int) -> int:
        return min(max(self.ecfg.min_bucket, _next_pow2(n)),
                   self.ecfg.max_len)

    def _retire(self, r: Request, now: float):
        r.done, r.t_done = True, now
        self.finished.append(r)

    @staticmethod
    def _right_pad(reqs: List[Request], rows: int, width: int):
        """RIGHT-padded token block + true-length vector for a prefill
        batch: the causal mask keeps pad columns out of attention, the
        lengths keep them out of recurrent state (models/decode.prefill).
        Rows beyond ``len(reqs)`` are batch-bucket padding (length 0)."""
        toks = np.zeros((rows, width), np.int32)
        lens = np.zeros((rows,), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        return toks, lens

    def _prefill_batch(self, reqs: List[Request], rows: int,
                       toks: np.ndarray, lens: np.ndarray) -> Dict:
        """Build a prefill batch dict, gathering each request's side-input
        rows (positional by uid, see :meth:`_extra_rows`) when the family
        takes them. Shapes depend only on (rows, width, side keys), so
        prefill compiles stay enumerable."""
        b = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens)}
        if self.cfg.family == "encdec":
            b["enc_embeds"] = jnp.asarray(self._extra_rows(
                "enc_embeds", reqs, rows,
                (self._enc_len, self.cfg.d_model)))
        if self.cfg.family == "vlm" and "patch_embeds" in self.extra:
            b["patch_embeds"] = jnp.asarray(
                self._extra_rows("patch_embeds", reqs, rows, None))
        return b

    def _admit(self, cache, slots: List[Optional[Request]],
               last_tok: np.ndarray, free: List[int]):
        """Fill free slots from the queue with one bucketed prefill call.

        Takes the queue head plus any later requests sharing its length
        bucket (FIFO otherwise), right-pads to (pow2 batch, pow2 length)
        so prefill shapes stay enumerable, samples each row's first token
        from its TRUE last-prompt position, and scatters each row's
        prefilled state — KV, recurrent rows, cross-attention KV — into
        its slot. Side-input families ride the same path: each request's
        enc/patch rows join the prefill batch, and a VLM slot's length
        starts past its patch positions. With speculative decoding on,
        the draft model prefills the SAME batch and its rows scatter
        into the draft slot pool in lockstep.
        """
        head = self.queue[0]
        w = self._bucket(len(head.prompt))
        limit = min(len(free), self.ecfg.prefill_batch)
        take = [head]
        for r in self.queue[1:]:
            if len(take) >= limit:
                break
            if self._bucket(len(r.prompt)) == w:
                take.append(r)
        for r in take:
            self.queue.remove(r)

        m = len(take)
        mp = min(_next_pow2(m), self.ecfg.prefill_batch)
        toks, lens = self._right_pad(take, mp, w)
        b = self._prefill_batch(take, mp, toks, lens)
        logits, pcache = self._prefill_bucket(self.params, b)
        dcache = None
        if self._spec_k:
            _, dcache = self._draft_prefill(self.draft_params, b)
        self.prefill_calls += 1
        self.prefill_tokens += sum(len(r.prompt) for r in take)
        self.energy_tokens += sum(len(r.prompt) for r in take)
        # each row's next token comes from its true last prompt position
        idx = jnp.asarray([len(r.prompt) - 1 for r in take]
                          + [0] * (mp - m))
        first = np.asarray(self._sample(logits[jnp.arange(mp), idx]))
        now = time.time()
        for i, r in enumerate(take):
            r.t_first_token = now
            t = int(first[i])
            r.output.append(t)
            if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                self._retire(r, now)                 # never occupies a slot
                continue
            slot = free.pop(0)
            ln = self._patch_len + len(r.prompt)
            cache = self._insert(cache, pcache, i, slot, ln)
            if dcache is not None:
                self._draft_cache = self._draft_insert(
                    self._draft_cache, dcache, i, slot, ln)
            slots[slot] = r
            r.slot = slot
            last_tok[slot] = t
            self.admissions.append(
                {"step": self.decode_steps, "uid": r.uid, "slot": slot})
        return cache

    def _place_admitted(self, r: Request, slot: int, token: int,
                        slots: List[Optional[Request]],
                        last_tok: np.ndarray, now: float) -> None:
        """Record a freshly-admitted request in its slot (or retire it on
        the spot when the prefill token already finishes it)."""
        r.t_first_token = now
        r.output.append(token)
        if token == r.eos_id or len(r.output) >= r.max_new_tokens:
            self._retire(r, now)
            self._mgr.retire(slot)     # pages freed; the prefix stays indexed
            return
        slots[slot] = r
        r.slot = slot
        last_tok[slot] = token
        self.admissions.append(
            {"step": self.decode_steps, "uid": r.uid, "slot": slot})

    def _admit_paged(self, cache, slots: List[Optional[Request]],
                     last_tok: np.ndarray, free: List[int]):
        """Admit from the queue into free slots through the radix index.

        A queue head with a cached shared prefix admits alone: the
        reused pages are ref-bumped into its block table and ONLY the
        un-cached suffix is prefilled against them
        (``models.decode.prefill_paged_suffix``). Cold requests batch
        through the same pow2-bucketed prefill as the contiguous path,
        then scatter into their private pages. Either way, the prompt's
        full pages are published to the index for later requests.

        Returns ``(cache, progressed)``. ``progressed=False`` means the
        page pool could not hold the queue head (``PoolExhausted``
        rolled the partial allocation back): nothing was admitted, and
        the caller must STOP admitting and decode instead — retirement
        frees pages — rather than spin on the same head.
        """
        if self._mgr.match_tokens([int(t) for t in self.queue[0].prompt]):
            return self._admit_paged_suffix(cache, slots, last_tok, free)
        return self._admit_paged_cold(cache, slots, last_tok, free)

    def _worst_case_pages(self, r: Request) -> int:
        """Pages ``r`` occupies if it decodes to its full budget: the
        cache length peaks at len(prompt) + max_new_tokens - 1 (the last
        sampled token is never appended). A speculative verify round can
        additionally write spec_k proposal positions past that peak
        before rolling back, so spec engines budget those pages too."""
        end = len(r.prompt) + r.max_new_tokens - 1 + self._spec_k
        return -(-end // self.ecfg.block_size)

    def _paged_headroom(self, slots: List[Optional[Request]]) -> int:
        """Free pages minus the growth still owed to live slots.

        Admission must budget for decode growth, not just the prompt:
        admitting on prompt pages alone can deadlock mid-decode when
        every live slot needs its next page and nothing is retirable.
        Gating on this headroom keeps the invariant that owed growth
        always fits the free list, so ``prepare_append`` cannot exhaust
        the pool between horizon boundaries.
        """
        owed = 0
        for i, s in enumerate(slots):
            if s is None:
                continue
            owed += max(0, self._worst_case_pages(s)
                        - len(self._mgr.slot_blocks(i)))
        return self._mgr.pool.free_blocks - owed

    def _admit_paged_suffix(self, cache, slots, last_tok, free):
        # peek, don't pop: if the pool can't hold the head's pages the
        # request must stay queued (admit() rolls its allocation back)
        r = self.queue[0]
        slot = free[0]
        prompt = [int(t) for t in r.prompt]
        # full shared prefix pages are reused; everything else — the
        # prompt tail AND the decode growth — must fit the headroom
        cached_probe = self._mgr.match_tokens(prompt)
        need = (self._worst_case_pages(r)
                - cached_probe // self.ecfg.block_size)
        if need > self._paged_headroom(slots):
            return cache, False
        try:
            cached = self._mgr.admit(slot, prompt)
        except PoolExhausted:
            return cache, False
        self.queue.pop(0)
        free.pop(0)
        suffix = r.prompt[cached:]
        w = self._bucket(len(suffix))
        toks = np.zeros((1, w), np.int32)
        toks[0, :len(suffix)] = suffix
        # gather only a pow2 bucket of prefix pages, not the whole
        # table — suffix attention width scales with the prefix, and
        # compile count stays one per (suffix, prefix) bucket pair
        bs = self.ecfg.block_size
        pb = min(_next_pow2(-(-cached // bs)), len(self._mgr.tables[slot]))
        logits, src = self._prefill_suffix(
            self.params, jnp.asarray(toks), cache,
            jnp.asarray(self._mgr.tables[slot][:pb])[None],
            np.int32(cached),
        )
        self.prefill_calls += 1
        self.prefill_tokens += len(suffix)
        self.energy_tokens += len(suffix)   # reused prefix costs nothing
        self.cached_prefix_tokens += cached
        cache = self._insert_paged(
            cache, src, 0, slot, jnp.asarray(self._mgr.tables[slot]),
            np.int32(cached), len(prompt))
        self._mgr.register(slot, prompt)
        first = np.asarray(self._sample(logits[:, len(suffix) - 1]))
        self._place_admitted(r, slot, int(first[0]), slots, last_tok,
                             time.time())
        if self._spec_k and slots[slot] is r:
            # the draft pool is contiguous and reuses no prefixes: it
            # prefills the FULL prompt even when the main model only
            # ran the suffix
            wf = self._bucket(len(prompt))
            dt = np.zeros((1, wf), np.int32)
            dt[0, :len(prompt)] = prompt
            db = {"tokens": jnp.asarray(dt),
                  "lengths": jnp.asarray(np.array([len(prompt)], np.int32))}
            _, dc = self._draft_prefill(self.draft_params, db)
            self._draft_cache = self._draft_insert(
                self._draft_cache, dc, 0, slot, len(prompt))
        return cache, True

    def _admit_paged_cold(self, cache, slots, last_tok, free):
        # same take policy as the contiguous _admit: the queue head plus
        # FIFO-later requests sharing its length bucket — but only other
        # index misses (a hit admits alone through the suffix path)
        head = self.queue[0]
        w = self._bucket(len(head.prompt))
        limit = min(len(free), self.ecfg.prefill_batch)
        take = [head]
        for r in self.queue[1:]:
            if len(take) >= limit:
                break
            if (self._bucket(len(r.prompt)) == w
                    and not self._mgr.match_tokens(
                        [int(t) for t in r.prompt])):
                take.append(r)

        # claim pages first so nothing registers mid-batch: identical
        # prompts inside one cold batch each prefill privately (the
        # second one hits the index only on a LATER admission). A
        # PoolExhausted admit rolls itself back and stops the batch
        # there — only successfully-placed requests leave the queue,
        # the rest wait for retirement to free pages.
        placed = []
        headroom = self._paged_headroom(slots)
        for r in take:
            slot = free[0]
            prompt = [int(t) for t in r.prompt]
            # gate on the full worst case (prompt + decode growth), not
            # just the prompt pages admit() allocates now — earlier
            # batch members' growth stays owed against the same free
            # list until they retire
            need = self._worst_case_pages(r)
            if need > headroom:
                break
            try:
                self._mgr.admit(slot, prompt)
            except PoolExhausted:
                break
            headroom -= need         # prompt pages taken + growth owed
            free.pop(0)
            placed.append((r, slot, prompt))
        if not placed:
            return cache, False
        for r, _, _ in placed:
            self.queue.remove(r)

        m = len(placed)
        mp = min(_next_pow2(m), self.ecfg.prefill_batch)
        toks, lens = self._right_pad([r for r, _, _ in placed], mp, w)
        b = {"tokens": jnp.asarray(toks), "lengths": jnp.asarray(lens)}
        logits, pcache = self._prefill_bucket(self.params, b)
        dcache = None
        if self._spec_k:
            _, dcache = self._draft_prefill(self.draft_params, b)
        self.prefill_calls += 1
        self.prefill_tokens += sum(len(r.prompt) for r, _, _ in placed)
        self.energy_tokens += sum(len(r.prompt) for r, _, _ in placed)
        idx = jnp.asarray([len(r.prompt) - 1 for r, _, _ in placed]
                          + [0] * (mp - m))
        first = np.asarray(self._sample(logits[jnp.arange(mp), idx]))
        now = time.time()
        for i, (r, slot, prompt) in enumerate(placed):
            cache = self._insert_paged(
                cache, pcache["kv"], i, slot,
                jnp.asarray(self._mgr.tables[slot]), np.int32(0),
                len(prompt))
            self._mgr.register(slot, prompt)
            self._place_admitted(r, slot, int(first[i]), slots, last_tok,
                                 now)
            if dcache is not None and slots[slot] is r:
                self._draft_cache = self._draft_insert(
                    self._draft_cache, dcache, i, slot, len(prompt))
        return cache, True

    def _run_continuous(self):
        n = self.ecfg.max_batch
        paged = self.ecfg.paged
        enc_len = self._enc_len if self.cfg.family == "encdec" else 0
        if paged:
            # persistent pool: pages indexed in an earlier run() still
            # hold their prefilled KV, so the cache outlives the run
            cache = self._kv_cache
        else:
            # under a mesh, constrain() shards the slot axis over "data"
            # eagerly here, so decode-step donation reuses placed buffers
            with self._ctx():
                cache = D.cache_init(self.params, self.cfg, n,
                                     self.ecfg.max_len, dtype=jnp.float32,
                                     enc_len=enc_len)
        if self._spec_k:
            # the draft slot pool is always contiguous (rollback is a
            # length edit; no prefix reuse) and mirrors the main pool's
            # slot assignment one-to-one
            with self._ctx():
                self._draft_cache = D.cache_init(
                    self.draft_params, self.ecfg.draft_config, n,
                    self.ecfg.max_len, dtype=jnp.float32, enc_len=enc_len)
        slots: List[Optional[Request]] = [None] * n
        last_tok = np.zeros((n,), np.int32)
        try:
            while self.queue or any(s is not None for s in slots):
                # admission at the horizon boundary. `stalled` breaks
                # the loop when the paged pool can't hold the queue
                # head (admit rolled back) — decoding frees pages via
                # retirement, so we must fall through, NOT spin here.
                stalled = False
                while (self.queue and any(s is None for s in slots)
                       and not stalled):
                    free = [i for i, s in enumerate(slots) if s is None]
                    if paged:
                        cache, progressed = self._admit_paged(
                            cache, slots, last_tok, free)
                        stalled = not progressed
                    else:
                        cache = self._admit(cache, slots, last_tok, free)
                if not any(s is not None for s in slots):
                    if stalled:
                        # nothing live to retire: the pool can never
                        # hold the queue head — surface it instead of
                        # spinning forever
                        raise PoolExhausted(
                            f"page pool ({self._mgr.pool.num_blocks} "
                            f"blocks) cannot hold the queue head's "
                            f"prompt plus its decode budget with no "
                            f"live slots left to retire; raise "
                            f"num_blocks"
                        )
                    continue                         # all admits retired at t=1
                if self._spec_k:
                    cache = self._spec_round(cache, slots, last_tok, paged)
                elif self._use_device_loop:
                    cache = self._horizon_step(cache, slots, last_tok, paged)
                else:
                    cache = self._host_step(cache, slots, last_tok, paged)
        finally:
            if paged:
                self._kv_cache = cache               # donated: keep the live
                # handle so the next run() reuses indexed prefix pages

    def _horizon_step(self, cache, slots: List[Optional[Request]],
                      last_tok: np.ndarray, paged: bool):
        """One host round-trip: up to ``decode_horizon`` decode steps on
        device (``models.decode.decode_multi_step[_paged]``), then drain
        the returned token buffer, stamp ONE boundary timestamp, and
        retire finished slots. The loop exits early on device once every
        live slot is done, so short tails don't burn horizon steps."""
        n = self.ecfg.max_batch
        h = self.ecfg.decode_horizon
        live = np.array([s is not None for s in slots])
        budget = np.zeros((n,), np.int32)
        eos = np.full((n,), -1, np.int32)
        for i, r in enumerate(slots):
            if r is None:
                continue
            budget[i] = r.max_new_tokens - len(r.output)
            eos[i] = r.eos_id
        t0 = time.time()
        if paged:
            # a CoW valve can only resolve on the host; if one would
            # trigger past the first position (reachable via fork()
            # only — full-page publishing keeps shared pages full),
            # fall back to a single-step round
            if any(self._mgr.mid_horizon_cow(i, min(h, int(budget[i])))
                   for i, s in enumerate(slots) if s is not None):
                h = 1

            # never pre-reserve past the pool: shrink this round's
            # horizon until the worst-case fresh-page demand fits the
            # free list (halving keeps the static-horizon compile set
            # at O(log H) entries under sustained pressure)
            bs = self.ecfg.block_size

            def _new_pages(hh: int) -> int:
                need = 0
                for i, s in enumerate(slots):
                    if s is None:
                        continue
                    end = int(self._mgr.lengths[i]) + min(hh, int(budget[i]))
                    need += max(0, -(-end // bs)
                                - len(self._mgr.slot_blocks(i)))
                return need

            while h > 1 and _new_pages(h) > self._mgr.pool.free_blocks:
                h //= 2
            # pre-reserve the whole horizon: grow each live slot's
            # table min(h, budget) tokens ahead (fresh pages at block
            # boundaries, eager copy-on-write when shared) so the
            # device loop never needs the host mid-horizon
            for i, s in enumerate(slots):
                if s is None:
                    continue
                for _ in range(min(h, int(budget[i]))):
                    cow = self._mgr.prepare_append(i)
                    if cow is not None:
                        cache = self._copy_page(cache, *cow)
            buf, emitted, done, last, cache, steps = self._decode_multi_paged(
                self.params, cache, jnp.asarray(self._mgr.tables),
                jnp.asarray(last_tok), jnp.asarray(live),
                jnp.asarray(eos), jnp.asarray(budget), h)
        else:
            buf, emitted, done, last, cache, steps = self._decode_multi(
                self.params, cache, jnp.asarray(last_tok),
                jnp.asarray(live), jnp.asarray(eos), jnp.asarray(budget), h)
        buf, emitted = np.asarray(buf), np.asarray(emitted)
        done, last, steps = np.asarray(done), np.asarray(last), int(steps)
        now = time.time()
        self.host_syncs += 1
        self.decode_wall_s += now - t0
        self.decode_steps += steps
        # occupancy per DEVICE step: slot i was live at step s of the
        # horizon iff it emitted more than s tokens
        for s in range(steps):
            self.step_occupancy.append(float(np.sum(emitted > s)) / n)
        for i, r in enumerate(slots):
            if r is None:
                continue
            r.output.extend(int(t) for t in buf[i, :emitted[i]])
            # energy: only tokens a live slot actually emitted (retired
            # rows keep stepping under the no-op mask — burned compute on
            # the TPU, but no modeled crossbar work is attributed)
            self.energy_tokens += int(emitted[i])
            last_tok[i] = int(last[i])
            if done[i]:
                self._retire(r, now)
                slots[i] = None              # freed at THIS boundary
                if paged:
                    self._mgr.retire(i)
        return cache

    def _host_step(self, cache, slots: List[Optional[Request]],
                   last_tok: np.ndarray, paged: bool):
        """Legacy per-token round-trip (temperature sampling, or
        ``device_loop=False``): one decode step, host-side sampling,
        EOS/budget checks and retirement."""
        n = self.ecfg.max_batch
        self.step_occupancy.append(sum(s is not None for s in slots) / n)
        t0 = time.time()
        if paged:
            # grow each live slot's table by one token (a fresh
            # page at block boundaries, copy-on-write if shared)
            for i, s in enumerate(slots):
                if s is None:
                    continue
                cow = self._mgr.prepare_append(i)
                if cow is not None:
                    cache = self._copy_page(cache, *cow)
            logits, cache = self._decode_paged(
                self.params, jnp.asarray(last_tok)[:, None], cache,
                jnp.asarray(self._mgr.tables))
        else:
            logits, cache = self._decode(
                self.params, jnp.asarray(last_tok)[:, None], cache)
        nxt = np.asarray(self._sample(logits[:, 0]))
        self.decode_steps += 1
        self.host_syncs += 1
        now = time.time()
        self.decode_wall_s += now - t0
        for i, r in enumerate(slots):
            if r is None:
                continue
            t = int(nxt[i])
            r.output.append(t)
            self.energy_tokens += 1
            last_tok[i] = t
            if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                self._retire(r, now)
                slots[i] = None              # freed THIS step
                if paged:
                    self._mgr.retire(i)
        return cache

    # -- speculative decoding -------------------------------------------------
    def _spec_round(self, cache, slots: List[Optional[Request]],
                    last_tok: np.ndarray, paged: bool):
        """One speculative round: draft proposes, the main model
        verifies, the longest argmax-matching proposal prefix plus one
        bonus token is emitted, and both caches roll back to the
        accepted length.

        The draft runs k+1 masked steps so its cache holds every
        position a full acceptance needs (``decode_propose``); the
        verify commits k+1 K/V positions but leaves lengths untouched,
        so the rollback is the single ``_set_len`` edit at the end
        (paged: plus ``PagedKVManager.truncate`` page releases). Paged
        rounds pre-reserve all k+1 positions per live slot BEFORE the
        verify; if the fresh-page demand exceeds the free list the
        round runs at width 1 — exactly a vanilla decode step (the
        admission headroom invariant guarantees one position always
        fits) — which keeps the draft cache in lockstep under pool
        pressure. Every emitted token is a main-model argmax at the
        same cache state vanilla decode would have, so outputs are
        token-identical to vanilla greedy serving.
        """
        n = self.ecfg.max_batch
        k = self._spec_k
        live = np.array([s is not None for s in slots])
        n_live = int(live.sum())
        t0 = time.time()
        k_round = k
        base_len = None
        if paged:
            bs = self.ecfg.block_size
            base_len = [int(self._mgr.lengths[i]) for i in range(n)]
            need = 0
            for i, s in enumerate(slots):
                if s is None:
                    continue
                end = base_len[i] + k + 1
                need += max(0, -(-end // bs)
                            - len(self._mgr.slot_blocks(i)))
            if need > self._mgr.pool.free_blocks:
                k_round = 0
            for i, s in enumerate(slots):
                if s is None:
                    continue
                for _ in range(k_round + 1):
                    cow = self._mgr.prepare_append(i)
                    if cow is not None:
                        cache = self._copy_page(cache, *cow)
        live_dev = jnp.asarray(live)
        last_dev = jnp.asarray(last_tok)
        props, self._draft_cache = self._draft_propose(
            self.draft_params, self._draft_cache, last_dev, live_dev,
            k_round + 1)
        if paged:
            logits, cache = self._verify_paged(
                self.params, cache, jnp.asarray(self._mgr.tables),
                live_dev, last_dev, props)
        else:
            logits, cache = self._verify(self.params, cache, last_dev,
                                         props)
        # one host sync per round: the proposals and the verify argmaxes
        # land together (async dispatch keeps the draft/verify pipelined)
        m = np.asarray(jnp.argmax(logits, axis=-1))     # (n, k_round+1)
        props = np.asarray(props)
        now = time.time()
        self.host_syncs += 1
        self.decode_wall_s += now - t0
        self.decode_steps += 1
        self.spec_rounds += 1
        self.step_occupancy.append(n_live / n)
        for i in range(n):
            r = slots[i]
            if r is None:
                continue
            a = 0
            while a < k_round and props[i, a] == m[i, a]:
                a += 1
            self.spec_proposed += k_round
            self.spec_accepted += a
            for t in m[i, :a + 1]:
                t = int(t)
                r.output.append(t)
                self.energy_tokens += 1
                last_tok[i] = t
                if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                    self._retire(r, now)
                    slots[i] = None
                    if paged:
                        self._mgr.retire(i)
                    break
            if paged and slots[i] is not None:
                self._mgr.truncate(i, base_len[i] + a + 1)
        # the rollback: both caches' lengths snap to the accepted
        # position (free slots to 0); junk K/V above the watermark is
        # never attended and the next round overwrites it in place
        lens = np.zeros((n,), np.int32)
        for i, r in enumerate(slots):
            if r is not None:
                lens[i] = (self._patch_len + len(r.prompt)
                           + len(r.output) - 1)
        lens_dev = jnp.asarray(lens)
        cache = self._set_len(cache, lens_dev)
        self._draft_cache = self._set_len(self._draft_cache, lens_dev)
        return cache

    # -- static batching ------------------------------------------------------

    def _extra_rows(self, key: str, reqs: List[Request], bp: int,
                    default_shape) -> np.ndarray:
        """Per-request side-input rows for a static batch.

        Rows come from ``Request.extra_idx`` when submit() set one, and
        are positional by submission order otherwise (request uid 1 is
        row 0, ...). Slicing the head of the array — the old behavior —
        handed EVERY batch the first batch's rows; gathering per request
        keeps later batches on their own inputs. Batch-bucket padding
        rows are zeros (their outputs are ignored).
        """
        arr = self.extra.get(key)
        if arr is None:
            arr = np.zeros((0,) + tuple(default_shape), np.float32)
        arr = np.asarray(arr)
        out = np.zeros((bp,) + arr.shape[1:], arr.dtype)
        for i, r in enumerate(reqs):
            if arr.shape[0] == 0:
                continue                     # no side inputs: zeros rows
            idx = r.extra_idx if r.extra_idx >= 0 else r.uid - 1
            if idx >= arr.shape[0]:
                raise ValueError(
                    f"request uid {r.uid} has no {key} row {idx}: "
                    f"{arr.shape[0]} rows were supplied at engine "
                    f"construction (side inputs are positional by "
                    f"submission order unless submit(extra_idx=...) "
                    f"picks a row)"
                )
            out[i] = arr[idx]
        return out

    def _run_batch(self, reqs: List[Request]):
        nreq = len(reqs)
        # pow2-bucket the batch dim: _prefill_full compiles once per
        # (batch bucket, padded length) pair instead of once per exact
        # admitted batch size (batch rows are independent everywhere in
        # the model, so padding rows are inert)
        bp = min(_next_pow2(nreq), self.ecfg.max_batch)
        # RIGHT-pad every family to a pow2 length bucket + per-row true
        # lengths: the causal mask keeps pad columns out of attention,
        # the lengths make recurrent prefill exact, and decode advances
        # each row at its own position (vector cache lengths) — so
        # mixed-length static batches decode bit-exactly with the
        # sequential and continuous paths. (The historical left-pad
        # variant was NOT exact for mixed lengths: pad positions sat
        # inside the causal window and leaked into attention.)
        w = self._bucket(max(len(r.prompt) for r in reqs))
        toks, lens = self._right_pad(reqs, bp, w)
        b = self._prefill_batch(reqs, bp, toks, lens)
        logits, cache = self._prefill_full(self.params, b)
        self.prefill_calls += 1
        self.prefill_tokens += sum(len(r.prompt) for r in reqs)
        self.energy_tokens += sum(len(r.prompt) for r in reqs)
        # each row's first token comes from its true last prompt position
        nxt = self._sample(
            logits[jnp.arange(bp), jnp.maximum(b["lengths"] - 1, 0)])
        first = np.asarray(nxt)
        t_first = time.time()
        for i, r in enumerate(reqs):
            t = int(first[i])
            r.output.append(t)
            r.t_first_token = t_first
            if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                r.done, r.t_done = True, t_first
        # submit() bounds every request's own writes (side/spec overhead
        # included), so live rows never clamp; a finished row that keeps
        # stepping only touches its own junk tail — batch rows are
        # independent and the cache dies with the batch
        max_new = max(r.max_new_tokens for r in reqs)
        for _ in range(max_new - 1):
            # occupancy relative to the slot pool a continuous scheduler
            # would have: retired-but-held and unfilled slots count as idle
            n_alive = sum(
                not r.done and len(r.output) < r.max_new_tokens for r in reqs
            )
            if n_alive == 0:
                break
            self.step_occupancy.append(n_alive / self.ecfg.max_batch)
            logits, cache = self._decode(
                self.params, jnp.asarray(nxt)[:, None], cache
            )
            self.decode_steps += 1
            nxt = self._sample(logits[:, 0])
            arr = np.asarray(nxt)
            now = time.time()
            for i, r in enumerate(reqs):
                if r.done or len(r.output) >= r.max_new_tokens:
                    continue
                t = int(arr[i])
                r.output.append(t)
                self.energy_tokens += 1
                if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                    r.done, r.t_done = True, now
        now = time.time()
        for r in reqs:
            r.done = True
            r.t_done = r.t_done or now
            self.finished.append(r)


def throughput_stats(reqs: List[Request]) -> Dict[str, float]:
    """Aggregate request metrics; robust to empty/never-started requests.

    Requests that never produced a token contribute to ``requests`` but
    not to TTFT (no first token to time); a request list with no finish
    timestamps falls back to enqueue time so ``tokens_per_s`` is 0 rather
    than garbage.

    Per-token latency (``mean_tpot_s``) is derived from the two REAL
    timestamps each request has — first token at admission, completion
    at its retirement boundary — divided by its decode-token count.
    Under the device horizon loop the engine only touches the host at
    horizon boundaries, so there are no per-token wall times to average
    (and none are fabricated): the boundary-to-boundary quotient is the
    honest figure at every ``decode_horizon``, and degrades gracefully
    to true per-token latency at horizon 1.
    """
    if not reqs:
        return {}
    total_tokens = sum(len(r.output) for r in reqs)
    t0 = min(r.t_enqueue for r in reqs)
    finished = [r.t_done for r in reqs if r.t_done]
    elapsed = (max(finished) - t0) if finished else 0.0
    started = [r for r in reqs if r.t_first_token > 0.0]
    ttft = [r.t_first_token - r.t_enqueue for r in started]
    tpot = [
        (r.t_done - r.t_first_token) / max(len(r.output) - 1, 1)
        for r in reqs
        if r.t_done and r.t_first_token and len(r.output) > 1
    ]
    return {
        "requests": len(reqs),
        "started": len(started),
        "total_tokens": total_tokens,
        "tokens_per_s": total_tokens / elapsed if elapsed > 0 else 0.0,
        "mean_ttft_s": float(np.mean(ttft)) if ttft else 0.0,
        "mean_tpot_s": float(np.mean(tpot)) if tpot else 0.0,
    }
