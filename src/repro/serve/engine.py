"""Batched serving engine: prefill + decode with KV caches.

Small-scale-runnable (CPU) but structured like a real engine:

  * requests enter a queue; the scheduler forms batches of equal padded
    prompt length (static batching with bucketing),
  * ``prefill`` processes the prompt batch in parallel and fills the
    caches; ``decode`` steps advance all sequences one token per call,
  * finished sequences (EOS or max tokens) retire; their slots back-fill
    from the queue at the next prefill boundary (continuous-batching
    lite),
  * PSQ-trained models can serve through the int4 weight-stationary
    kernel (``pack_psq_weights`` + quant mode on the config) — the HCiM
    deployment story on TPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import decode as D

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    eos_id: int = -1              # -1: never
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0      # 0 => greedy
    seed: int = 0


class ServeEngine:
    def __init__(self, params: PyTree, cfg: ArchConfig, ecfg: EngineConfig,
                 extra_inputs: Optional[Dict[str, np.ndarray]] = None):
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        self.extra = extra_inputs or {}
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self._uid = 0
        self._key = jax.random.PRNGKey(ecfg.seed)

        self._prefill = jax.jit(
            lambda p, b: D.prefill(p, cfg, b, ecfg.max_len, dtype=jnp.float32)
        )
        self._decode = jax.jit(
            lambda p, tok, cache: D.decode_step(p, cfg, tok, cache)
        )

    # -- API ---------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 16,
               eos_id: int = -1) -> int:
        self._uid += 1
        r = Request(self._uid, np.asarray(prompt, np.int32),
                    max_new_tokens, eos_id, t_enqueue=time.time())
        self.queue.append(r)
        return r.uid

    def run(self) -> List[Request]:
        """Drain the queue; returns finished requests with outputs."""
        while self.queue:
            batch = self.queue[: self.ecfg.max_batch]
            self.queue = self.queue[self.ecfg.max_batch:]
            self._run_batch(batch)
        return self.finished

    # -- internals ----------------------------------------------------------
    def _pad_prompts(self, reqs: List[Request]) -> np.ndarray:
        # left-pad to the longest prompt so last position is the newest token
        s = max(len(r.prompt) for r in reqs)
        out = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            out[i, s - len(r.prompt):] = r.prompt
        return out

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / self.ecfg.temperature)

    def _run_batch(self, reqs: List[Request]):
        tokens = self._pad_prompts(reqs)
        b = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "encdec":
            b["enc_embeds"] = jnp.asarray(
                self.extra.get(
                    "enc_embeds",
                    np.zeros((len(reqs), tokens.shape[1], self.cfg.d_model),
                             np.float32),
                )
            )[: len(reqs)]
        if self.cfg.family == "vlm" and "patch_embeds" in self.extra:
            b["patch_embeds"] = jnp.asarray(self.extra["patch_embeds"])[: len(reqs)]
        logits, cache = self._prefill(self.params, b)
        nxt = self._sample(logits[:, -1])
        t_first = time.time()
        for r, t in zip(reqs, np.asarray(nxt)):
            r.output.append(int(t))
            r.t_first_token = t_first
        max_new = max(r.max_new_tokens for r in reqs)
        for _ in range(max_new - 1):
            logits, cache = self._decode(
                self.params, jnp.asarray(nxt)[:, None], cache
            )
            nxt = self._sample(logits[:, 0])
            now = time.time()
            alive = False
            for i, r in enumerate(reqs):
                if r.done or len(r.output) >= r.max_new_tokens:
                    continue
                t = int(np.asarray(nxt)[i])
                r.output.append(t)
                if t == r.eos_id or len(r.output) >= r.max_new_tokens:
                    r.done, r.t_done = True, now
                else:
                    alive = True
            if not alive:
                break
        now = time.time()
        for r in reqs:
            r.done = True
            r.t_done = r.t_done or now
            self.finished.append(r)


def throughput_stats(reqs: List[Request]) -> Dict[str, float]:
    if not reqs:
        return {}
    total_tokens = sum(len(r.output) for r in reqs)
    t0 = min(r.t_enqueue for r in reqs)
    t1 = max(r.t_done for r in reqs)
    ttft = [r.t_first_token - r.t_enqueue for r in reqs]
    return {
        "requests": len(reqs),
        "total_tokens": total_tokens,
        "tokens_per_s": total_tokens / max(t1 - t0, 1e-9),
        "mean_ttft_s": float(np.mean(ttft)),
    }
