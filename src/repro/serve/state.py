"""Slot-state layer: one insert/select/retire/set-length interface over
every decode-cache layout.

The serve engine carries three physically different per-slot pools —
the contiguous stacked-KV stripe (attention families), the paged block
pool reached through per-slot block tables, and recurrent state leaves
(SSM/xLSTM/hybrid, no sequence axis at all). Historically each layout
was an ``if paged:`` / per-family branch inside the engine loop; this
module collapses them behind :class:`SlotState`:

``init_pool()``
    allocate the device pool (eager, under the engine's sharding rules
    so slot leaves place over the ``data`` mesh axis before the first
    donated jit call).

``insert(src, row, slot, length)``
    scatter row ``row`` of a prefill result into slot ``slot`` at the
    given true length. The contiguous path covers KV stripes, recurrent
    leaves and side-input pools in one generic leaf walk
    (``models.decode.cache_insert``); the paged path scatters through
    the slot's block table (``paged_cache_insert``).

``retire(slot)``
    free the slot. Contiguous/recurrent slots are simply unbound (the
    next insert overwrites every leaf); paged slots additionally
    release their page refcounts (indexed prefixes outlive requests).

``set_lengths(lens)``
    stamp the per-slot length vector — the speculative-decoding
    rollback primitive (paged engines pair it with
    ``PagedKVManager.truncate`` page releases).

Slot *bookkeeping* (which request occupies which slot, last sampled
token per slot) is shared by both layouts and lives on the base class,
so the scheduler and executors never touch layout-specific state.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax.numpy as jnp

import numpy as np

from repro.models import decode as D
from repro.serve.paged_kv import PagedKVManager

PyTree = Any


class SlotState:
    """Slot bookkeeping + the layout-agnostic pool interface.

    Holds the request-per-slot binding and last-token vector; concrete
    layouts implement ``init_pool`` / ``insert`` / ``retire`` /
    ``set_lengths`` against the engine's compiled functions. The device
    cache itself lives on the engine (``eng._cache``) because jit
    donation rebinds the handle on every call.
    """

    def __init__(self, eng):
        self.eng = eng
        n = eng.ecfg.max_batch
        self.slots: List[Optional[Any]] = [None] * n
        self.last_tok = np.zeros((n,), np.int32)

    # -- bookkeeping (layout-independent) -------------------------------
    @property
    def any_live(self) -> bool:
        return any(s is not None for s in self.slots)

    def free(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def live_flags(self) -> np.ndarray:
        return np.array([s is not None for s in self.slots])

    def bind(self, request, slot: int, token: int) -> None:
        self.slots[slot] = request
        request.slot = slot
        self.last_tok[slot] = token

    # -- pool interface -------------------------------------------------
    def init_pool(self) -> PyTree:
        raise NotImplementedError

    def insert(self, src: PyTree, row: int, slot: int,
               length: int) -> None:
        raise NotImplementedError

    def retire(self, slot: int) -> None:
        """Unbind the slot; layout subclasses release physical storage."""
        self.slots[slot] = None

    def set_lengths(self, lens: np.ndarray) -> None:
        raise NotImplementedError


class ContiguousSlotState(SlotState):
    """Contiguous per-slot stripes: stacked KV, recurrent leaves and
    side-input pools, all scattered by one generic leaf walk."""

    def init_pool(self) -> PyTree:
        eng = self.eng
        enc_len = eng._enc_len if eng.cfg.family == "encdec" else 0
        with eng._ctx():
            return D.cache_init(eng.params, eng.cfg, eng.ecfg.max_batch,
                                eng.ecfg.max_len, dtype=jnp.float32,
                                enc_len=enc_len)

    def insert(self, src, row, slot, length):
        eng = self.eng
        eng._cache = eng._insert(eng._cache, src, row, slot, length)

    def set_lengths(self, lens):
        eng = self.eng
        eng._cache = eng._set_len(eng._cache, jnp.asarray(lens))


class PagedSlotState(SlotState):
    """Paged block pool: per-slot block tables over fixed-size KV pages
    with radix shared-prefix reuse (``serve/paged_kv.py``)."""

    def __init__(self, eng, mgr: PagedKVManager):
        super().__init__(eng)
        self.mgr = mgr

    def init_pool(self) -> PyTree:
        eng = self.eng
        with eng._ctx():
            return D.paged_cache_init(
                eng.params, eng.cfg, eng.ecfg.max_batch, eng.ecfg.max_len,
                eng.ecfg.block_size, self.mgr.pool.num_blocks,
                dtype=jnp.float32,
            )

    def insert(self, src, row, slot, length):
        # paged admission scatters with an explicit start offset (prefix
        # reuse); the no-offset form used by the layout-agnostic callers
        # writes the whole prompt
        eng = self.eng
        eng._cache = eng._insert_paged(
            eng._cache, src, row, slot,
            jnp.asarray(self.mgr.tables[slot]), np.int32(0), length)

    def retire(self, slot):
        super().retire(slot)
        self.mgr.retire(slot)

    def set_lengths(self, lens):
        eng = self.eng
        eng._cache = eng._set_len(eng._cache, jnp.asarray(lens))

    def prepare_append(self, slot: int) -> None:
        """Grow one slot's table by one token: a fresh page at block
        boundaries, an eager copy-on-write duplication when shared."""
        cow = self.mgr.prepare_append(slot)
        if cow is not None:
            eng = self.eng
            eng._cache = eng._copy_page(eng._cache, *cow)

    def truncate(self, slot: int, length: int) -> None:
        self.mgr.truncate(slot, length)
