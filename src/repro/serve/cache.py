"""Weight-stationary serving cache (the HCiM deployment contract).

A crossbar accelerator programs weights into the array once and streams
activations past them; re-deriving integer weight codes, packed int4
planes and fixed-point scale factors on every matmul — what the QAT-path
``kernels.ops`` wrappers do, correctly, for training — throws that
property away at serve time. :class:`PackedLayer` restores it: all
per-layer quantization state is computed **once** at model-load time and
reused across every request.

``PackedLayer`` is a registered pytree, so packed models pass through
``jax.jit`` (the serving engine's prefill/decode closures) unchanged, and
``apply_linear`` treats a packed node exactly like a param dict.

Packed per layer (values only, gradients stopped):

  w_codes   int8 (K, O)      LSQ two's-complement weight codes
  w_packed  int8 (K/2, O)    two int4 codes per byte (``pack_int4``),
                             present when ``n_bits_w <= 4`` and K is even
  s_w       f32 () | (O,)    LSQ weight step (dequant scale)
  sf_q      f32 (T, ...)     dequantized fixed-point scale factors
  alpha     f32 ()           comparator threshold
  step_x    f32 ()           activation quantizer step (per-call x quant)
  sigma     f32 (n_a,)       input bit-stream significances
  kappa     f32 (n_w,)       weight bit-slice significances
  bias      f32 (O,) | None
  occupancy ColumnOccupancy | None — static per-(tile, column-block)
            zero-weight metadata (:mod:`repro.kernels.occupancy`), the
            handle the kernels use to skip all-zero ternary column
            blocks. Plain hashable python data, carried as pytree *aux*
            (not a leaf), so it survives jit, device placement and mesh
            re-placement untouched. ``None`` for scan-stacked packs
            (weights are traced under vmap — no static codes to inspect).

Example — pack a tiny layer once and serve from the cached state:

    >>> import jax
    >>> from repro.core.config import QuantConfig
    >>> from repro.core.psq_linear import init_linear
    >>> from repro.serve.cache import PackedLayer
    >>> cfg = QuantConfig(mode="psq", xbar_rows=32,
    ...                   kernel_backend="reference")
    >>> params = init_linear(jax.random.PRNGKey(0), 8, 4, cfg)
    >>> layer = PackedLayer.pack(params, cfg)      # the one-time work
    >>> layer.w_codes.shape
    (8, 4)
    >>> y, _ = layer.apply_serving(jax.numpy.ones((2, 8)))
    >>> y.shape
    (2, 4)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import psq, quant
from repro.core.config import QuantConfig
from repro.kernels import registry
from repro.kernels.int4_matmul import pack_int4
from repro.kernels.occupancy import (
    ColumnOccupancy, column_occupancy, merge_occupancies,
)

sg = jax.lax.stop_gradient

# module-level pack-event counter: the conformance suite asserts serving
# never re-packs a cached layer (incremented only in PackedLayer.pack).
PACK_EVENTS = 0


@dataclasses.dataclass
class PackedLayer:
    """One linear layer's quantization state, packed once."""

    cfg: QuantConfig
    w_codes: jax.Array
    s_w: jax.Array
    sf_q: jax.Array
    alpha: jax.Array
    step_x: jax.Array
    sigma: jax.Array
    kappa: jax.Array
    w_packed: Optional[jax.Array] = None
    bias: Optional[jax.Array] = None
    occupancy: Optional[ColumnOccupancy] = None

    # -- construction -------------------------------------------------------
    @classmethod
    def pack(
        cls, params: Dict[str, jax.Array], cfg: QuantConfig
    ) -> "PackedLayer":
        """The expensive one-time work: quantize + pack + precompute."""
        global PACK_EVENTS
        PACK_EVENTS += 1
        spec = cfg.spec
        w = params["w"]
        w_int, s_w, sf_q = psq.quantize_weights_for_serving(w, params, cfg)
        w_packed = None
        if spec.n_bits_w <= 4 and w.shape[0] % 2 == 0:
            w_packed = pack_int4(w_int)
        occupancy = None
        try:
            # concrete 2-D codes only; under vmap (scan-stacked packs) the
            # tracer->numpy conversion raises and we pack dense metadata-less
            w_np = np.asarray(w_int)
        except Exception:
            w_np = None
        if w_np is not None and w_np.ndim == 2:
            occupancy = column_occupancy(
                w_np, xbar_rows=cfg.xbar_rows, n_w=spec.n_bits_w
            )
        return cls(
            cfg=cfg,
            w_codes=w_int.astype(jnp.int8),
            s_w=s_w,
            sf_q=sf_q,
            alpha=sg(params["alpha"]),
            step_x=sg(params["step_x"]),
            sigma=quant.bit_weights(spec.n_bits_a),
            kappa=quant.bit_weights(spec.n_bits_w),
            w_packed=w_packed,
            bias=params.get("b"),
            occupancy=occupancy,
        )

    # -- serving forward ----------------------------------------------------
    def apply_serving(self, x: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
        """Full HCiM pipeline from cached state; only x is quantized here.

        Shares :func:`repro.kernels.ops.kernel_forward_values` with the
        per-call QAT path, so serving cannot drift from training.
        """
        from repro.kernels.ops import kernel_forward_values

        occ = self.occupancy if self.cfg.sparsity_skip else None
        y = kernel_forward_values(
            x, self.w_codes.astype(jnp.float32), self.s_w, self.sf_q,
            self.alpha, self.step_x, self.cfg, occupancy=occ,
        )
        if self.bias is not None:
            y = y + self.bias.astype(y.dtype)
        return y, {}

    def apply_int4(self, x: jax.Array) -> jax.Array:
        """Plain int4 weight-stationary decode matmul (no PSQ pipeline)."""
        if self.w_packed is None:
            raise ValueError("layer has no int4 planes (odd K or n_w > 4)")
        backend = registry.resolve_backend(self.cfg)
        o = self.w_packed.shape[-1]
        scale = jnp.broadcast_to(jnp.reshape(self.s_w, (-1,)), (o,))
        xf = x.reshape(-1, x.shape[-1])
        y = backend.int4_matmul(xf, self.w_packed, scale)
        y = y.reshape(x.shape[:-1] + (o,))
        if self.bias is not None:
            y = y + self.bias.astype(y.dtype)
        return y

    @property
    def packed_bytes(self) -> int:
        arrs = [self.w_codes, self.s_w, self.sf_q, self.alpha, self.step_x,
                self.sigma, self.kappa, self.w_packed, self.bias]
        return sum(a.nbytes for a in arrs if a is not None)


def _packed_flatten(p: PackedLayer):
    children = (p.w_codes, p.s_w, p.sf_q, p.alpha, p.step_x,
                p.sigma, p.kappa, p.w_packed, p.bias)
    return children, (p.cfg, p.occupancy)


def _packed_unflatten(aux, children) -> PackedLayer:
    cfg, occupancy = aux
    return PackedLayer(cfg, *children, occupancy=occupancy)


jax.tree_util.register_pytree_node(
    PackedLayer, _packed_flatten, _packed_unflatten
)


# ---------------------------------------------------------------------------
# Model-level cache
# ---------------------------------------------------------------------------

def _is_quantized_linear(node: Any) -> bool:
    # ndim 2: plain (K, O) linear; ndim 3: scan-stacked (n_layers, K, O)
    return (
        isinstance(node, dict)
        and "w" in node and "step_w" in node and "step_x" in node
        and getattr(node["w"], "ndim", 0) in (2, 3)
    )


def _pack_node(params: Dict[str, jax.Array], cfg: QuantConfig) -> PackedLayer:
    if params["w"].ndim == 2:
        return PackedLayer.pack(params, cfg)
    # stacked blocks: vmap the per-layer pack over the leading layer axis
    # (out_axes=0 broadcasts the layer-invariant sigma/kappa constants, so
    # every PackedLayer leaf keeps the axis lax.scan slices over).
    stacked = jax.vmap(lambda p: PackedLayer.pack(p, cfg))(params)
    # occupancy can't be derived under vmap (tracers), but the stacked
    # codes are concrete here: one conservative metadata object shared by
    # every scan slice — a block skips only if zero in ALL layers
    codes = np.asarray(stacked.w_codes)
    merged = merge_occupancies([
        column_occupancy(codes[i], xbar_rows=cfg.xbar_rows,
                         n_w=cfg.spec.n_bits_w)
        for i in range(codes.shape[0])
    ])
    return dataclasses.replace(stacked, occupancy=merged)


def _weight_fingerprint(params: Dict[str, jax.Array], cfg: QuantConfig):
    """Cheap identity check so a cache hit never serves stale weights.

    Two tiny reductions per layer (vs. full quantize+pack on miss): if
    the caller reloads different weights under the same path, the
    fingerprint changes and the layer re-packs instead of silently
    serving the old model.
    """
    w = params["w"]
    return (
        tuple(w.shape), str(w.dtype), cfg,
        float(jnp.sum(w)), float(jnp.sum(jnp.abs(w))),
        float(jnp.sum(jnp.abs(params["step_w"]))),
    )


class PackedModelCache:
    """Pack-once store keyed by layer path + weight fingerprint.

    ``packs`` counts layers actually quantized/packed; ``hits`` counts
    reuses. Re-packing the same model tree is all hits, zero packs — the
    invariant the serving path (and its test) relies on. Packing a tree
    with *changed* weights under the same paths re-packs (fingerprint
    mismatch), never serves stale state.

    >>> import jax
    >>> from repro.core.config import QuantConfig
    >>> from repro.core.psq_linear import init_linear
    >>> cfg = QuantConfig(mode="psq", xbar_rows=32,
    ...                   kernel_backend="reference")
    >>> tree = {"mlp": init_linear(jax.random.PRNGKey(0), 8, 4, cfg)}
    >>> cache = PackedModelCache()
    >>> packed = pack_tree_psq(tree, cfg, cache)
    >>> cache.stats()
    {'layers': 1, 'packs': 1, 'hits': 0}
    >>> _ = pack_tree_psq(tree, cfg, cache)        # reload: no re-pack
    >>> cache.stats()
    {'layers': 1, 'packs': 1, 'hits': 1}
    """

    def __init__(self):
        self._store: Dict[str, Tuple[tuple, PackedLayer]] = {}
        self.packs = 0
        self.hits = 0

    def get_or_pack(
        self, key: str, params: Dict[str, jax.Array], cfg: QuantConfig,
        placer=None,
    ) -> PackedLayer:
        """Cached pack; ``placer`` (layer -> layer) applies device placement.

        Placement is fingerprint-stable and never enters the store: the
        fingerprint is computed from the source params only, the cache
        always holds the unplaced packed state, and ``placer`` is applied
        to the returned value per call. Packing the same weights for a
        different mesh — or with no mesh after a meshed pack — is thus a
        cache **hit** that yields exactly the placement asked for (a
        cheap ``device_put``; a no-op when the sharding already matches),
        never re-derived and never somebody else's sharding.
        """
        fp = _weight_fingerprint(params, cfg)
        entry = self._store.get(key)
        if entry is not None and entry[0] == fp:
            self.hits += 1
            layer = entry[1]
        else:
            self.packs += 1
            layer = _pack_node(params, cfg)
            self._store[key] = (fp, layer)
        return placer(layer) if placer is not None else layer

    def __len__(self) -> int:
        return len(self._store)

    def stats(self) -> Dict[str, int]:
        return {"layers": len(self._store), "packs": self.packs,
                "hits": self.hits}


def pack_tree_psq(
    node: Any,
    cfg: QuantConfig,
    cache: Optional[PackedModelCache] = None,
    _path: str = "",
    *,
    mesh=None,
    rules=None,
):
    """Replace every quantized linear's params with a :class:`PackedLayer`.

    Embeddings, norms and non-linear leaves pass through untouched. Pass
    the same ``cache`` on subsequent loads (weight reload, engine restart
    on identical params) to reuse packed state instead of re-deriving it.

    ``mesh`` places every packed layer column-sharded over the mesh's
    ``model`` axis as it is packed (tensor-parallel serving; see
    ``docs/parallelism.md``) — the analogue of programming each device's
    crossbar columns once at load. Placement does not enter the cache
    fingerprint: re-packing identical weights for a different mesh is
    all hits, zero packs, and the cached state is merely re-placed.

    Requires a quantized config — packing an fp tree is a bug, not a
    no-op:

    >>> from repro.core.config import QuantConfig
    >>> pack_tree_psq({}, QuantConfig(mode="none"))
    Traceback (most recent call last):
        ...
    ValueError: pack_tree_psq needs a quantized QuantConfig (mode='none')
    """
    if not cfg.quantized:
        raise ValueError("pack_tree_psq needs a quantized QuantConfig "
                         f"(mode={cfg.mode!r})")
    if cache is None:
        cache = PackedModelCache()
    placer = None
    if mesh is not None:
        from repro.parallel.sharding import shard_packed_layer

        placer = lambda layer: shard_packed_layer(layer, mesh, rules)
    if _is_quantized_linear(node):
        return cache.get_or_pack(_path, node, cfg, placer=placer)
    if isinstance(node, dict):
        return {
            k: pack_tree_psq(v, cfg, cache, f"{_path}/{k}",
                             mesh=mesh, rules=rules)
            for k, v in node.items()
        }
    if isinstance(node, (list, tuple)):
        return type(node)(
            pack_tree_psq(v, cfg, cache, f"{_path}[{i}]",
                          mesh=mesh, rules=rules)
            for i, v in enumerate(node)
        )
    return node
