"""Serving: continuous-batching engine + weight-stationary PSQ cache
+ paged KV cache with shared-prefix reuse.

The stack is layered (docs/architecture.md, docs/scheduling.md):
``serve/scheduler.py`` owns admission decisions (policies, energy
pricing, the validated ``EngineConfig``), ``serve/state.py`` owns slot
placement across the contiguous / paged / recurrent pools, and
``serve/executor.py`` owns the compiled step functions behind one
``run_round()`` interface; ``serve/engine.py`` is the facade wiring
them together.

See docs/serving.md for the engine lifecycle (submit -> bucketed prefill
-> slot admission -> per-step retirement) and the backend matrix, and
docs/memory.md for the paged KV layout (block pool, radix prefix index,
copy-on-write/refcount rules).
"""
from repro.serve.cache import (  # noqa: F401
    PackedLayer,
    PackedModelCache,
    pack_tree_psq,
)
from repro.serve.engine import (  # noqa: F401
    ServeEngine,
    throughput_stats,
)
from repro.serve.paged_kv import (  # noqa: F401
    BlockPool,
    PagedKVManager,
    PoolExhausted,
    RadixPrefixIndex,
)
from repro.serve.scheduler import (  # noqa: F401
    ADMISSION_POLICIES,
    AdmissionPolicy,
    CostAwareEnergyBudget,
    EnergyModel,
    EngineConfig,
    Pow2BucketFCFS,
    Request,
    resolve_admission_policy,
)
from repro.serve.state import (  # noqa: F401
    ContiguousSlotState,
    PagedSlotState,
    SlotState,
)
