"""Serving: continuous-batching engine + weight-stationary PSQ cache
+ paged KV cache with shared-prefix reuse.

See docs/serving.md for the engine lifecycle (submit -> bucketed prefill
-> slot admission -> per-step retirement) and the backend matrix, and
docs/memory.md for the paged KV layout (block pool, radix prefix index,
copy-on-write/refcount rules).
"""
from repro.serve.cache import (  # noqa: F401
    PackedLayer,
    PackedModelCache,
    pack_tree_psq,
)
from repro.serve.engine import (  # noqa: F401
    EngineConfig,
    Request,
    ServeEngine,
    throughput_stats,
)
from repro.serve.paged_kv import (  # noqa: F401
    BlockPool,
    PagedKVManager,
    PoolExhausted,
    RadixPrefixIndex,
)
