"""Serving: continuous-batching engine + weight-stationary PSQ cache.

See docs/serving.md for the engine lifecycle (submit -> bucketed prefill
-> slot admission -> per-step retirement) and the backend matrix.
"""
from repro.serve.cache import (  # noqa: F401
    PackedLayer,
    PackedModelCache,
    pack_tree_psq,
)
from repro.serve.engine import (  # noqa: F401
    EngineConfig,
    Request,
    ServeEngine,
    throughput_stats,
)
