"""Batched serving engine (prefill/decode, KV caches, PSQ int4 path)."""
from repro.serve.engine import EngineConfig, Request, ServeEngine, throughput_stats
