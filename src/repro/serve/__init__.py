"""Batched serving engine (prefill/decode, KV caches, PSQ int4 path)."""
from repro.serve.cache import (  # noqa: F401
    PackedLayer,
    PackedModelCache,
    pack_tree_psq,
)
from repro.serve.engine import (  # noqa: F401
    EngineConfig,
    Request,
    ServeEngine,
    throughput_stats,
)
