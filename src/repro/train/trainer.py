"""Training loop: jitted train_step, metrics, fault-tolerant driver.

``make_train_step`` builds the pure step function that launch/dryrun.py
lowers on the production mesh; ``Trainer`` wires data, checkpointing,
failure recovery and straggler monitoring around it for real runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.train import checkpoint as ckpt_mod
from repro.train.fault import (
    FailureInjector,
    StragglerDetector,
    compressed_gradient,
    run_with_restarts,
)
from repro.train.optimizer import (
    OptConfig,
    OptState,
    adamw_update,
    init_opt_state,
)

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt: OptState

    def tree(self) -> Dict:
        return {"params": self.params, "opt": self.opt._asdict()}

    @classmethod
    def from_tree(cls, t: Dict) -> "TrainState":
        return cls(params=t["params"], opt=OptState(**t["opt"]))


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: OptConfig,
    compress_grads: bool = False,
) -> Callable:
    """Pure (state, batch[, err_buf]) -> (state, metrics[, err_buf])."""

    def step(state: TrainState, batch: Dict, err_buf: Optional[PyTree] = None):
        (loss, stats), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch), has_aux=True
        )(state.params)
        if compress_grads:
            grads, err_buf = compressed_gradient(grads, err_buf)
        params, opt, om = adamw_update(opt_cfg, state.params, grads, state.opt)
        metrics = {"loss": loss, **om}
        if "p_zero_frac" in stats:
            metrics["p_zero_frac"] = stats["p_zero_frac"]
        if "moe_aux_loss" in stats:
            metrics["moe_aux_loss"] = stats["moe_aux_loss"]
        new_state = TrainState(params=params, opt=opt)
        if compress_grads:
            return new_state, metrics, err_buf
        return new_state, metrics

    return step


def make_eval_step(cfg: ArchConfig) -> Callable:
    def step(params: PyTree, batch: Dict) -> Dict:
        loss, stats = T.loss_fn(params, cfg, batch)
        return {"loss": loss}

    return step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 2
    compress_grads: bool = False


class Trainer:
    """Fault-tolerant driver around the pure step function."""

    def __init__(
        self,
        cfg: ArchConfig,
        opt_cfg: OptConfig,
        tcfg: TrainerConfig,
        data_fn: Callable[[int], Dict],
        init_key: Optional[jax.Array] = None,
        injector: Optional[FailureInjector] = None,
        log_fn: Callable[[str], None] = print,
    ):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.data_fn = data_fn
        self.injector = injector
        self.log_fn = log_fn
        self.straggler = StragglerDetector()
        self.checkpointer = ckpt_mod.AsyncCheckpointer(
            tcfg.ckpt_dir, keep_last=tcfg.keep_last
        )
        key = init_key if init_key is not None else jax.random.PRNGKey(0)
        params = T.init_model(key, cfg)
        self._init_state = TrainState(params=params, opt=init_opt_state(params))
        self._step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, compress_grads=tcfg.compress_grads)
        )
        self.metrics_history: list = []

    # -- resume support -------------------------------------------------
    def _resume_step(self) -> int:
        latest = ckpt_mod.latest_step(self.tcfg.ckpt_dir)
        return 0 if latest is None else latest

    def _load_state(self, step: int) -> TrainState:
        if step == 0 and ckpt_mod.latest_step(self.tcfg.ckpt_dir) is None:
            return self._init_state
        tree, _, _ = ckpt_mod.restore(
            self.tcfg.ckpt_dir, self._init_state.tree(), step=step
        )
        return TrainState.from_tree(tree)

    # -- main loop -------------------------------------------------------
    def _loop(self, start_step: int) -> int:
        state = self._load_state(start_step)
        err_buf = None
        for step in range(start_step, self.tcfg.total_steps):
            if self.injector is not None:
                self.injector.check(step)
            t0 = time.time()
            batch = {
                k: jnp.asarray(v) for k, v in self.data_fn(step).items()
            }
            if self.tcfg.compress_grads:
                state, metrics, err_buf = self._step_fn(state, batch, err_buf)
            else:
                state, metrics = self._step_fn(state, batch)
            dt = time.time() - t0
            self.straggler.observe({0: dt})
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                self.metrics_history.append({"step": step, **m, "dt": dt})
                self.log_fn(
                    f"step {step:5d} loss {m['loss']:.4f} "
                    f"gnorm {m['grad_norm']:.2f} lr {m['lr']:.2e} ({dt:.2f}s)"
                )
            if (step + 1) % self.tcfg.ckpt_every == 0 or step == self.tcfg.total_steps - 1:
                self.checkpointer.save(step + 1, state.tree())
        self.checkpointer.wait()
        self._final_state = state
        return self.tcfg.total_steps

    def train(self) -> TrainState:
        run_with_restarts(self._loop, self._resume_step)
        return getattr(self, "_final_state", None) or self._load_state(
            self._resume_step()
        )
