"""Fault-tolerant checkpointing: atomic, sharded, auto-resumable.

Layout (one directory per step)::

    <dir>/step_000120/
        manifest.json        # tree structure, shapes, dtypes, shard map
        shard_00000.npz      # flattened leaves, chunked ~512 MB
        _COMMITTED           # written last: crash-safe marker

Writes go to ``step_X.tmp`` and are renamed into place only after the
commit marker is written — a process killed mid-write can never leave a
checkpoint that ``latest_step`` would pick up. ``restore`` reassembles
on any mesh/host topology (elastic re-shard happens at load: leaves are
stored unsharded-logical, device placement is the caller's concern).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SHARD_BYTES = 512 * 1024 * 1024


def _flatten_with_names(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        out.append((name, np.asarray(leaf)))
    return out


def save(
    ckpt_dir: str, step: int, tree: PyTree, keep_last: int = 3,
    extra: Optional[Dict] = None,
) -> str:
    """Atomically persist ``tree`` for ``step``. Returns the final path."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_names(tree)
    manifest: Dict[str, Any] = {
        "step": step,
        "time": time.time(),
        "extra": extra or {},
        "leaves": [],
        "shards": [],
    }
    shard_idx, shard_bytes, shard_payload = 0, 0, {}
    for i, (name, arr) in enumerate(leaves):
        key = f"leaf_{i:05d}"
        manifest["leaves"].append(
            {"name": name, "key": key, "shard": shard_idx,
             "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
        shard_payload[key] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _SHARD_BYTES:
            fn = f"shard_{shard_idx:05d}.npz"
            np.savez(os.path.join(tmp, fn), **shard_payload)
            manifest["shards"].append(fn)
            shard_idx, shard_bytes, shard_payload = shard_idx + 1, 0, {}
    if shard_payload or not manifest["shards"]:
        fn = f"shard_{shard_idx:05d}.npz"
        np.savez(os.path.join(tmp, fn), **shard_payload)
        manifest["shards"].append(fn)

    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write(str(step))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in sorted(os.listdir(ckpt_dir)):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "_COMMITTED")):
                out.append(int(d[5:]))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(
    ckpt_dir: str, tree_like: PyTree, step: Optional[int] = None
) -> Tuple[PyTree, int, Dict]:
    """Load into the structure of ``tree_like``; returns (tree, step, extra)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shards = {
        i: np.load(os.path.join(path, fn))
        for i, fn in enumerate(manifest["shards"])
    }
    by_name = {
        rec["name"]: shards[rec["shard"]][rec["key"]]
        for rec in manifest["leaves"]
    }
    names = [n for n, _ in _flatten_with_names(tree_like)]
    missing = [n for n in names if n not in by_name]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]} ...")
    flat = [by_name[n] for n in names]
    treedef = jax.tree_util.tree_structure(tree_like)
    return (
        jax.tree_util.tree_unflatten(treedef, flat),
        manifest["step"],
        manifest.get("extra", {}),
    )


class AsyncCheckpointer:
    """Overlaps checkpoint I/O with training (compute/IO overlap).

    ``save`` snapshots to host memory synchronously (cheap) and writes to
    disk on a background thread; ``wait`` joins before the next save or
    at shutdown so at most one write is in flight.
    """

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot now

        def _run():
            try:
                save(self.ckpt_dir, step, host_tree, self.keep_last, extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
