"""Training substrate: optimizer, trainer, checkpointing, fault tolerance."""
from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore, save
from repro.train.fault import (
    FailureInjector, PreemptionError, RestartPolicy, StragglerDetector,
    compressed_gradient, elastic_rescale_batch, remesh_plan, run_with_restarts,
)
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, lr_at
from repro.train.trainer import (
    Trainer, TrainerConfig, TrainState, make_eval_step, make_train_step,
)
