"""Fault tolerance for 1000+-node runs: failure handling, stragglers,
elastic re-meshing, and compressed cross-pod gradient reduction.

This container has one CPU device, so the *policies* are implemented and
unit-tested against injected signals (step times, failure events), and
the *mechanisms* (checkpoint/restart, re-mesh, compressed all-reduce)
run for real at small scale. On a TPU fleet the same code paths hang off
the coordinator's health callbacks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ---------------------------------------------------------------------------
# Failure simulation + restart policy
# ---------------------------------------------------------------------------

class PreemptionError(RuntimeError):
    """Raised by the failure injector to emulate a node loss / preemption."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples."""

    fail_at_steps: Tuple[int, ...] = ()
    raised: List[int] = dataclasses.field(default_factory=list)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self.raised:
            self.raised.append(step)
            raise PreemptionError(f"injected node failure at step {step}")


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 10
    backoff_s: float = 0.0
    restarts: int = 0

    def should_restart(self, exc: BaseException) -> bool:
        if not isinstance(exc, PreemptionError):
            return False
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return False
        if self.backoff_s:
            time.sleep(self.backoff_s)
        return True


def run_with_restarts(
    train_loop: Callable[[int], int],
    resume_step: Callable[[], int],
    policy: Optional[RestartPolicy] = None,
) -> int:
    """Drive ``train_loop(start_step)`` to completion across failures.

    ``train_loop`` returns the final step when it completes; on
    PreemptionError we restart from the latest committed checkpoint —
    exactly the crash-loop a cluster scheduler gives you.
    """
    policy = policy or RestartPolicy()
    while True:
        start = resume_step()
        try:
            return train_loop(start)
        except PreemptionError as e:
            if not policy.should_restart(e):
                raise


# ---------------------------------------------------------------------------
# Straggler detection
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerDetector:
    """EMA + z-score step-time monitor with re-dispatch decisions.

    At fleet scale each host reports step wall-time; hosts whose times
    are persistent outliers get flagged for replacement (PUMA-style
    backup workers / TPU slice re-scheduling). Detection logic is pure,
    so it is unit-testable with injected timings.
    """

    ema_decay: float = 0.9
    z_threshold: float = 3.0
    patience: int = 3
    _ema: Optional[float] = None
    _var: float = 0.0
    strikes: Dict[int, int] = dataclasses.field(default_factory=dict)

    def observe(self, host_times: Dict[int, float]) -> List[int]:
        """Feed one step's per-host times; returns hosts to replace."""
        tmed = float(np.median(list(host_times.values())))
        if self._ema is None:
            self._ema, self._var = tmed, (0.1 * tmed) ** 2
        self._ema = self.ema_decay * self._ema + (1 - self.ema_decay) * tmed
        self._var = self.ema_decay * self._var + (1 - self.ema_decay) * (
            tmed - self._ema
        ) ** 2
        sigma = max(self._var ** 0.5, 1e-6 * self._ema)
        to_replace = []
        for host, t in host_times.items():
            z = (t - self._ema) / sigma
            if z > self.z_threshold:
                self.strikes[host] = self.strikes.get(host, 0) + 1
            else:
                self.strikes[host] = 0
            if self.strikes[host] >= self.patience:
                to_replace.append(host)
                self.strikes[host] = 0
        return to_replace


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------

def remesh_plan(n_devices: int, model_parallel: int) -> Tuple[int, int]:
    """Largest (data, model) grid for the surviving device count.

    Model parallelism is kept fixed (weights must still fit); the data
    axis shrinks to what remains — e.g. losing one host of a (16, 16)
    mesh re-forms as (15, 16). Returns (data, model).
    """
    if n_devices < model_parallel:
        raise ValueError("fewer devices than the model-parallel degree")
    return n_devices // model_parallel, model_parallel


def elastic_rescale_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-replica batch constant across a re-mesh (linear scaling
    rule handles the LR elsewhere); returns the new global batch."""
    per_replica = global_batch // old_data
    return per_replica * new_data


# ---------------------------------------------------------------------------
# Gradient compression (int8 + error feedback) for cross-pod reduction
# ---------------------------------------------------------------------------

def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_gradient(
    grads: PyTree, error_buf: Optional[PyTree]
) -> Tuple[PyTree, PyTree]:
    """int8-quantize gradients with error feedback.

    Returns (dequantized grads to feed the optimizer / all-reduce,
    new error buffer). At fleet scale the int8 payload crosses the DCN
    (4x fewer bytes on the slowest link); error feedback keeps SGD
    convergence (Karimireddy et al. 2019).
    """
    if error_buf is None:
        error_buf = jax.tree.map(jnp.zeros_like, grads)

    def one(g, e):
        corrected = g + e
        q, s = compress_int8(corrected)
        deq = decompress_int8(q, s)
        return deq, corrected - deq

    flat = jax.tree.map(one, grads, error_buf)
    deq = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return deq, err
