"""Optimizers and LR schedules, pure JAX (no optax dependency).

AdamW with decoupled weight decay, global-norm gradient clipping, and a
quantization-aware parameter grouping: PSQ quantizer state (LSQ steps,
scale factors, thresholds) gets no weight decay and an optional LR
multiplier — standard LSQ practice, and what keeps scale-factor QAT
stable (paper §4.1).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

_QUANT_PARAM_KEYS = ("step_x", "step_w", "sf", "sf_step", "alpha")
_NO_DECAY_KEYS = _QUANT_PARAM_KEYS + ("scale", "bias", "b", "A_log", "D", "dt_bias")


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quant_lr_mult: float = 0.1        # LSQ state learns slower
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # cosine | linear | constant


class OptState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def _path_key(path) -> str:
    last = path[-1]
    return getattr(last, "key", getattr(last, "name", str(last)))


def _is_quant_param(path) -> bool:
    return _path_key(path) in _QUANT_PARAM_KEYS


def _no_decay(path) -> bool:
    return _path_key(path) in _NO_DECAY_KEYS


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "cosine":
        t = jnp.clip(
            (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = jnp.clip(
            1.0 - (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def init_opt_state(params: PyTree) -> OptState:
    zeros = jax.tree.map(jnp.zeros_like, params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.zeros_like, params))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw_update(
    cfg: OptConfig, params: PyTree, grads: PyTree, state: OptState
) -> Tuple[PyTree, OptState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.betas
    step = state.step + 1
    lr = lr_at(cfg, step)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    def upd(path, p, m, v):
        lr_p = lr * (cfg.quant_lr_mult if _is_quant_param(path) else 1.0)
        step_ = lr_p * (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if not _no_decay(path):
            step_ = step_ + lr_p * cfg.weight_decay * p
        return p - step_

    new_params = jax.tree_util.tree_map_with_path(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step=step, mu=mu, nu=nu), metrics
