"""Logical-axis sharding: one rules table instead of per-arch pjit specs.

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``); the launcher activates a
rules table mapping logical names to mesh axes. With no rules active
(unit tests, single CPU) every annotation is a no-op, so the same model
code runs everywhere.

Divisibility-aware: a rule only applies if the dimension divides by the
mesh-axis size — otherwise the dimension is left unsharded rather than
relying on implicit padding (keeps the compiled collectives clean; the
few non-divisible cases — e.g. 24 heads on a 16-way model axis — fall
back to the feature-dim sharding of the surrounding projections).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

# logical axis -> mesh axis (or tuple of axes) tables
RULES_2D: Dict[str, MeshAxes] = {
    "batch": "data",
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv_features": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ffn": "model",
    "ssm_inner": "model",
    "kv_seq": None,        # decode KV cache sequence dim
    "long_kv_seq": "data",  # 500k-context decode: cache sharded over data
    "sf_out": "model",     # PSQ scale-factor column dim (follows weight out)
    "ktiles": None,
}

RULES_3D: Dict[str, MeshAxes] = dict(RULES_2D, batch=("pod", "data"))


class _State(threading.local):
    def __init__(self):
        self.rules: Optional[Dict[str, MeshAxes]] = None
        self.mesh: Optional[Mesh] = None


_STATE = _State()


@contextlib.contextmanager
def axis_rules(rules: Dict[str, MeshAxes], mesh: Optional[Mesh] = None):
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def active_rules() -> Optional[Dict[str, MeshAxes]]:
    return _STATE.rules


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_to_pspec(
    logical: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    rules: Optional[Dict[str, MeshAxes]] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    rules = rules if rules is not None else _STATE.rules
    mesh = mesh if mesh is not None else _STATE.mesh
    if rules is None:
        return P()
    spec = []
    used = set()
    for i, name in enumerate(logical):
        ax = rules.get(name) if name is not None else None
        if ax is not None and mesh is not None and shape is not None:
            if shape[i] % _axis_size(mesh, ax) != 0:
                ax = None  # divisibility guard
        if ax is not None:
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            if any(a in used for a in axes):
                ax = None  # each mesh axis shards at most one dim
            else:
                used.update(axes)
        spec.append(ax)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint (no-op without active rules)."""
    if _STATE.rules is None:
        return x
    spec = logical_to_pspec(logical, shape=x.shape)
    if _STATE.mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(_STATE.mesh, spec)
        )
    return jax.lax.with_sharding_constraint(x, spec)
