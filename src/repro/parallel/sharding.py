"""Logical-axis sharding: one rules table instead of per-arch pjit specs.

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``); the launcher activates a
rules table mapping logical names to mesh axes. With no rules active
(unit tests, single CPU) every annotation is a no-op, so the same model
code runs everywhere:

    >>> import jax, jax.numpy as jnp
    >>> constrain(jnp.ones((4, 8)), "batch", "ffn").shape  # no rules: no-op
    (4, 8)
    >>> mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 2)))
    >>> with axis_rules(RULES_2D, mesh):
    ...     logical_to_pspec(["batch", "seq", "ffn"], shape=(4, 8, 16))
    PartitionSpec('data', None, 'model')

Divisibility-aware: a rule only applies if the dimension divides by the
mesh-axis size — otherwise the dimension is left unsharded rather than
relying on implicit padding (keeps the compiled collectives clean; the
few non-divisible cases — e.g. 24 heads on a 16-way model axis — fall
back to the feature-dim sharding of the surrounding projections):

    >>> with axis_rules(RULES_2D, mesh):
    ...     logical_to_pspec(["batch", "ffn"], shape=(4, 7))  # 7 % 2 != 0
    PartitionSpec('data',)

Tensor-parallel PSQ serving rides the same table: the ``sf_out`` rule
maps every output-column-sized dimension of a packed layer (weight
codes, int4 planes, DCiM scale factors, bias) to the ``model`` mesh axis
— the JAX analogue of assigning crossbar columns plus their digital-CiM
scale-factor slices to different dies. :func:`packed_layer_pspecs`
derives the per-leaf specs and :func:`tp_axes` tells the serving matmul
(``core.psq_linear``) whether the active rules call for a sharded
(shard_map + psum) execution.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

# logical axis -> mesh axis (or tuple of axes) tables
RULES_2D: Dict[str, MeshAxes] = {
    "batch": "data",
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv_features": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ffn": "model",
    "ssm_inner": "model",
    "kv_seq": None,        # decode KV cache sequence dim
    "long_kv_seq": "data",  # 500k-context decode: cache sharded over data
    "kv_blocks": "data",   # paged KV page pool: pages spread over data
    # per-slot recurrent state pools (SSM/xLSTM/hybrid: ssm states, mLSTM
    # C/n/m, sLSTM scalars, conv buffers) — slot axis shards like KV slots
    "recurrent_state": "data",
    "sf_out": "model",     # PSQ scale-factor column dim (follows weight out)
    "ktiles": None,
}

RULES_3D: Dict[str, MeshAxes] = dict(RULES_2D, batch=("pod", "data"))

# Expert-parallel serving: a dedicated ``expert`` mesh axis owns the
# expert dim of MoE FFN stacks (router stays replicated; activations
# inside an expert shard still follow the 2-D table). Activated by the
# engine / launcher for meshes that carry an ``expert`` axis
# (``--mesh DATA,MODEL,EXPERT``); :func:`expert_axes` is the query the
# MoE layer uses to pick the shard_map execution.
RULES_EXPERT: Dict[str, MeshAxes] = dict(RULES_2D, experts="expert")

# names of the raw expert-stacked weight leaves of a MoE block
# (repro.models.moe.init_moe) — the leaves expert placement targets
_EXPERT_WEIGHT_KEYS = ("w_gate", "w_up", "w_down")


class _State(threading.local):
    def __init__(self):
        self.rules: Optional[Dict[str, MeshAxes]] = None
        self.mesh: Optional[Mesh] = None


_STATE = _State()


@contextlib.contextmanager
def axis_rules(rules: Dict[str, MeshAxes], mesh: Optional[Mesh] = None):
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules, _STATE.mesh = rules, mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def active_rules() -> Optional[Dict[str, MeshAxes]]:
    return _STATE.rules


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_to_pspec(
    logical: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    rules: Optional[Dict[str, MeshAxes]] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Map logical axis names to a PartitionSpec under the active rules."""
    rules = rules if rules is not None else _STATE.rules
    mesh = mesh if mesh is not None else _STATE.mesh
    if rules is None:
        return P()
    spec = []
    used = set()
    for i, name in enumerate(logical):
        ax = rules.get(name) if name is not None else None
        if ax is not None and mesh is not None and shape is not None:
            if shape[i] % _axis_size(mesh, ax) != 0:
                ax = None  # divisibility guard
        if ax is not None:
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            if any(a in used for a in axes):
                ax = None  # each mesh axis shards at most one dim
            else:
                used.update(axes)
        spec.append(ax)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint (no-op without active rules)."""
    if _STATE.rules is None:
        return x
    spec = logical_to_pspec(logical, shape=x.shape)
    if _STATE.mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(_STATE.mesh, spec)
        )
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Packed-layer specs (tensor-parallel PSQ serving)
# ---------------------------------------------------------------------------
#
# A PackedLayer (repro.serve.cache) is the weight-stationary state of one
# crossbar-programmed linear: weight codes (K, O), optional int4 planes
# (K/2, O), DCiM scale factors (T, n_a, n_w, O or 1), plus scalars. Its
# natural tensor-parallel split is COLUMN-wise — each device owns a
# contiguous slice of output columns and the matching scale-factor
# columns, exactly as HCiM assigns crossbar columns + their digital CiM
# slices to arrays. Every column-sized dim maps to the logical ``sf_out``
# axis; everything else is replicated. Scan-stacked layers (leading
# layer axis) get a leading ``None``.

def packed_layer_pspecs(layer: Any, rules: Optional[Dict[str, MeshAxes]] = None,
                        mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree for one packed layer under the (active) rules.

    Logical axes are assigned per field for the UNSTACKED rank —
    scan-stacked leaves (leading layer axis, ``w_codes.ndim == 3``) get a
    leading ``None``; ``s_w`` is () for the per-layer LSQ step and
    ("sf_out",) for the per-channel variant, disambiguated through the
    stacking of ``w_codes`` (base rank 2).

    The divisibility guard of :func:`logical_to_pspec` applies per leaf:
    an output dim that does not divide the ``model`` axis — or the size-1
    trailing dim of a reduced-granularity ``sf_q`` — stays replicated.
    The result has the same pytree structure as ``layer`` (spec leaves),
    so it can feed ``shard_map`` in_specs or ``NamedSharding`` placement
    directly.
    """
    rules = rules if rules is not None else (_STATE.rules or RULES_2D)
    mesh = mesh if mesh is not None else _STATE.mesh
    stacked = layer.w_codes.ndim == 3
    col = "sf_out"

    def spec(arr, logical):
        if arr is None:
            return None
        names = [None] * (arr.ndim - len(logical)) + list(logical)
        return logical_to_pspec(names, shape=arr.shape, rules=rules, mesh=mesh)

    s_w_logical = (col,) if layer.s_w.ndim - int(stacked) == 1 else ()
    return type(layer)(
        cfg=layer.cfg,
        w_codes=spec(layer.w_codes, (None, col)),
        s_w=spec(layer.s_w, s_w_logical),
        sf_q=spec(layer.sf_q, (None, None, None, col)),
        alpha=spec(layer.alpha, ()),
        step_x=spec(layer.step_x, ()),
        sigma=spec(layer.sigma, (None,)),
        kappa=spec(layer.kappa, (None,)),
        w_packed=spec(layer.w_packed, (None, col)),
        bias=spec(layer.bias, (col,)),
        # occupancy is pytree AUX data, not a leaf: the spec tree must
        # carry the identical value or tree.map(layer, specs) rejects the
        # structure mismatch. (Each TP shard still runs dense — the global
        # metadata fails the per-shard shape guard by design.)
        occupancy=getattr(layer, "occupancy", None),
    )


def shard_packed_layer(layer: Any, mesh: Mesh,
                       rules: Optional[Dict[str, MeshAxes]] = None) -> Any:
    """Place one packed layer's leaves on ``mesh`` column-sharded.

    A plain ``device_put`` per leaf with the :func:`packed_layer_pspecs`
    sharding — the one-time serving-cache placement step (re-placing an
    already-placed layer is a no-op transfer).
    """
    rules = rules if rules is not None else RULES_2D
    specs = packed_layer_pspecs(layer, rules=rules, mesh=mesh)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, NamedSharding(mesh, s)), layer, specs
    )


def shard_packed_tree(tree: Any, mesh: Mesh,
                      rules: Optional[Dict[str, MeshAxes]] = None) -> Any:
    """Recursively place every packed layer in a served param tree.

    Non-packed nodes (embeddings, norms, plain param dicts) pass through
    untouched — they stay replicated under the jitted serving step.
    """
    if hasattr(tree, "apply_serving"):
        return shard_packed_layer(tree, mesh, rules)
    if isinstance(tree, dict):
        return {k: shard_packed_tree(v, mesh, rules) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(shard_packed_tree(v, mesh, rules) for v in tree)
    return tree


def expert_axes() -> Optional[Tuple[Mesh, str]]:
    """The (mesh, axis name) expert parallelism is active on, else None.

    The expert-parallel analogue of :func:`tp_axes`: active when a rules
    table is installed with a REAL mesh, the table maps the logical
    ``experts`` axis to a single mesh axis (``RULES_EXPERT``), and that
    axis has size > 1. ``repro.models.moe.apply_moe`` consults this to
    decide between the single-device dispatch and the shard_map
    expert-parallel execution.
    """
    rules, mesh = _STATE.rules, _STATE.mesh
    if rules is None or not isinstance(mesh, Mesh):
        return None
    ax = rules.get("experts")
    if not isinstance(ax, str) or mesh.shape.get(ax, 1) <= 1:
        return None
    return mesh, ax


def rules_for_mesh(mesh: Optional[Mesh]) -> Dict[str, MeshAxes]:
    """Pick the default rules table for a mesh by its axis names.

    A mesh carrying an ``expert`` axis gets :data:`RULES_EXPERT`
    (expert-parallel MoE next to the usual data x model rules); a
    ``pod`` axis gets :data:`RULES_3D`; anything else — including no
    mesh — the 2-D table.
    """
    names = set(getattr(mesh, "axis_names", ()) or ())
    if "expert" in names:
        return RULES_EXPERT
    if "pod" in names:
        return RULES_3D
    return RULES_2D


def shard_expert_params(tree: Any, mesh: Mesh,
                        rules: Optional[Dict[str, MeshAxes]] = None) -> Any:
    """Place raw expert-stacked MoE weights over the ``experts`` axis.

    Walks a (served) param tree and ``device_put``s every
    ``w_gate``/``w_up``/``w_down`` leaf with its expert dim — position
    ``ndim - 3``, which holds for both per-layer ``(E, d, ff)`` and
    scan-stacked ``(L, E, d, ff)`` stacks — on the rules' ``experts``
    mesh axis. Router weights, PSQ quantizer states and every non-MoE
    node pass through replicated (untouched). Leaves whose expert count
    does not divide the axis stay replicated too (the divisibility
    story of the rules table).
    """
    rules = rules if rules is not None else RULES_EXPERT
    ax = rules.get("experts")
    if not isinstance(ax, str) or mesh.shape.get(ax, 1) <= 1:
        return tree

    def place(node: Any) -> Any:
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if (k in _EXPERT_WEIGHT_KEYS and hasattr(v, "ndim")
                        and v.ndim >= 3
                        and v.shape[v.ndim - 3] % mesh.shape[ax] == 0):
                    spec = [None] * v.ndim
                    spec[v.ndim - 3] = ax
                    out[k] = jax.device_put(v, NamedSharding(mesh, P(*spec)))
                else:
                    out[k] = place(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(place(v) for v in node)
        return node

    return place(tree)


def tp_axes() -> Optional[Tuple[Mesh, str]]:
    """The (mesh, axis name) tensor parallelism is active on, else None.

    Active means: a rules table is installed with a REAL mesh (shard_map
    cannot run on an AbstractMesh), the table maps the PSQ column axis
    ``sf_out`` to a single mesh axis, and that axis has size > 1. The
    serving matmul consults this to decide between the single-device and
    the shard_map + psum execution of a packed layer.
    """
    rules, mesh = _STATE.rules, _STATE.mesh
    if rules is None or not isinstance(mesh, Mesh):
        return None
    ax = rules.get("sf_out")
    if not isinstance(ax, str) or mesh.shape.get(ax, 1) <= 1:
        return None
    return mesh, ax


def data_pspec(ndim: int, shape: Sequence[int],
               exclude: Tuple[str, ...] = ()) -> P:
    """Leading-axis batch spec for an activation under the active rules.

    The leading dim follows the ``batch`` rule (divisibility-guarded);
    all other dims stay replicated. ``exclude`` drops mesh axes that the
    caller already uses manually (e.g. the tensor-parallel axis inside a
    ``shard_map``).
    """
    rules, mesh = _STATE.rules, _STATE.mesh
    if rules is None:
        return P()
    ax = rules.get("batch")
    if isinstance(ax, str) and ax in exclude:
        ax = None
    if isinstance(ax, tuple):
        ax = tuple(a for a in ax if a not in exclude) or None
    guarded = dict(rules, batch=ax)
    return logical_to_pspec(
        ["batch"] + [None] * (ndim - 1), shape=shape, rules=guarded, mesh=mesh
    )
