"""Distribution: logical-axis sharding rules + collective helpers."""
from repro.parallel.sharding import (
    RULES_2D, RULES_3D, axis_rules, constrain, logical_to_pspec,
)
