"""HCiM core: PSQ quantization-aware training + crossbar execution model.

The paper's primary contribution (ADC-less partial-sum quantization with
learned, fixed-point scale factors processed by a digital CiM array) is
implemented here as a composable quantized-matmul that every layer of the
model zoo routes through.
"""
from repro.core.config import (
    DENSE,
    PSQ_BINARY,
    PSQ_TERNARY,
    QuantConfig,
    adc_baseline,
)
from repro.core.psq import (
    init_psq_params,
    num_tiles,
    psq_matmul,
    psq_matmul_dequant_reference,
)
from repro.core.psq_linear import apply_linear, init_linear
from repro.core.quant import CIFAR_SPEC, IMAGENET_SPEC, QuantSpec

__all__ = [
    "DENSE",
    "PSQ_BINARY",
    "PSQ_TERNARY",
    "QuantConfig",
    "QuantSpec",
    "CIFAR_SPEC",
    "IMAGENET_SPEC",
    "adc_baseline",
    "apply_linear",
    "init_linear",
    "init_psq_params",
    "num_tiles",
    "psq_matmul",
    "psq_matmul_dequant_reference",
]
