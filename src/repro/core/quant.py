"""Quantization primitives for PSQ-QAT (paper §4.1).

Implements LSQ (Esser et al. [14]) learned-step quantizers with
straight-through estimators, two's-complement bit slicing/streaming, and
the fixed-point scale-factor quantizer introduced by HCiM.

Conventions
-----------
* ``round_ste``      — round-to-nearest-even (LSQ standard) with STE.
* ``round_comparator`` — ties away from zero, matching comparator
  semantics of Eq. (1) (``p = 1`` iff ``a >= alpha``, ``p = -1`` iff
  ``a <= -alpha``).
* All integer-valued tensors are carried in float32: every quantity in
  the HCiM datapath is bounded by ``xbar_rows <= 128`` and therefore
  exactly representable (f32 is exact on integers < 2**24, bf16 up to
  256 — both safe for bit-plane partial sums).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

sg = jax.lax.stop_gradient


# ---------------------------------------------------------------------------
# Straight-through helpers
# ---------------------------------------------------------------------------

def grad_scale(x: jax.Array, scale) -> jax.Array:
    """Identity in the forward pass; multiplies the gradient by ``scale``.

    LSQ scales the step-size gradient by ``1/sqrt(numel * qp)`` to balance
    its magnitude against weight gradients (Esser et al., §3.1).
    """
    return x * scale + sg(x - x * scale)


def round_ste(x: jax.Array) -> jax.Array:
    """Round-to-nearest-even with a straight-through gradient."""
    return x + sg(jnp.round(x) - x)


def round_comparator(x: jax.Array) -> jax.Array:
    """Round half away from zero (comparator convention, no STE).

    Used for comparator thresholds so the boundary cases of Eq. (1)
    (``a == ±alpha``) land on ``p = ±1`` exactly as the hardware does.
    """
    return jnp.sign(x) * jnp.floor(jnp.abs(x) + 0.5)


def round_comparator_ste(x: jax.Array) -> jax.Array:
    return x + sg(round_comparator(x) - x)


def clip_ste_passthrough(x: jax.Array, lo, hi) -> jax.Array:
    """Clip with full gradient pass-through (BNN-style hard clipping)."""
    return x + sg(jnp.clip(x, lo, hi) - x)


# ---------------------------------------------------------------------------
# LSQ quantizer
# ---------------------------------------------------------------------------

def lsq_grad_factor(numel: int, qp: int) -> float:
    return 1.0 / float(jnp.sqrt(jnp.maximum(numel * qp, 1)).item()) if False else float(
        1.0 / (max(numel * qp, 1) ** 0.5)
    )


def lsq_quantize(
    x: jax.Array,
    step: jax.Array,
    qn: int,
    qp: int,
    g: Optional[float] = None,
) -> jax.Array:
    """Fake-quantize ``x`` with learned step ``step`` to integers [qn, qp].

    Returns the dequantized value ``round(clip(x/s, qn, qp)) * s`` with
    LSQ gradients for both ``x`` (clipped STE) and ``step``.
    ``step`` may be scalar or broadcastable (per-channel).
    """
    if g is None:
        g = lsq_grad_factor(x.size, max(qp, 1))
    s = grad_scale(jnp.maximum(step, 1e-9), g)
    v = x / s
    v = jnp.clip(v, qn, qp)  # clip gradient: zero outside range (LSQ)
    return round_ste(v) * s


def lsq_quantize_int(
    x: jax.Array,
    step: jax.Array,
    qn: int,
    qp: int,
    g: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Like :func:`lsq_quantize` but returns ``(int_codes, step)``.

    ``int_codes`` carries STE gradients w.r.t. ``x`` and (via the
    division) ``step``; its forward value is an exact integer in
    ``[qn, qp]`` stored as f32.
    """
    if g is None:
        g = lsq_grad_factor(x.size, max(qp, 1))
    s = grad_scale(jnp.maximum(step, 1e-9), g)
    v = jnp.clip(x / s, qn, qp)
    return round_ste(v), s


# ---------------------------------------------------------------------------
# Two's-complement bit slicing / streaming
# ---------------------------------------------------------------------------

def twos_complement_bits(x_int: jax.Array, n_bits: int) -> jax.Array:
    """Decompose signed integers into two's-complement bit planes.

    Parameters
    ----------
    x_int : integer-valued f32 array, values in ``[-2**(n-1), 2**(n-1)-1]``.
    n_bits : total bits ``n``.

    Returns
    -------
    bits : ``(n_bits,) + x.shape`` array of {0.,1.}, where
        ``sum_k weight(k) * bits[k] == x_int`` with
        ``weight(k) = 2**k`` for ``k < n-1`` and ``-2**(n-1)`` for the MSB.

    The forward value is exact; no gradient flows through (callers use the
    surrogate-STE assembly in :mod:`repro.core.psq` for gradients).
    """
    x_int = sg(x_int)
    u = jnp.mod(x_int, 2.0 ** n_bits)  # wrap negatives: two's complement
    planes = []
    for k in range(n_bits):
        planes.append(jnp.mod(jnp.floor(u / (2.0 ** k)), 2.0))
    return jnp.stack(planes, axis=0)


def bit_weights(n_bits: int, signed: bool = True) -> jnp.ndarray:
    """Significance of each two's-complement bit plane."""
    w = [2.0 ** k for k in range(n_bits)]
    if signed:
        w[-1] = -(2.0 ** (n_bits - 1))
    return jnp.asarray(w, dtype=jnp.float32)


def unsigned_bits(x_int: jax.Array, n_bits: int) -> jax.Array:
    """Bit planes of unsigned integers (e.g. unsigned activations)."""
    x_int = sg(x_int)
    planes = []
    for k in range(n_bits):
        planes.append(jnp.mod(jnp.floor(x_int / (2.0 ** k)), 2.0))
    return jnp.stack(planes, axis=0)


# ---------------------------------------------------------------------------
# HCiM scale-factor quantizer (paper §4.1)
# ---------------------------------------------------------------------------

def quantize_scale_factors(
    sf: jax.Array,
    layer_step: jax.Array,
    n_bits: int,
    g: Optional[float] = None,
) -> jax.Array:
    """Quantize the (non-negative) scale-factor tensor to fixed point.

    HCiM's contribution over [25]: scale factors become ``n_bits``-bit
    unsigned fixed-point numbers sharing a single per-layer step
    ``layer_step`` (itself learned, LSQ-style), so the DCiM array only
    ever adds/subtracts small integers; the per-layer step merges into
    the following normalization layer at deployment.
    """
    qp = 2 ** n_bits - 1
    if g is None:
        g = lsq_grad_factor(sf.size, qp)
    s = grad_scale(jnp.maximum(layer_step, 1e-9), g)
    v = jnp.clip(sf / s, 0.0, float(qp))
    return round_ste(v) * s


def quantize_scale_factors_int(
    sf: jax.Array, layer_step: jax.Array, n_bits: int, g: Optional[float] = None
) -> Tuple[jax.Array, jax.Array]:
    qp = 2 ** n_bits - 1
    if g is None:
        g = lsq_grad_factor(sf.size, qp)
    s = grad_scale(jnp.maximum(layer_step, 1e-9), g)
    v = jnp.clip(sf / s, 0.0, float(qp))
    return round_ste(v), s


# ---------------------------------------------------------------------------
# Comparator quantizers (Eq. 1)
# ---------------------------------------------------------------------------

def ternary_comparator(a: jax.Array, alpha: jax.Array) -> jax.Array:
    """Exact ternary comparator of Eq. (1): two latch comparators at ±alpha.

    Differentiable in ``alpha`` (LSQ quotient + round-STE); callers pass a
    stop-gradient ``a`` when the activation gradient is routed through the
    tile-level surrogate instead (see :mod:`repro.core.psq`).
    """
    alpha = jnp.maximum(alpha, 1e-6)
    v = a / (2.0 * alpha)
    v = clip_ste_passthrough(v, -1.0, 1.0)
    return round_comparator_ste(v)


def binary_comparator(a: jax.Array, window: jax.Array) -> jax.Array:
    """Binary comparator: ``p = +1`` iff ``a >= 0`` else ``-1``.

    ``window`` only shapes the (unused-by-default) STE pass-through; the
    forward value is the exact sign with sign(0) = +1 per Eq. (1).
    """
    window = jnp.maximum(window, 1e-6)
    v = clip_ste_passthrough(a / window, -1.0, 1.0)
    p = jnp.where(sg(a) >= 0.0, 1.0, -1.0)
    return v + sg(p - v)


def adc_quantize(ps: jax.Array, adc_bits: int, xbar_rows: int) -> jax.Array:
    """b-bit ADC on a unipolar partial sum ``ps ∈ [0, xbar_rows]``.

    Models the paper's baseline: uniform ``2**b`` codes across the full
    crossbar range, ties-away rounding (flash/SAR comparator ladders),
    values above the top code clip (the usual one-LSB convention by which
    a 128-row crossbar "ideally requires 7-bit ADCs").
    """
    # An ADC with 2**b codes over [0, R]; once the LSB reaches one unit of
    # partial sum the converter is effectively lossless (the paper's "a
    # 128-row crossbar ideally requires 7-bit ADCs" convention).
    step = max(1.0, xbar_rows / float(2 ** adc_bits))
    code = round_comparator_ste(ps / step)
    code = clip_ste_passthrough(code, 0.0, float(2 ** adc_bits - 1))
    return code * step


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Bit-widths of one PSQ deployment (paper §5.1).

    CIFAR recipe:    a4 / w4 / sf4  partial sums accumulated in 8b.
    ImageNet recipe: a3 / w3 / sf8  partial sums accumulated in 16b.
    """

    n_bits_a: int = 4
    n_bits_w: int = 4
    n_bits_sf: int = 4
    ps_accum_bits: int = 8

    @property
    def a_qn(self) -> int:
        return -(2 ** (self.n_bits_a - 1))

    @property
    def a_qp(self) -> int:
        return 2 ** (self.n_bits_a - 1) - 1

    @property
    def w_qn(self) -> int:
        return -(2 ** (self.n_bits_w - 1))

    @property
    def w_qp(self) -> int:
        return 2 ** (self.n_bits_w - 1) - 1


CIFAR_SPEC = QuantSpec(n_bits_a=4, n_bits_w=4, n_bits_sf=4, ps_accum_bits=8)
IMAGENET_SPEC = QuantSpec(n_bits_a=3, n_bits_w=3, n_bits_sf=8, ps_accum_bits=16)
