"""Quantized-execution configuration (the paper's technique as a config)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.quant import QuantSpec, CIFAR_SPEC


# How many scale factors a layer carries (Fig. 2(d) granularity study).
#   column     : one per (K-tile, input-bit-stream, weight-bit, out-column)
#                — the paper's operating point (Eq. 2: n_a * #columns per
#                crossbar).
#   per_stream : one per (K-tile, input-bit-stream)      (shared columns)
#   per_tile   : one per K-tile                          (shared streams)
#   per_layer  : a single scale factor                   (Fig 2d far left)
SF_GRANULARITIES = ("column", "per_stream", "per_tile", "per_layer")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Execution mode of every PSQLinear in the model.

    mode:
      "none" — plain dense matmul (fp baseline).
      "psq"  — HCiM path: bit-sliced crossbar MVM, binary/ternary
               comparator partial sums, learned fixed-point scale factors
               accumulated DCiM-style (paper §4).
      "adc"  — analog-CiM baseline: bit-sliced crossbar MVM with a b-bit
               ADC per column (paper §5 baselines, b ∈ {4, 6, 7}).
    """

    mode: str = "none"                      # none | psq | adc
    psq_levels: str = "ternary"             # ternary | binary (Eq. 1)
    spec: QuantSpec = CIFAR_SPEC            # bit widths (a/w/sf)
    xbar_rows: int = 128                    # crossbar size R (config A=128, B=64)
    adc_bits: int = 7                       # for mode == "adc"
    sf_granularity: str = "column"
    per_channel_w: bool = False             # paper quantizes per layer
    collect_stats: bool = False             # export ternary sparsity etc.
    use_kernel: bool = False                # kernel path vs jnp QAT reference
    # named implementation from repro.kernels.registry; None -> process
    # default ("pallas-interpret" unless overridden). Setting a backend
    # implies the kernel path (see ``kernel_path``).
    kernel_backend: Optional[str] = None
    fuse_planes: bool = False               # single-MXU-pass bit-plane fusion
    # skip all-zero ternary column blocks using pack-time occupancy
    # metadata (bit-exact; serving path only — QAT re-derives weights per
    # call and has no static metadata to skip with)
    sparsity_skip: bool = True

    def __post_init__(self):
        assert self.mode in ("none", "psq", "adc"), self.mode
        assert self.psq_levels in ("ternary", "binary"), self.psq_levels
        assert self.sf_granularity in SF_GRANULARITIES, self.sf_granularity
        assert self.xbar_rows in (32, 64, 128, 256), self.xbar_rows

    @property
    def quantized(self) -> bool:
        return self.mode != "none"

    @property
    def kernel_path(self) -> bool:
        """Route through the kernel registry rather than the jnp QAT ref."""
        return self.use_kernel or self.kernel_backend is not None

    def sf_shape(self, n_tiles: int, n_out: int) -> Tuple[int, int, int, int]:
        n_a, n_w = self.spec.n_bits_a, self.spec.n_bits_w
        if self.sf_granularity == "column":
            return (n_tiles, n_a, n_w, n_out)
        if self.sf_granularity == "per_stream":
            return (n_tiles, n_a, 1, 1)
        if self.sf_granularity == "per_tile":
            return (n_tiles, 1, 1, 1)
        return (1, 1, 1, 1)

    def num_scale_factors(self, k_in: int, n_out: int) -> int:
        import math

        t = math.ceil(k_in / self.xbar_rows)
        shape = self.sf_shape(t, n_out)
        n = 1
        for d in shape:
            n *= d
        return n


DENSE = QuantConfig(mode="none")
PSQ_TERNARY = QuantConfig(mode="psq", psq_levels="ternary")
PSQ_BINARY = QuantConfig(mode="psq", psq_levels="binary")


def adc_baseline(bits: int, xbar_rows: int = 128) -> QuantConfig:
    return QuantConfig(mode="adc", adc_bits=bits, xbar_rows=xbar_rows)
