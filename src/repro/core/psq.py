"""Partial-Sum Quantization crossbar matmul — the HCiM datapath (paper §4).

The analog crossbar / comparator / DCiM pipeline is modeled *bit-exactly*:

  1. activations and weights are LSQ-quantized to ``n_a`` / ``n_w`` bit
     integers (two's complement),
  2. the K (reduction) dimension is blocked into crossbar tiles of
     ``R = xbar_rows`` rows; each (input-bit-stream j, weight-bit-slice k,
     tile t) produces an analog column partial sum
     ``ps[j,k,t,o] = sum_{i in t} x_bit[j,i] * w_bit[k,i,o]  in [0, R]``,
  3. the column is read differentially (bipolar weight cells), giving the
     signed comparator input ``a = 2*ps - rowsum[j,t]  in [-R, R]``,
  4. a 1- or 1.5-bit comparator produces ``p in {-1,0,1}`` (Eq. 1),
  5. the DCiM array accumulates ``PS += p * s_q * sigma_j`` where ``s_q``
     is the learned, fixed-point-quantized scale factor and ``sigma_j``
     the stream significance (the 2^j shift of Fig. 2(a)),
  6. bit-slices and tiles are combined digitally by shift-add, and a
     single digital correction ``0.5 * c_w * sum_i x_int`` recovers the
     unipolar-to-bipolar offset (``c_w = sum_k kappa_k = -1`` for two's
     complement) — one scalar per input row, folded into the DCiM
     accumulation in hardware.

Gradients (QAT, §4.1): the forward value is the exact HCiM arithmetic;
gradients w.r.t. activations/weights flow through a tile-level surrogate
(the unquantized integer matmul — BNN-style full pass-through STE), while
scale factors, the ternary threshold ``alpha`` and the per-layer
scale-factor step get their LSQ gradients through an explicit path whose
forward value coincides with the exact one.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.config import QuantConfig

sg = jax.lax.stop_gradient


def num_tiles(k_in: int, xbar_rows: int) -> int:
    return math.ceil(k_in / xbar_rows)


def pad_to_tiles(x: jax.Array, axis: int, xbar_rows: int) -> jax.Array:
    k = x.shape[axis]
    t = num_tiles(k, xbar_rows)
    pad = t * xbar_rows - k
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Exact bit-plane partial sums
# ---------------------------------------------------------------------------

def tile_partial_sums(
    xb_j: jax.Array,  # (B, T*R) bits of one input stream
    wb_k: jax.Array,  # (T*R, O) bits of one weight slice
    xbar_rows: int,
) -> Tuple[jax.Array, jax.Array]:
    """Per-tile crossbar column outputs for one (stream, slice) pair.

    Returns ``ps`` of shape (B, T, O) — the unipolar analog column sums —
    and ``rowsum`` of shape (B, T) — the per-tile count of active input
    bits (the reference column used for differential sensing).

    Bit values are {0,1} and tiles have at most 128 rows, so float32 (and
    MXU bf16-with-f32-accum) arithmetic is exact.
    """
    b, kr = xb_j.shape
    t = kr // xbar_rows
    o = wb_k.shape[1]
    xt = xb_j.reshape(b, t, xbar_rows)
    wt = wb_k.reshape(t, xbar_rows, o)
    ps = jnp.einsum("btr,tro->bto", xt, wt, precision=jax.lax.Precision.HIGHEST)
    rowsum = jnp.sum(xt, axis=-1)  # (B, T)
    return ps, rowsum


# ---------------------------------------------------------------------------
# The full PSQ matmul
# ---------------------------------------------------------------------------

def psq_matmul(
    x: jax.Array,            # (..., K) activations
    w: jax.Array,            # (K, O) weight master copy
    params: Dict[str, jax.Array],
    cfg: QuantConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """HCiM quantized matmul. Returns (y, stats).

    ``params`` holds the learned quantization state:
      step_x : ()            LSQ activation step
      step_w : () or (O,)    LSQ weight step
      sf     : cfg.sf_shape  scale factors (non-negative)
      sf_step: ()            per-layer scale-factor fixed-point step (S_L)
      alpha  : ()            ternary threshold (binary: STE window)
    """
    spec = cfg.spec
    orig_shape = x.shape
    k_in = x.shape[-1]
    o = w.shape[-1]
    xf = x.reshape(-1, k_in)
    bsz = xf.shape[0]
    r = cfg.xbar_rows
    t = num_tiles(k_in, r)

    # --- LSQ integer codes (STE gradients attached) ---
    x_int, s_x = quant.lsq_quantize_int(xf, params["step_x"], spec.a_qn, spec.a_qp)
    g_w = quant.lsq_grad_factor(w.size, spec.w_qp)
    w_int, s_w = quant.lsq_quantize_int(w, params["step_w"], spec.w_qn, spec.w_qp, g=g_w)

    # --- surrogate: tile-level integer matmul, carries the x/w gradients ---
    y_sur = jnp.einsum(
        "bk,ko->bo", x_int, w_int, precision=jax.lax.Precision.HIGHEST
    )

    # --- exact bit-plane pipeline (values only) ---
    x_pad = pad_to_tiles(sg(x_int), 1, r)
    w_pad = pad_to_tiles(sg(w_int), 0, r)
    xbits = quant.twos_complement_bits(x_pad, spec.n_bits_a)   # (n_a, B, T*R)
    wbits = quant.twos_complement_bits(w_pad, spec.n_bits_w)   # (n_w, T*R, O)
    sigma = quant.bit_weights(spec.n_bits_a)                   # stream weights
    kappa = quant.bit_weights(spec.n_bits_w)                   # slice weights
    c_w = jnp.sum(kappa)                                       # = -1 (2's comp)

    sf_q = None
    if cfg.mode == "psq":
        sf_q_int, sl = quant.quantize_scale_factors_int(
            params["sf"], params["sf_step"], spec.n_bits_sf
        )
        sf_q = sf_q_int * sl  # dequantized fixed-point scale factors

    y_q = jnp.zeros((bsz, o), dtype=jnp.float32)
    zeros = jnp.array(0.0)
    total = jnp.array(0.0)
    ps_max = jnp.array(0.0)
    for j in range(spec.n_bits_a):
        ps_j, rowsum_j = tile_partial_sums(xbits[j], wbits[0], r)
        for k in range(spec.n_bits_w):
            if k > 0:
                ps_j, _ = tile_partial_sums(xbits[j], wbits[k], r)
            if cfg.mode == "adc":
                ps_q = quant.adc_quantize(sg(ps_j), cfg.adc_bits, r)
                y_q = y_q + kappa[k] * sigma[j] * jnp.sum(ps_q, axis=1)
            else:
                # differential (bipolar) comparator input, in [-R, R]
                a = 2.0 * ps_j - rowsum_j[:, :, None]          # (B, T, O)
                if cfg.psq_levels == "ternary":
                    p = quant.ternary_comparator(sg(a), params["alpha"])
                else:
                    # binary has no threshold in Eq. 1: freeze alpha so the
                    # (forward-irrelevant) STE window cannot drift it.
                    p = quant.binary_comparator(sg(a), sg(params["alpha"]))
                # DCiM accumulate: PS += sigma_j * p * s_q  (per column)
                sf_jk = jnp.broadcast_to(
                    sf_q[:, min(j, sf_q.shape[1] - 1), min(k, sf_q.shape[2] - 1)],
                    (t, o) if sf_q.shape[-1] == o else (t, 1),
                )
                contrib = p * sf_jk[None, :, :]
                y_q = y_q + 0.5 * kappa[k] * sigma[j] * jnp.sum(contrib, axis=1)
                if cfg.collect_stats:
                    zeros = zeros + jnp.sum(sg(p) == 0.0)
                    total = total + p.size
                    ps_max = jnp.maximum(ps_max, jnp.max(jnp.abs(sg(a))))

    if cfg.mode == "psq":
        # digital offset correction: 0.5 * c_w * sum_i x_int (per row)
        corr = 0.5 * c_w * jnp.sum(sg(x_int), axis=-1, keepdims=True)
        y_q = y_q + corr

    # exact forward + surrogate gradient assembly
    y_int = y_q + (y_sur - sg(y_sur))

    y = y_int * s_x * jnp.reshape(s_w, (1, -1) if jnp.ndim(s_w) else ())
    stats: Dict[str, jax.Array] = {}
    if cfg.collect_stats and cfg.mode == "psq":
        stats["p_zero_frac"] = zeros / jnp.maximum(total, 1.0)
        stats["comparator_in_max"] = ps_max
    return y.reshape(orig_shape[:-1] + (o,)), stats


def psq_matmul_dequant_reference(
    x: jax.Array, w: jax.Array, params: Dict[str, jax.Array], cfg: QuantConfig
) -> jax.Array:
    """Slow, fully materialized oracle used by unit tests.

    Computes the same function as :func:`psq_matmul` by materializing the
    full (n_a, n_w, B, T, O) partial-sum tensor — no loops, no surrogate
    tricks, values only (stop-gradient everywhere).
    """
    spec = cfg.spec
    k_in = x.shape[-1]
    o = w.shape[-1]
    xf = x.reshape(-1, k_in)
    r = cfg.xbar_rows
    t = num_tiles(k_in, r)

    x_int, s_x = quant.lsq_quantize_int(xf, params["step_x"], spec.a_qn, spec.a_qp)
    w_int, s_w = quant.lsq_quantize_int(
        w, params["step_w"], spec.w_qn, spec.w_qp,
        g=quant.lsq_grad_factor(w.size, spec.w_qp),
    )
    x_int, w_int, s_x, s_w = sg(x_int), sg(w_int), sg(s_x), sg(s_w)

    x_pad = pad_to_tiles(x_int, 1, r).reshape(-1, t, r)
    w_pad = pad_to_tiles(w_int, 0, r).reshape(t, r, o)
    xbits = quant.twos_complement_bits(x_pad, spec.n_bits_a)   # (n_a,B,T,R)
    wbits = quant.twos_complement_bits(w_pad, spec.n_bits_w)   # (n_w,T,R,O)
    ps = jnp.einsum("jbtr,ktro->jkbto", xbits, wbits)          # exact ints
    sigma = quant.bit_weights(spec.n_bits_a)
    kappa = quant.bit_weights(spec.n_bits_w)

    if cfg.mode == "adc":
        ps_q = quant.adc_quantize(ps, cfg.adc_bits, r)
        y_int = jnp.einsum("j,k,jkbto->bo", sigma, kappa, ps_q)
    else:
        rowsum = jnp.sum(xbits, axis=-1)                       # (n_a,B,T)
        a = 2.0 * ps - rowsum[:, None, :, :, None]
        if cfg.psq_levels == "ternary":
            alpha = jnp.maximum(params["alpha"], 1e-6)
            p = jnp.where(a >= alpha, 1.0, jnp.where(a <= -alpha, -1.0, 0.0))
        else:
            p = jnp.where(a >= 0.0, 1.0, -1.0)
        sf_q_int, sl = quant.quantize_scale_factors_int(
            params["sf"], params["sf_step"], spec.n_bits_sf
        )
        sf_q = sg(sf_q_int * sl)
        # reduced granularities broadcast up to the full (T, n_a, n_w, O)
        sf_full = jnp.broadcast_to(sf_q, (t, spec.n_bits_a, spec.n_bits_w, o))
        y_int = 0.5 * jnp.einsum("j,k,jkbto,tjko->bo", sigma, kappa, p, sf_full)
        c_w = jnp.sum(kappa)
        y_int = y_int + 0.5 * c_w * jnp.sum(x_int, axis=-1, keepdims=True)

    y = y_int * s_x * jnp.reshape(s_w, (1, -1) if jnp.ndim(s_w) else ())
    return y.reshape(x.shape[:-1] + (o,))


# ---------------------------------------------------------------------------
# Values-only serving state (shared by kernels.ops and the serving cache)
# ---------------------------------------------------------------------------

def quantize_weights_for_serving(
    w: jax.Array, params: Dict[str, jax.Array], cfg: QuantConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """LSQ weight codes + dequantized fixed-point scale factors, values only.

    Returns ``(w_int, s_w, sf_q)`` with every gradient stopped — the exact
    tensors the integer-level kernels consume. Deriving them here (rather
    than inline in each caller) guarantees the per-call kernel path and
    the :class:`repro.serve.cache.PackedLayer` pack-once path stay
    bit-identical by construction.

    ``sf_q`` is broadcast up to ``T`` tiles on its leading axis (reduced
    granularities keep size-1 trailing axes; the kernels broadcast those).
    In ``adc`` mode a neutral all-ones tensor is returned so the kernel
    signature stays uniform.
    """
    spec = cfg.spec
    t = num_tiles(w.shape[0], cfg.xbar_rows)
    w_int, s_w = quant.lsq_quantize_int(
        w, params["step_w"], spec.w_qn, spec.w_qp,
        g=quant.lsq_grad_factor(w.size, spec.w_qp),
    )
    w_int, s_w = sg(w_int), sg(s_w)
    if cfg.mode == "psq":
        sf_q_int, sl = quant.quantize_scale_factors_int(
            params["sf"], params["sf_step"], spec.n_bits_sf
        )
        sf_q = sg(sf_q_int * sl)
        if sf_q.shape[0] != t:  # per_layer granularity
            sf_q = jnp.broadcast_to(sf_q, (t,) + sf_q.shape[1:])
    else:
        sf_q = jnp.ones((t, spec.n_bits_a, spec.n_bits_w, 1), jnp.float32)
    return w_int, s_w, sf_q


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def init_psq_params(
    key: jax.Array,
    k_in: int,
    n_out: int,
    cfg: QuantConfig,
    w_std: Optional[float] = None,
) -> Dict[str, jax.Array]:
    """Initialize quantizer state for one PSQ linear layer.

    LSQ-style analytic init: for bit vectors with ~half the bits set, the
    differential column output ``a`` has std ≈ sqrt(R/2); the ternary
    threshold starts at 0.67·std (≈50 % zeros, matching Fig. 2(c)) and
    scale factors at E[|a| : |a|>alpha] ≈ sqrt(R).
    """
    spec = cfg.spec
    w_std = w_std if w_std is not None else 1.0 / math.sqrt(k_in)
    r = float(cfg.xbar_rows)
    t = num_tiles(k_in, cfg.xbar_rows)
    std_a = math.sqrt(r / 2.0)
    sf_init = math.sqrt(r)
    params = {
        # 2*std/sqrt(qp) LSQ init, assuming unit-ish activation std.
        "step_x": jnp.asarray(2.0 / math.sqrt(spec.a_qp), jnp.float32),
        "step_w": jnp.asarray(2.0 * w_std / math.sqrt(spec.w_qp), jnp.float32),
        "alpha": jnp.asarray(0.67 * std_a, jnp.float32),
    }
    if cfg.mode == "psq":
        shape = cfg.sf_shape(t, n_out)
        params["sf"] = jnp.full(shape, sf_init, jnp.float32)
        params["sf_step"] = jnp.asarray(
            sf_init / (2 ** (spec.n_bits_sf - 1)), jnp.float32
        )
    return params
