"""PSQLinear — a linear layer whose execution mode is the paper's knob.

Every projection in the model zoo routes through this module so the HCiM
technique (mode="psq"), the ADC baselines (mode="adc") and the fp path
(mode="none") are selectable per experiment from the config system.

Serving additionally routes through the tensor-parallel path when the
active sharding rules ask for it (``parallel.sharding.tp_axes``): a
packed layer's columns are split over the ``model`` mesh axis, each
device runs the full PSQ pipeline on its column slice via the registered
kernel backend (per-shard dispatch — the kernel sees local shapes), and
one ``psum`` performs the cross-device shift-add that recombines the
column blocks. Column splitting is bit-exact: every step of the HCiM
pipeline downstream of the weight codes (bit-plane partial sums,
comparator, DCiM scale-factor accumulate, digital offset correction) is
independent per output column.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.core import psq
from repro.core.config import QuantConfig
from repro.kernels import occupancy, registry
from repro.parallel import sharding as shd

Params = Dict[str, jax.Array]


def init_linear(
    key: jax.Array,
    k_in: int,
    n_out: int,
    cfg: QuantConfig,
    use_bias: bool = False,
    w_init_std: Optional[float] = None,
    dtype=jnp.float32,
) -> Params:
    """Create parameters for one (possibly quantized) linear layer."""
    wkey, _ = jax.random.split(key)
    std = w_init_std if w_init_std is not None else 1.0 / math.sqrt(k_in)
    p: Params = {"w": (jax.random.normal(wkey, (k_in, n_out)) * std).astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((n_out,), dtype)
    if cfg.quantized:
        p.update(psq.init_psq_params(key, k_in, n_out, cfg, w_std=std))
        if cfg.per_channel_w:
            p["step_w"] = jnp.full((n_out,), float(p["step_w"]), jnp.float32)
    return p


def pack_weight_int4(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-out-channel int4 packing: (..., K, O) -> int8 (..., K/2, O).

    Deployment format for PSQ-trained weights (4-bit is the paper's CIFAR
    recipe): two two's-complement nibbles per byte along K, so the decode
    step streams 4x fewer weight bytes from HBM than bf16.
    """
    scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 7.0
    wi = jnp.clip(jnp.round(w / jnp.maximum(scale, 1e-9)), -8, 7)
    u = jnp.mod(wi.astype(jnp.int32), 16)
    lo, hi = u[..., 0::2, :], u[..., 1::2, :]
    return (lo | (hi << 4)).astype(jnp.int8), scale.astype(jnp.float32)


def _unpack_int4_matmul(x: jax.Array, packed: jax.Array, scale: jax.Array):
    w8 = packed.astype(jnp.int32)
    lo = w8 & 0xF
    hi = (w8 >> 4) & 0xF
    lo = lo - 16 * (lo >= 8).astype(jnp.int32)
    hi = hi - 16 * (hi >= 8).astype(jnp.int32)
    w_int = jnp.stack([lo, hi], axis=-2)
    w_int = w_int.reshape(*packed.shape[:-2], 2 * packed.shape[-2],
                          packed.shape[-1])
    w = w_int.astype(x.dtype) * scale.astype(x.dtype)
    return x @ w


def pack_tree_for_serving(node):
    """Replace every linear master weight in a param tree by its int4
    packed + per-channel-scale pair (embeddings/norms untouched)."""
    if isinstance(node, dict):
        out = {}
        for k, v in node.items():
            if (
                k == "w" and hasattr(v, "ndim") and v.ndim >= 2
                and v.shape[-2] % 2 == 0
            ):
                out["w_packed"], out["w_scale"] = pack_weight_int4(v)
            else:
                out[k] = pack_tree_for_serving(v)
        return out
    if isinstance(node, (list, tuple)):
        return type(node)(pack_tree_for_serving(v) for v in node)
    return node


def serve_linear_tp(
    layer, x: jax.Array, mesh: Mesh, axis: str
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Tensor-parallel packed-layer forward: columns over ``axis``.

    ``shard_map`` hands each device its column slice of the packed state
    (specs from :func:`repro.parallel.sharding.packed_layer_pspecs`);
    the kernel backend is dispatched per shard on the local ``(B, K) x
    (K, O/n)`` problem; each shard scatters its block into a zero
    ``(B, O)`` buffer and a single ``psum`` over ``axis`` recombines —
    the cross-device digital shift-add. Adding disjoint blocks of exact
    values keeps the result bit-identical to the single-device forward.

    Falls back to the unsharded forward when the column count does not
    divide the axis (the divisibility story of the rules table).

    Sparsity skipping survives the split: the replicated occupancy
    metadata describes the GLOBAL column space, so it is re-sliced to
    the local ``(K, O/n)`` problem before entering the mapped trace
    (:func:`repro.kernels.occupancy.shard_occupancy` — the conservative
    AND across shard slices, since ``shard_map`` traces once for every
    device). When the split is not representable (a shard boundary
    inside a metadata block) the re-slice returns ``None`` and the
    shape guard (``occupancy_for_kernel``) keeps the shards dense —
    correct either way, because skipped blocks are all-zero weights.
    """
    n = mesh.shape[axis]
    o = layer.w_codes.shape[-1]
    if o % n != 0:
        return layer.apply_serving(x)
    socc = occupancy.shard_occupancy(layer.occupancy, n)
    if socc is not layer.occupancy:
        # occupancy is pytree aux data: replacing it never touches the
        # array leaves or their shard specs
        layer = dataclasses.replace(layer, occupancy=socc)
    # fail fast on an unavailable backend before entering the mapped
    # trace, where the registry error would lose the sharding context
    registry.resolve_backend(layer.cfg)
    specs = shd.packed_layer_pspecs(layer, mesh=mesh)
    xspec = shd.data_pspec(x.ndim, x.shape, exclude=(axis,))

    def local_fn(lyr, xl):
        y, _ = lyr.apply_serving(xl)
        idx = jax.lax.axis_index(axis)
        full = jnp.zeros(y.shape[:-1] + (o,), y.dtype)
        full = jax.lax.dynamic_update_slice_in_dim(
            full, y, idx * (o // n), axis=y.ndim - 1
        )
        return jax.lax.psum(full, axis)

    fn = shard_map(local_fn, mesh=mesh, in_specs=(specs, xspec),
                   out_specs=xspec, check_rep=False)
    return fn(layer, x), {}


def apply_linear(
    params: Params,
    x: jax.Array,
    cfg: QuantConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """y = quantized_matmul(x, w) + b. Returns (y, stats)."""
    if hasattr(params, "apply_serving"):
        # PackedLayer (repro.serve.cache): weight-stationary packed state,
        # quantized/packed once at model load — bias folded in there.
        tp = shd.tp_axes()
        if tp is not None:
            return serve_linear_tp(params, x, *tp)
        return params.apply_serving(x)
    if "w_packed" in params:  # int4 weight-stationary serving path
        y = _unpack_int4_matmul(x, params["w_packed"], params["w_scale"])
        stats: Dict[str, jax.Array] = {}
    elif not cfg.quantized:
        y = x @ params["w"].astype(x.dtype)
        stats = {}
    elif cfg.kernel_path:
        from repro.kernels import ops as kernel_ops

        y, stats = kernel_ops.psq_matmul(x, params["w"], params, cfg)
    else:
        y, stats = psq.psq_matmul(x, params["w"], params, cfg)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y, stats


def linear_flops(k_in: int, n_out: int, tokens: int) -> int:
    return 2 * k_in * n_out * tokens
