"""PSQLinear — a linear layer whose execution mode is the paper's knob.

Every projection in the model zoo routes through this module so the HCiM
technique (mode="psq"), the ADC baselines (mode="adc") and the fp path
(mode="none") are selectable per experiment from the config system.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import psq
from repro.core.config import QuantConfig

Params = Dict[str, jax.Array]


def init_linear(
    key: jax.Array,
    k_in: int,
    n_out: int,
    cfg: QuantConfig,
    use_bias: bool = False,
    w_init_std: Optional[float] = None,
    dtype=jnp.float32,
) -> Params:
    """Create parameters for one (possibly quantized) linear layer."""
    wkey, _ = jax.random.split(key)
    std = w_init_std if w_init_std is not None else 1.0 / math.sqrt(k_in)
    p: Params = {"w": (jax.random.normal(wkey, (k_in, n_out)) * std).astype(dtype)}
    if use_bias:
        p["b"] = jnp.zeros((n_out,), dtype)
    if cfg.quantized:
        p.update(psq.init_psq_params(key, k_in, n_out, cfg, w_std=std))
        if cfg.per_channel_w:
            p["step_w"] = jnp.full((n_out,), float(p["step_w"]), jnp.float32)
    return p


def pack_weight_int4(w: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-out-channel int4 packing: (..., K, O) -> int8 (..., K/2, O).

    Deployment format for PSQ-trained weights (4-bit is the paper's CIFAR
    recipe): two two's-complement nibbles per byte along K, so the decode
    step streams 4x fewer weight bytes from HBM than bf16.
    """
    scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 7.0
    wi = jnp.clip(jnp.round(w / jnp.maximum(scale, 1e-9)), -8, 7)
    u = jnp.mod(wi.astype(jnp.int32), 16)
    lo, hi = u[..., 0::2, :], u[..., 1::2, :]
    return (lo | (hi << 4)).astype(jnp.int8), scale.astype(jnp.float32)


def _unpack_int4_matmul(x: jax.Array, packed: jax.Array, scale: jax.Array):
    w8 = packed.astype(jnp.int32)
    lo = w8 & 0xF
    hi = (w8 >> 4) & 0xF
    lo = lo - 16 * (lo >= 8).astype(jnp.int32)
    hi = hi - 16 * (hi >= 8).astype(jnp.int32)
    w_int = jnp.stack([lo, hi], axis=-2)
    w_int = w_int.reshape(*packed.shape[:-2], 2 * packed.shape[-2],
                          packed.shape[-1])
    w = w_int.astype(x.dtype) * scale.astype(x.dtype)
    return x @ w


def pack_tree_for_serving(node):
    """Replace every linear master weight in a param tree by its int4
    packed + per-channel-scale pair (embeddings/norms untouched)."""
    if isinstance(node, dict):
        out = {}
        for k, v in node.items():
            if (
                k == "w" and hasattr(v, "ndim") and v.ndim >= 2
                and v.shape[-2] % 2 == 0
            ):
                out["w_packed"], out["w_scale"] = pack_weight_int4(v)
            else:
                out[k] = pack_tree_for_serving(v)
        return out
    if isinstance(node, (list, tuple)):
        return type(node)(pack_tree_for_serving(v) for v in node)
    return node


def apply_linear(
    params: Params,
    x: jax.Array,
    cfg: QuantConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """y = quantized_matmul(x, w) + b. Returns (y, stats)."""
    if hasattr(params, "apply_serving"):
        # PackedLayer (repro.serve.cache): weight-stationary packed state,
        # quantized/packed once at model load — bias folded in there.
        return params.apply_serving(x)
    if "w_packed" in params:  # int4 weight-stationary serving path
        y = _unpack_int4_matmul(x, params["w_packed"], params["w_scale"])
        stats: Dict[str, jax.Array] = {}
    elif not cfg.quantized:
        y = x @ params["w"].astype(x.dtype)
        stats = {}
    elif cfg.kernel_path:
        from repro.kernels import ops as kernel_ops

        y, stats = kernel_ops.psq_matmul(x, params["w"], params, cfg)
    else:
        y, stats = psq.psq_matmul(x, params["w"], params, cfg)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y, stats


def linear_flops(k_in: int, n_out: int, tokens: int) -> int:
    return 2 * k_in * n_out * tokens
