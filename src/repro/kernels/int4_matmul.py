"""Weight-stationary int4 matmul Pallas kernel (PSQ deployment path).

PSQ-trained networks carry 4-bit integer weights; at decode time the
dominant roofline term is HBM weight traffic. Packing two 4-bit codes per
byte cuts weight bytes 4x vs bf16 — nibbles are unpacked in VREGs right
before the MXU dot, so HBM only ever sees packed bytes. This is the
TPU-native counterpart of HCiM's weight-stationary crossbars and the main
lever for the decode-cell hillclimbs in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _int4_kernel(x_ref, w_ref, o_ref):
    t = pl.program_id(2)
    x = x_ref[...].astype(jnp.float32)            # (BB, BK)
    w8 = w_ref[...].astype(jnp.int32)             # (BK//2, BO) packed
    lo = w8 & 0xF
    hi = (w8 >> 4) & 0xF
    lo = lo - 16 * (lo >= 8).astype(jnp.int32)    # sign-extend nibble
    hi = hi - 16 * (hi >= 8).astype(jnp.int32)
    kk, bo = w8.shape
    w_int = jnp.stack([lo, hi], axis=1).reshape(2 * kk, bo).astype(jnp.float32)
    acc = jax.lax.dot(
        x.astype(jnp.bfloat16),
        w_int.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += acc


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_o", "block_k", "interpret")
)
def int4_matmul_kernel(
    x: jax.Array,            # (B, K)
    w_packed: jax.Array,     # (K//2, O) int8
    scale: jax.Array,        # (O,) per-channel dequant scale
    *,
    block_b: int = 128,
    block_o: int = 256,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, k = x.shape
    o = w_packed.shape[1]
    assert w_packed.shape[0] * 2 == k, "packed weight K mismatch"

    bb = min(block_b, _ceil_to(b, 8))
    bo = min(block_o, _ceil_to(o, 128))
    bk = min(block_k, _ceil_to(k, 256))
    b_pad, o_pad, k_pad = _ceil_to(b, bb), _ceil_to(o, bo), _ceil_to(k, bk)

    x_p = jnp.pad(x, ((0, b_pad - b), (0, k_pad - k)))
    w_p = jnp.pad(w_packed, ((0, (k_pad - k) // 2), (0, o_pad - o)))

    grid = (b_pad // bb, o_pad // bo, k_pad // bk)
    y = pl.pallas_call(
        _int4_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bk), lambda bi, oi, ti: (bi, ti)),
            pl.BlockSpec((bk // 2, bo), lambda bi, oi, ti: (ti, oi)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda bi, oi, ti: (bi, oi)),
        out_shape=jax.ShapeDtypeStruct((b_pad, o_pad), jnp.float32),
        interpret=interpret,
    )(x_p, w_p)
    return y[:b, :o] * scale[None, :]


def pack_int4(w_int: jax.Array) -> jax.Array:
    """Pack integer codes in [-8, 7] (even K) into bytes, row-interleaved."""
    k, o = w_int.shape
    assert k % 2 == 0
    w = jnp.mod(w_int.astype(jnp.int32), 16)      # two's-complement nibbles
    lo = w[0::2]
    hi = w[1::2]
    return (lo | (hi << 4)).astype(jnp.int8)
