"""Kernel backend registry — one dispatch point for the HCiM datapath.

Every implementation of the integer-level PSQ pipeline (and of the int4
weight-stationary decode matmul) registers here under a name; callers
select a backend per call, per config (``QuantConfig.kernel_backend``) or
process-wide (``set_default_backend`` / ``REPRO_KERNEL_BACKEND``), and
the rest of the stack — ``kernels.ops``, ``core.psq_linear``, the serving
cache, ``benchmarks/kernel_bench.py`` — never hard-codes an
implementation again.

Built-in backends:

  reference        pure-jnp oracle (:mod:`repro.kernels.ref`) — bit-exact
                   semantics, always available, the conformance baseline.
  pallas-interpret Pallas kernels in interpret mode — runs anywhere
                   (CPU containers included), exercises the real kernel
                   code path minus Mosaic lowering.
  pallas           compiled Pallas kernels — TPU/GPU only; the serving
                   fast path.

Backends expose three entry points with fixed signatures:

  psq_matmul(x_int, w_int, sf_q, alpha, *, n_a, n_w, levels, adc_bits,
             xbar_rows, fuse_planes=False,
             occupancy=None) -> y_int                      (B, O)
  int4_matmul(x, w_packed, scale) -> y                     (B, O)
  paged_attention(q, k_pool, v_pool, block_tables, lengths,
                  k_new, v_new) -> ctx                     (B, H, D)

``x_int``/``w_int`` are integer-valued f32 codes, ``sf_q`` the
dequantized fixed-point scale factors broadcastable to
``(T, n_a, n_w, O)`` — exactly the contract of
:func:`repro.kernels.ref.psq_matmul_ref`. ``occupancy`` is optional
pack-time sparsity metadata (:mod:`repro.kernels.occupancy`); backends
may skip all-zero ternary column blocks with it, but must stay
bit-exact against the reference oracle whether or not they do. ``paged_attention`` is the
single-token decode attention over the paged KV pool (block-table
indirection; contract in :mod:`repro.kernels.paged_attention`) — it is
optional for third-party backends (``None`` means not implemented, and
``models.decode.decode_step_paged`` falls back to its inline gather
path when no backend is requested).

Example — look up the conformance oracle and check what's registered:

    >>> from repro.kernels import registry
    >>> registry.get_backend("reference").name
    'reference'
    >>> all(b in registry.registered_backends()
    ...     for b in ("reference", "pallas-interpret", "pallas"))
    True

Per-shard dispatch under tensor parallelism
-------------------------------------------
The serving TP path (``core.psq_linear.serve_linear_tp``) calls the
backend *inside* a ``shard_map`` body, so ``psq_matmul`` sees the LOCAL
problem — ``(B, K) x (K, O/n)`` for an ``n``-way column split — and a
Pallas backend lowers one kernel per device over its own column block
(GSPMD cannot partition a ``pallas_call``; manual sharding is how the
kernels scale out). Resolution is shape-independent, so the same
selection order applies per shard; callers resolve once *before*
entering the mapped trace to fail fast on unavailable backends (see
:func:`resolve_backend`). :func:`describe` gives launchers a one-line
availability table for logs and bench metadata.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional

import jax

__all__ = [
    "KernelBackend",
    "available_backends",
    "default_backend",
    "describe",
    "get_backend",
    "register_backend",
    "registered_backends",
    "resolve_backend",
    "set_default_backend",
]

_ENV_VAR = "REPRO_KERNEL_BACKEND"


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """A named implementation of the HCiM kernel contract."""

    name: str
    description: str
    psq_matmul: Callable[..., jax.Array]
    int4_matmul: Callable[..., jax.Array]
    # availability is queried lazily: it can depend on jax.default_backend()
    is_available: Callable[[], bool] = lambda: True
    # optional paged-decode attention (kernels/paged_attention.py contract)
    paged_attention: Optional[Callable[..., jax.Array]] = None

    def require_available(self) -> "KernelBackend":
        if not self.is_available():
            raise RuntimeError(
                f"kernel backend {self.name!r} is registered but not "
                f"available on the {jax.default_backend()!r} platform "
                f"(available: {available_backends()})"
            )
        return self


_REGISTRY: Dict[str, KernelBackend] = {}
_DEFAULT_NAME = "pallas-interpret"


def register_backend(backend: KernelBackend) -> KernelBackend:
    """Add (or replace) a backend; returns it so use as a statement or fn.

    A new implementation only has to satisfy the two-entry-point
    contract; the conformance suite and ``benchmarks/kernel_bench.py``
    pick it up automatically::

        register_backend(KernelBackend(
            name="my-backend",
            description="what it is",
            psq_matmul=my_psq_matmul,
            int4_matmul=my_int4_matmul,
        ))
    """
    _REGISTRY[backend.name] = backend
    return backend


def registered_backends() -> List[str]:
    """All registered backend names, available on this platform or not.

    >>> "reference" in registered_backends()
    True
    """
    return sorted(_REGISTRY)


def describe() -> List[Dict[str, object]]:
    """Availability table: one row per registered backend.

    Stable name order; ``available`` is evaluated lazily against the
    current JAX platform. Launchers and benches embed this in their
    logs/JSON so a recorded run states which implementations it could
    have dispatched to.

    >>> rows = describe()
    >>> [r["name"] for r in rows] == registered_backends()
    True
    >>> all(set(r) == {"name", "description", "available"} for r in rows)
    True
    """
    return [
        {"name": n, "description": _REGISTRY[n].description,
         "available": _REGISTRY[n].is_available()}
        for n in sorted(_REGISTRY)
    ]


def available_backends() -> List[str]:
    """Backend names runnable on the current JAX platform.

    Always a subset of :func:`registered_backends`; the ``reference``
    oracle is available everywhere.

    >>> set(available_backends()) <= set(registered_backends())
    True
    >>> "reference" in available_backends()
    True
    """
    return [n for n in sorted(_REGISTRY) if _REGISTRY[n].is_available()]


def get_backend(name: Optional[str] = None) -> KernelBackend:
    """Look up a backend by name (``None`` -> the process default).

    Raises ``KeyError`` for unknown names and ``RuntimeError`` for
    backends that cannot run on the current platform.

    >>> get_backend("reference").name
    'reference'
    >>> get_backend("no-such-backend")  # doctest: +IGNORE_EXCEPTION_DETAIL
    Traceback (most recent call last):
        ...
    KeyError: unknown kernel backend 'no-such-backend'
    """
    resolved = name or default_backend()
    try:
        backend = _REGISTRY[resolved]
    except KeyError:
        raise KeyError(
            f"unknown kernel backend {resolved!r}; "
            f"registered: {registered_backends()}"
        ) from None
    return backend.require_available()


def set_default_backend(name: str) -> None:
    """Process-wide default used when a config does not pin a backend.

    (``REPRO_KERNEL_BACKEND`` in the environment still beats this — the
    example sets it aside to show the in-process value, then restores.)

    >>> import os
    >>> saved = os.environ.pop("REPRO_KERNEL_BACKEND", None)
    >>> set_default_backend("reference")
    >>> default_backend()
    'reference'
    >>> set_default_backend("pallas-interpret")   # restore the built-in
    >>> if saved is not None: os.environ["REPRO_KERNEL_BACKEND"] = saved
    """
    global _DEFAULT_NAME
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown kernel backend {name!r}; "
            f"registered: {registered_backends()}"
        )
    _DEFAULT_NAME = name


def default_backend() -> str:
    """Env override (``REPRO_KERNEL_BACKEND``) beats the in-process default.

    >>> import os
    >>> saved = os.environ.pop("REPRO_KERNEL_BACKEND", None)
    >>> default_backend() in registered_backends()
    True
    >>> if saved is not None: os.environ["REPRO_KERNEL_BACKEND"] = saved
    """
    return os.environ.get(_ENV_VAR) or _DEFAULT_NAME


def resolve_backend(cfg) -> KernelBackend:
    """Backend for a :class:`repro.core.config.QuantConfig`.

    ``cfg.kernel_backend`` pins one explicitly; otherwise the process
    default applies. Accepts any object with a ``kernel_backend``
    attribute (or a plain name / None).

    >>> import os
    >>> saved = os.environ.pop("REPRO_KERNEL_BACKEND", None)
    >>> resolve_backend("reference").name
    'reference'
    >>> resolve_backend(None).name == default_backend()
    True
    >>> if saved is not None: os.environ["REPRO_KERNEL_BACKEND"] = saved
    """
    if cfg is None:
        return get_backend(None)
    if isinstance(cfg, str):
        return get_backend(cfg)
    return get_backend(getattr(cfg, "kernel_backend", None))


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

def _reference_psq(x_int, w_int, sf_q, alpha, *, n_a, n_w, levels,
                   adc_bits=7, xbar_rows=128, fuse_planes=False,
                   occupancy=None):
    # fuse_planes is a Pallas MXU-occupancy knob; jnp semantics are
    # plane-order independent so the oracle accepts and ignores it.
    del fuse_planes
    from repro.kernels.ref import psq_matmul_ref

    return psq_matmul_ref(
        x_int, w_int, sf_q, alpha,
        n_a=n_a, n_w=n_w, levels=levels,
        adc_bits=adc_bits, xbar_rows=xbar_rows,
        occupancy=occupancy,
    )


def _reference_int4(x, w_packed, scale):
    from repro.kernels.ref import int4_matmul_ref

    return int4_matmul_ref(w_packed, scale, x)


def _pallas_psq(interpret: bool):
    def call(x_int, w_int, sf_q, alpha, *, n_a, n_w, levels,
             adc_bits=7, xbar_rows=128, fuse_planes=False,
             occupancy=None):
        from repro.kernels.psq_matmul import psq_matmul_kernel

        return psq_matmul_kernel(
            x_int, w_int, sf_q, alpha,
            n_a=n_a, n_w=n_w, levels=levels, adc_bits=adc_bits,
            xbar_rows=xbar_rows, fuse_planes=fuse_planes,
            occupancy=occupancy, interpret=interpret,
        )

    return call


def _pallas_int4(interpret: bool):
    def call(x, w_packed, scale):
        from repro.kernels.int4_matmul import int4_matmul_kernel

        return int4_matmul_kernel(x, w_packed, scale, interpret=interpret)

    return call


def _reference_paged(q, k_pool, v_pool, block_tables, lengths, k_new, v_new):
    from repro.kernels.paged_attention import paged_attention_ref

    return paged_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                               k_new, v_new)


def _pallas_paged(interpret: bool):
    def call(q, k_pool, v_pool, block_tables, lengths, k_new, v_new):
        from repro.kernels.paged_attention import paged_attention_kernel

        return paged_attention_kernel(q, k_pool, v_pool, block_tables,
                                      lengths, k_new, v_new,
                                      interpret=interpret)

    return call


def _compiled_pallas_available() -> bool:
    # pallas_call only lowers through Mosaic/Triton on accelerators;
    # CPU supports interpret mode exclusively.
    return jax.default_backend() in ("tpu", "gpu", "cuda", "rocm")


register_backend(KernelBackend(
    name="reference",
    description="pure-jnp bit-exact oracle (conformance baseline)",
    psq_matmul=_reference_psq,
    int4_matmul=_reference_int4,
    paged_attention=_reference_paged,
))

register_backend(KernelBackend(
    name="pallas-interpret",
    description="Pallas kernels, interpreter (portable, correctness path)",
    psq_matmul=_pallas_psq(interpret=True),
    int4_matmul=_pallas_int4(interpret=True),
    paged_attention=_pallas_paged(interpret=True),
))

register_backend(KernelBackend(
    name="pallas",
    description="compiled Pallas kernels (TPU/GPU serving fast path)",
    psq_matmul=_pallas_psq(interpret=False),
    int4_matmul=_pallas_int4(interpret=False),
    is_available=_compiled_pallas_available,
    paged_attention=_pallas_paged(interpret=False),
))
