"""Pallas flash-attention kernel (TPU): VMEM-resident online softmax.

§Perf finding (EXPERIMENTS.md, qwen3 hillclimb): XLA-level chunked
attention does NOT cut HBM traffic — the per-block accumulator spills to
HBM every loop iteration, so measured bytes went UP 28 %. The fix has to
be a fused kernel whose running (m, l, acc) statistics live in VMEM
across the whole KV sweep; then HBM sees exactly q+k+v+out. This module
is that kernel:

  * grid = (batch*kv_head*group, q_blocks); each program owns one q tile,
  * K/V stream through VMEM via BlockSpec; the online-softmax loop runs
    in-register/VMEM (jax.lax.fori_loop over KV tiles),
  * causal + sliding-window masks applied per tile; tiles fully in the
    causal future are skipped via the loop bound (halves the sweep).

Validated in interpret mode against the naive SDPA oracle.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_len: int, kv_block: int,
                  causal: bool, window: int, q_block: int, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (Lq, D)
    lq, d = q.shape
    q_start = qi * q_block

    n_kv = kv_len // kv_block
    if causal:
        # last kv tile that can be visible to this q tile
        last = (q_start + lq - 1) // kv_block + 1
        n_iter = jnp.minimum(n_kv, last)
    else:
        n_iter = n_kv

    def body(i, carry):
        m, l, acc = carry
        # size-1 slice, not a bare int 0: this JAX's interpret-mode
        # discharge rule requires Slice-or-array indices in pl.load
        k = pl.load(
            k_ref, (pl.ds(0, 1), pl.ds(i * kv_block, kv_block), slice(None))
        )[0]
        v = pl.load(
            v_ref, (pl.ds(0, 1), pl.ds(i * kv_block, kv_block), slice(None))
        )[0]
        logits = jax.lax.dot_general(
            q, k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
        )                                              # (Lq, Lkv)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (lq, kv_block), 0)
        kpos = i * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, (lq, kv_block), 1
        )
        mask = jnp.ones((lq, kv_block), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((lq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((lq,), jnp.float32)
    a0 = jnp.zeros((lq, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_iter, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret"),
)
def flash_attention(
    q: jax.Array,        # (BH, S, D)  batch*heads flattened
    k: jax.Array,        # (BH, S_kv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_block: int = 256,
    kv_block: int = 512,
    interpret: bool = True,
) -> jax.Array:
    bh, s, d = q.shape
    s_kv = k.shape[1]
    lq = min(q_block, s)
    lkv = min(kv_block, s_kv)
    assert s % lq == 0 and s_kv % lkv == 0, "pad seq to block multiples"
    grid = (bh, s // lq)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, kv_len=s_kv, kv_block=lkv, causal=causal,
            window=window, q_block=lq, scale=1.0 / math.sqrt(d),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, lq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, s_kv, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, s_kv, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        interpret=interpret,
    )(q, k, v)


def flash_attention_gqa(q, k, v, causal=True, window=0, **kw):
    """(B,S,H,D) x (B,Skv,Hk,D) convenience wrapper (expands GQA groups)."""
    b, s, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(
        b * h, k.shape[1], d
    )
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(
        b * h, v.shape[1], d
    )
    o = flash_attention(qf, kf, vf, causal=causal, window=window, **kw)
    return o.reshape(b, h, s, d).transpose(0, 2, 1, 3).reshape(b, s, h * d)
