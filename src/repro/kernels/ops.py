"""Jitted wrappers binding the registered kernel backends into the
QAT/serving APIs.

``psq_matmul`` — drop-in replacement for :func:`repro.core.psq.psq_matmul`
(same signature, same values): forward runs the backend selected through
:mod:`repro.kernels.registry` (``cfg.kernel_backend`` or the process
default), backward re-derives the straight-through gradients from the jnp
reference semantics via a custom VJP (the standard recompute-in-backward
pattern of fused kernels).

``int4_matmul`` — weight-stationary deployment matmul for PSQ-trained
weights (values only; serving path, no gradients needed).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import psq as psq_ref
from repro.core import quant
from repro.core.config import QuantConfig
from repro.kernels import registry
from repro.kernels.int4_matmul import pack_int4

sg = jax.lax.stop_gradient


def kernel_forward_values(
    x: jax.Array,
    w_int: jax.Array,
    s_w: jax.Array,
    sf_q: jax.Array,
    alpha: jax.Array,
    step_x: jax.Array,
    cfg: QuantConfig,
    occupancy=None,
) -> jax.Array:
    """Values-only HCiM forward from pre-derived weight-side state.

    The single activation-quantize -> backend -> rescale path shared by
    the per-call QAT wrapper below and the pack-once serving cache
    (:class:`repro.serve.cache.PackedLayer`) — one definition, so the two
    paths cannot drift apart. ``occupancy`` is optional pack-time
    sparsity metadata (:mod:`repro.kernels.occupancy`): passed through to
    the backend only when present, so third-party backends registered
    against the pre-sparsity contract keep working on the dense path.
    """
    spec = cfg.spec
    backend = registry.resolve_backend(cfg)
    orig_shape = x.shape
    xf = x.reshape(-1, x.shape[-1])
    x_int, s_x = quant.lsq_quantize_int(xf, step_x, spec.a_qn, spec.a_qp)
    x_int, s_x = sg(x_int), sg(s_x)
    extra = {"occupancy": occupancy} if occupancy is not None else {}
    y_int = backend.psq_matmul(
        x_int.astype(jnp.float32), w_int, sf_q, sg(alpha),
        n_a=spec.n_bits_a, n_w=spec.n_bits_w,
        levels=cfg.psq_levels if cfg.mode == "psq" else "adc",
        adc_bits=cfg.adc_bits, xbar_rows=cfg.xbar_rows,
        fuse_planes=cfg.fuse_planes, **extra,
    )
    y = y_int * s_x * jnp.reshape(s_w, (1, -1) if jnp.ndim(s_w) else ())
    return y.reshape(orig_shape[:-1] + (w_int.shape[-1],))


def _kernel_forward(x, w, params, cfg: QuantConfig) -> jax.Array:
    """Values-only HCiM forward, weight state re-derived per call (QAT)."""
    w_int, s_w, sf_q = psq_ref.quantize_weights_for_serving(w, params, cfg)
    return kernel_forward_values(
        x, w_int, s_w, sf_q, params["alpha"], params["step_x"], cfg
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _psq_matmul_kernel_qat(x, w, params, cfg: QuantConfig):
    return _kernel_forward(x, w, params, cfg)


def _qat_fwd(x, w, params, cfg: QuantConfig):
    return _kernel_forward(x, w, params, cfg), (x, w, params)


def _qat_bwd(cfg: QuantConfig, res, gy):
    x, w, params = res
    ref = lambda x_, w_, p_: psq_ref.psq_matmul(x_, w_, p_, cfg)[0]
    _, vjp = jax.vjp(ref, x, w, params)
    return vjp(gy)


_psq_matmul_kernel_qat.defvjp(_qat_fwd, _qat_bwd)


def psq_matmul(
    x: jax.Array, w: jax.Array, params: Dict[str, jax.Array], cfg: QuantConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Backend-dispatched HCiM matmul with reference-derived QAT gradients."""
    return _psq_matmul_kernel_qat(x, w, params, cfg), {}


def int4_matmul(
    x: jax.Array,
    w_packed: jax.Array,
    scale: jax.Array,
    backend: Optional[str] = None,
    **kw,
) -> jax.Array:
    """Weight-stationary int4 matmul through a registered backend."""
    if "interpret" in kw:  # legacy knob: map onto the backend names
        backend = backend or ("pallas-interpret" if kw.pop("interpret")
                              else "pallas")
    impl = registry.get_backend(backend)
    orig_shape = x.shape
    y = impl.int4_matmul(x.reshape(-1, x.shape[-1]), w_packed, scale)
    return y.reshape(orig_shape[:-1] + (w_packed.shape[-1],))


__all__ = ["psq_matmul", "int4_matmul", "pack_int4",
           "kernel_forward_values"]
