"""Jitted wrappers binding the Pallas kernels into the QAT/serving APIs.

``psq_matmul`` — drop-in replacement for :func:`repro.core.psq.psq_matmul`
(same signature, same values): forward runs the Pallas kernel, backward
re-derives the straight-through gradients from the jnp reference
semantics via a custom VJP (the standard recompute-in-backward pattern of
fused kernels).

``int4_matmul`` — weight-stationary deployment matmul for PSQ-trained
weights (values only; serving path, no gradients needed).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import psq as psq_ref
from repro.core import quant
from repro.core.config import QuantConfig
from repro.kernels.int4_matmul import int4_matmul_kernel, pack_int4
from repro.kernels.psq_matmul import psq_matmul_kernel

sg = jax.lax.stop_gradient

_INTERPRET = True  # CPU container: Pallas runs in interpret mode


def _kernel_forward(x, w, params, cfg: QuantConfig) -> jax.Array:
    """Values-only HCiM forward through the Pallas kernel."""
    spec = cfg.spec
    orig_shape = x.shape
    xf = x.reshape(-1, x.shape[-1])
    x_int, s_x = quant.lsq_quantize_int(xf, params["step_x"], spec.a_qn, spec.a_qp)
    w_int, s_w = quant.lsq_quantize_int(
        w, params["step_w"], spec.w_qn, spec.w_qp,
        g=quant.lsq_grad_factor(w.size, spec.w_qp),
    )
    x_int, w_int, s_x, s_w = sg(x_int), sg(w_int), sg(s_x), sg(s_w)

    if cfg.mode == "psq":
        sf_q_int, sl = quant.quantize_scale_factors_int(
            params["sf"], params["sf_step"], spec.n_bits_sf
        )
        sf_q = sg(sf_q_int * sl)
        t = psq_ref.num_tiles(x.shape[-1], cfg.xbar_rows)
        if sf_q.shape[0] != t:  # per_layer granularity
            sf_q = jnp.broadcast_to(sf_q, (t,) + sf_q.shape[1:])
    else:
        t = psq_ref.num_tiles(x.shape[-1], cfg.xbar_rows)
        sf_q = jnp.ones((t, spec.n_bits_a, spec.n_bits_w, 1), jnp.float32)

    y_int = psq_matmul_kernel(
        x_int, w_int, sf_q, sg(params["alpha"]),
        n_a=spec.n_bits_a, n_w=spec.n_bits_w,
        levels=cfg.psq_levels if cfg.mode == "psq" else "adc",
        adc_bits=cfg.adc_bits, xbar_rows=cfg.xbar_rows,
        interpret=_INTERPRET,
    )
    y = y_int * s_x * jnp.reshape(s_w, (1, -1) if jnp.ndim(s_w) else ())
    return y.reshape(orig_shape[:-1] + (w.shape[-1],))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _psq_matmul_kernel_qat(x, w, params, cfg: QuantConfig):
    return _kernel_forward(x, w, params, cfg)


def _qat_fwd(x, w, params, cfg: QuantConfig):
    return _kernel_forward(x, w, params, cfg), (x, w, params)


def _qat_bwd(cfg: QuantConfig, res, gy):
    x, w, params = res
    ref = lambda x_, w_, p_: psq_ref.psq_matmul(x_, w_, p_, cfg)[0]
    _, vjp = jax.vjp(ref, x, w, params)
    return vjp(gy)


_psq_matmul_kernel_qat.defvjp(_qat_fwd, _qat_bwd)


def psq_matmul(
    x: jax.Array, w: jax.Array, params: Dict[str, jax.Array], cfg: QuantConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Kernel-backed HCiM matmul with reference-derived QAT gradients."""
    return _psq_matmul_kernel_qat(x, w, params, cfg), {}


def int4_matmul(
    x: jax.Array, w_packed: jax.Array, scale: jax.Array, **kw
) -> jax.Array:
    orig_shape = x.shape
    y = int4_matmul_kernel(
        x.reshape(-1, x.shape[-1]), w_packed, scale,
        interpret=kw.get("interpret", _INTERPRET),
    )
    return y.reshape(orig_shape[:-1] + (w_packed.shape[-1],))


__all__ = ["psq_matmul", "int4_matmul", "pack_int4"]
