"""HCiM kernel layer.

``registry`` is the extension point: every implementation of the PSQ
crossbar pipeline / int4 decode matmul registers there by name and the
rest of the stack dispatches through it (see ``kernels/ops.py`` for the
QAT-facing wrappers). Add new backends by calling
:func:`repro.kernels.registry.register_backend`.
"""
from repro.kernels.registry import (  # noqa: F401
    KernelBackend,
    available_backends,
    default_backend,
    describe,
    get_backend,
    register_backend,
    registered_backends,
    resolve_backend,
    set_default_backend,
)
