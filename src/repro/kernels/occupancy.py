"""Pack-time ternary occupancy metadata for sparsity-skipping kernels.

HCiM's digital CiM array clock-gates columns whose ternary comparator
output is zero (paper §4.2.2, Fig. 5a). The *statically known* slice of
that sparsity is visible at pack time: a weight column whose codes are
all zero inside one crossbar tile produces ``ps = 0`` for every input,
so its comparator input collapses to ``-rowsum`` — no matmul needed.
:func:`column_occupancy` records, per (crossbar tile, column block):

* whether the **entire** ``(xbar_rows, block)`` weight slab is zero
  (``zero_blocks`` — the unit the kernels actually skip),
* the fraction of all-zero columns in the block (``zero_col_frac`` —
  feeds the :func:`repro.hwmodel.system.serve_energy` accounting),
* the same fraction per weight bit-slice plane (``plane_zero_frac``).

The metadata is plain hashable python data (nested tuples), so it rides
along as pytree *aux data* on :class:`repro.serve.cache.PackedLayer` and
as a static argument of the jitted Pallas kernel — it never enters a
trace and survives mesh re-placement untouched.

    >>> import numpy as np
    >>> w = np.zeros((4, 4)); w[:, 0] = 3          # column 0 dense
    >>> occ = column_occupancy(w, xbar_rows=2, n_w=4, block=2)
    >>> occ.n_tiles, occ.n_cols
    (2, 4)
    >>> occ.zero_blocks      # block 0 holds the dense column
    ((False, True), (False, True))
    >>> occ.zero_col_frac
    ((0.5, 1.0), (0.5, 1.0))
    >>> round(occ.mean_zero_fraction, 3)
    0.75
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import numpy as np

# metadata column-block width: matches the Pallas kernel's default
# block_o (and the TPU lane count), so one metadata block maps onto one
# kernel grid block in the common case
META_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class ColumnOccupancy:
    """Static per-(tile, column-block) zero-weight occupancy of one layer.

    Frozen + tuple-valued so instances are hashable (jit static args,
    pytree aux data) and comparable (pytree structure equality across
    mesh re-placement).
    """

    n_cols: int                                   # O of the packed layer
    n_tiles: int                                  # T = ceil(K / xbar_rows)
    n_w: int                                      # weight bit planes
    block: int                                    # metadata block width
    zero_blocks: Tuple[Tuple[bool, ...], ...]     # (T, NB)
    zero_col_frac: Tuple[Tuple[float, ...], ...]  # (T, NB)
    plane_zero_frac: Tuple[Tuple[Tuple[float, ...], ...], ...]  # (T,n_w,NB)

    @property
    def n_blocks(self) -> int:
        return math.ceil(self.n_cols / self.block)

    @property
    def mean_zero_fraction(self) -> float:
        """Fraction of (tile, column) pairs that are all-zero — the
        statically-skippable share of DCiM column events, fed to the
        energy model as its serve-time occupancy.

        Weighted by real columns per block (the last block may be
        ragged), so the figure is exact for any O.
        """
        total = zero = 0.0
        for t in range(self.n_tiles):
            for b in range(self.n_blocks):
                cols = min(self.block, self.n_cols - b * self.block)
                total += cols
                zero += self.zero_col_frac[t][b] * cols
        return zero / total if total else 0.0

    @property
    def skippable_block_fraction(self) -> float:
        """Fraction of (tile, block) kernel grid steps that skip the MXU."""
        flat = [f for row in self.zero_blocks for f in row]
        return sum(flat) / len(flat) if flat else 0.0

    def zero_blocks_np(self) -> np.ndarray:
        return np.asarray(self.zero_blocks, dtype=bool)

    def matches(self, n_cols: int, xbar_rows: int, k: int) -> bool:
        """True when this metadata describes a ``(k, n_cols)`` weight at
        the given tiling — the guard that keeps a tensor-parallel shard
        (local columns, global metadata) on the dense path."""
        return (self.n_cols == n_cols
                and self.n_tiles == math.ceil(k / xbar_rows))


def column_occupancy(
    w_int, *, xbar_rows: int, n_w: int, block: int = META_BLOCK
) -> ColumnOccupancy:
    """Derive :class:`ColumnOccupancy` from integer weight codes.

    ``w_int`` is the ``(K, O)`` two's-complement LSQ code matrix (any
    integer-valued array-like; concrete, not traced). A column is
    *zero in tile t* iff every one of its ``xbar_rows`` codes in that
    tile is 0 — equivalently every bit-slice plane is zero, which is why
    the whole-block flag licenses skipping every (stream, plane) matmul.

    >>> import numpy as np
    >>> occ = column_occupancy(np.zeros((8, 3)), xbar_rows=8, n_w=4)
    >>> occ.zero_blocks, occ.n_blocks
    (((True,),), 1)
    >>> occ.mean_zero_fraction, occ.skippable_block_fraction
    (1.0, 1.0)
    """
    w = np.asarray(w_int, dtype=np.float64)
    if w.ndim != 2:
        raise ValueError(f"column_occupancy needs a 2-D (K, O) weight, "
                         f"got shape {w.shape}")
    k, o = w.shape
    t = math.ceil(k / xbar_rows)
    kp = t * xbar_rows
    w = np.pad(w, ((0, kp - k), (0, 0))).reshape(t, xbar_rows, o)

    zero_cols = np.all(w == 0.0, axis=1)                       # (T, O)
    u = np.mod(w, float(2 ** n_w))
    plane_zero = np.stack(
        [np.all(np.mod(np.floor(u / 2.0 ** j), 2.0) == 0.0, axis=1)
         for j in range(n_w)], axis=1,
    )                                                          # (T, n_w, O)

    nb = math.ceil(o / block)
    zb, zf, pf = [], [], []
    for ti in range(t):
        zb_row, zf_row, pf_row = [], [], []
        for bi in range(nb):
            sl = slice(bi * block, min((bi + 1) * block, o))
            zb_row.append(bool(np.all(zero_cols[ti, sl])))
            zf_row.append(float(np.mean(zero_cols[ti, sl])))
            pf_row.append(tuple(
                float(np.mean(plane_zero[ti, j, sl])) for j in range(n_w)
            ))
        zb.append(tuple(zb_row))
        zf.append(tuple(zf_row))
        # store as (n_w, NB) per tile
        pf.append(tuple(
            tuple(pf_row[bi][j] for bi in range(nb)) for j in range(n_w)
        ))
    return ColumnOccupancy(
        n_cols=o, n_tiles=t, n_w=n_w, block=block,
        zero_blocks=tuple(zb), zero_col_frac=tuple(zf),
        plane_zero_frac=tuple(pf),
    )


def merge_occupancies(occs) -> Optional[ColumnOccupancy]:
    """Conservative intersection across scan-stacked layers.

    ``lax.scan`` slices a stacked :class:`~repro.serve.cache.PackedLayer`
    into per-layer views that all share ONE static metadata object, so
    the merged metadata must be safe for every layer: a block is
    skippable only when it is zero in **all** layers (logical AND), and
    the occupancy fractions are the per-layer minimum. Returns ``None``
    for an empty list, any ``None`` entry, or mismatched tilings.

    >>> import numpy as np
    >>> a = column_occupancy(np.zeros((4, 4)), xbar_rows=4, n_w=2, block=2)
    >>> b = np.zeros((4, 4)); b[:, 0] = 1
    >>> m = merge_occupancies([a, column_occupancy(b, xbar_rows=4, n_w=2,
    ...                                            block=2)])
    >>> m.zero_blocks                      # block 0 dense in layer b
    ((False, True),)
    >>> merge_occupancies([]) is None
    True
    """
    occs = list(occs)
    if not occs or any(o is None for o in occs):
        return None
    first = occs[0]
    key = (first.n_cols, first.n_tiles, first.n_w, first.block)
    if any((o.n_cols, o.n_tiles, o.n_w, o.block) != key for o in occs[1:]):
        return None
    zb = np.logical_and.reduce([o.zero_blocks_np() for o in occs])
    zf = np.minimum.reduce([np.asarray(o.zero_col_frac) for o in occs])
    pf = np.minimum.reduce([np.asarray(o.plane_zero_frac) for o in occs])
    return ColumnOccupancy(
        n_cols=first.n_cols, n_tiles=first.n_tiles, n_w=first.n_w,
        block=first.block,
        zero_blocks=tuple(tuple(bool(v) for v in row) for row in zb),
        zero_col_frac=tuple(tuple(float(v) for v in row) for row in zf),
        plane_zero_frac=tuple(
            tuple(tuple(float(v) for v in row) for row in plane)
            for plane in pf
        ),
    )


def shard_occupancy(
    occ: Optional[ColumnOccupancy], n_shards: int
) -> Optional[ColumnOccupancy]:
    """Re-slice global column metadata for an ``n_shards``-way column
    split, merged conservatively across shards.

    ``shard_map`` traces the tensor-parallel forward ONCE for every
    device (SPMD), so the per-shard static metadata must be a single
    object that is *safe for every shard*: shard ``s`` sees global
    columns ``[s*O/n, (s+1)*O/n)``, and the returned metadata marks a
    local block skippable only when the corresponding block is zero in
    ALL shards (logical AND; fractions are the per-shard minimum —
    exactly :func:`merge_occupancies` over the shard slices).

    Returns ``occ`` unchanged for ``n_shards <= 1`` and ``None`` (the
    dense path) when the split is not representable: columns that do
    not divide evenly, or a shard boundary that would cut through a
    metadata block.

    >>> import numpy as np
    >>> w = np.zeros((4, 8)); w[:, 0] = 1            # block 0 dense
    >>> occ = column_occupancy(w, xbar_rows=4, n_w=2, block=2)
    >>> s = shard_occupancy(occ, 2)                  # local O = 4
    >>> s.n_cols, s.zero_blocks
    (4, ((False, True),))
    >>> shard_occupancy(occ, 3) is None              # 8 % 3 != 0
    True
    """
    if occ is None or n_shards <= 1:
        return occ
    if occ.n_cols % n_shards:
        return None
    o_local = occ.n_cols // n_shards
    if o_local % occ.block:
        return None           # a shard boundary would split a block
    nbl = o_local // occ.block
    shards = []
    for s in range(n_shards):
        sl = slice(s * nbl, (s + 1) * nbl)
        shards.append(ColumnOccupancy(
            n_cols=o_local, n_tiles=occ.n_tiles, n_w=occ.n_w,
            block=occ.block,
            zero_blocks=tuple(row[sl] for row in occ.zero_blocks),
            zero_col_frac=tuple(row[sl] for row in occ.zero_col_frac),
            plane_zero_frac=tuple(
                tuple(p[sl] for p in plane)
                for plane in occ.plane_zero_frac
            ),
        ))
    return merge_occupancies(shards)


def kernel_block_flags(
    occ: ColumnOccupancy, block_o: int, o_pad: int
) -> np.ndarray:
    """Align metadata blocks to a kernel's column grid: int32 (T, O_pad/BO).

    A kernel grid block is skippable iff **every** metadata block it
    overlaps is all-zero (conservative when widths disagree); blocks
    past the real column count are pure padding and always skippable.

    >>> import numpy as np
    >>> occ = column_occupancy(np.zeros((4, 100)), xbar_rows=4, n_w=4)
    >>> kernel_block_flags(occ, 128, 128)
    array([[1]], dtype=int32)
    """
    zb = occ.zero_blocks_np()                    # (T, NB) at width occ.block
    n_ob = o_pad // block_o
    flags = np.zeros((occ.n_tiles, n_ob), np.int32)
    for oi in range(n_ob):
        lo = oi * block_o
        hi = min(lo + block_o, occ.n_cols)
        if lo >= occ.n_cols:
            flags[:, oi] = 1                     # padding-only block
            continue
        b0 = lo // occ.block
        b1 = math.ceil(hi / occ.block)
        flags[:, oi] = np.all(zb[:, b0:b1], axis=1)
    return flags


def occupancy_for_kernel(
    occ: Optional[ColumnOccupancy], n_cols: int, k: int, xbar_rows: int
) -> Optional[ColumnOccupancy]:
    """Validate metadata against the actual kernel operands.

    Returns ``occ`` when it describes this ``(k, n_cols)`` problem and
    has at least one skippable block; ``None`` otherwise (dense path) —
    notably under tensor parallelism, where each shard sees local
    columns but the replicated metadata still describes the global O.
    """
    if occ is None or not occ.matches(n_cols, xbar_rows, k):
        return None
    if not any(any(row) for row in occ.zero_blocks):
        return None
    return occ
