"""Pure-jnp oracles for the Pallas kernels (bit-exact, no tiling tricks).

These mirror the kernels' integer I/O contracts exactly; the QAT-level
semantics (LSQ quantizers, STE) live in :mod:`repro.core.psq` and have
their own materialized reference there.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import bit_weights
from repro.kernels.occupancy import ColumnOccupancy, occupancy_for_kernel


def psq_matmul_ref(
    x_int: jax.Array,        # (B, K) integer-valued f32
    w_int: jax.Array,        # (K, O)
    sf_q: jax.Array,         # broadcastable to (T, n_a, n_w, O)
    alpha: jax.Array,        # ()
    *,
    n_a: int,
    n_w: int,
    levels: str,
    adc_bits: int = 7,
    xbar_rows: int = 128,
    occupancy: Optional[ColumnOccupancy] = None,
) -> jax.Array:
    """Oracle for :func:`repro.kernels.psq_matmul.psq_matmul_kernel`.

    ``occupancy`` (pack-time metadata, see :mod:`repro.kernels.occupancy`)
    enables the sparsity-skipping path: partial sums are only computed for
    (tile, column-block) pairs whose weight slab is not all-zero. Skipped
    pairs keep their exact value — ``ps = 0`` by construction — so the
    result is bit-identical to the dense path (partial sums of {0,1}
    products are exact integers in f32; no rounding depends on the
    evaluation order).
    """
    b, k = x_int.shape
    o = w_int.shape[1]
    r = xbar_rows
    t = math.ceil(k / r)
    kp = t * r
    x = jnp.pad(x_int, ((0, 0), (0, kp - k))).reshape(b, t, r)
    w = jnp.pad(w_int, ((0, kp - k), (0, 0))).reshape(t, r, o)

    u_x = jnp.mod(x, 2.0 ** n_a)
    u_w = jnp.mod(w, 2.0 ** n_w)
    xbits = jnp.stack(
        [jnp.mod(jnp.floor(u_x / 2.0 ** j), 2.0) for j in range(n_a)]
    )  # (n_a, B, T, R)
    wbits = jnp.stack(
        [jnp.mod(jnp.floor(u_w / 2.0 ** kk), 2.0) for kk in range(n_w)]
    )  # (n_w, T, R, O)
    occ = occupancy_for_kernel(occupancy, o, k, xbar_rows)
    if occ is None:
        ps = jnp.einsum("jbtr,ktro->jkbto", xbits, wbits,
                        precision=jax.lax.Precision.HIGHEST)
    else:
        # sparsity skip: scatter per-tile partial sums over the NON-zero
        # columns only; all-zero columns keep the exact ps = 0 they would
        # have computed. The metadata is static (host numpy), so column
        # index sets are compile-time constants under jit.
        zb = occ.zero_blocks_np()
        col_block = np.arange(o) // occ.block
        ps = jnp.zeros((n_a, n_w, b, t, o), jnp.float32)
        for ti in range(t):
            cols = np.nonzero(~zb[ti][col_block])[0]
            if cols.size == 0:
                continue
            ps_t = jnp.einsum(
                "jbr,kro->jkbo", xbits[:, :, ti, :], wbits[:, ti, :, :][..., cols],
                precision=jax.lax.Precision.HIGHEST,
            )
            ps = ps.at[:, :, :, ti, cols].set(ps_t)
    sigma = bit_weights(n_a)
    kappa = bit_weights(n_w)

    if levels == "adc":
        step = max(1.0, xbar_rows / float(2 ** adc_bits))
        qmax = float(2 ** adc_bits - 1)
        code = jnp.clip(jnp.floor(ps / step + 0.5), 0.0, qmax)
        return jnp.einsum("j,k,jkbto->bo", sigma, kappa, code * step)

    rowsum = jnp.sum(xbits, axis=-1)                    # (n_a, B, T)
    a = 2.0 * ps - rowsum[:, None, :, :, None]
    if levels == "ternary":
        al = jnp.maximum(alpha, 1e-6)
        p = jnp.where(a >= al, 1.0, jnp.where(a <= -al, -1.0, 0.0))
    else:
        p = jnp.where(a >= 0.0, 1.0, -1.0)
    sf_full = jnp.broadcast_to(sf_q, (t, n_a, n_w, o))
    y = 0.5 * jnp.einsum("j,k,jkbto,tjko->bo", sigma, kappa, p, sf_full)
    # static two's-complement offset (== jnp.sum(kappa), but jit-safe)
    c_w = sum(2.0 ** k for k in range(n_w - 1)) - 2.0 ** (n_w - 1)
    return y + 0.5 * c_w * jnp.sum(x_int, axis=-1, keepdims=True)


def int4_matmul_ref(
    w_packed: jax.Array,     # (K//2, O) int8, two 4-bit codes per byte
    scale: jax.Array,        # (O,) or (K//group, O) dequant scales
    x: jax.Array,            # (B, K) activations
) -> jax.Array:
    """Oracle for the weight-stationary int4 decode matmul."""
    kk, o = w_packed.shape
    w8 = w_packed.astype(jnp.int32)
    lo = w8 & 0xF
    hi = (w8 >> 4) & 0xF
    lo = lo - 16 * (lo >= 8)
    hi = hi - 16 * (hi >= 8)
    # packed row r holds original rows 2r (low nibble) and 2r+1 (high)
    w_int = jnp.stack([lo, hi], axis=1).reshape(2 * kk, o).astype(jnp.float32)
    if scale.ndim == 1:
        w_deq = w_int * scale[None, :]
    else:
        group = (2 * kk) // scale.shape[0]
        w_deq = w_int * jnp.repeat(scale, group, axis=0)
    return jnp.dot(x.astype(jnp.float32), w_deq,
                   precision=jax.lax.Precision.HIGHEST)
