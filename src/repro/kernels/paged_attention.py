"""Paged decode attention: block-table indirection inside the kernel.

Single-token decode attention against the paged KV pool
(``models/decode.paged_cache_init``): each query attends its slot's
pages through a block table instead of a contiguous stripe. Two
implementations share one contract (registered through
``kernels/registry.py`` as the ``paged_attention`` entry point):

``paged_attention_ref``
  Pure-jnp oracle — gathers the slot's pages into the contiguous view
  and runs exactly the concat-new-column softmax of
  ``models.attention.decode_attention``, so it is bit-compatible with
  the contiguous decode path. Conformance baseline.

``paged_attention_kernel``
  Pallas kernel, grid ``(batch, kv_head)``: each program walks its
  slot's block table with an online-softmax ``fori_loop`` — one page of
  K/V live at a time, never materializing the gathered
  ``(B, max_len, H_kv, D)`` view — then folds the new token's K/V in as
  a final column. ``interpret=True`` runs anywhere (the CI path);
  compiled mode is the TPU/GPU serving fast path.

Contract (all backends)::

  paged_attention(q, k_pool, v_pool, block_tables, lengths,
                  k_new, v_new) -> ctx

  q            (B, H, D)       this step's queries, RoPE applied
  k/v_pool     (NB, bs, Hk, D) ONE layer's page pool
  block_tables (B, MB) int32   page ids, sequence order (0 = trash page)
  lengths      (B,)    int32   per-slot token counts (past tokens only)
  k/v_new      (B, Hk, D)      this token's K/V (enters the softmax as
                               an explicit extra column, NOT yet in the
                               pool — the caller commits it after the
                               layer scan)
  ctx          (B, H, D)

Sliding-window attention is not part of the kernel contract — the
gather-based inline path in ``models/decode.decode_step_paged`` handles
windowed families.

    >>> import jax, jax.numpy as jnp
    >>> q = jnp.ones((2, 4, 8)); kn = jnp.ones((2, 2, 8))
    >>> pool = jnp.zeros((5, 4, 2, 8))
    >>> bt = jnp.zeros((2, 3), jnp.int32)
    >>> lengths = jnp.zeros((2,), jnp.int32)
    >>> paged_attention_ref(q, pool, pool, bt, lengths, kn, kn).shape
    (2, 4, 8)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, block_tables, lengths,
                        k_new, v_new) -> jax.Array:
    """Gather-based oracle, decode-attention math (see module contract)."""
    b, h, d = q.shape
    nb, bs, hk, _ = k_pool.shape
    g = h // hk
    mb = block_tables.shape[1]
    kg = k_pool[block_tables].reshape(b, mb * bs, hk, d)
    vg = v_pool[block_tables].reshape(b, mb * bs, hk, d)
    qh = q.reshape(b, 1, hk, g, d)
    logits = jnp.einsum(
        "bskgd,btkd->bkgst", qh.astype(kg.dtype), kg,
        preferred_element_type=jnp.float32,
    )
    kpos = jnp.arange(mb * bs)
    valid = kpos[None, :] < lengths[:, None]
    logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
    logit_new = jnp.einsum(
        "bskgd,btkd->bkgst", qh.astype(k_new.dtype), k_new[:, None],
        preferred_element_type=jnp.float32,
    )
    scale = 1.0 / math.sqrt(d)
    full = jnp.concatenate([logits, logit_new], axis=-1) * scale
    probs = jax.nn.softmax(full.astype(jnp.float32), axis=-1)
    p_past, p_new = probs[..., :-1], probs[..., -1:]
    ctx = jnp.einsum(
        "bkgst,btkd->bskgd", p_past.astype(kg.dtype), vg,
        preferred_element_type=jnp.float32,
    ) + jnp.einsum(
        "bkgst,btkd->bskgd", p_new.astype(v_new.dtype), v_new[:, None],
        preferred_element_type=jnp.float32,
    )
    return ctx.astype(q.dtype).reshape(b, h, d)


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
                  o_ref, *, bs: int, scale: float):
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
    g, d = q.shape
    bt_row = bt_ref[0]                                   # (MB,)
    length = len_ref[0]
    n_iter = (length + bs - 1) // bs

    def body(i, carry):
        m, l, acc = carry
        blk = bt_row[i]
        # one page of this program's kv head, streamed through VMEM
        k = pl.load(
            k_ref, (pl.ds(blk, 1), slice(None), pl.ds(0, 1), slice(None))
        )[0, :, 0]                                       # (bs, D)
        v = pl.load(
            v_ref, (pl.ds(blk, 1), slice(None), pl.ds(0, 1), slice(None))
        )[0, :, 0]
        logits = jax.lax.dot_general(
            q, k.astype(jnp.float32), (((1,), (1,)), ((), ()))
        )                                                # (G, bs)
        pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
        logits = jnp.where(pos < length, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l * corr + jnp.sum(p, axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    a0 = jnp.zeros((g, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_iter, body, (m0, l0, a0))

    # the new token's own K/V as the final online-softmax column
    kn = kn_ref[0, 0, 0].astype(jnp.float32)             # (D,)
    vn = vn_ref[0, 0, 0].astype(jnp.float32)
    col = q @ kn                                         # (G,)
    m2 = jnp.maximum(m, col)
    corr = jnp.exp(m - m2)
    p_new = jnp.exp(col - m2)
    l2 = l * corr + p_new
    acc2 = acc * corr[:, None] + p_new[:, None] * vn[None, :]
    o_ref[0, 0] = (acc2 / jnp.maximum(l2, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_kernel(q, k_pool, v_pool, block_tables, lengths,
                           k_new, v_new, *, interpret: bool = True
                           ) -> jax.Array:
    """Pallas paged attention (see module contract)."""
    b, h, d = q.shape
    nb, bs, hk, _ = k_pool.shape
    g = h // hk
    mb = block_tables.shape[1]
    q4 = q.reshape(b, hk, g, d)
    kn4 = k_new.reshape(b, hk, 1, d)
    vn4 = v_new.reshape(b, hk, 1, d)
    out = pl.pallas_call(
        functools.partial(_paged_kernel, bs=bs, scale=1.0 / math.sqrt(d)),
        grid=(b, hk),
        in_specs=[
            pl.BlockSpec((1, mb), lambda i, j: (i, 0)),          # tables
            pl.BlockSpec((1,), lambda i, j: (i,)),               # lengths
            pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((nb, bs, 1, d), lambda i, j: (0, 0, j, 0)),
            pl.BlockSpec((nb, bs, 1, d), lambda i, j: (0, 0, j, 0)),
            pl.BlockSpec((1, 1, 1, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, 1, d), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q4, k_pool, v_pool, kn4, vn4)
    return out.reshape(b, h, d)
