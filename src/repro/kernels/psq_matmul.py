"""Pallas TPU kernel for the HCiM crossbar datapath (paper §4.2).

TPU adaptation of the analog-crossbar + comparator + DCiM pipeline:

* the K (reduction) dimension is blocked by ``xbar_rows`` — one grid step
  along the last grid axis corresponds to one analog crossbar tile;
* input bit-streams / weight bit-slices are extracted in VREGs
  (floor/mod on integer-valued f32 — cheap VPU work);
* each (stream j, slice k) pair issues one MXU matmul on {0,1} bit
  matrices (bf16 operands, f32 accumulation — exact for sums ≤ 256);
* the comparator and the DCiM scale-factor accumulate
  ``acc += 0.5 * kappa_k * sigma_j * p * s_q`` are fused elementwise ops
  on the matmul result while it is still in VMEM/VREGs — this is the
  TPU-native analogue of performing the scale-factor math *in memory*
  next to the partial sums (no HBM round-trip for ps / p / s);
* crossbar tiles accumulate into the output block across the innermost
  grid axis (digital shift-add across crossbars).

The kernel computes values only (inference / deployment path). QAT
gradients are attached in :mod:`repro.kernels.ops` via a custom VJP whose
backward pass reuses the jnp reference semantics.

Optimized variant (``fuse_planes=True``, a beyond-paper optimization
recorded in EXPERIMENTS.md §Perf): all ``n_a × n_w`` bit-plane pairs are
evaluated by a single MXU call on an ``(n_a·BB, R) x (R, n_w·BO)``
operand pair, turning 16 skinny matmuls into one large one (better MXU
occupancy at identical FLOPs).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.occupancy import (
    ColumnOccupancy,
    kernel_block_flags,
    occupancy_for_kernel,
)


def _py_bit_weights(n: int):
    """Two's-complement plane significances as static python floats."""
    w = [float(2 ** k) for k in range(n)]
    w[-1] = -float(2 ** (n - 1))
    return w


def _extract_bit(u: jax.Array, k: int) -> jax.Array:
    return jnp.mod(jnp.floor(u / float(2 ** k)), 2.0)


def _comparator(a, alpha, levels: str):
    if levels == "ternary":
        return jnp.where(a >= alpha, 1.0, jnp.where(a <= -alpha, -1.0, 0.0))
    return jnp.where(a >= 0.0, 1.0, -1.0)


def _psq_kernel(
    alpha_ref,
    z_ref,
    x_ref,
    w_ref,
    sf_ref,
    o_ref,
    *,
    n_a: int,
    n_w: int,
    levels: str,
    adc_bits: int,
    xbar_rows: int,
    fuse_planes: bool,
    sparsity_skip: bool,
):
    t = pl.program_id(2)
    x = x_ref[...].astype(jnp.float32)       # (BB, R) integer-valued
    alpha = alpha_ref[0, 0]
    sigma = _py_bit_weights(n_a)             # python floats: static constants
    kappa = _py_bit_weights(n_w)
    c_w = sum(kappa)

    bb, r = x.shape
    bo = o_ref.shape[1]
    u_x = jnp.mod(x, float(2 ** n_a))

    def _dense_acc():
        w = w_ref[...].astype(jnp.float32)   # (R, BO)
        u_w = jnp.mod(w, float(2 ** n_w))
        if levels == "adc":
            step = max(1.0, xbar_rows / float(2 ** adc_bits))
            qmax = float(2 ** adc_bits - 1)
            acc = jnp.zeros((bb, bo), jnp.float32)
            for j in range(n_a):
                xb = _extract_bit(u_x, j).astype(jnp.bfloat16)
                for k in range(n_w):
                    wb = _extract_bit(u_w, k).astype(jnp.bfloat16)
                    ps = jax.lax.dot(xb, wb, preferred_element_type=jnp.float32)
                    code = jnp.clip(
                        jnp.sign(ps) * jnp.floor(jnp.abs(ps) / step + 0.5),
                        0.0, qmax,
                    )
                    acc += (float(sigma[j]) * float(kappa[k]) * step) * code
            return acc
        if fuse_planes:
            # one (n_a*BB, R) x (R, n_w*BO) MXU pass for all bit-plane pairs
            xb_all = jnp.concatenate(
                [_extract_bit(u_x, j) for j in range(n_a)], axis=0
            ).astype(jnp.bfloat16)                           # (n_a*BB, R)
            wb_all = jnp.concatenate(
                [_extract_bit(u_w, k) for k in range(n_w)], axis=1
            ).astype(jnp.bfloat16)                           # (R, n_w*BO)
            ps_all = jax.lax.dot(xb_all, wb_all,
                                 preferred_element_type=jnp.float32)
            rows_all = jnp.sum(xb_all.astype(jnp.float32), axis=1,
                               keepdims=True)
            acc = jnp.zeros((bb, bo), jnp.float32)
            for j in range(n_a):
                ps_j = ps_all[j * bb:(j + 1) * bb]
                rs_j = rows_all[j * bb:(j + 1) * bb]
                for k in range(n_w):
                    a = 2.0 * ps_j[:, k * bo:(k + 1) * bo] - rs_j
                    p = _comparator(a, alpha, levels)
                    sf = sf_ref[0, j, k, :].astype(jnp.float32)
                    acc += (0.5 * float(sigma[j]) * float(kappa[k])) * p * sf[None, :]
            acc += 0.5 * c_w * jnp.sum(x, axis=1, keepdims=True)
            return acc
        acc = jnp.zeros((bb, bo), jnp.float32)
        for j in range(n_a):
            xb = _extract_bit(u_x, j)
            rowsum = jnp.sum(xb, axis=1, keepdims=True)
            xb16 = xb.astype(jnp.bfloat16)
            for k in range(n_w):
                wb = _extract_bit(u_w, k).astype(jnp.bfloat16)
                ps = jax.lax.dot(xb16, wb, preferred_element_type=jnp.float32)
                a = 2.0 * ps - rowsum
                p = _comparator(a, alpha, levels)
                sf = sf_ref[0, j, k, :].astype(jnp.float32)
                acc += (0.5 * float(sigma[j]) * float(kappa[k])) * p * sf[None, :]
        # unipolar->bipolar digital correction, this tile's rows only
        acc += 0.5 * c_w * jnp.sum(x, axis=1, keepdims=True)
        return acc

    def _skip_acc():
        # All-zero weight block (pack-time occupancy metadata): every
        # partial sum is exactly 0, so the comparator input collapses to
        # ``-rowsum`` — no MXU work. Each op below mirrors the dense
        # branch on ``ps = 0`` verbatim (same values, same accumulation
        # order), so the result is bit-identical to dense execution.
        acc = jnp.zeros((bb, bo), jnp.float32)
        for j in range(n_a):
            xb = _extract_bit(u_x, j)
            rowsum = jnp.sum(xb, axis=1, keepdims=True)
            a0 = 0.0 - rowsum                  # == 2.0 * ps - rowsum, ps = 0
            p0 = _comparator(a0, alpha, levels)
            for k in range(n_w):
                sf = sf_ref[0, j, k, :].astype(jnp.float32)
                acc += (0.5 * float(sigma[j]) * float(kappa[k])) * p0 * sf[None, :]
        acc += 0.5 * c_w * jnp.sum(x, axis=1, keepdims=True)
        return acc

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    if not sparsity_skip:
        o_ref[...] += _dense_acc()
    elif levels == "adc":
        # a zero block contributes an exact 0 under ADC quantization:
        # skipping is simply not accumulating
        @pl.when(z_ref[0, 0] == 0)
        def _adc_dense():
            o_ref[...] += _dense_acc()
    else:
        flag = z_ref[0, 0]

        @pl.when(flag == 0)
        def _dense():
            o_ref[...] += _dense_acc()

        @pl.when(flag != 0)
        def _skip():
            o_ref[...] += _skip_acc()


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_a", "n_w", "levels", "adc_bits", "xbar_rows",
        "block_b", "block_o", "fuse_planes", "occupancy", "interpret",
    ),
)
def psq_matmul_kernel(
    x_int: jax.Array,        # (B, K) integer-valued f32
    w_int: jax.Array,        # (K, O) integer-valued f32
    sf_q: jax.Array,         # (T, n_a, n_w, O) dequantized fixed-point SFs
    alpha: jax.Array,        # () ternary threshold
    *,
    n_a: int,
    n_w: int,
    levels: str,             # ternary | binary | adc
    adc_bits: int = 7,
    xbar_rows: int = 128,
    block_b: int = 128,
    block_o: int = 128,
    fuse_planes: bool = False,
    occupancy: Optional[ColumnOccupancy] = None,
    interpret: bool = True,
) -> jax.Array:
    """Quantized integer output ``y_int_q`` (B, O) of the HCiM pipeline.

    ``occupancy`` (hashable pack-time metadata, hence a jit static arg)
    enables the sparsity-skipping path: each ``(tile, column-block)``
    grid step whose weight slab is all-zero takes the cheap comparator
    branch instead of the ``n_a x n_w`` MXU pass — bit-identical output
    by construction (see :mod:`repro.kernels.occupancy`).
    """
    b, k = x_int.shape
    o = w_int.shape[1]
    r = xbar_rows
    t = math.ceil(k / r)

    bb = min(block_b, _ceil_to(b, 8))
    bo = min(block_o, _ceil_to(o, 128))
    b_pad = _ceil_to(b, bb)
    o_pad = _ceil_to(o, bo)
    k_pad = t * r

    occ = occupancy_for_kernel(occupancy, o, k, xbar_rows)
    sparsity_skip = occ is not None
    if sparsity_skip:
        flags_np = kernel_block_flags(occ, bo, o_pad)      # (T, O_pad/BO)
    else:
        flags_np = np.zeros((t, o_pad // bo), np.int32)

    x_p = jnp.pad(x_int, ((0, b_pad - b), (0, k_pad - k)))
    w_p = jnp.pad(w_int, ((0, k_pad - k), (0, o_pad - o)))
    # reduced scale-factor granularities broadcast up to full column shape
    sf_full = jnp.broadcast_to(sf_q, (t, n_a, n_w, o))
    sf_p = jnp.pad(sf_full, ((0, 0), (0, 0), (0, 0), (0, o_pad - o)))
    alpha_arr = jnp.reshape(alpha, (1, 1)).astype(jnp.float32)
    z_arr = jnp.asarray(flags_np)

    grid = (b_pad // bb, o_pad // bo, t)
    out = pl.pallas_call(
        functools.partial(
            _psq_kernel,
            n_a=n_a,
            n_w=n_w,
            levels=levels,
            adc_bits=adc_bits,
            xbar_rows=r,
            fuse_planes=fuse_planes,
            sparsity_skip=sparsity_skip,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, oi, ti: (0, 0)),
            pl.BlockSpec((1, 1), lambda bi, oi, ti: (ti, oi)),
            pl.BlockSpec((bb, r), lambda bi, oi, ti: (bi, ti)),
            pl.BlockSpec((r, bo), lambda bi, oi, ti: (ti, oi)),
            pl.BlockSpec((1, n_a, n_w, bo), lambda bi, oi, ti: (ti, 0, 0, oi)),
        ],
        out_specs=pl.BlockSpec((bb, bo), lambda bi, oi, ti: (bi, oi)),
        out_shape=jax.ShapeDtypeStruct((b_pad, o_pad), jnp.float32),
        interpret=interpret,
    )(alpha_arr, z_arr, x_p, w_p, sf_p)
    return out[:b, :o]
