"""Device library for the HCiM energy/latency/area model (paper §5.1).

All component numbers are the paper's own (Table 3 and §5.1 citations);
where the paper relies on a cited value without printing it (shift-and-add
unit, comparator energy, crossbar MVM energy, SRAM buffer access) we adopt
the cited sources' canonical numbers and mark them ``calibrated`` — the
calibration targets are the paper's *reported ratios* (28x / 12x energy vs
7-/4-bit ADC, ternary >= 15 % below binary, 24 % DCiM energy drop at 50 %
sparsity), not free fits per figure.

Units: energy pJ, latency ns, area mm^2. 65 nm unless noted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class ColumnPeripheral:
    """Whatever digitizes/processes one crossbar column's partial sum."""

    name: str
    bits: float            # effective ADC precision (1.5 == ternary)
    latency_ns: float      # per column conversion/processing (Table 3)
    energy_pj: float       # per column event (Table 3)
    area_mm2: float        # per instance (Table 3)
    per_xbar: int = 1      # instances per crossbar (paper: 1 ADC / 1 DCiM)


# --- Table 3 (65 nm) -------------------------------------------------------
ADC_SAR_7B = ColumnPeripheral("sar7", 7, 1.52, 4.10, 0.004)    # [8] area-opt
ADC_SAR_6B = ColumnPeripheral("sar6", 6, 0.15, 0.59, 0.027)    # [9] energy-eff
ADC_FLASH_4B = ColumnPeripheral("flash4", 4, 0.05, 1.86, 0.003)  # [11]
DCIM_A = ColumnPeripheral("dcim_a", 1.5, 0.06, 0.22, 0.009)    # 24x128
DCIM_B = ColumnPeripheral("dcim_b", 1.5, 0.10, 0.22, 0.005)    # 24x64

ADCS: Dict[int, ColumnPeripheral] = {7: ADC_SAR_7B, 6: ADC_SAR_6B, 4: ADC_FLASH_4B}


@dataclasses.dataclass(frozen=True)
class HwParams:
    """System-level constants (PUMA [4] components + cited sources)."""

    # -- analog crossbar (8T SRAM charge-based, Ali et al. [3]) --
    # Bare-array charge-based MAC at 65 nm is ~1 fJ per 1b x 1b event;
    # the premise of the paper (and [23]: "ADCs consume 60 % energy /
    # 80 % area") is that column conversion, not the analog MVM,
    # dominates. Calibrated jointly with sna_energy so the baseline
    # reproduces Fig. 1 (15x vs 7-bit ADC system) and Fig. 6 ("at least
    # 3x on average vs all baselines").
    xbar_mac_energy_pj: float = 0.0008        # per (row x col x stream) MAC
    xbar_read_latency_ns: float = 2.0         # one bit-stream crossbar evaluation
    xbar_area_mm2: float = 0.0015             # 128x128 8T array + drivers
    # -- input drivers (1-bit streaming, no DAC needed at bit-stream=1) --
    driver_energy_pj_per_row: float = 0.002
    # -- digital shift-and-add tree behind ADCs (PUMA S&A unit) --
    sna_energy_pj: float = 0.18               # per column event   [calibrated]
    sna_area_mm2: float = 0.0002
    # -- latch comparator for binary/ternary readout (Bindra et al. [7]) --
    comparator_energy_pj: float = 0.01        # per compare         [7]
    comparator_area_mm2: float = 0.0001       # per comparator      [7]
    comparator_latency_ns: float = 0.05
    # -- on-chip SRAM buffer access (for the no-DCiM strawman: scale
    #    factors fetched per use instead of living in the DCiM array) --
    sram_access_pj_per_byte: float = 1.2      # 64 kB SRAM @65 nm
    # -- digital multiplier (Quarry-style scale-factor processing, PUMA) --
    mult_energy_pj: float = 0.6               # 8x8 mult            [4]
    # -- inter-tile partial-sum movement (shared bus, per 16-bit word) --
    ps_move_energy_pj: float = 0.2
    # -- DCiM array internals (§4.2, 10T SRAM, 500 MHz @ 1 V) --
    dcim_clock_ghz: float = 0.5
    dcim_pipeline_depth: int = 3              # Read-Compute-Store (Fig. 4)
    # fraction of DCiM column energy that sparsity gating cannot remove
    # (clocking/control/RWL); chosen so 50 % sparsity -> 24 % energy drop
    # as measured in Fig. 5(a).
    dcim_fixed_energy_frac: float = 0.52


DEFAULT_HW = HwParams()


# --- technology scaling (Stillmaker & Baas [26]) ---------------------------
# 65 nm -> 32 nm general-purpose scaling, as applied by the paper to put
# Table-3 components next to PUMA's 32 nm system numbers.
SCALE_65_TO_32 = {
    "energy": 0.24,   # ~ (32/65)^2 capacitance/voltage scaling
    "latency": 0.53,  # gate-delay scaling
    "area": 0.24,
}


def scale_peripheral(p: ColumnPeripheral, factors=None) -> ColumnPeripheral:
    f = factors or SCALE_65_TO_32
    return dataclasses.replace(
        p,
        latency_ns=p.latency_ns * f["latency"],
        energy_pj=p.energy_pj * f["energy"],
        area_mm2=p.area_mm2 * f["area"],
    )
