"""Workload layer shapes for the paper's benchmark suite (§5.1).

CIFAR-10: ResNet-20/32/44 [16], Wide-ResNet-20 [25], VGG-9/11 [1].
ImageNet: ResNet-18 (for the Fig. 5(b) EDAP comparison).
Convolutions map to crossbars via im2col: K = kh*kw*cin, one input
vector per output spatial position.
"""
from __future__ import annotations

from typing import List

from repro.hwmodel.system import LayerShape


def _conv(name, cin, cout, hw_out, k=3) -> LayerShape:
    return LayerShape(name, k * k * cin, cout, hw_out * hw_out)


def _fc(name, k, o) -> LayerShape:
    return LayerShape(name, k, o, 1)


def resnet_cifar(n_per_stage: int, widths=(16, 32, 64), name="resnet") -> List[LayerShape]:
    """6n+2 CIFAR ResNet: 3 stages at 32/16/8 spatial resolution."""
    w1, w2, w3 = widths
    layers = [_conv(f"{name}.conv1", 3, w1, 32)]
    for i in range(n_per_stage * 2):
        layers.append(_conv(f"{name}.s1.{i}", w1, w1, 32))
    layers.append(_conv(f"{name}.s2.0", w1, w2, 16))
    layers.append(LayerShape(f"{name}.s2.ds", w1, w2, 16 * 16))  # 1x1 downsample
    for i in range(1, n_per_stage * 2):
        layers.append(_conv(f"{name}.s2.{i}", w2, w2, 16))
    layers.append(_conv(f"{name}.s3.0", w2, w3, 8))
    layers.append(LayerShape(f"{name}.s3.ds", w2, w3, 8 * 8))
    for i in range(1, n_per_stage * 2):
        layers.append(_conv(f"{name}.s3.{i}", w3, w3, 8))
    layers.append(_fc(f"{name}.fc", w3, 10))
    return layers


def resnet20() -> List[LayerShape]:
    return resnet_cifar(3, name="resnet20")


def resnet32() -> List[LayerShape]:
    return resnet_cifar(5, name="resnet32")


def resnet44() -> List[LayerShape]:
    return resnet_cifar(7, name="resnet44")


def wide_resnet20() -> List[LayerShape]:
    """Wide ResNet-20 as used by [25] (4x width multiplier)."""
    return resnet_cifar(3, widths=(64, 128, 256), name="wrn20")


def vgg9() -> List[LayerShape]:
    """CIFAR VGG-9 following the d-psgd reference configs [1]."""
    return [
        _conv("vgg9.c1", 3, 64, 32),
        _conv("vgg9.c2", 64, 64, 32),
        _conv("vgg9.c3", 64, 128, 16),
        _conv("vgg9.c4", 128, 128, 16),
        _conv("vgg9.c5", 128, 256, 8),
        _conv("vgg9.c6", 256, 256, 8),
        _fc("vgg9.fc1", 256 * 4 * 4, 512),
        _fc("vgg9.fc2", 512, 10),
    ]


def vgg11() -> List[LayerShape]:
    """VGG-11 (config A) adapted to 32x32 inputs."""
    return [
        _conv("vgg11.c1", 3, 64, 32),
        _conv("vgg11.c2", 64, 128, 16),
        _conv("vgg11.c3", 128, 256, 8),
        _conv("vgg11.c4", 256, 256, 8),
        _conv("vgg11.c5", 256, 512, 4),
        _conv("vgg11.c6", 512, 512, 4),
        _conv("vgg11.c7", 512, 512, 2),
        _conv("vgg11.c8", 512, 512, 2),
        _fc("vgg11.fc1", 512, 512),
        _fc("vgg11.fc2", 512, 10),
    ]


def resnet18_imagenet() -> List[LayerShape]:
    L = [LayerShape("r18.conv1", 7 * 7 * 3, 64, 112 * 112)]
    plan = [(64, 64, 56, 4), (64, 128, 28, 4), (128, 256, 14, 4), (256, 512, 7, 4)]
    for idx, (cin, cout, sp, n) in enumerate(plan):
        L.append(_conv(f"r18.s{idx}.0", cin, cout, sp))
        if cin != cout:
            L.append(LayerShape(f"r18.s{idx}.ds", cin, cout, sp * sp))
        for i in range(1, n):
            L.append(_conv(f"r18.s{idx}.{i}", cout, cout, sp))
    L.append(_fc("r18.fc", 512, 1000))
    return L


WORKLOADS = {
    "resnet20": resnet20,
    "resnet32": resnet32,
    "resnet44": resnet44,
    "wrn20": wide_resnet20,
    "vgg9": vgg9,
    "vgg11": vgg11,
    "resnet18_imagenet": resnet18_imagenet,
}
