"""PUMA-style system-level energy/latency/area accounting (paper §5).

Counts crossbar, peripheral and data-movement events for a workload's
layer shapes, exactly the way the paper's cycle-accurate comparison is
set up: weight-stationary crossbars (weights and scale factors pre-loaded
and reused), one ADC *or* one DCiM array per analog crossbar, inputs
bit-streamed, batch-1 inference.

Three system styles are modeled:
  * ``adc``    — analog CiM baseline with a b-bit ADC + shift-and-add.
  * ``quarry`` — PSQ-trained net, 1/1.5-bit comparator readout, but scale
                 factors fetched from SRAM and applied in digital
                 multipliers (Quarry [6]-style; the strawman motivating
                 Fig. 2(c)).
  * ``hcim``   — this paper: comparator readout + in-memory DCiM
                 scale-factor add/sub with ternary sparsity gating.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Union

from repro.hwmodel import dcim as dcim_mod
from repro.hwmodel.devices import (
    ADCS,
    ColumnPeripheral,
    DEFAULT_HW,
    HwParams,
    scale_peripheral,
)


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One MVM layer: y[o] += sum_k x[k] w[k,o], evaluated n_vec times."""

    name: str
    k: int        # reduction dim (im2col: kh*kw*cin)
    o: int        # output channels
    n_vec: int    # input vectors per inference (conv: H_out*W_out)


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    style: str                    # adc | quarry | hcim
    xbar_rows: int = 128          # crossbar geometry (square, config A/B)
    n_bits_a: int = 4
    n_bits_w: int = 4
    n_bits_sf: int = 4
    adc_bits: int = 7             # for style == "adc"
    levels: str = "ternary"       # hcim/quarry readout: ternary | binary
    sparsity: float = 0.5         # mean ternary p==0 fraction (Fig. 2(c))
    tech_scale: bool = False      # scale 65 nm components to 32 nm [26]


@dataclasses.dataclass
class Tally:
    energy_pj: float = 0.0
    latency_ns: float = 0.0
    area_mm2: float = 0.0
    breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, key: str, pj: float):
        self.energy_pj += pj
        self.breakdown[key] = self.breakdown.get(key, 0.0) + pj

    @property
    def edap(self) -> float:
        return self.energy_pj * self.latency_ns * self.area_mm2

    @property
    def latency_area(self) -> float:
        return self.latency_ns * self.area_mm2


def _peripheral(cfg: SystemConfig) -> ColumnPeripheral:
    if cfg.style == "adc":
        p = ADCS[cfg.adc_bits]
    else:
        geo = dcim_mod.DCiMConfig(
            columns=cfg.xbar_rows,
            n_streams=cfg.n_bits_a,
            sf_bits=cfg.n_bits_sf,
        )
        p = dcim_mod.peripheral_for(geo)
    return scale_peripheral(p) if cfg.tech_scale else p


def _scaled_hw(hw: HwParams) -> HwParams:
    """Apply the 65->32 nm scaling [26] to every digital/analog constant,
    exactly as the paper does before plugging components into PUMA."""
    from repro.hwmodel.devices import SCALE_65_TO_32 as F

    return dataclasses.replace(
        hw,
        xbar_mac_energy_pj=hw.xbar_mac_energy_pj * F["energy"],
        driver_energy_pj_per_row=hw.driver_energy_pj_per_row * F["energy"],
        sna_energy_pj=hw.sna_energy_pj * F["energy"],
        comparator_energy_pj=hw.comparator_energy_pj * F["energy"],
        sram_access_pj_per_byte=hw.sram_access_pj_per_byte * F["energy"],
        mult_energy_pj=hw.mult_energy_pj * F["energy"],
        ps_move_energy_pj=hw.ps_move_energy_pj * F["energy"],
        xbar_read_latency_ns=hw.xbar_read_latency_ns * F["latency"],
        dcim_clock_ghz=hw.dcim_clock_ghz / F["latency"],
        xbar_area_mm2=hw.xbar_area_mm2 * F["area"],
        sna_area_mm2=hw.sna_area_mm2 * F["area"],
        comparator_area_mm2=hw.comparator_area_mm2 * F["area"],
    )


def evaluate_layer(
    layer: LayerShape, cfg: SystemConfig, hw: HwParams = DEFAULT_HW,
    sparsity: Optional[float] = None,
) -> Tally:
    """Energy/latency/area of one layer for one inference."""
    if cfg.tech_scale:
        hw = _scaled_hw(hw)
    r = cfg.xbar_rows
    n_streams = cfg.n_bits_a
    tiles_k = math.ceil(layer.k / r)
    cols = layer.o * cfg.n_bits_w                 # bit-slice = 1
    tiles_c = math.ceil(cols / r)
    n_xbars = tiles_k * tiles_c
    col_events = tiles_k * cols * n_streams       # per input vector
    sp = cfg.sparsity if sparsity is None else sparsity

    t = Tally()

    # --- analog MVM (identical across styles) ---
    macs = layer.k * cols * n_streams
    t.add("xbar_mvm", layer.n_vec * macs * hw.xbar_mac_energy_pj)
    t.add(
        "drivers",
        layer.n_vec * layer.k * n_streams * tiles_c * hw.driver_energy_pj_per_row,
    )

    # --- column processing ---
    per = _peripheral(cfg)
    if cfg.style == "adc":
        t.add("adc", layer.n_vec * col_events * per.energy_pj)
        t.add("shift_add", layer.n_vec * col_events * hw.sna_energy_pj)
    else:
        n_comp = 2 if cfg.levels == "ternary" else 1
        t.add(
            "comparators",
            layer.n_vec * col_events * n_comp * hw.comparator_energy_pj,
        )
        eff_sp = sp if cfg.levels == "ternary" else 0.0
        if cfg.style == "hcim":
            # ``per`` is already tech-scaled by _peripheral when requested
            e_col = dcim_mod.dcim_column_energy_pj(eff_sp, per, hw)
            t.add("dcim", layer.n_vec * col_events * e_col)
        else:  # quarry-style digital scale-factor processing
            active = 1.0 - eff_sp
            t.add(
                "sf_mult",
                layer.n_vec * col_events * active * hw.mult_energy_pj,
            )
            sf_bytes = cfg.n_bits_sf / 8.0
            t.add(
                "sf_sram_fetch",
                layer.n_vec * col_events * active * sf_bytes
                * hw.sram_access_pj_per_byte,
            )

    # --- cross-tile partial-sum movement + accumulation ---
    if tiles_k > 1:
        words = (tiles_k - 1) * layer.o
        t.add("ps_movement", layer.n_vec * words * hw.ps_move_energy_pj)

    # --- latency (per vector, streams sequential; crossbars parallel;
    #     one peripheral per crossbar serializes its columns) ---
    cols_per_xbar = min(cols, r)
    if cfg.style == "adc":
        col_lat = cols_per_xbar * n_streams * per.latency_ns
    else:
        geo = dcim_mod.DCiMConfig(
            columns=cfg.xbar_rows, n_streams=n_streams, sf_bits=cfg.n_bits_sf
        )
        # dcim clock already scaled inside hw when tech_scale
        col_lat = dcim_mod.dcim_latency_ns(geo, hw) * (
            cols_per_xbar / geo.columns
        )
    xbar_lat = n_streams * hw.xbar_read_latency_ns
    t.latency_ns = layer.n_vec * (xbar_lat + col_lat)

    # --- area ---
    xbar_a = hw.xbar_area_mm2
    per_a = per.area_mm2
    if cfg.style == "adc":
        unit = xbar_a + per_a + hw.sna_area_mm2
    else:
        n_comp = 2 if cfg.levels == "ternary" else 1
        unit = xbar_a + per_a + n_comp * r * hw.comparator_area_mm2
    t.area_mm2 = n_xbars * unit
    return t


def evaluate_workload(
    layers: Sequence[LayerShape],
    cfg: SystemConfig,
    hw: HwParams = DEFAULT_HW,
    layer_sparsity: Optional[Dict[str, float]] = None,
) -> Tally:
    total = Tally()
    for layer in layers:
        sp = None if layer_sparsity is None else layer_sparsity.get(layer.name)
        t = evaluate_layer(layer, cfg, hw, sparsity=sp)
        for k, v in t.breakdown.items():
            total.add(k, v)
        total.latency_ns += t.latency_ns       # layers run sequentially
        total.area_mm2 += t.area_mm2           # all layers resident (PUMA)
    return total


SERVE_STYLES = ("adc", "quarry", "hcim")


def _occupancy_fraction(v) -> float:
    # accepts a plain float or anything exposing ``mean_zero_fraction``
    # (e.g. repro.kernels.occupancy.ColumnOccupancy)
    return float(getattr(v, "mean_zero_fraction", v))


def serve_energy(
    layer_shapes: Sequence,
    occupancy: Union[None, float, Mapping[str, object]] = None,
    style: str = "hcim",
    *,
    xbar_rows: int = 128,
    n_bits_a: int = 4,
    n_bits_w: int = 4,
    n_bits_sf: int = 4,
    adc_bits: int = 7,
    levels: str = "ternary",
    hw: HwParams = DEFAULT_HW,
    tech_scale: bool = False,
) -> Dict[str, object]:
    """Serving-stack entry point: modeled energy/EDAP for a set of MVMs.

    The thin adapter :mod:`repro.serve.engine` and the benches call to
    attribute modeled hardware cost to served tokens. ``layer_shapes``
    are :class:`LayerShape` instances or ``(name, k, o, n_vec)`` tuples
    (``n_vec = 1`` models one decode token; every energy term is linear
    in ``n_vec``, so callers scale per-token results by served tokens).

    ``occupancy`` is the ternary zero fraction the model *measured* —
    a scalar applied to every layer, a ``{name: fraction}`` mapping
    (missing names fall back to 0.0, i.e. no sparsity credit), or
    ``None`` for 0.0. Values may be plain floats or objects exposing
    ``mean_zero_fraction`` (pack-time
    :class:`repro.kernels.occupancy.ColumnOccupancy` metadata).

    Delegates to :func:`evaluate_workload`, so it agrees with the
    :class:`Tally` path by construction.

    >>> shapes = [("fc", 256, 128, 1)]
    >>> e = serve_energy(shapes, occupancy=0.5, style="hcim")
    >>> sorted(e)
    ['area_mm2', 'breakdown', 'edap', 'energy_pj', 'latency_ns', 'occupancy', 'style']
    >>> e["energy_pj"] < serve_energy(shapes, occupancy=0.5, style="adc")["energy_pj"]
    True
    >>> (serve_energy(shapes, occupancy=0.9)["energy_pj"]
    ...  <= serve_energy(shapes, occupancy=0.1)["energy_pj"])
    True
    >>> serve_energy(shapes, style="dram")
    Traceback (most recent call last):
        ...
    ValueError: unknown energy style 'dram'; choose from ('adc', 'quarry', 'hcim')
    """
    if style not in SERVE_STYLES:
        raise ValueError(f"unknown energy style {style!r}; "
                         f"choose from {SERVE_STYLES}")
    layers = [
        ls if isinstance(ls, LayerShape) else LayerShape(*ls)
        for ls in layer_shapes
    ]
    if occupancy is None:
        base_sp, layer_sp = 0.0, None
    elif isinstance(occupancy, Mapping):
        base_sp = 0.0
        layer_sp = {name: _occupancy_fraction(v)
                    for name, v in occupancy.items()}
    else:
        base_sp, layer_sp = _occupancy_fraction(occupancy), None
    cfg = SystemConfig(
        style=style, xbar_rows=xbar_rows, n_bits_a=n_bits_a,
        n_bits_w=n_bits_w, n_bits_sf=n_bits_sf, adc_bits=adc_bits,
        levels=levels, sparsity=base_sp, tech_scale=tech_scale,
    )
    tally = evaluate_workload(layers, cfg, hw, layer_sparsity=layer_sp)
    mean_occ = base_sp
    if layer_sp is not None and layers:
        weights = [math.ceil(l.k / xbar_rows) * l.o * l.n_vec for l in layers]
        occs = [layer_sp.get(l.name, 0.0) for l in layers]
        wsum = sum(weights)
        mean_occ = (sum(o * w for o, w in zip(occs, weights)) / wsum
                    if wsum else 0.0)
    return {
        "style": style,
        "occupancy": mean_occ,
        "energy_pj": tally.energy_pj,
        "latency_ns": tally.latency_ns,
        "area_mm2": tally.area_mm2,
        "edap": tally.edap,
        "breakdown": dict(tally.breakdown),
    }
