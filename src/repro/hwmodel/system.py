"""PUMA-style system-level energy/latency/area accounting (paper §5).

Counts crossbar, peripheral and data-movement events for a workload's
layer shapes, exactly the way the paper's cycle-accurate comparison is
set up: weight-stationary crossbars (weights and scale factors pre-loaded
and reused), one ADC *or* one DCiM array per analog crossbar, inputs
bit-streamed, batch-1 inference.

Three system styles are modeled:
  * ``adc``    — analog CiM baseline with a b-bit ADC + shift-and-add.
  * ``quarry`` — PSQ-trained net, 1/1.5-bit comparator readout, but scale
                 factors fetched from SRAM and applied in digital
                 multipliers (Quarry [6]-style; the strawman motivating
                 Fig. 2(c)).
  * ``hcim``   — this paper: comparator readout + in-memory DCiM
                 scale-factor add/sub with ternary sparsity gating.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.hwmodel import dcim as dcim_mod
from repro.hwmodel.devices import (
    ADCS,
    ColumnPeripheral,
    DEFAULT_HW,
    HwParams,
    scale_peripheral,
)


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One MVM layer: y[o] += sum_k x[k] w[k,o], evaluated n_vec times."""

    name: str
    k: int        # reduction dim (im2col: kh*kw*cin)
    o: int        # output channels
    n_vec: int    # input vectors per inference (conv: H_out*W_out)


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    style: str                    # adc | quarry | hcim
    xbar_rows: int = 128          # crossbar geometry (square, config A/B)
    n_bits_a: int = 4
    n_bits_w: int = 4
    n_bits_sf: int = 4
    adc_bits: int = 7             # for style == "adc"
    levels: str = "ternary"       # hcim/quarry readout: ternary | binary
    sparsity: float = 0.5         # mean ternary p==0 fraction (Fig. 2(c))
    tech_scale: bool = False      # scale 65 nm components to 32 nm [26]


@dataclasses.dataclass
class Tally:
    energy_pj: float = 0.0
    latency_ns: float = 0.0
    area_mm2: float = 0.0
    breakdown: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, key: str, pj: float):
        self.energy_pj += pj
        self.breakdown[key] = self.breakdown.get(key, 0.0) + pj

    @property
    def edap(self) -> float:
        return self.energy_pj * self.latency_ns * self.area_mm2

    @property
    def latency_area(self) -> float:
        return self.latency_ns * self.area_mm2


def _peripheral(cfg: SystemConfig) -> ColumnPeripheral:
    if cfg.style == "adc":
        p = ADCS[cfg.adc_bits]
    else:
        geo = dcim_mod.DCiMConfig(
            columns=cfg.xbar_rows,
            n_streams=cfg.n_bits_a,
            sf_bits=cfg.n_bits_sf,
        )
        p = dcim_mod.peripheral_for(geo)
    return scale_peripheral(p) if cfg.tech_scale else p


def _scaled_hw(hw: HwParams) -> HwParams:
    """Apply the 65->32 nm scaling [26] to every digital/analog constant,
    exactly as the paper does before plugging components into PUMA."""
    from repro.hwmodel.devices import SCALE_65_TO_32 as F

    return dataclasses.replace(
        hw,
        xbar_mac_energy_pj=hw.xbar_mac_energy_pj * F["energy"],
        driver_energy_pj_per_row=hw.driver_energy_pj_per_row * F["energy"],
        sna_energy_pj=hw.sna_energy_pj * F["energy"],
        comparator_energy_pj=hw.comparator_energy_pj * F["energy"],
        sram_access_pj_per_byte=hw.sram_access_pj_per_byte * F["energy"],
        mult_energy_pj=hw.mult_energy_pj * F["energy"],
        ps_move_energy_pj=hw.ps_move_energy_pj * F["energy"],
        xbar_read_latency_ns=hw.xbar_read_latency_ns * F["latency"],
        dcim_clock_ghz=hw.dcim_clock_ghz / F["latency"],
        xbar_area_mm2=hw.xbar_area_mm2 * F["area"],
        sna_area_mm2=hw.sna_area_mm2 * F["area"],
        comparator_area_mm2=hw.comparator_area_mm2 * F["area"],
    )


def evaluate_layer(
    layer: LayerShape, cfg: SystemConfig, hw: HwParams = DEFAULT_HW,
    sparsity: Optional[float] = None,
) -> Tally:
    """Energy/latency/area of one layer for one inference."""
    if cfg.tech_scale:
        hw = _scaled_hw(hw)
    r = cfg.xbar_rows
    n_streams = cfg.n_bits_a
    tiles_k = math.ceil(layer.k / r)
    cols = layer.o * cfg.n_bits_w                 # bit-slice = 1
    tiles_c = math.ceil(cols / r)
    n_xbars = tiles_k * tiles_c
    col_events = tiles_k * cols * n_streams       # per input vector
    sp = cfg.sparsity if sparsity is None else sparsity

    t = Tally()

    # --- analog MVM (identical across styles) ---
    macs = layer.k * cols * n_streams
    t.add("xbar_mvm", layer.n_vec * macs * hw.xbar_mac_energy_pj)
    t.add(
        "drivers",
        layer.n_vec * layer.k * n_streams * tiles_c * hw.driver_energy_pj_per_row,
    )

    # --- column processing ---
    per = _peripheral(cfg)
    if cfg.style == "adc":
        t.add("adc", layer.n_vec * col_events * per.energy_pj)
        t.add("shift_add", layer.n_vec * col_events * hw.sna_energy_pj)
    else:
        n_comp = 2 if cfg.levels == "ternary" else 1
        t.add(
            "comparators",
            layer.n_vec * col_events * n_comp * hw.comparator_energy_pj,
        )
        eff_sp = sp if cfg.levels == "ternary" else 0.0
        if cfg.style == "hcim":
            # ``per`` is already tech-scaled by _peripheral when requested
            e_col = dcim_mod.dcim_column_energy_pj(eff_sp, per, hw)
            t.add("dcim", layer.n_vec * col_events * e_col)
        else:  # quarry-style digital scale-factor processing
            active = 1.0 - eff_sp
            t.add(
                "sf_mult",
                layer.n_vec * col_events * active * hw.mult_energy_pj,
            )
            sf_bytes = cfg.n_bits_sf / 8.0
            t.add(
                "sf_sram_fetch",
                layer.n_vec * col_events * active * sf_bytes
                * hw.sram_access_pj_per_byte,
            )

    # --- cross-tile partial-sum movement + accumulation ---
    if tiles_k > 1:
        words = (tiles_k - 1) * layer.o
        t.add("ps_movement", layer.n_vec * words * hw.ps_move_energy_pj)

    # --- latency (per vector, streams sequential; crossbars parallel;
    #     one peripheral per crossbar serializes its columns) ---
    cols_per_xbar = min(cols, r)
    if cfg.style == "adc":
        col_lat = cols_per_xbar * n_streams * per.latency_ns
    else:
        geo = dcim_mod.DCiMConfig(
            columns=cfg.xbar_rows, n_streams=n_streams, sf_bits=cfg.n_bits_sf
        )
        # dcim clock already scaled inside hw when tech_scale
        col_lat = dcim_mod.dcim_latency_ns(geo, hw) * (
            cols_per_xbar / geo.columns
        )
    xbar_lat = n_streams * hw.xbar_read_latency_ns
    t.latency_ns = layer.n_vec * (xbar_lat + col_lat)

    # --- area ---
    xbar_a = hw.xbar_area_mm2
    per_a = per.area_mm2
    if cfg.style == "adc":
        unit = xbar_a + per_a + hw.sna_area_mm2
    else:
        n_comp = 2 if cfg.levels == "ternary" else 1
        unit = xbar_a + per_a + n_comp * r * hw.comparator_area_mm2
    t.area_mm2 = n_xbars * unit
    return t


def evaluate_workload(
    layers: Sequence[LayerShape],
    cfg: SystemConfig,
    hw: HwParams = DEFAULT_HW,
    layer_sparsity: Optional[Dict[str, float]] = None,
) -> Tally:
    total = Tally()
    for layer in layers:
        sp = None if layer_sparsity is None else layer_sparsity.get(layer.name)
        t = evaluate_layer(layer, cfg, hw, sparsity=sp)
        for k, v in t.breakdown.items():
            total.add(k, v)
        total.latency_ns += t.latency_ns       # layers run sequentially
        total.area_mm2 += t.area_mm2           # all layers resident (PUMA)
    return total
