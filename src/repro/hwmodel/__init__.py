"""Energy/latency/area model of HCiM vs ADC-based analog CiM (paper §5)."""
from repro.hwmodel.devices import (
    ADCS, ADC_FLASH_4B, ADC_SAR_6B, ADC_SAR_7B, DCIM_A, DCIM_B,
    DEFAULT_HW, HwParams, scale_peripheral,
)
from repro.hwmodel.dcim import (
    CONFIG_A, CONFIG_B, DCiMConfig, cim_add_sub_row,
    dcim_column_energy_pj, dcim_latency_ns, dcim_latency_per_column_ns,
)
from repro.hwmodel.system import (
    LayerShape, SERVE_STYLES, SystemConfig, Tally, evaluate_layer,
    evaluate_workload, serve_energy,
)
from repro.hwmodel.workloads import WORKLOADS
