"""DCiM array model: Read-Compute-Store pipeline + sparsity gating (§4.2).

The digital CiM array stores, per analog crossbar:
  * ``n_streams`` scale-factor rows of ``n_bits_sf`` bits per column,
  * one partial-sum row of ``ps_accum_bits`` bits per column
(Table 1: config A = 4*128*4 + 1*128*8 bits -> a 24x128 array).

For each input bit-stream the array performs one in-memory add *or*
subtract (sign of p) of the scale-factor row into the partial-sum row,
processing odd and even columns in alternate cycles (precision mismatch,
§4.2.1), pipelined Read -> Compute -> Store (Fig. 4). Columns whose
ternary p is zero neither precharge, compute, nor store (§4.2.2).
"""
from __future__ import annotations

import dataclasses
import math

from repro.hwmodel.devices import DCIM_A, DCIM_B, ColumnPeripheral, HwParams, DEFAULT_HW


@dataclasses.dataclass(frozen=True)
class DCiMConfig:
    """Geometry of one DCiM array (Table 1)."""

    columns: int = 128           # = analog crossbar columns
    n_streams: int = 4           # input_precision / bit_stream
    sf_bits: int = 4
    ps_bits: int = 8

    @property
    def rows(self) -> int:
        # scale-factor memory rows + partial-sum register rows
        return self.n_streams * self.sf_bits + self.ps_bits

    @property
    def name(self) -> str:
        return f"dcim_{self.n_streams * self.sf_bits + self.ps_bits}x{self.columns}"


CONFIG_A = DCiMConfig(columns=128)
CONFIG_B = DCiMConfig(columns=64)


def dcim_cycles_per_xbar_readout(cfg: DCiMConfig, hw: HwParams = DEFAULT_HW) -> int:
    """Clock cycles to fold all streams' scale factors into the PS row.

    ops = n_streams x (odd + even column phases); the 3-stage R-C-S
    pipeline overlaps successive ops (Fig. 4), plus one drain/writeback
    slot per stream boundary (the fitted +n_streams term reproduces the
    0.06 / 0.10 ns-per-column averages of Table 3 within 10 %).
    """
    ops = cfg.n_streams * 2
    return hw.dcim_pipeline_depth + ops - 1 + cfg.n_streams


def dcim_latency_ns(cfg: DCiMConfig, hw: HwParams = DEFAULT_HW) -> float:
    return dcim_cycles_per_xbar_readout(cfg, hw) / hw.dcim_clock_ghz


def dcim_latency_per_column_ns(cfg: DCiMConfig, hw: HwParams = DEFAULT_HW) -> float:
    """Average per (column x stream) — Table 3's reporting convention."""
    return dcim_latency_ns(cfg, hw) / (cfg.columns * cfg.n_streams)


def dcim_column_energy_pj(
    sparsity: float,
    peripheral: ColumnPeripheral = DCIM_A,
    hw: HwParams = DEFAULT_HW,
) -> float:
    """Energy per (column x stream) event at a given ternary sparsity.

    ``E = E0 * (f_fixed + (1 - f_fixed) * (1 - sparsity))`` — gated
    columns skip bit-line precharge, adder/subtractor clocking and the
    store cycle (§4.2.2); clocking/control stays. With f_fixed = 0.52,
    0 % -> 50 % sparsity gives the 24 % reduction of Fig. 5(a).
    """
    sparsity = min(max(sparsity, 0.0), 1.0)
    f = hw.dcim_fixed_energy_frac
    return peripheral.energy_pj * (f + (1.0 - f) * (1.0 - sparsity))


def dcim_array_area_mm2(cfg: DCiMConfig) -> float:
    base = DCIM_A if cfg.columns >= 128 else DCIM_B
    return base.area_mm2


def peripheral_for(cfg: DCiMConfig) -> ColumnPeripheral:
    return DCIM_A if cfg.columns >= 128 else DCIM_B


# ---------------------------------------------------------------------------
# Functional in-memory adder/subtractor (bit-level, used by unit tests to
# show the §4.2.1 logic computes exact two's-complement adds/subtracts).
# ---------------------------------------------------------------------------

def cim_add_sub_row(ps: int, sf: int, p: int, ps_bits: int) -> int:
    """One DCiM op: PS <- PS + p * sf, exact wrap at ps_bits (hardware reg).

    Implements the column peripheral of Fig. 3(d): a chain of full
    adder/subtractors where the MUX (select = p) picks carry vs borrow;
    p = 0 clock-gates the column (PS unchanged).
    """
    if p == 0:
        return ps
    mask = (1 << ps_bits) - 1
    if p > 0:
        # full adder chain on (OR, NAND) latched bit-lines
        return (ps + sf) & mask
    # in-memory full subtractor (borrow via the idle WBL read, §4.2.1)
    return (ps - sf) & mask


def twos_complement_to_int(v: int, bits: int) -> int:
    v &= (1 << bits) - 1
    return v - (1 << bits) if v >= (1 << (bits - 1)) else v
