"""Deterministic, seekable, host-sharded synthetic data pipelines.

Production posture without external datasets:
  * token streams are a stateless function of (seed, step, host_shard) —
    any step is reproducible after restart (checkpoint stores only the
    step counter, the "restore data state" problem disappears),
  * the LM stream is a mixture of Zipf-distributed unigrams and embedded
    Markov n-gram structure so models have something learnable (loss
    drops well below the uniform-vocab entropy),
  * a CIFAR-shaped classification generator supports the paper-faithful
    PSQ-QAT reproduction (ResNet-20-style training, §5.1) — random class
    prototypes + noise, linearly separable at controllable SNR.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    markov_order: int = 2
    structure: float = 0.8      # fraction of tokens drawn from the Markov core

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


def _markov_table(cfg: DataConfig) -> np.ndarray:
    """Deterministic sparse transition table: vocab -> 8 successors."""
    rng = np.random.RandomState(cfg.seed + 7)
    return rng.randint(0, cfg.vocab_size, size=(cfg.vocab_size, 8))


class TokenStream:
    """Stateless-per-step LM batches: ``batch_at(step)`` is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._table = _markov_table(cfg)
        # Zipf unigram distribution (heavy head, like natural text)
        ranks = np.arange(1, cfg.vocab_size + 1)
        p = 1.0 / ranks ** 1.1
        self._unigram = p / p.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 613 + cfg.host_id) % (2 ** 31)
        )
        b, s = cfg.host_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=b, p=self._unigram)
        structured = rng.rand(b, s) < cfg.structure
        nxt_choice = rng.randint(0, 8, size=(b, s))
        random_draw = rng.choice(cfg.vocab_size, size=(b, s), p=self._unigram)
        for t in range(s):
            follow = self._table[toks[:, t], nxt_choice[:, t]]
            toks[:, t + 1] = np.where(structured[:, t], follow, random_draw[:, t])
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclasses.dataclass(frozen=True)
class ClassificationConfig:
    n_classes: int = 10
    dim: int = 3 * 32 * 32
    train_noise: float = 1.0
    seed: int = 0


class ClassificationStream:
    """CIFAR-shaped synthetic classification (paper QAT reproduction)."""

    def __init__(self, cfg: ClassificationConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        protos = rng.randn(cfg.n_classes, cfg.dim)
        self.protos = protos / np.linalg.norm(protos, axis=1, keepdims=True)

    def batch_at(self, step: int, batch: int) -> Tuple[np.ndarray, np.ndarray]:
        rng = np.random.RandomState(self.cfg.seed * 99991 + step)
        labels = rng.randint(0, self.cfg.n_classes, size=batch)
        x = self.protos[labels] + rng.randn(batch, self.cfg.dim) * self.cfg.train_noise
        return x.astype(np.float32), labels.astype(np.int32)
