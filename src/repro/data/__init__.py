"""Deterministic synthetic data pipelines (seekable, host-sharded)."""
from repro.data.pipeline import (
    ClassificationConfig, ClassificationStream, DataConfig, TokenStream,
)
