import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: parameters,
optimizer state, batches and KV caches enter as ShapeDtypeStructs with
explicit NamedShardings; ``jit(...).lower(...).compile()`` must succeed
on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, and the
compiled artifact yields the memory/cost/collective numbers that feed
EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch tinyllama-1.1b --shape train_4k [--multi-pod] [--quant psq]
    PYTHONPATH=src python -m repro.launch.dryrun --all  # full matrix
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.core.config import PSQ_TERNARY, QuantConfig
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models import decode as D
from repro.parallel.sharding import RULES_2D, RULES_3D, axis_rules
from repro.train.optimizer import OptConfig
from repro.train.trainer import make_train_step

from repro.launch.hlo_analysis import analyze as hlo_analyze

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def model_flops(cfg, cell) -> float:
    """Analytic model FLOPs (global): 6ND/2ND matmul term + the
    attention quadratic term (dominant at 32k contexts), for §Roofline."""
    n = cfg.param_count()
    if cfg.family == "moe":
        # active params only: top_k of n_experts expert FFNs
        e_ff = cfg.moe_d_ff or cfg.d_ff
        expert_p = cfg.n_experts * 3 * cfg.d_model * e_ff * cfg.n_layers
        active = n - expert_p + expert_p * cfg.moe_top_k / cfg.n_experts
        n = active
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    fwd = 2.0 * n * tokens

    # attention core: QK^T and AV, 2 mult-adds each, causal halves it;
    # SWA replaces S with the window; SSM/xLSTM layers are linear in S
    # (chunked quadratic with chunk 128).
    s = cell.seq_len
    hd = cfg.resolved_head_dim
    kv_len = min(cfg.sliding_window, s) if cfg.sliding_window else s
    if cell.kind == "decode":
        # one query over the cache
        per_attn_layer = 4.0 * cell.global_batch * kv_len * cfg.n_heads * hd
        tokens_eff = cell.global_batch
    else:
        per_attn_layer = 2.0 * cell.global_batch * s * kv_len * cfg.n_heads * hd
        tokens_eff = tokens
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        chunk_side = 128
        n_ssm = cfg.n_layers - n_attn
        di = cfg.ssm_expand * cfg.d_model
        ssm_core = 4.0 * tokens_eff * chunk_side * di * n_ssm
        attn_fl = per_attn_layer * n_attn + ssm_core
    elif cfg.family == "ssm":
        di = int(cfg.xlstm_proj_factor * cfg.d_model)
        attn_fl = 4.0 * tokens_eff * 128 * di * cfg.n_layers
    elif cfg.family == "encdec":
        # bidirectional encoder (no causal halving) + causal decoder +
        # cross attention
        enc = 4.0 * cell.global_batch * s * s * cfg.n_heads * hd * cfg.n_enc_layers
        attn_fl = per_attn_layer * cfg.n_layers * 2 + (
            enc if cell.kind != "decode" else
            4.0 * cell.global_batch * s * cfg.n_heads * hd * cfg.n_layers
        )
    else:
        attn_fl = per_attn_layer * cfg.n_layers

    fwd = fwd + attn_fl
    if cell.kind == "train":
        return 3.0 * fwd  # fwd + 2x bwd
    return fwd


def _quant_cfg(quant: str) -> Optional[QuantConfig]:
    if quant == "none":
        return None
    if quant == "psq":
        return PSQ_TERNARY
    if quant == "binary":
        return dataclasses.replace(PSQ_TERNARY, psq_levels="binary")
    raise ValueError(quant)


# §Perf hillclimb variants: config/sharding deltas applied per cell
VARIANTS = {
    "base": {},
    "flash": {"attn_impl": "flash"},
    "flash_bf16": {"attn_impl": "flash", "compute_dtype": "bf16"},
    "bf16": {"compute_dtype": "bf16"},
    "fsdp": {},           # + shard a weight dim over the data axis (ZeRO-3)
    "flash_bf16_fsdp": {"attn_impl": "flash", "compute_dtype": "bf16"},
    "int4serve": {},      # decode: int4-packed PSQ deployment weights
    "int4serve_flash": {"attn_impl": "flash"},
    "densemoe": {"moe_impl": "dense"},
    "densemoe_flash_bf16": {"moe_impl": "dense", "attn_impl": "flash",
                            "compute_dtype": "bf16"},
    # decode: shard the KV cache on batch only (local cache updates — no
    # cross-shard select on the sequence axis), optionally + int4 weights
    "kvbatch": {},
    "kvbatch_int4": {},
}


def _fsdp_pspec(path, leaf, mesh):
    """param_pspec + shard the largest leftover dim over 'data' (ZeRO-3)."""
    base = S.param_pspec(path, leaf, mesh)
    spec = list(base) + [None] * (leaf.ndim - len(base))
    if leaf.ndim >= 2 and "data" not in [s for s in spec if isinstance(s, str)]:
        cand = sorted(
            range(leaf.ndim), key=lambda i: -leaf.shape[i]
        )
        for i in cand:
            if spec[i] is None and leaf.shape[i] % mesh.shape["data"] == 0:
                spec[i] = "data"
                break
    while spec and spec[-1] is None:
        spec.pop()
    from jax.sharding import PartitionSpec as _P

    return _P(*spec)


def build_cell(arch: str, shape: str, multi_pod: bool, quant: str = "none",
               variant: str = "base"):
    """Returns (jitted_fn, example_args_sds) for one cell, inside mesh ctx."""
    cfg = get_config(arch)
    qc = _quant_cfg(quant)
    if qc is not None:
        cfg = cfg.with_quant(qc)
    if VARIANTS.get(variant):
        cfg = dataclasses.replace(cfg, **VARIANTS[variant])
    cell = S.SHAPES[shape]
    ok, why = S.cell_is_applicable(cfg, cell)
    if not ok:
        return None, why

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dict(RULES_3D if multi_pod else RULES_2D)
    dp = ("pod", "data") if multi_pod else "data"

    if variant.startswith("int4serve") or variant.endswith("_int4"):
        from repro.core.psq_linear import pack_tree_for_serving

        params_sds = jax.eval_shape(
            lambda: pack_tree_for_serving(
                T.init_model(jax.random.PRNGKey(0), cfg)
            )
        )
    else:
        params_sds = S.abstract_params(cfg)
    spec_fn = _fsdp_pspec if "fsdp" in variant else S.param_pspec
    param_sh = S.tree_shardings(params_sds, mesh, spec_fn)

    if cell.kind == "train":
        cfg_t = dataclasses.replace(cfg, remat="block")
        state_sds = S.abstract_state(cfg_t)
        # params and Adam moments share the same layout rules
        from repro.train.trainer import TrainState
        from repro.train.optimizer import OptState

        state_sh = TrainState(
            params=param_sh,
            opt=OptState(
                step=NamedSharding(mesh, P()), mu=param_sh, nu=param_sh
            ),
        )
        batch_sds = S.batch_specs(cfg_t, cell)
        batch_sh = S.batch_shardings(batch_sds, mesh, dp)
        step = make_train_step(cfg_t, OptConfig())
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh))
        args = (state_sds, batch_sds)
        return (mesh, rules, fn, args), ""

    if cell.kind == "prefill":
        batch_sds = S.batch_specs(cfg, cell)
        batch_sh = S.batch_shardings(batch_sds, mesh, dp)

        def prefill_logits(params, batch):
            logits, _ = T.forward(params, cfg, batch, last_only=True)
            return logits

        fn = jax.jit(prefill_logits, in_shardings=(param_sh, batch_sh))
        return (mesh, rules, fn, (params_sds, batch_sds)), ""

    # decode
    long_ctx = cell.seq_len >= 100_000
    rules = dict(rules, kv_seq="model")
    batch_sds = S.batch_specs(cfg, cell)
    batch_sh = S.batch_shardings(batch_sds, mesh, dp)
    cache_sds = S.abstract_cache(cfg, cell, params_sds)

    def cache_fn(p_, l_, m_):
        spec = S.cache_pspec(p_, l_, m_, long_ctx, dp)
        if variant.startswith("kvbatch"):
            spec = P(*[None if a == "model" else a for a in spec])
        return spec

    cache_sh = S.tree_shardings(cache_sds, mesh, cache_fn)

    def serve_step(params, token, cache):
        return D.decode_step(params, cfg, token, cache)

    # donate the cache: in-place DUS instead of a full write-back per layer
    fn = jax.jit(serve_step, in_shardings=(param_sh, batch_sh["token"], cache_sh),
                 donate_argnums=(2,))
    return (mesh, rules, fn, (params_sds, batch_sds["token"], cache_sds)), ""


def run_cell(
    arch: str, shape: str, multi_pod: bool, quant: str = "none",
    variant: str = "base", save: bool = True, verbose: bool = True,
) -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}|{shape}|{mesh_name}|{quant}|{variant}"
    built, why = build_cell(arch, shape, multi_pod, quant, variant)
    if built is None:
        rec = {"cell": tag, "status": "skipped", "reason": why}
        if verbose:
            print(f"[dryrun] SKIP  {tag}: {why}", flush=True)
        return rec

    mesh, rules, fn, args = built
    t0 = time.time()
    try:
        with mesh:
            with axis_rules(rules, mesh):
                lowered = fn.lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
    except Exception as e:  # sharding/compile bug in this cell
        rec = {"cell": tag, "status": "failed",
               "error": f"{type(e).__name__}: {str(e)[:500]}"}
        if verbose:
            print(f"[dryrun] FAIL  {tag}: {rec['error'][:200]}", flush=True)
        if save:
            os.makedirs(RESULTS_DIR, exist_ok=True)
            sfx = "" if variant == "base" else f"_{variant}"
            with open(os.path.join(
                RESULTS_DIR,
                f"{arch}_{shape}_{'2x16x16' if multi_pod else '16x16'}_{quant}{sfx}.json",
            ), "w") as f:
                json.dump(rec, f, indent=1)
        return rec
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # scan-aware accounting (cost_analysis counts while bodies once)
    an = hlo_analyze(hlo)
    cfg_full = get_config(arch)
    cell = S.SHAPES[shape]
    n_chips = 512 if multi_pod else 256

    rec = {
        "cell": tag,
        "status": "ok",
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "quant": quant,
        "variant": variant,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops_per_device": an["flops"],
        "bytes_per_device": an["bytes"],
        "collective_bytes_per_device": an["collectives"],
        "xla_cost_analysis_flops_raw": cost.get("flops", 0.0),
        "model_flops_global": model_flops(cfg_full, cell),
        "n_chips": n_chips,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }
    if verbose:
        gb = 1024 ** 3
        coll = an["collectives"]
        print(
            f"[dryrun] OK    {tag}: lower {t_lower:.0f}s compile "
            f"{t_compile:.0f}s | {an['flops']/1e12:.2f} TFLOP/dev "
            f"(model {rec['model_flops_global']/n_chips/1e12:.2f}) "
            f"| args {rec['memory']['argument_bytes']/gb:.2f} GiB/dev "
            f"temp {rec['memory']['temp_bytes']/gb:.2f} GiB/dev "
            f"| coll {coll.get('total', 0)/1e9:.3f} GB/dev",
            flush=True,
        )
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = "" if variant == "base" else f"_{variant}"
        stem = os.path.join(RESULTS_DIR, f"{arch}_{shape}_{mesh_name}_{quant}{suffix}")
        with open(stem + ".json", "w") as f:
            json.dump(rec, f, indent=1)
        if len(hlo) < 200 * 1024 * 1024:  # keep HLO for offline re-analysis
            import gzip

            with gzip.open(stem + ".hlo.gz", "wt") as f:
                f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--shape", default="train_4k", choices=list(S.SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quant", default="none", choices=["none", "psq", "binary"])
    ap.add_argument("--all", action="store_true", help="full 40-cell matrix")
    ap.add_argument("--variant", default="base", choices=list(VARIANTS))
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch == "all") else [args.arch]
    shapes = list(S.SHAPES) if (args.all or args.shape == "all") else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp, args.quant, args.variant))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"[dryrun] done: {n_ok} compiled, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed")
    if n_ok + n_skip < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
