"""Scan-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, but the whole
framework executes layer stacks as scans — so FLOPs/bytes would be
undercounted by ~n_layers. This module re-derives costs from the
post-optimization HLO text with loop trip-count multiplication:

  * computations are parsed into blocks; a call graph (while bodies,
    fusions, calls, conditionals) assigns each computation an execution
    multiplicity, with while bodies multiplied by their trip count
    (extracted from the loop-condition constant);
  * FLOPs: ``2 * prod(result) * contracted_elements`` for every ``dot``
    — fusion bodies included (MXU work is real wherever it sits);
  * bytes: HBM-traffic model — for every *top-level* op of a reachable
    non-fusion computation, result bytes (write) + operand bytes (read).
    Fusion-internal ops stay in VMEM/VREGs and are NOT counted, matching
    the intent of XLA's "bytes accessed";
  * collectives: result-size proxy per op, trip-multiplied.

Validated against cost_analysis() on scan-free programs (tests).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_COMP_HEADER = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*\((.*)\)\s*->.*{\s*$")
_PARAM = re.compile(r"([\w\.\-]+)\s*:\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
_TYPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\](?:{[^}]*})?")
_DEF = re.compile(r"^(?:ROOT )?%?([\w\.\-]+)\s*=\s*\(?([a-z0-9]+)\[([0-9,]*)\]")
# ops that alias/forward data without touching HBM
_FREE_OPS = (" parameter(", "constant(", "get-tuple-element(", " tuple(",
             "bitcast(", "bitcast-convert(", "after-all(", "partition-id(")
_DOT_RESULT = re.compile(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\bdot\(")
_DOT_ARGS = re.compile(r"\bdot\(([^)]*)\)")
_ARGS_OF_OP = re.compile(r"\b[a-z0-9\-]+\(([^)]*)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BODY_REF = re.compile(r"body=%?([\w\.\-]+)")
_COND_REF = re.compile(r"condition=%?([\w\.\-]+)")
_FUSION_REF = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_COLLECTIVE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def _dims(s: str) -> List[int]:
    return [int(x) for x in s.split(",") if x] if s else []


def _split_args(s: str) -> List[str]:
    """Split an operand list on top-level commas only.

    Modern XLA prints typed operands (``f32[512,512]{1,0} %arg``) whose
    shape/layout brackets contain commas — a naive ``split(",")`` shreds
    them and silently zeroes every downstream count.
    """
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return [p for p in parts if p]


def _arg_name(a: str) -> str:
    """Operand name with any type annotation stripped: the ``%``-token."""
    for tok in reversed(a.split()):
        if tok.startswith("%"):
            return tok.lstrip("%")
    return a.split()[-1].lstrip("%") if a.split() else a


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    header: str = ""
    flops: float = 0.0
    io_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)
    while_calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    fusion_calls: List[str] = dataclasses.field(default_factory=list)
    # plain `call` ops (CPU thread-parallel wrappers etc.): the callee is a
    # real computation whose ops touch HBM, so it is byte-counted itself
    # and the call site is free — unlike fusions.
    plain_calls: List[str] = dataclasses.field(default_factory=list)
    param_reads: Optional[List[float]] = None


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        m = _COMP_HEADER.match(line)
        if m and line.endswith("{"):
            cur = Computation(name=m.group(1), lines=[], header=m.group(2))
            comps[cur.name] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            cur.lines.append(line)
    return comps


def _symtab(c: Computation) -> Dict[str, Tuple[str, List[int]]]:
    tab: Dict[str, Tuple[str, List[int]]] = {}
    for pname, pdtype, pdims in _PARAM.findall(c.header):
        tab[pname] = (pdtype, _dims(pdims))
    for line in c.lines:
        md = _DEF.match(line)
        if md:
            tab[md.group(1)] = (md.group(2), _dims(md.group(3)))
    return tab


def _compute_param_reads(c: Computation):
    """Effective read size per body parameter: params consumed only by
    dynamic-slice/gather are read at the slice size (scan xs, KV caches)."""
    symtab = _symtab(c)

    def op_bytes(name: str) -> float:
        rec = symtab.get(_arg_name(name))
        return _prod(rec[1]) * _DTYPE_BYTES.get(rec[0], 4) if rec else 0.0

    param_names = [p[0] for p in _PARAM.findall(c.header)]
    sliced_reads: Dict[str, float] = {}
    consumed_fully: Dict[str, bool] = {}
    for line in c.lines:
        md = _DEF.match(line)
        if md is None or "(" not in line or " parameter(" in line:
            continue
        mo = _ARGS_OF_OP.search(line.split("=", 1)[1])
        if not mo:
            continue
        args = [_arg_name(a) for a in _split_args(mo.group(1))]
        is_slice = ("dynamic-slice(" in line or " gather(" in line)
        res_bytes = _prod(_dims(md.group(3))) * _DTYPE_BYTES.get(md.group(2), 4)
        for i, a in enumerate(args):
            if a in param_names:
                if is_slice and i == 0:
                    sliced_reads[a] = sliced_reads.get(a, 0.0) + res_bytes
                else:
                    consumed_fully[a] = True
    c.param_reads = [
        op_bytes(p) if (p in consumed_fully or p not in sliced_reads)
        else sliced_reads[p]
        for p in param_names
    ]


def _analyze_comp(c: Computation, comps: Dict[str, "Computation"]):
    symtab = _symtab(c)

    def op_bytes(name: str) -> float:
        rec = symtab.get(_arg_name(name))
        return _prod(rec[1]) * _DTYPE_BYTES.get(rec[0], 4) if rec else 0.0

    for line in c.lines:
        # --- dot flops ---
        mr = _DOT_RESULT.search(line)
        if mr:
            result = _dims(mr.group(2))
            mc = _CONTRACT.search(line)
            ma = _DOT_ARGS.search(line)
            lhs: List[int] = []
            if ma:
                dot_args = _split_args(ma.group(1))
                first = dot_args[0] if dot_args else ""
                mt = _TYPE.match(first)
                if mt:
                    lhs = _dims(mt.group(2))
                else:
                    rec = symtab.get(_arg_name(first))
                    lhs = rec[1] if rec else []
            cdims = _dims(mc.group(1)) if mc else []
            if lhs and cdims:
                k = _prod(lhs[i] for i in cdims if i < len(lhs))
                c.flops += 2.0 * _prod(result) * k
        # --- HBM traffic: result + operand bytes of this top-level op ---
        md = _DEF.match(line)
        if md and not any(tok in line for tok in _FREE_OPS):
            res_bytes = _prod(_dims(md.group(3))) * _DTYPE_BYTES.get(
                md.group(2), 4
            )
            rhs = line.split("=", 1)[1]
            mo = _ARGS_OF_OP.search(rhs)
            args = _split_args(mo.group(1)) if mo else []
            if (" while(" in line or " conditional(" in line
                    or " call(" in line):
                pass  # carried state is aliased; bodies account their io
            elif "dynamic-slice(" in line or " gather(" in line:
                c.io_bytes += 2.0 * res_bytes  # read slice + write result
            elif "dynamic-update-slice(" in line or " scatter(" in line:
                upd_idx = 1 if "dynamic-update-slice(" in line else 2
                if len(args) > upd_idx:
                    c.io_bytes += 2.0 * op_bytes(args[upd_idx])
            elif " fusion(" in line:
                # operands read at their *effective* size (slice-aware)
                c.io_bytes += res_bytes
                mf0 = _FUSION_REF.search(line)
                body = comps.get(mf0.group(1)) if mf0 else None
                reads = getattr(body, "param_reads", None)
                if reads is not None:
                    c.io_bytes += sum(
                        min(r, op_bytes(a) or r)
                        for r, a in zip(reads, args)
                    )
                else:
                    c.io_bytes += sum(op_bytes(a) for a in args)
            else:
                c.io_bytes += res_bytes
                for a in args:
                    if a.startswith("%") or (a and not _TYPE.match(a)):
                        c.io_bytes += op_bytes(a)
                    else:
                        mt = _TYPE.match(a)
                        if mt:
                            c.io_bytes += _prod(_dims(mt.group(2))) * \
                                _DTYPE_BYTES.get(mt.group(1), 4)
        # --- collectives ---
        mcol = _COLLECTIVE.search(line)
        if mcol and "-done" not in line.split("=", 1)[-1][:40]:
            if md:
                nbytes = _prod(_dims(md.group(3))) * _DTYPE_BYTES.get(
                    md.group(2), 4
                )
                kind = mcol.group(1)
                c.coll[kind] = c.coll.get(kind, 0.0) + nbytes
        # --- call graph ---
        if " while(" in line:
            mb = _BODY_REF.search(line)
            mc2 = _COND_REF.search(line)
            if mb and mc2:
                c.while_calls.append((mb.group(1), mc2.group(1)))
        elif " call(" in line:
            mf = _FUSION_REF.search(line)
            if mf:
                c.plain_calls.append(mf.group(1))
        else:
            # fusions, and to_apply-carrying ops (reduce/scatter/map):
            # internals stay on-chip, only flops are real
            mf = _FUSION_REF.search(line)
            if mf:
                c.fusion_calls.append(mf.group(1))


def _trip_count(cond: Computation) -> int:
    best = 1
    for line in cond.lines:
        for m in _CONST_INT.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def analyze(hlo: str, entry: Optional[str] = None) -> Dict[str, float]:
    """Trip-count-aware flops/bytes/collectives (per device)."""
    comps = parse_computations(hlo)
    for c in comps.values():
        _compute_param_reads(c)
    for c in comps.values():
        _analyze_comp(c, comps)
    if entry is None:
        entry = next(
            (n for n in comps if n.startswith("main") or ".main" in n),
            next(iter(comps), None),
        )
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {"total": 0.0}}

    totals = {"flops": 0.0, "bytes": 0.0}
    coll: Dict[str, float] = {}
    stack: List[str] = []

    def visit(name: str, mult: float, count_bytes: bool):
        c = comps.get(name)
        if c is None or name in stack:
            return
        stack.append(name)
        totals["flops"] += mult * c.flops
        if count_bytes:
            totals["bytes"] += mult * c.io_bytes
            for k, v in c.coll.items():
                coll[k] = coll.get(k, 0.0) + mult * v
        for body, cond in c.while_calls:
            trip = _trip_count(comps[cond]) if cond in comps else 1
            visit(body, mult * trip, count_bytes)
            visit(cond, mult * trip, count_bytes)
        for sub in c.fusion_calls:
            # fusion internals: MXU flops are real, HBM bytes are not
            visit(sub, mult, False)
        for sub in c.plain_calls:
            # real sub-computations: their ops touch HBM themselves
            visit(sub, mult, count_bytes)
        stack.pop()

    visit(entry, 1.0, True)
    coll["total"] = sum(coll.values())
    totals["collectives"] = coll
    return totals
