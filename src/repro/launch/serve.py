"""Serving launcher: continuous-batching prefill/decode on the devices.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 8 [--int4 | --psq-packed] [--backend reference] \
        [--slots 4] [--mode auto|continuous|static] \
        [--decode-horizon H] [--mesh DATA,MODEL] [--devices N]

Every family — KV-cache, recurrent-state (SSM/xLSTM/hybrid) AND the
side-input families (encdec cross-KV, VLM patch embeds) — serves
through the continuous-batching slot pool (per-step retirement +
mid-flight admission, per-slot side-input pools; see docs/serving.md).
``--mode static`` keeps the drain-the-queue oracle loop around for
comparison. ``--paged`` switches the slot pool to the paged KV cache —
fixed-size pages, block tables and shared-prefix radix reuse;
attention-KV families only (docs/memory.md). ``--decode-horizon H``
batches up to H greedy decode steps into one on-device
``lax.while_loop`` per host round-trip (bit-exact with H=1; greedy
only). ``--spec-k K`` turns on speculative decoding: a small draft
model (``--draft`` arch, default a 1-layer copy of the served config)
proposes K greedy tokens per slot and the main model verifies them in
one masked forward — token-identical to vanilla greedy decode, see
docs/serving.md for the lifecycle and rollback rule.

Admission is policy-driven (docs/scheduling.md): ``--admission fcfs``
(default) is the pow2-bucket FIFO wave; ``--admission cost-aware
--energy-budget PJ`` budgets in-flight requests against their modeled
worst-case serve energy (``hwmodel.serve_energy`` — HCiM's pack-time
occupancy metadata makes the price static), deferring admissions that
would push the in-flight total past the cap.

``--streaming`` serves the same workload through the incremental
:class:`StreamingFrontend` (submit/poll over ``ServeEngine.step()``)
instead of one blocking ``run()``, printing tokens as rounds complete —
the API the replayable-arrival benchmark drives
(``benchmarks/serve_bench.py --streaming``).

Multi-device: ``--mesh 1,4`` runs the PSQ datapath tensor-parallel over
a 4-way ``model`` axis (packed layers column-sharded, one psum per
matmul) and ``--mesh 4,1`` shards the decode slot pool over ``data``.
A third component adds an ``expert`` axis — ``--mesh 1,1,4`` serves MoE
configs expert-parallel (expert FFN stacks sharded over experts, router
replicated, bit-exact dispatch; see docs/parallelism.md). On CPU,
``--devices N`` forges N virtual devices (sets
``--xla_force_host_platform_device_count`` — must run before any other
JAX use in the process).
"""
from __future__ import annotations

import argparse
import dataclasses
import math
from typing import Dict, List, Optional, Tuple


class StreamingFrontend:
    """Incremental submit/poll API over :meth:`ServeEngine.step`.

    The engine's blocking ``run()`` drains everything before returning;
    this front-end instead advances ONE scheduling round per
    :meth:`step` call and buffers each request's newly-emitted tokens
    until the caller :meth:`poll`\\ s them — the shape a network serving
    layer needs (arrivals between rounds, partial responses out as soon
    as a round completes). Purely host-side bookkeeping: scheduling,
    placement and execution stay in the engine layers, so streamed
    tokens are bit-identical to a drain-the-queue ``run()``.
    """

    def __init__(self, engine):
        self.engine = engine
        self._pending: Dict[int, List[int]] = {}   # undelivered tokens
        self._finished: set = set()

    def submit(self, prompt, max_new_tokens: int = 16,
               eos_id: Optional[int] = None,
               extra_idx: Optional[int] = None) -> int:
        """Enqueue a prompt mid-flight; returns its uid."""
        uid = self.engine.submit(prompt, max_new_tokens=max_new_tokens,
                                 eos_id=eos_id, extra_idx=extra_idx)
        self._pending[uid] = []
        return uid

    def step(self) -> None:
        """Advance one scheduling round (admission + one executor
        round) and buffer every request's new tokens."""
        for uid, toks in self.engine.step().items():
            self._pending.setdefault(uid, []).extend(toks)
        self._finished.update(r.uid for r in self.engine.finished)

    def poll(self, uid: int) -> Tuple[List[int], bool]:
        """Drain ``uid``'s tokens emitted since the last poll, plus a
        finished flag. ``([], True)`` after the final drain."""
        out = self._pending.get(uid, [])
        self._pending[uid] = []
        return out, uid in self._finished

    @property
    def drained(self) -> bool:
        return self.engine.drained


def _parse_args():
    # configs/argparse only — jax is imported after --devices is applied
    from repro.configs import list_archs
    from repro.kernels import registry

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--int4", action="store_true",
                    help="serve int4-packed PSQ deployment weights")
    ap.add_argument("--psq-packed", action="store_true",
                    help="serve the full HCiM pipeline from the "
                         "weight-stationary PackedLayer cache")
    ap.add_argument("--backend", default=None,
                    choices=registry.registered_backends(),
                    help="kernel backend for --psq-packed "
                         "(default: 'reference' on CPU)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slot-pool size (static: batch size)")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "continuous", "static"],
                    help="scheduler: continuous batching (KV families) "
                         "or the static drain-the-queue loop")
    ap.add_argument("--decode-horizon", type=int, default=1,
                    help="greedy decode steps per on-device while-loop "
                         "round-trip (continuous scheduler; 1 = one "
                         "host sync per token)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: page pool + block tables + "
                         "shared-prefix radix reuse (continuous only; "
                         "see docs/memory.md)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV page for --paged "
                         "(must divide --max-len)")
    ap.add_argument("--no-prefix-reuse", action="store_true",
                    help="keep the paged layout but disable the "
                         "shared-prefix radix index")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft-proposed tokens "
                         "per verify round (0 = off; continuous greedy "
                         "KV families only)")
    ap.add_argument("--draft", default=None, choices=list_archs(),
                    help="draft arch for --spec-k (same family; "
                         "default: 1-layer copy of --arch)")
    ap.add_argument("--energy-style", default="hcim",
                    choices=["adc", "quarry", "hcim"],
                    help="hwmodel accounting style for the per-request "
                         "energy/EDAP attribution in stats() "
                         "(docs/energy.md)")
    ap.add_argument("--admission", default="fcfs",
                    choices=["fcfs", "cost-aware"],
                    help="admission policy: pow2-bucket FIFO waves, or "
                         "energy-budgeted admission against the modeled "
                         "per-request serve energy (docs/scheduling.md)")
    ap.add_argument("--energy-budget", type=float, default=0.0,
                    metavar="PJ",
                    help="in-flight modeled-energy cap in pJ for "
                         "--admission cost-aware")
    ap.add_argument("--streaming", action="store_true",
                    help="serve through the incremental submit/poll "
                         "front-end (arrivals mid-flight, tokens out "
                         "per round) instead of one blocking run()")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL[,EXPERT]",
                    help="mesh axis sizes, e.g. 1,4 (model-parallel PSQ "
                         "columns), 2,2, or 1,1,4 (expert-parallel MoE "
                         "serving); needs DATA*MODEL*EXPERT devices "
                         "(default: all devices data-parallel)")
    ap.add_argument("--devices", type=int, default=0,
                    help="CPU only: forge N virtual devices via XLA_FLAGS "
                         "(must be the first JAX use in the process)")
    return ap.parse_args()


def main():
    args = _parse_args()
    if args.devices:
        from repro.launch.mesh import force_host_device_count

        force_host_device_count(args.devices)

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.config import PSQ_TERNARY
    from repro.core.psq_linear import pack_tree_for_serving
    from repro.kernels import registry
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_model
    from repro.serve import (
        EngineConfig, PackedModelCache, ServeEngine, pack_tree_psq,
        throughput_stats,
    )

    cfg = get_config(args.arch).reduced()
    if args.mesh:
        sizes = tuple(int(v) for v in args.mesh.split(","))
        if len(sizes) not in (2, 3):
            raise SystemExit(
                f"--mesh takes DATA,MODEL or DATA,MODEL,EXPERT sizes, "
                f"got {args.mesh!r}"
            )
        axes = ("data", "model", "expert")[: len(sizes)]
        n = math.prod(sizes)
        if n > len(jax.devices()):
            raise SystemExit(
                f"--mesh {args.mesh} needs {n} devices, have "
                f"{len(jax.devices())} (on CPU add --devices {n})"
            )
        if len(sizes) == 3 and sizes[2] > 1 and cfg.family != "moe":
            raise SystemExit(
                f"--mesh {args.mesh}: an expert axis > 1 only applies to "
                f"MoE archs; {args.arch} has no experts"
            )
        mesh = jax.make_mesh(sizes, axes)
    else:
        mesh = make_host_mesh()
    print(f"[serve] mesh: "
          f"{'x'.join(f'{k}={v}' for k, v in mesh.shape.items())}  "
          f"backends: {registry.describe()}")

    if args.psq_packed:
        backend = args.backend or (
            "reference" if jax.default_backend() == "cpu" else "pallas"
        )
        qcfg = dataclasses.replace(PSQ_TERNARY, kernel_backend=backend)
        cfg = cfg.with_quant(qcfg)
        params = init_model(jax.random.PRNGKey(0), cfg)
        cache = PackedModelCache()
        params = pack_tree_psq(params, qcfg, cache, mesh=mesh)
        print(f"[serve] packed {cache.stats()['layers']} layers once "
              f"(backend={backend}, column-sharded over the model axis)")
    else:
        params = init_model(jax.random.PRNGKey(0), cfg)
    if args.int4:
        params = pack_tree_for_serving(params)

    draft_cfg, draft_params = None, None
    if args.spec_k:
        draft_cfg = (get_config(args.draft).reduced() if args.draft
                     else dataclasses.replace(cfg, n_layers=1))
        draft_params = init_model(jax.random.PRNGKey(1), draft_cfg)
        print(f"[serve] spec decode: k={args.spec_k}, draft "
              f"{args.draft or '1-layer copy'} "
              f"({draft_cfg.n_layers} layers)")

    extra = {}
    rng = np.random.RandomState(0)
    if cfg.family == "encdec":
        extra["enc_embeds"] = rng.randn(
            args.requests, args.max_len, cfg.d_model
        ).astype(np.float32) * 0.1
    eng = ServeEngine(
        params, cfg,
        EngineConfig(max_batch=args.slots, max_len=args.max_len,
                     temperature=args.temperature, mode=args.mode,
                     decode_horizon=args.decode_horizon,
                     paged=args.paged, block_size=args.block_size,
                     prefix_reuse=not args.no_prefix_reuse,
                     energy_style=args.energy_style,
                     spec_k=args.spec_k, draft_config=draft_cfg,
                     admission_policy=args.admission,
                     energy_budget_pj=args.energy_budget),
        extra_inputs=extra,
        mesh=mesh,
        draft_params=draft_params,
    )
    prompts = [rng.randint(0, cfg.vocab_size, size=rng.randint(4, 16))
               for _ in range(args.requests)]
    if args.streaming:
        fe = StreamingFrontend(eng)
        uids: list = []
        rounds = 0
        pending = list(prompts)
        while pending or not fe.drained:
            # stagger arrivals: two submits per round exercises
            # mid-flight admission instead of one up-front wave
            for p in pending[:2]:
                uids.append(fe.submit(p, max_new_tokens=args.max_new_tokens))
            del pending[:2]
            fe.step()
            rounds += 1
            for uid in uids:
                toks, done_flag = fe.poll(uid)
                if toks:
                    print(f"[stream] round {rounds:3d} uid {uid}: "
                          f"+{len(toks)} tok"
                          f"{' (done)' if done_flag else ''}")
        done = eng.finished
        print(f"[stream] drained after {rounds} rounds")
    else:
        for p in prompts:
            eng.submit(p, max_new_tokens=args.max_new_tokens)
        done = eng.run()
    stats = throughput_stats(done)
    sched = eng.stats()
    fmt = "psq-packed" if args.psq_packed else ("int4" if args.int4 else "fp")
    print(f"[serve] {args.arch} weights={fmt} scheduler={sched}")
    print(f"[serve] {args.arch} weights={fmt}: {stats}")
    if args.spec_k:
        print(f"[serve] {args.arch} spec: rounds={sched['spec_rounds']}, "
              f"accept_rate={sched['spec_accept_rate']:.3f}")
    if args.admission == "cost-aware":
        print(f"[serve] {args.arch} admission=cost-aware "
              f"budget={args.energy_budget:.0f} pJ "
              f"deferrals={sched['admission_deferrals']}")
    print(f"[serve] {args.arch} energy[{sched['energy_style']}]: "
          f"{sched['energy_pj_total']:.1f} pJ total, "
          f"{sched['energy_pj_per_request']:.1f} pJ/request, "
          f"edap {sched['edap_total']:.3g}, "
          f"mean occupancy {sched['mean_occupancy']:.3f}")


if __name__ == "__main__":
    main()
