"""Serving launcher: continuous-batching prefill/decode on the devices.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 8 [--int4 | --psq-packed] [--backend reference] \
        [--slots 4] [--mode auto|continuous|static]

KV-cache families serve through the continuous-batching slot pool
(per-step retirement + mid-flight admission, see docs/serving.md);
recurrent/side-input families fall back to static batching.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.core.config import PSQ_TERNARY
from repro.core.psq_linear import pack_tree_for_serving
from repro.kernels import registry
from repro.launch.mesh import make_host_mesh
from repro.models import init_model
from repro.parallel.sharding import RULES_2D, axis_rules
from repro.serve import (
    EngineConfig, PackedModelCache, ServeEngine, pack_tree_psq,
    throughput_stats,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--int4", action="store_true",
                    help="serve int4-packed PSQ deployment weights")
    ap.add_argument("--psq-packed", action="store_true",
                    help="serve the full HCiM pipeline from the "
                         "weight-stationary PackedLayer cache")
    ap.add_argument("--backend", default=None,
                    choices=registry.registered_backends(),
                    help="kernel backend for --psq-packed "
                         "(default: 'reference' on CPU)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slot-pool size (static: batch size)")
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "continuous", "static"],
                    help="scheduler: continuous batching (KV families) "
                         "or the static drain-the-queue loop")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.psq_packed:
        backend = args.backend or (
            "reference" if jax.default_backend() == "cpu" else "pallas"
        )
        qcfg = dataclasses.replace(PSQ_TERNARY, kernel_backend=backend)
        cfg = cfg.with_quant(qcfg)
        params = init_model(jax.random.PRNGKey(0), cfg)
        cache = PackedModelCache()
        params = pack_tree_psq(params, qcfg, cache)
        print(f"[serve] packed {cache.stats()['layers']} layers once "
              f"(backend={backend})")
    else:
        params = init_model(jax.random.PRNGKey(0), cfg)
    if args.int4:
        params = pack_tree_for_serving(params)

    mesh = make_host_mesh()
    extra = {}
    rng = np.random.RandomState(0)
    if cfg.family == "encdec":
        extra["enc_embeds"] = rng.randn(
            args.requests, args.max_len, cfg.d_model
        ).astype(np.float32) * 0.1
    with mesh, axis_rules(RULES_2D, mesh):
        eng = ServeEngine(
            params, cfg,
            EngineConfig(max_batch=args.slots, max_len=args.max_len,
                         temperature=args.temperature, mode=args.mode),
            extra_inputs=extra,
        )
        for _ in range(args.requests):
            eng.submit(rng.randint(0, cfg.vocab_size, size=rng.randint(4, 16)),
                       max_new_tokens=args.max_new_tokens)
        done = eng.run()
    stats = throughput_stats(done)
    fmt = "psq-packed" if args.psq_packed else ("int4" if args.int4 else "fp")
    print(f"[serve] {args.arch} weights={fmt} scheduler={eng.stats()}")
    print(f"[serve] {args.arch} weights={fmt}: {stats}")


if __name__ == "__main__":
    main()
