"""Training launcher: builds the mesh from available devices, activates
the logical sharding rules, and drives the fault-tolerant Trainer.

On the production fleet this binary runs once per host (jax.distributed
initializes from the cluster env); on a dev box it runs the same code on
however many local devices exist.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --preset reduced --steps 50 [--quant psq] [--model-parallel 2]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, list_archs
from repro.core.config import PSQ_TERNARY
from repro.data import DataConfig, TokenStream
from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import RULES_2D, axis_rules
from repro.train import OptConfig, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=list_archs())
    ap.add_argument("--preset", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--quant", default="none", choices=["none", "psq", "binary"])
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.preset == "reduced":
        cfg = cfg.reduced()
    if args.quant != "none":
        q = PSQ_TERNARY if args.quant == "psq" else dataclasses.replace(
            PSQ_TERNARY, psq_levels="binary"
        )
        cfg = cfg.with_quant(dataclasses.replace(q, xbar_rows=64))

    mesh = make_host_mesh(model_parallel=args.model_parallel)
    stream = TokenStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.global_batch,
    ))

    def data_fn(step):
        b = stream.batch_at(step)
        if cfg.family == "encdec":
            import numpy as np

            b["enc_embeds"] = np.zeros(
                (args.global_batch, args.seq_len, cfg.d_model), np.float32
            )
        return b

    with mesh, axis_rules(RULES_2D, mesh):
        trainer = Trainer(
            cfg,
            OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 2),
                      total_steps=args.steps),
            TrainerConfig(
                total_steps=args.steps,
                ckpt_every=max(args.steps // 3, 10),
                log_every=max(args.steps // 10, 1),
                ckpt_dir=args.ckpt_dir,
                compress_grads=args.compress_grads,
            ),
            data_fn=data_fn,
        )
        trainer.train()
    print(f"[train] done: {args.arch} ({args.preset}, quant={args.quant}) "
          f"on mesh {dict(mesh.shape)}")


if __name__ == "__main__":
    main()
