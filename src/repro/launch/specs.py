"""Abstract input specs + sharding for every (arch x shape x mesh) cell.

``input_specs(arch, shape)`` returns ShapeDtypeStruct stand-ins (no
device allocation) for everything the lowered step consumes; the
companion ``*_shardings`` map them to NamedShardings via path-pattern
rules with divisibility guards.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.models import decode as D
from repro.train.optimizer import init_opt_state
from repro.train.trainer import TrainState

PyTree = Any


# ---------------------------------------------------------------------------
# shape cells (assigned input-shape set for the LM pool)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_is_applicable(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """long_500k only for sub-quadratic archs; decode only with a decoder."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k context skipped (DESIGN.md)"
    if cell.kind == "decode" and not cfg.has_decoder:
        return False, "encoder-only arch has no decode step"
    return True, ""


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict[str, jax.ShapeDtypeStruct]:
    b = cell.global_batch
    s = cell.seq_len
    i32 = jnp.int32
    if cell.kind == "train":
        out = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "encdec":
            out["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16
            )
        return out
    if cell.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "encdec":
            out["enc_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), jnp.bfloat16
            )
        return out
    # decode: one new token against a cache of seq_len
    return {"token": jax.ShapeDtypeStruct((b, 1), i32)}


def _batch_pspec(name: str, ndim: int, dp) -> P:
    spec = [dp] + [None] * (ndim - 1)
    return P(*spec)


def batch_shardings(
    specs: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh, dp
) -> Dict[str, NamedSharding]:
    out = {}
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    for k, v in specs.items():
        ax = dp if v.shape and v.shape[0] % dp_size == 0 else None
        out[k] = NamedSharding(mesh, _batch_pspec(k, max(v.ndim, 1), ax))
    return out


# ---------------------------------------------------------------------------
# parameter / optimizer / cache specs
# ---------------------------------------------------------------------------

def abstract_params(cfg: ArchConfig) -> PyTree:
    return jax.eval_shape(lambda: T.init_model(jax.random.PRNGKey(0), cfg))


def abstract_state(cfg: ArchConfig) -> PyTree:
    def mk():
        p = T.init_model(jax.random.PRNGKey(0), cfg)
        return TrainState(params=p, opt=init_opt_state(p))

    return jax.eval_shape(mk)


def abstract_cache(cfg: ArchConfig, cell: ShapeCell, params_sds: PyTree) -> PyTree:
    enc_sds = None
    if cfg.family == "encdec":
        enc_sds = jax.ShapeDtypeStruct(
            (cell.global_batch, cell.seq_len, cfg.d_model), jnp.bfloat16
        )

    def mk(p, enc):
        return D.init_cache(
            p, cfg, cell.global_batch, cell.seq_len,
            dtype=jnp.bfloat16, enc_out=enc,
        )

    return jax.eval_shape(mk, params_sds, enc_sds)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _guard(spec, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not divide the dimension."""
    out = []
    for i, ax in enumerate(spec):
        if ax is not None and (
            i >= len(shape) or shape[i] % _axis_size(mesh, ax) != 0
        ):
            ax = None
        out.append(ax)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspec(path, leaf, mesh: Mesh, mdl="model") -> P:
    """Pattern rules: trailing-dims spec by layer-name, leading stack dims
    replicated."""
    keys = [
        str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
        for p in path
    ]
    last = keys[-1]
    parent = keys[-2] if len(keys) >= 2 else ""
    shape = leaf.shape

    def tail(spec_tail):
        lead = [None] * (len(shape) - len(spec_tail))
        return _guard(lead + list(spec_tail), shape, mesh)

    if "embed" in keys and last == "table":
        return tail([mdl, None])
    if "lm_head" in keys and last == "w":
        return tail([None, mdl])
    if parent in ("wq", "wk", "wv", "gate", "up", "fc", "up_proj", "in_proj",
                  "w_in") and last in ("w", "w_packed", "w_scale"):
        return tail([None, mdl])
    if parent in ("wo", "down", "proj", "down_proj", "out_proj") and last in (
            "w", "w_packed"):
        return tail([mdl, None])
    if parent in ("wo", "down", "proj", "down_proj", "out_proj") and last == "w_scale":
        return tail([None, None])
    if "lm_head" in keys and last in ("w_packed", "w_scale"):
        return tail([None, mdl])
    if last == "b" and parent in ("wq", "wk", "wv", "gate", "up", "fc",
                                  "up_proj", "in_proj", "w_in"):
        return tail([mdl])
    if last == "router":
        return tail([None, None])
    if last in ("w_gate", "w_up", "w_down"):
        e, d1, d2 = shape[-3], shape[-2], shape[-1]
        if e % _axis_size(mesh, mdl) == 0:
            return tail([mdl, None, None])       # expert parallelism
        if last == "w_down":
            return tail([None, mdl, None])       # shard expert ffn dim
        return tail([None, None, mdl])
    if last == "conv_w":
        return tail([None, mdl])
    if last == "conv_b":
        return tail([mdl])
    if last in ("A_log", "D", "dt_bias"):
        return tail([mdl])
    if last == "sf":                              # PSQ scale factors
        return tail([None, None, None, mdl])
    if last in ("wq", "wk", "wv") and len(shape) >= 3:  # xlstm head-blockdiag
        return tail([mdl, None, None])
    return P()  # norms, scalars, thresholds, biases -> replicated


def tree_shardings(
    tree_sds: PyTree, mesh: Mesh, spec_fn: Callable
) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_sds)
    shardings = [
        NamedSharding(mesh, spec_fn(path, leaf, mesh)) for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, shardings)


def cache_pspec(path, leaf, mesh: Mesh, long_ctx: bool, dp) -> P:
    keys = [
        str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
        for p in path
    ]
    last = keys[-1]
    shape = leaf.shape
    if last in ("k", "v") and len(shape) == 5:
        # (L, B, S, Hk, D): batch over data; sequence over model (32k) or
        # data x model (500k, where batch=1 cannot shard)
        if long_ctx:
            return _guard([None, None, ("data", "model"), None, None], shape, mesh)
        return _guard([None, dp, "model", None, None], shape, mesh)
    if last in ("state",):      # mamba (L, B, H, N, P)
        return _guard([None, dp, "model", None, None], shape, mesh)
    if last in ("C",):          # mlstm (L, B, H, dk, dv)
        return _guard([None, dp, "model", None, None], shape, mesh)
    if last in ("n",) and len(shape) >= 3:
        return _guard([None, dp, "model"] + [None] * (len(shape) - 3), shape, mesh)
    if len(shape) >= 2:
        return _guard([None, dp] + [None] * (len(shape) - 2), shape, mesh)
    return P()
