"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries only data parallelism (gradient all-reduce), i.e. the
only collectives crossing the inter-pod DCN are reductions, optionally
int8-compressed (repro.train.fault.compressed_gradient).

A function, not a module constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever-is-available mesh for tests / elastic re-meshing demos."""
    n = len(jax.devices())
    from repro.train.fault import remesh_plan

    data, model = remesh_plan(n, model_parallel)
    return jax.make_mesh((data, model), ("data", "model"))


def force_host_device_count(n: int) -> None:
    """Forge ``n`` virtual CPU devices via ``XLA_FLAGS``.

    Must run before the XLA backend initializes — importing jax is fine,
    touching devices/arrays is not (the flag is read once at backend
    init). A count already present in ``XLA_FLAGS`` wins, so an explicit
    environment (CI jobs, tests/conftest.py) is never overridden.
    """
    import os

    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}"
        )
