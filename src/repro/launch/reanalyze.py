"""Re-derive dry-run JSON metrics from stored .hlo.gz without recompiling.

The dry-run persists post-optimization HLO next to each cell's JSON;
when the HLO analyzer improves, this tool refreshes flops/bytes/
collectives in place (seconds instead of the ~40 min compile sweep).

    PYTHONPATH=src python -m repro.launch.reanalyze
"""
from __future__ import annotations

import glob
import gzip
import json
import os

from repro.launch.hlo_analysis import analyze

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def main():
    n = 0
    for fn in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        stem = fn[: -len(".json")]
        hlo_fn = stem + ".hlo.gz"
        if not os.path.exists(hlo_fn):
            continue
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        with gzip.open(hlo_fn, "rt") as f:
            hlo = f.read()
        an = analyze(hlo)
        rec["flops_per_device"] = an["flops"]
        rec["bytes_per_device"] = an["bytes"]
        rec["collective_bytes_per_device"] = an["collectives"]
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
        print(f"[reanalyze] {os.path.basename(stem)}: "
              f"{an['flops']/1e12:.2f} TF, {an['bytes']/1e9:.1f} GB, "
              f"coll {an['collectives'].get('total',0)/1e9:.2f} GB", flush=True)
    print(f"[reanalyze] {n} cells refreshed")


if __name__ == "__main__":
    main()
