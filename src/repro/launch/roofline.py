"""§Roofline: three-term analysis from the compiled dry-run artifacts.

    compute_s    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF bf16)
    memory_s     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
    collective_s = collective_bytes_per_device / link_bw    (50 GB/s/link)

FLOPs/bytes come from the scan-aware HLO analyzer (hlo_analysis.py);
collective bytes use the result-size proxy summed over all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute with loop
trip multiplication. MODEL_FLOPS is the analytic 6ND(+attention) count;
its ratio to HLO FLOPs flags remat/dispatch waste.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.launch import specs as S

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link (1-link conservative)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def load_cells(mesh: Optional[str] = None, quant: Optional[str] = None) -> List[Dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            out.append(rec)
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        if quant is not None and rec.get("quant", "none") != quant:
            continue
        out.append(rec)
    return out


def roofline_terms(rec: Dict) -> Dict:
    from repro.launch.dryrun import model_flops  # late import (XLA flags)

    cfg = get_config(rec["arch"])
    cell = S.SHAPES[rec["shape"]]
    n_chips = rec.get("n_chips", 256)
    mf = model_flops(cfg, cell)
    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    memory_s = rec["bytes_per_device"] / HBM_BW
    coll = rec["collective_bytes_per_device"].get("total", 0.0)
    collective_s = coll / LINK_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "model_flops_per_chip": mf / n_chips,
        "useful_ratio": (mf / n_chips) / max(rec["flops_per_device"], 1.0),
        "step_s_bound": max(compute_s, memory_s, collective_s),
    }
    dom = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1],
    )[0]
    terms["dominant"] = dom
    # roofline fraction: useful model flops per chip over what the peak
    # could deliver in the bound step time
    terms["roofline_frac"] = (
        terms["model_flops_per_chip"] / PEAK_FLOPS
    ) / max(terms["step_s_bound"], 1e-12)
    return terms


_SUGGEST = {
    "compute": "cut non-model FLOPs (remat policy, MoE dispatch, attn chunking)",
    "memory": "shrink resident/streamed bytes (int4/PSQ weights, bf16 master, fused attn)",
    "collective": "reshard to cut gathers (seq-parallel attn, reduce-scatter grads, overlap)",
}


def table(cells: List[Dict], md: bool = True) -> str:
    rows = []
    for rec in cells:
        if rec.get("status") == "skipped":
            rows.append(
                (rec["cell"].split("|")[0], rec["cell"].split("|")[1], "—",
                 "—", "—", "—", "—", "—", f"SKIP: {rec['reason'][:40]}")
            )
            continue
        if rec.get("status") != "ok":
            rows.append((rec.get("cell", "?"), "", "—", "—", "—", "—", "—",
                         "—", f"FAIL"))
            continue
        t = roofline_terms(rec)
        rows.append((
            rec["arch"], rec["shape"],
            f"{t['compute_s']*1e3:.1f}", f"{t['memory_s']*1e3:.1f}",
            f"{t['collective_s']*1e3:.1f}", t["dominant"],
            f"{t['useful_ratio']:.2f}", f"{t['roofline_frac']*100:.1f}%",
            _SUGGEST[t["dominant"]],
        ))
    hdr = ("arch", "shape", "T_comp ms", "T_mem ms", "T_coll ms",
           "bound", "useful", "roofline", "what would move it")
    if not md:
        return "\n".join(",".join(map(str, r)) for r in [hdr] + rows)
    w = [max(len(str(r[i])) for r in [hdr] + rows) for i in range(len(hdr))]
    lines = ["| " + " | ".join(str(h).ljust(w[i]) for i, h in enumerate(hdr)) + " |",
             "|" + "|".join("-" * (w[i] + 2) for i in range(len(hdr))) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(str(c).ljust(w[i]) for i, c in enumerate(r)) + " |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--quant", default="none")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    cells = load_cells(mesh=args.mesh, quant=args.quant)
    print(table(cells, md=not args.csv))


if __name__ == "__main__":
    main()
