"""H2O-Danube-3-4B [arXiv:2401.16818]: llama+mistral mix with SWA.

Sliding-window attention (4096) makes prefill sub-quadratic and decode
attention O(window), so this arch serves the long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, head_dim=120,
    sliding_window=4096, subquadratic=True,
)
