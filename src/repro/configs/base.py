"""Architecture configuration schema for the assigned model pool."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.config import DENSE, QuantConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention flavor
    qk_norm: bool = False
    sliding_window: int = 0        # 0 = full attention
    rope_theta: float = 10000.0
    attn_bias: bool = False
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    act: str = "swiglu"            # swiglu | gelu
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    moe_chunk: int = 4096
    moe_impl: str = "dispatch"     # dispatch | dense (weighted-dense mixture)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0            # hybrid: shared attn block period (zamba2)
    # xLSTM
    slstm_every: int = 0           # xlstm: sLSTM block period
    xlstm_proj_factor: float = 2.0
    # encoder-decoder
    n_enc_layers: int = 0
    # modality frontend stub ("none" | "audio" | "vision")
    frontend: str = "none"
    frontend_len: int = 0          # stub embedding positions per sample
    # capability flags
    subquadratic: bool = False     # can serve long_500k
    has_decoder: bool = True
    # execution
    quant: QuantConfig = DENSE
    remat: str = "none"            # none | block (activation checkpointing)
    attn_impl: str = "naive"       # naive | flash (chunked online softmax)
    compute_dtype: str = "f32"     # f32 | bf16 (activation/compute dtype)
    # scan_layers=False unrolls every layer scan into an explicit Python
    # loop over the same stacked params — the slow-compile reference the
    # golden-parity suite pins the scan path against (bit-exact by
    # construction: identical per-layer math, only the loop construct
    # differs)
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def with_quant(self, quant: QuantConfig) -> "ArchConfig":
        return dataclasses.replace(self, quant=quant)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND math."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.act == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        per_layer = 0
        n_attn_layers = self.n_layers
        if self.family == "moe":
            e_ff = self.moe_d_ff or self.d_ff
            moe = self.n_experts * 3 * d * e_ff + d * self.n_experts
            per_layer = attn + moe + (3 * d * e_ff if self.dense_residual else 0)
            total_blocks = self.n_layers * per_layer
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            mamba = d * (2 * di + 2 * self.ssm_state + di // self.ssm_head_dim) + di * d
            n_attn = self.n_layers // max(self.attn_every, 1)
            # zamba2: ONE shared attn+mlp block reused at every attn slot
            total_blocks = self.n_layers * mamba + (attn + mlp)
        elif self.family == "ssm":
            di = int(self.xlstm_proj_factor * d)
            hd = di // self.n_heads
            # q/k/v are block-diagonal per head in xLSTM
            mlstm = d * 2 * di + 3 * self.n_heads * hd * hd + di * d
            total_blocks = self.n_layers * mlstm
        else:
            per_layer = attn + mlp
            total_blocks = self.n_layers * per_layer
            if self.family == "encdec":
                total_blocks += self.n_enc_layers * (attn + mlp) + self.n_layers * attn
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total_blocks + emb

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 3 if self.attn_every == 0 else 7),
            n_enc_layers=min(self.n_enc_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 8),
            moe_top_k=min(self.moe_top_k, 2),
            moe_d_ff=128 if self.moe_d_ff else 0,
            moe_chunk=64,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            attn_every=3 if self.attn_every else 0,
            slstm_every=2 if self.slstm_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            frontend_len=min(self.frontend_len, 16) if self.frontend_len else 0,
        )
