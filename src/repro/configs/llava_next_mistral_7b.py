"""LLaVA-NeXT (mistral-7b backbone) [hf:llava-hf/llava-v1.6-mistral-7b]:
dense decoder consuming stub anyres patch embeddings (frontend_len
positions prepended; the vision tower itself is out of scope)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, head_dim=128,
    frontend="vision", frontend_len=576,
)
