"""Whisper-large-v3 backbone [arXiv:2212.04356]: 32-layer encoder +
32-layer decoder with cross attention. The conv/audio frontend is a STUB:
input_specs() supplies precomputed frame embeddings (B, S, d).

Adaptation note (DESIGN.md): the backbone uses RoPE in place of whisper's
learned/sinusoidal absolute positions — the assigned spec covers the
transformer backbone only.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866, head_dim=64,
    norm_type="layernorm", act="gelu", attn_bias=True,
    frontend="audio",
)
