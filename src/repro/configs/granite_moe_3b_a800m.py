"""IBM Granite-MoE-3B-A800M [hf:ibm-granite]: 40 experts, top-8."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, head_dim=64,
    n_experts=40, moe_top_k=8, moe_d_ff=512, tie_embeddings=True,
)
