"""xLSTM-350M [arXiv:2405.04517]: mLSTM blocks with sLSTM every 6th.
Recurrent state (no KV cache) -> serves long_500k natively."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    slstm_every=6, xlstm_proj_factor=2.0, subquadratic=True,
)
