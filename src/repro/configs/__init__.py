"""Architecture registry: --arch <id> resolves here."""
from typing import Dict, List

from repro.configs.base import ArchConfig
from repro.configs.starcoder2_3b import CONFIG as _starcoder2
from repro.configs.qwen3_14b import CONFIG as _qwen3
from repro.configs.tinyllama_1_1b import CONFIG as _tinyllama
from repro.configs.h2o_danube_3_4b import CONFIG as _danube
from repro.configs.zamba2_7b import CONFIG as _zamba2
from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.xlstm_350m import CONFIG as _xlstm
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.llava_next_mistral_7b import CONFIG as _llava

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _starcoder2, _qwen3, _tinyllama, _danube, _zamba2,
        _arctic, _granite, _xlstm, _whisper, _llava,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> List[str]:
    return sorted(ARCHS)
