"""Snowflake Arctic-480B [hf:Snowflake/snowflake-arctic-base]:
128-expert top-2 MoE with a parallel dense residual FFN."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab_size=32000, head_dim=128,
    n_experts=128, moe_top_k=2, moe_d_ff=4864, dense_residual=True,
    moe_chunk=2048,
)
