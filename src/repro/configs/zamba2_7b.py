"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone + ONE shared attention
block applied every 6 layers (weight sharing is zamba's signature).
SSM-dominant -> serves long_500k."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
    subquadratic=True,
)
