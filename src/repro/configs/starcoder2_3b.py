"""StarCoder2-3B [arXiv:2402.19173; hf]: GQA(kv=2), RoPE, GELU, LN, bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12288,
    vocab_size=49152, head_dim=128,
    norm_type="layernorm", act="gelu", attn_bias=True,
    rope_theta=1e5, tie_embeddings=True,
)
