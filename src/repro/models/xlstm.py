"""xLSTM blocks (Beck et al., arXiv:2405.04517) for the xlstm-350m arch.

mLSTM: matrix-memory LSTM with exponential gating. Training/prefill uses
the stabilized parallel (quadratic-masked) formulation; decode uses the
O(1)-per-step recurrence on a (d_k, d_v) state — tests assert the two
agree. sLSTM: scalar-memory recurrent cell with per-head block-diagonal
recurrence, evaluated with lax.scan.

All projections are PSQLinear (HCiM applies to the whole block).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import QuantConfig
from repro.core.psq_linear import apply_linear, init_linear
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_rmsnorm, init_rmsnorm
from repro.parallel.sharding import constrain

Params = Dict


class XLSTMConfig(NamedTuple):
    d_model: int
    n_heads: int = 4
    proj_factor: float = 2.0      # mLSTM inner expansion
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return int(self.proj_factor * self.d_model)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key: jax.Array, cfg: XLSTMConfig, quant: QuantConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, di = cfg.d_model, cfg.d_inner
    h, hd = cfg.n_heads, cfg.head_dim
    std = 1.0 / math.sqrt(hd)
    return {
        "up_proj": init_linear(ks[0], d, 2 * di, quant),
        # q/k/v are block-diagonal per head (xLSTM's head-wise projections
        # — this is what puts the 24L/d1024 config at ~350M params)
        "wq": jax.random.normal(ks[1], (h, hd, hd)) * std,
        "wk": jax.random.normal(ks[2], (h, hd, hd)) * std,
        "wv": jax.random.normal(ks[3], (h, hd, hd)) * std,
        "w_if": init_linear(ks[4], di, 2 * cfg.n_heads, quant),
        "conv_w": jax.random.normal(ks[5], (cfg.conv_width, di)) * 0.2,
        "conv_b": jnp.zeros((di,)),
        "out_norm": init_rmsnorm(di),
        "down_proj": init_linear(ks[6], di, d, quant),
    }


def _head_proj(x_heads: jax.Array, w: jax.Array) -> jax.Array:
    """Block-diagonal projection: (..., H, Dh) x (H, Dh, Dh)."""
    return jnp.einsum("...hd,hde->...he", x_heads, w)


def _causal_conv(x, w, b):
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def _mlstm_parallel(q, k, v, i_pre, f_pre):
    """Stabilized parallel mLSTM (Beck et al. eq. 19-27).

    q,k,v: (B, S, H, D); i_pre/f_pre: (B, S, H) pre-activation gates.
    """
    b, s, h, d = q.shape
    logf = jax.nn.log_sigmoid(f_pre)                    # (B,S,H)
    cums = jnp.cumsum(logf, axis=1)
    # D~[t, s'] = cumlogf_t - cumlogf_s' + i_s'  for s' <= t
    dmat = cums[:, :, None, :] - cums[:, None, :, :] + i_pre[:, None, :, :]
    tri = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)            # (B,S,1,H) stabilizer
    dexp = jnp.exp(dmat - m)
    scores = jnp.einsum("bshd,bthd->bsth", q, k) / math.sqrt(d)
    w = scores * dexp                                   # (B,S,S,H)
    norm = jnp.maximum(
        jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m[:, :, 0, :])
    )                                                   # (B,S,H)
    y = jnp.einsum("bsth,bthd->bshd", w, v) / norm[..., None]
    return y


def _mlstm_chunked(q, k, v, i_pre, f_pre, chunk: int = 128,
                   lengths: Optional[jax.Array] = None):
    """Chunk-scanned stabilized mLSTM == the parallel form (tested).

    Only an (B, L, L, H) intra-chunk tensor is live at a time, so the
    train_4k cell stays compilable; the carried (C, n, m) state is the
    same triple the decode recurrence uses.

    Positions at or beyond a row's limit — chunk padding, and everything
    past ``lengths[b]`` when per-row ``lengths`` are given (RIGHT-padded
    batches) — are exact state no-ops: the forget contribution is forced
    to ``log f = 0`` (keep) and the input gate to ``-1e30`` (no write),
    so the carry after the last true token matches an unpadded forward.
    """
    b, s, h, d = q.shape
    L = min(chunk, s)
    nc = math.ceil(s / L)
    pad = nc * L - s
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)))
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)))
    limit = s if lengths is None else lengths[:, None]
    valid = jnp.broadcast_to(
        jnp.arange(nc * L)[None, :] < limit, (b, nc * L)
    )
    split = lambda t: jnp.moveaxis(
        t.reshape(b, nc, L, *t.shape[2:]), 1, 0
    )
    qc, kc, vc, ic, fc = map(split, (q, k, v, i_pre, f_pre))
    vdc = split(valid)
    tri = jnp.tril(jnp.ones((L, L), bool))

    def step(carry, inp):
        C, n, m = carry                                  # (B,H,D,D),(B,H,D),(B,H)
        qt, kt, vt, it, ft, vd = inp                     # (B,L,...)
        # masked steps keep state exactly: log f = 0, input gate = -inf
        it = jnp.where(vd[..., None], it, -1e30)
        logf = jnp.where(
            vd[..., None], jax.nn.log_sigmoid(ft), 0.0
        )                                                # (B,L,H)
        bcum = jnp.cumsum(logf, axis=1)
        # intra-chunk log weights
        dmat = bcum[:, :, None, :] - bcum[:, None, :, :] + it[:, None, :, :]
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)                  # (B,L,H)
        m_inter = bcum + m[:, None, :]                   # old-state branch
        m_t = jnp.maximum(m_intra, m_inter)              # (B,L,H)
        dexp = jnp.exp(dmat - m_t[:, :, None, :])
        scores = jnp.einsum("blhd,bmhd->blmh", qt, kt) / math.sqrt(d)
        w = scores * dexp                                # (B,L,L,H)
        inter_scale = jnp.exp(m_inter - m_t)             # (B,L,H)
        num = jnp.einsum("blmh,bmhd->blhd", w, vt) + inter_scale[
            ..., None
        ] * jnp.einsum("blhd,bhdv->blhv", qt, C)
        den_intra = jnp.sum(w, axis=2)                   # (B,L,H)
        den_inter = inter_scale * jnp.einsum("blhd,bhd->blh", qt, n)
        den = jnp.maximum(jnp.abs(den_intra + den_inter), jnp.exp(-m_t))
        y = num / den[..., None]
        # carry update (composed decode steps over the chunk)
        m_state = jnp.maximum(
            bcum[:, -1, :] + m,
            jnp.max(bcum[:, -1:, :] - bcum + it, axis=1),
        )
        dec_old = jnp.exp(bcum[:, -1, :] + m - m_state)  # (B,H)
        wk = jnp.exp(bcum[:, -1:, :] - bcum + it - m_state[:, None, :])
        kt_s = kt / math.sqrt(d)
        C_new = C * dec_old[..., None, None] + jnp.einsum(
            "blh,blhd,blhv->bhdv", wk, kt_s, vt
        )
        n_new = n * dec_old[..., None] + jnp.einsum("blh,blhd->bhd", wk, kt_s)
        return (C_new, n_new, m_state), y

    C0 = jnp.zeros((b, h, d, d), q.dtype)
    n0 = jnp.zeros((b, h, d), q.dtype)
    m0 = jnp.full((b, h), -1e9, q.dtype)
    carry, ys = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, ic, fc, vdc))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * L, h, d)
    return y[:, :s], carry


def apply_mlstm(
    p: Params, x: jax.Array, cfg: XLSTMConfig, quant: QuantConfig,
    chunk: int = 128, return_cache: bool = False,
    lengths: Optional[jax.Array] = None,
):
    """Parallel (chunked) forward. x: (B, S, d).

    Per-row ``lengths`` (B,) mark each row's TRUE token count in a
    RIGHT-padded batch: padded positions are exact state no-ops inside
    :func:`_mlstm_chunked` and the returned conv cache is the per-row
    window ending at the true length — the final (C, n, m, conv) state
    matches an unpadded forward bit for bit (padded outputs are junk;
    callers read true positions only).
    """
    b, s, _ = x.shape
    up, stats = apply_linear(p["up_proj"], x, quant)
    xm, z = jnp.split(up, 2, axis=-1)
    xc = jax.nn.silu(_causal_conv(xm, p["conv_w"], p["conv_b"]))
    hshape = (b, s, cfg.n_heads, cfg.head_dim)
    q = _head_proj(xc.reshape(hshape), p["wq"])
    k = _head_proj(xc.reshape(hshape), p["wk"])
    v = _head_proj(xm.reshape(hshape), p["wv"])
    gates, _ = apply_linear(p["w_if"], xc, quant)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)         # (B,S,H)
    y, (C, n, m) = _mlstm_chunked(q, k, v, i_pre, f_pre, chunk=chunk,
                                  lengths=lengths)
    y = y.reshape(b, s, cfg.d_inner)
    y = apply_rmsnorm(p["out_norm"], y) * jax.nn.silu(z)
    y = constrain(y, "batch", "seq", "ssm_inner")
    out, st = apply_linear(p["down_proj"], y, quant)
    stats.update(st)
    if return_cache:
        tail = ssm_mod.conv_tail_window(xm, cfg.conv_width - 1, lengths)
        return out, stats, {"C": C, "n": n, "m": m, "conv": tail}
    return out, stats


def init_mlstm_cache(batch: int, cfg: XLSTMConfig, dtype=jnp.float32) -> Dict:
    h, d = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, h, d, d), dtype),    # matrix memory (k ⊗ v)
        "n": jnp.zeros((batch, h, d), dtype),
        "m": jnp.full((batch, h), -1e9, dtype),     # log-space stabilizer
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
    }


def decode_mlstm(
    p: Params, x: jax.Array, cache: Dict, cfg: XLSTMConfig, quant: QuantConfig
) -> Tuple[jax.Array, Dict, Dict]:
    """One-token recurrent step; math identical to the parallel form."""
    b = x.shape[0]
    up, stats = apply_linear(p["up_proj"], x, quant)
    xm, z = jnp.split(up[:, 0], 2, axis=-1)
    conv_buf = jnp.concatenate([cache["conv"], xm[:, None]], axis=1)
    xc = jnp.einsum("bwc,wc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    h, d = cfg.n_heads, cfg.head_dim
    qh = _head_proj(xc.reshape(b, h, d), p["wq"])
    kh = _head_proj(xc.reshape(b, h, d), p["wk"])
    vh = _head_proj(xm.reshape(b, h, d), p["wv"])
    gates, _ = apply_linear(p["w_if"], xc, quant)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)         # (B,H)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + cache["m"], i_pre)
    fw = jnp.exp(logf + cache["m"] - m_new)             # (B,H)
    iw = jnp.exp(i_pre - m_new)
    kh_s = kh / math.sqrt(d)
    C = cache["C"] * fw[..., None, None] + iw[..., None, None] * (
        kh_s[..., :, None] * vh[..., None, :]
    )
    n = cache["n"] * fw[..., None] + iw[..., None] * kh_s
    num = jnp.einsum("bhd,bhdv->bhv", qh, C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", qh, n)), jnp.exp(-m_new)
    )
    y = (num / den[..., None]).reshape(b, cfg.d_inner)
    y = apply_rmsnorm(p["out_norm"], y) * jax.nn.silu(z)
    out, st = apply_linear(p["down_proj"], y[:, None], quant)
    stats.update(st)
    new_cache = {"C": C, "n": n, "m": m_new, "conv": conv_buf[:, 1:]}
    return out, new_cache, stats


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key: jax.Array, cfg: XLSTMConfig, quant: QuantConfig) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    return {
        # input projections for the 4 gates (z, i, f, o)
        "w_in": init_linear(ks[0], d, 4 * d, quant),
        # block-diagonal recurrent kernel per head per gate
        "r": jax.random.normal(ks[1], (4, h, hd, hd)) * (1.0 / math.sqrt(hd)),
        "bias": jnp.zeros((4, d)),
        "out_norm": init_rmsnorm(d),
    }


def apply_slstm(
    p: Params, x: jax.Array, cfg: XLSTMConfig, quant: QuantConfig,
    return_cache: bool = False, lengths: Optional[jax.Array] = None,
):
    """Sequential sLSTM over time (lax.scan).

    Per-row ``lengths`` (B,) mark each row's TRUE token count in a
    RIGHT-padded batch: at padded steps the carried (c, n, m, h) state
    is held unchanged (per-row select), so the final cache matches an
    unpadded forward bit for bit.
    """
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    zin, stats = apply_linear(p["w_in"], x, quant)
    zin = zin.reshape(b, s, 4, d) + p["bias"]
    limit = s if lengths is None else lengths[:, None]
    valid = jnp.broadcast_to(jnp.arange(s)[None, :] < limit, (b, s))

    def step(carry, inp):
        c, n, m, hprev = carry                          # (B,d)/(B,d)/(B,h)/(B,d)
        pre, vd = inp                                   # (B,4,d), (B,)
        hh = hprev.reshape(b, h, hd)
        rec = jnp.einsum("ghij,bhj->gbhi", p["r"], hh).reshape(4, b, d)
        zt = jnp.tanh(pre[:, 0] + rec[0])
        i_pre = (pre[:, 1] + rec[1]).reshape(b, h, hd).mean(-1)   # per head
        f_pre = (pre[:, 2] + rec[2]).reshape(b, h, hd).mean(-1)
        ot = jax.nn.sigmoid(pre[:, 3] + rec[3])
        logf = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(logf + m, i_pre)
        fw = jnp.exp(logf + m - m_new)[..., None]       # (B,h,1)
        iw = jnp.exp(i_pre - m_new)[..., None]
        ch = c.reshape(b, h, hd) * fw + iw * zt.reshape(b, h, hd)
        nh = n.reshape(b, h, hd) * fw + iw
        hnew = ot * (ch / jnp.maximum(jnp.abs(nh), 1.0)).reshape(b, d)
        new = (ch.reshape(b, d), nh.reshape(b, d), m_new, hnew)
        # padded steps hold the carry (state no-op per row)
        keep = lambda nw, old: jnp.where(vd[:, None], nw, old)
        new = tuple(map(keep, new, (c, n, m, hprev)))
        return new, new[3]

    init = (
        jnp.zeros((b, d)), jnp.zeros((b, d)),
        jnp.full((b, h), -1e9), jnp.zeros((b, d)),
    )
    carry, ys = jax.lax.scan(
        step, init, (jnp.moveaxis(zin, 1, 0), jnp.moveaxis(valid, 1, 0))
    )
    y = jnp.moveaxis(ys, 0, 1)
    out = apply_rmsnorm(p["out_norm"], y)
    if return_cache:
        c, n, m, hprev = carry
        return out, stats, {"c": c, "n": n, "m": m, "h": hprev}
    return out, stats


def init_slstm_cache(batch: int, cfg: XLSTMConfig, dtype=jnp.float32) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, h), -1e9, dtype),
        "h": jnp.zeros((batch, d), dtype),
    }


def decode_slstm(
    p: Params, x: jax.Array, cache: Dict, cfg: XLSTMConfig, quant: QuantConfig
) -> Tuple[jax.Array, Dict, Dict]:
    b, _, d = x.shape
    h = cfg.n_heads
    hd = d // h
    zin, stats = apply_linear(p["w_in"], x, quant)
    pre = zin.reshape(b, 4, d) + p["bias"]
    hh = cache["h"].reshape(b, h, hd)
    rec = jnp.einsum("ghij,bhj->gbhi", p["r"], hh).reshape(4, b, d)
    zt = jnp.tanh(pre[:, 0] + rec[0])
    i_pre = (pre[:, 1] + rec[1]).reshape(b, h, hd).mean(-1)
    f_pre = (pre[:, 2] + rec[2]).reshape(b, h, hd).mean(-1)
    ot = jax.nn.sigmoid(pre[:, 3] + rec[3])
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + cache["m"], i_pre)
    fw = jnp.exp(logf + cache["m"] - m_new)[..., None]
    iw = jnp.exp(i_pre - m_new)[..., None]
    ch = cache["c"].reshape(b, h, hd) * fw + iw * zt.reshape(b, h, hd)
    nh = cache["n"].reshape(b, h, hd) * fw + iw
    hnew = ot * (ch / jnp.maximum(jnp.abs(nh), 1.0)).reshape(b, d)
    y = apply_rmsnorm(p["out_norm"], hnew)
    new_cache = {
        "c": ch.reshape(b, d), "n": nh.reshape(b, d), "m": m_new, "h": hnew
    }
    return y[:, None], new_cache, stats
