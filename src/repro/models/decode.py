"""Serving-side execution on the stacked-layer layout.

Caches are *stacked* pytrees (leading dim = #layers in the stack), so
``decode_step`` is a single ``lax.scan`` over (layer params, layer cache)
— one compiled block regardless of depth. Under the active rules table
(``parallel/sharding.py``) per-slot cache leaves follow the ``batch``
axis onto the ``data`` mesh axis; the KV sequence dim stays local except
for the 500k-context cells (``long_kv_seq`` -> ``data``).

``decode_step`` is exactly what launch/dryrun.py lowers for the
``decode_*`` / ``long_500k`` shape cells; ``prefill`` is the parallel
prompt pass that fills the same cache structure (no token-by-token scan:
attention K/V come from the parallel forward, SSM/xLSTM final states
from their chunked forms).

Two KV layouts share the same decode math:

* **contiguous** — one ``(B, max_len, H_kv, D)`` stripe per slot
  (``init_cache``/``cache_init``), the static path and the default
  continuous path. Recurrent families (ssm/xlstm/hybrid) ride this
  layout too: their per-slot state rows (SSM states, mLSTM/sLSTM
  triples, conv buffers — no sequence axis) scatter through the same
  ``cache_insert``, with ``prefill`` threading per-row true lengths so
  right-padded buckets stay bit-exact;
* **paged** — one ``(num_blocks, block_size, H_kv, D)`` page pool per
  layer plus per-slot block tables (``paged_cache_init`` /
  ``decode_step_paged`` / ``prefill_paged_suffix``), the
  continuous-engine layout that enables shared-prefix reuse
  (``serve/paged_kv.py``, docs/memory.md).

The serving engine does not call ``decode_step`` once per token: the
greedy hot loop runs through ``decode_multi_step`` /
``decode_multi_step_paged``, a device-side ``lax.while_loop`` that takes
up to ``decode_horizon`` steps per host round-trip (on-device argmax,
per-slot EOS/budget flags, retirement masks via ``step_mask``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.psq_linear import apply_linear
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.transformer import (
    attn_config,
    encode,
    layer_scan,
    ssm_config,
    stack_plan,
    xlstm_config,
)
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _kv_zeros(n: int, batch: int, max_len: int, cfg: ArchConfig,
              dtype, long_ctx: bool) -> Dict:
    seq_ax = "long_kv_seq" if long_ctx else "kv_seq"
    shape = (n, batch, max_len, cfg.n_kv_heads, cfg.resolved_head_dim)

    def z():
        # distinct buffers for k and v: a shared zeros array would be the
        # same buffer twice in a donated cache (serve engine donation)
        return constrain(jnp.zeros(shape, dtype),
                         None, "batch", seq_ax, "kv_heads", "head_dim")

    return {"k": z(), "v": z()}


def _constrain_state(tree):
    """Recurrent state pools follow the slot axis onto the mesh.

    Every stacked recurrent leaf — ``(n_layers, batch, ...)`` SSM
    states, mLSTM (C, n, m), sLSTM scalars, conv buffers — has the slot
    ("batch") axis at position 1; the ``recurrent_state -> data`` rule
    (``parallel/sharding.py``) shards it like the KV slot pool. No-op
    without active rules.
    """
    return jax.tree.map(
        lambda a: constrain(
            a, *([None, "recurrent_state"] + [None] * (a.ndim - 2))
        ),
        tree,
    )


def _stack_cache(init_one, n: int):
    if n == 0:
        return None
    return _constrain_state(jax.vmap(lambda _: init_one())(jnp.arange(n)))


def init_cache(
    params: Params, cfg: ArchConfig, batch: int, max_len: int,
    dtype=jnp.bfloat16, enc_out: Optional[jax.Array] = None,
) -> Dict:
    long_ctx = max_len >= 100_000
    plan = stack_plan(cfg)
    cache: Dict[str, Any] = {"length": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        cache["kv"] = _kv_zeros(cfg.n_layers, batch, max_len, cfg, dtype, long_ctx)
    elif cfg.family == "hybrid":
        g, pg, tail = plan["groups"], plan["per_group"], plan["tail"]
        scfg = ssm_config(cfg)
        cache["ssm_groups"] = _stack_cache(
            lambda: ssm_mod.init_mamba2_cache(batch, scfg), g * pg
        )
        cache["ssm_tail"] = _stack_cache(
            lambda: ssm_mod.init_mamba2_cache(batch, scfg), tail
        )
        cache["kv_shared"] = _kv_zeros(g, batch, max_len, cfg, dtype, long_ctx)
    elif cfg.family == "ssm":
        g, pg, tail = plan["groups"], plan["per_group"], plan["tail"]
        xcfg = xlstm_config(cfg)
        cache["mlstm_groups"] = _stack_cache(
            lambda: xlstm_mod.init_mlstm_cache(batch, xcfg), g * pg
        )
        cache["slstm"] = _stack_cache(
            lambda: xlstm_mod.init_slstm_cache(batch, xcfg), g
        )
        cache["mlstm_tail"] = _stack_cache(
            lambda: xlstm_mod.init_mlstm_cache(batch, xcfg), tail
        )
    if cfg.family == "encdec" and enc_out is not None:
        cross = jax.vmap(
            lambda lp: attn_mod.cross_attention_cache(
                lp["cross"], enc_out, attn_config(cfg), cfg.quant
            )
        )(params["blocks"])
        cache["cross"] = cross
    return cache


# ---------------------------------------------------------------------------
# slot-indexed batch caches (continuous batching)
# ---------------------------------------------------------------------------

def cache_init(
    params: Params, cfg: ArchConfig, n_slots: int, max_len: int,
    dtype=jnp.bfloat16, enc_len: int = 0,
) -> Dict:
    """A decode-slot pool: :func:`init_cache` with per-slot lengths.

    The returned cache is shaped exactly like the static one except
    ``cache["length"]`` is an ``(n_slots,)`` int32 vector, so each slot
    advances independently — :func:`decode_step` masks, positions and
    writes per slot. Fresh slots start at length 0; admit a request with
    :func:`cache_insert`. Under active sharding rules the length vector
    follows the slot ("batch") axis, like every other per-slot leaf.

    ``enc_len > 0`` (encdec only) adds a per-slot cross-attention KV
    pool — ``cache["cross"]["k"/"v"]`` of shape
    ``(n_layers, n_slots, enc_len, H_kv, D)`` — that admission scatters
    each request's encoder-output KV into, exactly like the self KV
    stripes. Free slots hold zeros: cross-attention over an all-zero
    K/V is a uniform softmax times zero values, a harmless constant that
    per-slot masking never lets a live request see.
    """
    cache = init_cache(params, cfg, n_slots, max_len, dtype=dtype)
    if cfg.family == "encdec" and enc_len > 0:
        shape = (cfg.n_layers, n_slots, enc_len,
                 cfg.n_kv_heads, cfg.resolved_head_dim)

        def z():
            return constrain(jnp.zeros(shape, dtype),
                             None, "batch", None, "kv_heads", "head_dim")

        cache["cross"] = {"k": z(), "v": z()}
    cache["length"] = constrain(jnp.zeros((n_slots,), jnp.int32), "batch")
    return cache


def cache_insert(dst: Dict, src: Dict, row, slot, length) -> Dict:
    """Scatter row ``row`` of a prefilled cache into ``slot`` of a live pool.

    ``src`` is the cache returned by :func:`prefill` over a (bucketed)
    prompt batch; ``dst`` is a :func:`cache_init` pool mid-decode. Every
    stacked cache leaf — KV stripes AND recurrent leaves (SSM
    ``state``/``conv``, mLSTM ``C``/``n``/``m``, sLSTM scalars) — has
    the batch axis at position 1, so one generic dynamic-update-slice
    per leaf moves the new request's state in; the
    prompt axis of ``src`` may be shorter than the pool's ``max_len``
    (only the prefilled prefix is copied). ``length`` is the request's
    TRUE prompt length — positions beyond it in ``src`` are right-pad
    junk that stays masked (and is progressively overwritten by decode
    writes, which land exactly at the slot's length).

    ``row``/``slot``/``length`` may be traced scalars: under ``jax.jit``
    this op is shape-stable across admissions (one compile per prefill
    bucket shape).

    A prefilled chunk may also be WIDER than the pool on trailing axes
    — VLM patch positions push the prefill KV width to
    ``patches + bucket``, which can exceed the pool's ``max_len``. Every
    TRUE position is below ``max_len`` (the engine's submit gate bounds
    ``patches + prompt + max_new``), so the overhang is right-pad junk
    and is sliced off before the scatter.
    """
    def ins(d, s_leaf):
        chunk = jax.lax.dynamic_slice_in_dim(s_leaf, row, 1, axis=1)
        if any(cs > ds for cs, ds in zip(chunk.shape[2:], d.shape[2:])):
            chunk = chunk[(slice(None), slice(None))
                          + tuple(slice(0, ds) for ds in d.shape[2:])]
        start = (0, slot) + (0,) * (d.ndim - 2)
        return jax.lax.dynamic_update_slice(d, chunk.astype(d.dtype), start)

    out = {
        k: jax.tree.map(ins, dst[k], src[k])
        for k in dst if k != "length"
    }
    out["length"] = dst["length"].at[slot].set(
        jnp.asarray(length, dst["length"].dtype))
    return out


def hoist_decode_params(params: Params, cfg: ArchConfig) -> Params:
    """Fold per-token-invariant decode constants into served params.

    Mamba2 layers gain ``A = -exp(A_log)`` (``ssm.decode_constants``) so
    :func:`decode_step` stops re-deriving it from weights on every token
    step; other families pass through unchanged. Outputs are
    bit-identical — the same elementwise expression, evaluated once at
    load instead of per step (the serve engine applies this at
    construction; verified by an HLO op-count test).
    """
    if cfg.family != "hybrid":
        return params
    out = dict(params)
    for key in ("mamba_groups", "mamba_tail"):
        blk = params.get(key)
        if blk is not None:
            out[key] = {**blk, "mamba": ssm_mod.decode_constants(blk["mamba"])}
    return out


# ---------------------------------------------------------------------------
# paged cache (fixed page pool + per-slot block tables)
# ---------------------------------------------------------------------------

# families whose decode state is a pure KV cache — the only ones the
# paged layout supports (recurrent state has no sequence axis to page;
# encdec cross-attention KV has no pages and serves through the
# contiguous continuous scheduler instead)
_PAGED_FAMILIES = ("dense", "moe", "vlm")


def _check_paged_family(cfg: ArchConfig) -> None:
    if cfg.family not in _PAGED_FAMILIES:
        raise ValueError(
            f"paged KV cache supports the pure KV-cache families "
            f"{_PAGED_FAMILIES}, got {cfg.family!r}"
        )


def paged_cache_init(
    params: Params, cfg: ArchConfig, n_slots: int, max_len: int,
    block_size: int, num_blocks: int, dtype=jnp.bfloat16,
) -> Dict:
    """A paged decode pool: page-granular KV storage + per-slot lengths.

    Instead of one contiguous ``(n_slots, max_len, ...)`` stripe per
    leaf (:func:`cache_init`), KV lives in ONE pool of ``num_blocks``
    pages of ``block_size`` tokens per layer stack —
    ``(n_layers, num_blocks, block_size, H_kv, D)`` — and a slot reaches
    its sequence through a block table (``serve/paged_kv.py``) passed to
    :func:`decode_step_paged` each step. Page 0 is the trash page free
    slots write into. Under active sharding rules the page axis follows
    the ``kv_blocks`` rule (``data`` mesh axis) and lengths follow
    ``batch``.
    """
    del params
    _check_paged_family(cfg)
    if max_len % block_size:
        raise ValueError(
            f"max_len ({max_len}) must be a multiple of "
            f"block_size ({block_size})"
        )
    shape = (cfg.n_layers, num_blocks, block_size,
             cfg.n_kv_heads, cfg.resolved_head_dim)

    def z():
        # distinct k/v buffers: donation-safe, like _kv_zeros
        return constrain(jnp.zeros(shape, dtype),
                         None, "kv_blocks", None, "kv_heads", "head_dim")

    return {
        "kv": {"k": z(), "v": z()},
        "length": constrain(jnp.zeros((n_slots,), jnp.int32), "batch"),
    }


def paged_cache_insert(dst: Dict, src_kv: Dict, row, slot, block_row,
                       start, total_len) -> Dict:
    """Scatter prefilled K/V rows into a slot's pages.

    ``src_kv`` is a ``{"k", "v"}`` pair of stacked ``(L, B, W, H_kv, D)``
    leaves (a :func:`prefill` cache's ``kv`` for cold admission, or
    :func:`prefill_paged_suffix` output for a prefix hit); token ``t`` of
    row ``row`` lands at sequence position ``start + t`` — page
    ``block_row[(start + t) // bs]``, offset ``(start + t) % bs``.
    Right-pad positions (``start + t >= total_len``) are routed to the
    trash page. ``row``/``slot``/``start``/``total_len`` may be traced:
    one compile per source width ``W``.
    """
    bs = dst["kv"]["k"].shape[2]
    mb = block_row.shape[-1]
    w = src_kv["k"].shape[2]
    t = jnp.arange(w)
    pos = start + t
    bi = jnp.minimum(pos // bs, mb - 1)
    blk = jnp.where(pos < total_len, block_row[bi], 0)
    off = pos % bs

    def ins(pool, s_leaf):
        chunk = jnp.take(s_leaf, row, axis=1).astype(pool.dtype)
        return pool.at[:, blk, off].set(chunk)

    return {
        "kv": {
            "k": ins(dst["kv"]["k"], src_kv["k"]),
            "v": ins(dst["kv"]["v"], src_kv["v"]),
        },
        "length": dst["length"].at[slot].set(
            jnp.asarray(total_len, dst["length"].dtype)),
    }


def _gather_pages(pool: jax.Array, block_tables: jax.Array) -> jax.Array:
    """(NB, bs, H_kv, D) pool + (B, MB) tables -> (B, MB*bs, H_kv, D).

    Page order in the table is sequence order, so the gathered view is
    value-identical to the contiguous per-slot stripe — which is what
    makes paged decode bit-exact with the contiguous path.
    """
    b, mb = block_tables.shape
    g = pool[block_tables]                 # (B, MB, bs, Hkv, D)
    return g.reshape(b, mb * pool.shape[1], *pool.shape[2:])


def _commit_kv_paged(kv: Dict, upd: Dict, length: jax.Array,
                     block_tables: jax.Array, step_mask=None) -> Dict:
    """Write all layers' new-token K/V into each slot's current page.

    The paged analogue of :func:`_commit_kv`: position ``length[b]``
    maps through the block table; retired slots' tables point at the
    trash page (and their clamped page index lands there too), so the
    fixed-shape scatter never corrupts live pages. ``step_mask`` (B,)
    bool writes masked-out slots' OLD page contents back (a no-op), so
    a slot that finishes mid-horizon stops touching its pages.
    """
    bs = kv["k"].shape[2]
    b, mb = block_tables.shape
    bi = jnp.minimum(length // bs, mb - 1)
    blk = block_tables[jnp.arange(b), bi]
    off = length % bs

    def wr(pool, new):                      # new: (L, B, 1, Hkv, D)
        val = new[:, :, 0].astype(pool.dtype)
        if step_mask is not None:
            val = jnp.where(step_mask[None, :, None, None], val,
                            pool[:, blk, off])
        return pool.at[:, blk, off].set(val)

    return {"k": wr(kv["k"], upd["k_new"]), "v": wr(kv["v"], upd["v_new"])}


def decode_step_paged(
    params: Params, cfg: ArchConfig, token: jax.Array, cache: Dict,
    block_tables: jax.Array, attn_backend: Optional[str] = None,
    step_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """One paged serving step: token (B,1) -> (logits (B,1,V), new cache).

    The inner attention gathers each slot's pages into the same
    ``(B, max_len, H_kv, D)`` view :func:`decode_step` reads, then runs
    the identical per-slot-length decode attention — greedy outputs are
    bit-exact with the contiguous path. ``attn_backend`` instead routes
    the attention core through a registered paged-attention kernel
    (``kernels/paged_attention.py``; ``reference`` / ``pallas-interpret``
    / ``pallas``) that never materializes the gathered view.
    ``step_mask`` (B,) bool makes masked-out slots full no-ops (page
    writes return old contents, lengths freeze) — the retirement mask
    :func:`decode_multi_step_paged` applies to slots that finish
    mid-horizon.
    """
    _check_paged_family(cfg)
    length = cache["length"]
    x = L.apply_embedding(params["embed"], token)

    paged_fn = None
    if attn_backend is not None:
        from repro.kernels import registry as _registry

        paged_fn = _registry.get_backend(attn_backend).paged_attention
        if paged_fn is None:
            raise ValueError(
                f"kernel backend {attn_backend!r} does not implement "
                f"paged_attention"
            )
        if cfg.sliding_window > 0:
            raise ValueError(
                "paged-attention kernels implement full causal attention; "
                "sliding-window families use the inline gather path "
                "(attn_backend=None)"
            )

    def body(x_, xs):
        lp, k_l, v_l = xs
        if paged_fn is None:
            kv = {"k": _gather_pages(k_l, block_tables),
                  "v": _gather_pages(v_l, block_tables)}
            return _attn_decode_one(lp, x_, kv, length, cfg, params=params)
        return _attn_decode_one_paged_kernel(
            lp, x_, k_l, v_l, block_tables, length, cfg, paged_fn
        )

    x, kv_upd = layer_scan(
        body, x, (params["blocks"], cache["kv"]["k"], cache["kv"]["v"]),
        unroll=not cfg.scan_layers,
    )
    new_cache = {
        "kv": _commit_kv_paged(cache["kv"], kv_upd, length, block_tables,
                               step_mask=step_mask),
        "length": (length + 1 if step_mask is None
                   else length + step_mask.astype(length.dtype)),
    }
    x = L.apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = L.apply_lm_head(params["embed"], x, params.get("lm_head"))
    return logits, new_cache


def _attn_decode_one_paged_kernel(lp, x, k_pool, v_pool, block_tables,
                                  length, cfg: ArchConfig, paged_fn):
    """One block's decode step with the attention core dispatched to a
    registered paged-attention kernel (block-table indirection inside
    the kernel instead of a gathered KV view)."""
    q = cfg.quant
    acfg = attn_config(cfg)
    b = x.shape[0]
    lv = jnp.broadcast_to(length, (b,)) if jnp.ndim(length) == 0 else length
    xin = L.apply_norm(cfg.norm_type, lp["norm1"], x)
    qh, k_new, v_new, _ = attn_mod._project_qkv(
        lp["attn"], xin, acfg, q, lv[:, None]
    )
    ctx = paged_fn(
        qh[:, 0], k_pool, v_pool, block_tables, lv,
        k_new[:, 0].astype(k_pool.dtype), v_new[:, 0].astype(v_pool.dtype),
    )
    ctx = ctx.astype(x.dtype).reshape(b, 1, acfg.n_heads * acfg.head_dim)
    h, _ = apply_linear(lp["attn"]["wo"], ctx, q)
    kv_out = {"k_new": k_new.astype(k_pool.dtype),
              "v_new": v_new.astype(v_pool.dtype)}
    return _ffn_block(lp, x + h, cfg, q), kv_out


def _prefix_sdpa(q, k_new, v_new, k_pref, v_pref, prefix_len, window: int):
    """Suffix-prefill attention: queries at ``prefix_len + i`` attend the
    cached prefix pages (masked to ``kpos < prefix_len``) plus the
    causal suffix — one softmax over both column groups, decode-style.
    """
    b, w, h, d = q.shape
    hk = k_new.shape[2]
    g = h // hk
    s = k_pref.shape[1]
    qh = q.reshape(b, w, hk, g, d)
    lp_past = jnp.einsum(
        "bskgd,btkd->bkgst", qh.astype(k_pref.dtype), k_pref,
        preferred_element_type=jnp.float32,
    )
    kpos = jnp.arange(s)
    qpos = prefix_len[:, None] + jnp.arange(w)[None, :]           # (b, w)
    valid = jnp.broadcast_to(
        kpos[None, None, :] < prefix_len[:, None, None], (b, w, s)
    )
    if window > 0:
        valid &= kpos[None, None, :] > qpos[:, :, None] - window
    lp_past = jnp.where(valid[:, None, None], lp_past, attn_mod.NEG_INF)
    lp_self = jnp.einsum(
        "bskgd,btkd->bkgst", qh.astype(k_new.dtype), k_new,
        preferred_element_type=jnp.float32,
    )
    i = jnp.arange(w)
    self_valid = i[None, :] <= i[:, None]                          # (wq, wk)
    if window > 0:
        self_valid &= i[None, :] > i[:, None] - window
    lp_self = jnp.where(self_valid[None, None, None], lp_self,
                        attn_mod.NEG_INF)
    scale = 1.0 / math.sqrt(d)
    full = jnp.concatenate([lp_past, lp_self], axis=-1) * scale
    probs = jax.nn.softmax(full.astype(jnp.float32), axis=-1)
    p_past, p_self = probs[..., :s], probs[..., s:]
    ctx = jnp.einsum(
        "bkgst,btkd->bskgd", p_past.astype(k_pref.dtype), v_pref,
        preferred_element_type=jnp.float32,
    ) + jnp.einsum(
        "bkgst,btkd->bskgd", p_self.astype(v_new.dtype), v_new,
        preferred_element_type=jnp.float32,
    )
    return ctx.astype(q.dtype).reshape(b, w, h * d)


def prefill_paged_suffix(
    params: Params, cfg: ArchConfig, tokens: jax.Array, cache: Dict,
    block_tables: jax.Array, prefix_len, per_token_ffn: bool = False,
) -> Tuple[jax.Array, Dict]:
    """Prefill ONLY a prompt's un-cached suffix against reused pages.

    ``tokens`` (B, W) are the suffix tokens (right-padded); the cached
    prefix K/V — ``prefix_len`` tokens already sitting in the slot's
    pages via the radix index — is read through ``block_tables``
    (B, MB). RoPE positions are offset by ``prefix_len`` and every
    suffix query attends [cached prefix, causal suffix] in one softmax,
    so the result matches a full-prompt prefill. Returns
    ``(suffix logits (B, W, V), {"k", "v"} stacked (L, B, W, Hkv, D))``
    ready for :func:`paged_cache_insert` at ``start=prefix_len``.

    ``per_token_ffn`` routes each position in its own MoE group (see
    :func:`_ffn_block`): the spec-decode verify reuses this function as
    a width-(K+1) decode step and must be bit-exact with sequential
    width-1 decoding, whereas prompt-suffix prefill keeps the default
    width-chunked routing that full prefill uses.
    """
    _check_paged_family(cfg)
    q = cfg.quant
    acfg = attn_config(cfg)
    b, w = tokens.shape
    lv = (jnp.broadcast_to(prefix_len, (b,))
          if jnp.ndim(prefix_len) == 0 else prefix_len)
    x = L.apply_embedding(params["embed"], tokens)
    positions = lv[:, None] + jnp.arange(w)[None, :]

    def body(x_, xs):
        lp, k_l, v_l = xs
        xin = L.apply_norm(cfg.norm_type, lp["norm1"], x_)
        qh, kh, vh, _ = attn_mod._project_qkv(lp["attn"], xin, acfg, q,
                                              positions)
        ctx = _prefix_sdpa(
            qh, kh, vh,
            _gather_pages(k_l, block_tables),
            _gather_pages(v_l, block_tables),
            lv, cfg.sliding_window,
        )
        h, _ = apply_linear(lp["attn"]["wo"], ctx, q)
        x2 = _ffn_block(lp, x_ + h, cfg, q, per_token=per_token_ffn)
        return x2, (kh.astype(k_l.dtype), vh.astype(v_l.dtype))

    x, (ks, vs) = layer_scan(
        body, x, (params["blocks"], cache["kv"]["k"], cache["kv"]["v"]),
        unroll=not cfg.scan_layers,
    )
    x = L.apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = L.apply_lm_head(params["embed"], x, params.get("lm_head"))
    return logits, {"k": ks, "v": vs}


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _commit_kv(kv, upd, length, step_mask=None):
    """Write all layers' new-token K/V with ONE tiny in-place update
    (never rewrite the stacked cache inside the layer scan).

    ``length`` scalar: one write position for the whole batch.
    ``length`` (B,) vector: per-slot positions (continuous batching) —
    vmapped over the batch axis so each slot lands at its own offset.
    ``step_mask`` (B,) bool (vector lengths only): slots with a False
    mask get their OLD value written back — the commit is a true no-op
    for retired slots inside :func:`decode_multi_step`, so the donated
    cache never picks up junk from a slot that finished mid-horizon.
    """
    if jnp.ndim(length) == 0:
        return {
            "k": jax.lax.dynamic_update_slice(
                kv["k"], upd["k_new"], (0, 0, length, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                kv["v"], upd["v_new"], (0, 0, length, 0, 0)),
        }
    if step_mask is not None:
        read = jax.vmap(
            lambda c, l: jax.lax.dynamic_slice_in_dim(c, l, 1, axis=1),
            in_axes=(1, 0), out_axes=1,
        )
        m = step_mask[None, :, None, None, None]
        upd = {
            "k_new": jnp.where(m, upd["k_new"], read(kv["k"], length)),
            "v_new": jnp.where(m, upd["v_new"], read(kv["v"], length)),
        }
    write = jax.vmap(
        lambda c, u, l: jax.lax.dynamic_update_slice(c, u, (0, l, 0, 0)),
        in_axes=(1, 1, 0), out_axes=1,
    )
    return {
        "k": write(kv["k"], upd["k_new"], length),
        "v": write(kv["v"], upd["v_new"], length),
    }


def _select_slots(step_mask, new, old):
    """Per-slot select between a step's new state and the old one.

    Every stacked recurrent leaf has the slot ("batch") axis at
    position 1 (see :func:`cache_insert`), so one broadcasted ``where``
    per leaf freezes retired slots' state mid-horizon.
    """
    return jax.tree.map(
        lambda n_, o_: jnp.where(
            step_mask.reshape((1, -1) + (1,) * (n_.ndim - 2)), n_, o_
        ),
        new, old,
    )


def _ffn_block(lp, x, cfg: ArchConfig, q, per_token: bool = False):
    """Post-attention block tail (norm2 + MoE-or-MLP, dense residual)
    shared by the prefill, decode and paged-suffix paths.

    ``per_token=True`` folds the width axis into the batch so every
    token routes in its own MoE group of one — capacity-based dispatch
    is width-dependent (tokens in a chunk compete for expert capacity),
    and the spec-decode verify needs each position's output bit-exact
    with the width-1 decode path it replaces. MLP families are
    per-token already; the fold is a no-op reshape, so it is applied
    only where it matters.
    """
    if per_token and "moe" in lp and x.shape[1] > 1:
        b, s, d = x.shape
        y = _ffn_block(lp, x.reshape(b * s, 1, d), cfg, q)
        return y.reshape(b, s, d)
    z = L.apply_norm(cfg.norm_type, lp["norm2"], x)
    if "moe" in lp:
        h, _ = moe_mod.apply_moe(
            lp["moe"], z, cfg.n_experts, cfg.moe_top_k, q,
            act=cfg.act, chunk_size=cfg.moe_chunk, impl=cfg.moe_impl,
        )
        if cfg.dense_residual:
            h2, _ = L.apply_mlp(lp["mlp"], z, cfg.act, q)
            h = h + h2
    else:
        h, _ = L.apply_mlp(lp["mlp"], z, cfg.act, q)
    return x + h


def _attn_decode_one(lp, x, kv, length, cfg: ArchConfig, params=None,
                     shared: bool = False, cross_cache=None):
    q = cfg.quant
    ap = params["shared_attn"] if shared else lp["attn"]
    nrm = params["shared_norm"] if shared else lp["norm1"]
    h, (k_new, v_new), _ = attn_mod.decode_attention(
        ap, L.apply_norm(cfg.norm_type, nrm, x),
        {**kv, "length": length}, attn_config(cfg), q,
        defer_update=True,
    )
    kv_out = {"k_new": k_new.astype(kv["k"].dtype),
              "v_new": v_new.astype(kv["v"].dtype)}
    x = x + h
    if shared:
        h, _ = L.apply_mlp(
            params["shared_mlp"],
            L.apply_norm(cfg.norm_type, params["shared_mlp_norm"], x),
            cfg.act, q,
        )
        return x + h, kv_out
    if cross_cache is not None:
        h, _ = attn_mod.decode_cross_attention(
            lp["cross"], L.apply_norm(cfg.norm_type, lp["norm_cross"], x),
            cross_cache, attn_config(cfg), q,
        )
        x = x + h
    return _ffn_block(lp, x, cfg, q), kv_out


def decode_step(
    params: Params, cfg: ArchConfig, token: jax.Array, cache: Dict,
    step_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """One serving step: token (B,1) -> (logits (B,1,V), updated cache).

    Works on both cache flavors: a scalar ``length`` advances the whole
    batch in lockstep (static batching), an ``(B,)`` vector advances each
    slot at its own position (continuous batching via :func:`cache_init`
    / :func:`cache_insert`) — masking, RoPE and K/V writes are per-slot.

    ``step_mask`` (B,) bool (vector lengths only) makes the step a full
    cache no-op for masked-out slots: their length freezes, the K/V
    commit writes their old value back, and recurrent state is held —
    the retirement mask :func:`decode_multi_step` applies to slots that
    finish mid-horizon, so a done slot's continued (batched) execution
    cannot perturb the donated cache.
    """
    q = cfg.quant
    length = cache["length"]
    x = L.apply_embedding(params["embed"], token)
    new_cache: Dict[str, Any] = {
        "length": (length + 1 if step_mask is None
                   else length + step_mask.astype(length.dtype))
    }
    plan = stack_plan(cfg)

    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        has_cross = "cross" in cache

        def body(x_, xs):
            lp, kv, cc = xs
            x2, kv_out = _attn_decode_one(
                lp, x_, kv, length, cfg, params=params,
                cross_cache=cc if has_cross else None,
            )
            return x2, kv_out

        xs = (
            params["blocks"],
            {"k": cache["kv"]["k"], "v": cache["kv"]["v"]},
            cache.get("cross", jnp.zeros((cfg.n_layers,))),
        )
        x, kv_upd = layer_scan(body, x, xs, unroll=not cfg.scan_layers)
        new_cache["kv"] = _commit_kv(cache["kv"], kv_upd, length,
                                     step_mask=step_mask)
        if has_cross:
            new_cache["cross"] = cache["cross"]
    elif cfg.family == "hybrid":
        g, pg, tail = plan["groups"], plan["per_group"], plan["tail"]
        scfg = ssm_config(cfg)
        if g > 0:
            grouped_p = jax.tree.map(
                lambda a: a.reshape(g, pg, *a.shape[1:]), params["mamba_groups"]
            )
            grouped_c = jax.tree.map(
                lambda a: a.reshape(g, pg, *a.shape[1:]), cache["ssm_groups"]
            )

            def superstep(x_, xs):
                gp, gc, kv = xs

                def inner(xi, ys):
                    lp, lc = ys
                    h, st, _ = ssm_mod.decode_mamba2(
                        lp["mamba"],
                        L.apply_norm(cfg.norm_type, lp["norm1"], xi),
                        lc, scfg, q,
                    )
                    return xi + h, st

                x_, st_new = layer_scan(inner, x_, (gp, gc),
                                        unroll=not cfg.scan_layers)
                x_, kv_out = _attn_decode_one(
                    None, x_, kv, length, cfg, params=params, shared=True
                )
                return x_, (st_new, kv_out)

            x, (ssm_new, kv_upd) = layer_scan(
                superstep, x,
                (grouped_p, grouped_c,
                 {"k": cache["kv_shared"]["k"], "v": cache["kv_shared"]["v"]}),
                unroll=not cfg.scan_layers,
            )
            ssm_flat = jax.tree.map(
                lambda a: a.reshape(g * pg, *a.shape[2:]), ssm_new
            )
            if step_mask is not None:
                ssm_flat = _select_slots(step_mask, ssm_flat,
                                         cache["ssm_groups"])
            new_cache["ssm_groups"] = ssm_flat
            new_cache["kv_shared"] = _commit_kv(
                cache["kv_shared"], kv_upd, length, step_mask=step_mask)
        else:
            # g == 0 (pure-mamba stack): carry the empty group leaves so
            # the cache pytree is step-invariant (while_loop carry)
            new_cache["ssm_groups"] = cache.get("ssm_groups")
            new_cache["kv_shared"] = cache.get("kv_shared")
        if tail:
            def tail_body(x_, ys):
                lp, lc = ys
                h, st, _ = ssm_mod.decode_mamba2(
                    lp["mamba"], L.apply_norm(cfg.norm_type, lp["norm1"], x_),
                    lc, scfg, q,
                )
                return x_ + h, st

            x, tail_new = layer_scan(
                tail_body, x, (params["mamba_tail"], cache["ssm_tail"]),
                unroll=not cfg.scan_layers,
            )
            if step_mask is not None:
                tail_new = _select_slots(step_mask, tail_new,
                                         cache["ssm_tail"])
            new_cache["ssm_tail"] = tail_new
        else:
            new_cache["ssm_tail"] = cache.get("ssm_tail")
    elif cfg.family == "ssm":
        g, pg, tail = plan["groups"], plan["per_group"], plan["tail"]
        xcfg = xlstm_config(cfg)

        def ml_body(x_, ys):
            lp, lc = ys
            h, st, _ = xlstm_mod.decode_mlstm(
                lp["mlstm"], L.apply_norm(cfg.norm_type, lp["norm1"], x_),
                lc, xcfg, q,
            )
            return x_ + h, st

        if g > 0:
            grouped_p = jax.tree.map(
                lambda a: a.reshape(g, pg, *a.shape[1:]), params["mlstm_groups"]
            )
            grouped_c = jax.tree.map(
                lambda a: a.reshape(g, pg, *a.shape[1:]), cache["mlstm_groups"]
            )

            def superstep(x_, xs):
                gp, gc, sp, sc = xs
                x_, ml_new = layer_scan(ml_body, x_, (gp, gc),
                                        unroll=not cfg.scan_layers)
                h, s_new, _ = xlstm_mod.decode_slstm(
                    sp["slstm"],
                    L.apply_norm(cfg.norm_type, sp["norm1"], x_),
                    sc, xcfg, q,
                )
                return x_ + h, (ml_new, s_new)

            x, (ml_new, sl_new) = layer_scan(
                superstep, x,
                (grouped_p, grouped_c, params["slstm_blocks"], cache["slstm"]),
                unroll=not cfg.scan_layers,
            )
            ml_flat = jax.tree.map(
                lambda a: a.reshape(g * pg, *a.shape[2:]), ml_new
            )
            if step_mask is not None:
                ml_flat = _select_slots(step_mask, ml_flat,
                                        cache["mlstm_groups"])
                sl_new = _select_slots(step_mask, sl_new, cache["slstm"])
            new_cache["mlstm_groups"] = ml_flat
            new_cache["slstm"] = sl_new
        else:
            # g == 0: carry the empty group leaves so the cache pytree
            # is step-invariant (while_loop carry)
            new_cache["mlstm_groups"] = cache.get("mlstm_groups")
            new_cache["slstm"] = cache.get("slstm")
        if tail:
            x, tail_new = layer_scan(
                ml_body, x, (params["mlstm_tail"], cache["mlstm_tail"]),
                unroll=not cfg.scan_layers,
            )
            if step_mask is not None:
                tail_new = _select_slots(step_mask, tail_new,
                                         cache["mlstm_tail"])
            new_cache["mlstm_tail"] = tail_new
        else:
            new_cache["mlstm_tail"] = cache.get("mlstm_tail")

    x = L.apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = L.apply_lm_head(params["embed"], x, params.get("lm_head"))
    return logits, new_cache


# ---------------------------------------------------------------------------
# parallel prefill
# ---------------------------------------------------------------------------

def prefill(
    params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
    max_len: int, dtype=jnp.bfloat16,
) -> Tuple[jax.Array, Dict]:
    """Parallel prompt pass that returns (prompt logits, filled cache).

    ``batch["lengths"]`` (optional, (B,) int32) marks each row's TRUE
    prompt length in a RIGHT-padded batch. Attention K/V need no help
    (the causal mask keeps right-pad junk out of true positions; junk
    K/V rows stay masked by per-slot lengths at decode), but recurrent
    state folds every token it sees — with ``lengths`` the SSM/xLSTM
    scans make padded positions exact state no-ops and return each row's
    final state *at its true length* (see ``apply_mamba2`` /
    ``apply_mlstm`` / ``apply_slstm``), which is what lets bucketed
    continuous-batching prefill admit recurrent families bit-exactly.
    When given, ``cache["length"]`` is the per-row vector.
    """
    q = cfg.quant
    tokens = batch["tokens"]
    lengths = batch.get("lengths")
    b = tokens.shape[0]
    x = L.apply_embedding(params["embed"], tokens)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    s = x.shape[1]
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["enc_embeds"], {})
    # VLM patch positions can push the prefill width past max_len (the
    # overhang is right-pad junk; cache_insert slices it back off)
    cache = init_cache(params, cfg, b, max(max_len, s), dtype=dtype,
                       enc_out=enc_out)
    plan = stack_plan(cfg)

    def attn_prefill_one(lp, x_, shared=False, cross=None):
        ap = params["shared_attn"] if shared else lp["attn"]
        nrm = params["shared_norm"] if shared else lp["norm1"]
        xin = L.apply_norm(cfg.norm_type, nrm, x_)
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))
        qh, kh, vh, _ = attn_mod._project_qkv(ap, xin, attn_config(cfg), q, pos)
        ctx = attn_mod._sdpa(qh, kh, vh, True, cfg.sliding_window)
        h, _ = apply_linear(ap["wo"], ctx, q)
        x_ = x_ + h
        if shared:
            h, _ = L.apply_mlp(
                params["shared_mlp"],
                L.apply_norm(cfg.norm_type, params["shared_mlp_norm"], x_),
                cfg.act, q,
            )
            return x_ + h, (kh, vh)
        if cross is not None:
            h, _ = attn_mod.apply_attention(
                lp["cross"], L.apply_norm(cfg.norm_type, lp["norm_cross"], x_),
                attn_config(cfg), q, xkv=cross,
            )
            x_ = x_ + h
        return _ffn_block(lp, x_, cfg, q), (kh, vh)

    def write_kv(kv_stacked, k_layers, v_layers):
        k = jax.lax.dynamic_update_slice_in_dim(
            kv_stacked["k"], k_layers.astype(kv_stacked["k"].dtype), 0, axis=2
        )
        v = jax.lax.dynamic_update_slice_in_dim(
            kv_stacked["v"], v_layers.astype(kv_stacked["v"].dtype), 0, axis=2
        )
        return {"k": k, "v": v}

    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        def body(x_, xs):
            lp, cc = xs
            x2, kv = attn_prefill_one(
                lp, x_, cross=enc_out if cfg.family == "encdec" else None
            )
            return x2, kv

        x, (ks, vs) = layer_scan(
            body, x, (params["blocks"], jnp.zeros((cfg.n_layers,))),
            unroll=not cfg.scan_layers,
        )
        cache["kv"] = write_kv(cache["kv"], ks, vs)
    elif cfg.family == "hybrid":
        g, pg, tail = plan["groups"], plan["per_group"], plan["tail"]
        scfg = ssm_config(cfg)

        def mamba_one(x_, lp):
            h, _, st = ssm_mod.apply_mamba2(
                lp["mamba"], L.apply_norm(cfg.norm_type, lp["norm1"], x_),
                scfg, q, return_cache=True, lengths=lengths,
            )
            return x_ + h, st

        if g > 0:
            grouped_p = jax.tree.map(
                lambda a: a.reshape(g, pg, *a.shape[1:]), params["mamba_groups"]
            )

            def superstep(x_, gp):
                x_, st = layer_scan(mamba_one, x_, gp,
                                    unroll=not cfg.scan_layers)
                x_, kv = attn_prefill_one(None, x_, shared=True)
                return x_, (st, kv)

            x, (ssm_states, (ks, vs)) = layer_scan(
                superstep, x, grouped_p, unroll=not cfg.scan_layers)
            cache["ssm_groups"] = _constrain_state(jax.tree.map(
                lambda a: a.reshape(g * pg, *a.shape[2:]), ssm_states
            ))
            cache["kv_shared"] = write_kv(cache["kv_shared"], ks, vs)
        if tail:
            x, tail_states = layer_scan(mamba_one, x, params["mamba_tail"],
                                        unroll=not cfg.scan_layers)
            cache["ssm_tail"] = _constrain_state(tail_states)
    elif cfg.family == "ssm":
        g, pg, tail = plan["groups"], plan["per_group"], plan["tail"]
        xcfg = xlstm_config(cfg)

        def ml_one(x_, lp):
            h, _, st = xlstm_mod.apply_mlstm(
                lp["mlstm"], L.apply_norm(cfg.norm_type, lp["norm1"], x_),
                xcfg, q, return_cache=True, lengths=lengths,
            )
            return x_ + h, st

        if g > 0:
            grouped_p = jax.tree.map(
                lambda a: a.reshape(g, pg, *a.shape[1:]), params["mlstm_groups"]
            )

            def superstep(x_, xs):
                gp, sp = xs
                x_, ml_st = layer_scan(ml_one, x_, gp,
                                       unroll=not cfg.scan_layers)
                h, _, s_st = xlstm_mod.apply_slstm(
                    sp["slstm"], L.apply_norm(cfg.norm_type, sp["norm1"], x_),
                    xcfg, q, return_cache=True, lengths=lengths,
                )
                return x_ + h, (ml_st, s_st)

            x, (ml_states, s_states) = layer_scan(
                superstep, x, (grouped_p, params["slstm_blocks"]),
                unroll=not cfg.scan_layers,
            )
            cache["mlstm_groups"] = _constrain_state(jax.tree.map(
                lambda a: a.reshape(g * pg, *a.shape[2:]), ml_states
            ))
            cache["slstm"] = _constrain_state(s_states)
        if tail:
            x, tail_states = layer_scan(ml_one, x, params["mlstm_tail"],
                                        unroll=not cfg.scan_layers)
            cache["mlstm_tail"] = _constrain_state(tail_states)

    x = L.apply_norm(cfg.norm_type, params["final_norm"], x)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = x[:, batch["patch_embeds"].shape[1]:]
    logits = L.apply_lm_head(params["embed"], x, params.get("lm_head"))
    if lengths is not None:
        # patch positions sit below the prompt tokens in the KV cache,
        # so each row's true cache length is patches + prompt length
        off = (batch["patch_embeds"].shape[1]
               if cfg.family == "vlm" and "patch_embeds" in batch else 0)
        cache["length"] = jnp.asarray(lengths, jnp.int32) + off
    else:
        cache["length"] = jnp.asarray(s, jnp.int32)
    return logits, cache


# ---------------------------------------------------------------------------
# on-device multi-step decode
# ---------------------------------------------------------------------------
#
# The serving hot loop. Instead of one jit call (and one host sync) per
# token, the engine calls decode_multi_step once per *horizon*: a
# lax.while_loop runs up to H decode steps entirely on device — greedy
# argmax sampling, per-slot EOS / max-new-token flags, and retirement
# masks (a slot that finishes mid-horizon keeps executing in the batch,
# but its step is a full cache no-op via ``step_mask``, so cache
# donation stays valid). The loop exits early once every live slot is
# done, and the host syncs only at horizon boundaries — O(tokens/H)
# round-trips per request instead of O(tokens).
#
# Greedy only: argmax needs no RNG carry and is what makes the loop
# bit-exact-testable against the host loop. Temperature sampling stays
# on the host path in serve/engine.py.


def _multi_step_loop(step_fn, cache, last_tok, live, eos_ids, budget,
                     horizon: int):
    """Run ``step_fn`` up to ``horizon`` times under a device while-loop.

    ``step_fn(cache, token_B1, emit_mask) -> (logits, cache)`` is one
    masked decode step. Carry: (cache, last token, done mask, token
    buffer, per-slot emitted count, per-slot remaining budget, step).
    Returns ``(buf, emitted, done, last_tok, cache, steps)`` where
    ``buf`` is (B, H) int32 with -1 in never-written positions.
    """
    n = last_tok.shape[0]
    last_tok = constrain(last_tok.astype(jnp.int32), "batch")
    done0 = constrain(jnp.logical_not(live) | (budget <= 0), "batch")
    buf0 = constrain(jnp.full((n, horizon), -1, jnp.int32), "batch", None)
    emitted0 = constrain(jnp.zeros((n,), jnp.int32), "batch")
    budget0 = constrain(budget.astype(jnp.int32), "batch")
    eos_ids = constrain(eos_ids.astype(jnp.int32), "batch")

    def cond(carry):
        _, _, done, _, _, _, s = carry
        return (s < horizon) & jnp.any(~done)

    def body(carry):
        cache, last, done, buf, emitted, rem, s = carry
        emit = ~done
        logits, cache = step_fn(cache, last[:, None], emit)
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        tok = constrain(jnp.where(emit, tok, -1), "batch")
        buf = jax.lax.dynamic_update_slice(buf, tok[:, None], (0, s))
        emitted = emitted + emit.astype(jnp.int32)
        rem = rem - emit.astype(jnp.int32)
        done = done | (emit & ((tok == eos_ids) | (rem <= 0)))
        last = jnp.where(emit, tok, last)
        return (cache, last, done, buf, emitted, rem, s + 1)

    carry = (cache, last_tok, done0, buf0, emitted0, budget0,
             jnp.asarray(0, jnp.int32))
    cache, last, done, buf, emitted, _, steps = jax.lax.while_loop(
        cond, body, carry
    )
    return buf, emitted, done, last, cache, steps


def decode_multi_step(
    params: Params, cfg: ArchConfig, cache: Dict, last_tok: jax.Array,
    live: jax.Array, eos_ids: jax.Array, budget: jax.Array, horizon: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, Dict, jax.Array]:
    """Up to ``horizon`` greedy decode steps on device (contiguous cache).

    Args: ``last_tok`` (B,) last token per slot, ``live`` (B,) bool slot
    occupancy, ``eos_ids`` (B,) per-request EOS (-1 = none), ``budget``
    (B,) remaining new-token allowance. ``horizon`` is static — one
    compile per horizon value. Returns ``(buf, emitted, done, last_tok,
    cache, steps)``: ``buf[i, :emitted[i]]`` are slot i's new tokens.
    Bit-exact with ``horizon`` host-driven :func:`decode_step` calls
    under greedy sampling.
    """
    def step_fn(c, tok, emit):
        return decode_step(params, cfg, tok, c, step_mask=emit)

    return _multi_step_loop(step_fn, cache, last_tok, live, eos_ids,
                            budget, horizon)


def decode_multi_step_paged(
    params: Params, cfg: ArchConfig, cache: Dict, block_tables: jax.Array,
    last_tok: jax.Array, live: jax.Array, eos_ids: jax.Array,
    budget: jax.Array, horizon: int, attn_backend: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, Dict, jax.Array]:
    """Paged analogue of :func:`decode_multi_step`.

    ``block_tables`` must already map every position the loop can write
    — the engine pre-reserves min(horizon, budget) pages per live slot
    via ``PagedKVManager.prepare_append`` before invoking this (and
    falls back to horizon=1 for a round where a copy-on-write valve
    would trigger mid-horizon; see ``PagedKVManager.mid_horizon_cow``).
    """
    def step_fn(c, tok, emit):
        return decode_step_paged(params, cfg, tok, c, block_tables,
                                 attn_backend=attn_backend, step_mask=emit)

    return _multi_step_loop(step_fn, cache, last_tok, live, eos_ids,
                            budget, horizon)


# ---------------------------------------------------------------------------
# speculative decoding (draft propose + batched verify)
# ---------------------------------------------------------------------------
#
# A small draft model (same family/vocab, its own ArchConfig + cache)
# proposes K greedy tokens per slot; the main model scores all K+1
# positions (pending token + proposals) in ONE masked forward —
# decode_verify below, the width-(K+1) generalization of decode_step
# built on the same _prefix_sdpa math as paged suffix prefill. The
# engine accepts the longest prefix where the draft's proposal equals
# the main model's argmax, emits one bonus token, and rolls both caches
# back with a per-slot length edit (plus PagedKVManager.truncate on the
# paged path). Greedy outputs are token-identical to vanilla decode by
# construction: every emitted token IS a main-model argmax at the same
# cache state.
#
# _SPEC_FAMILIES: pure-KV families only. Recurrent state (ssm/hybrid)
# folds every token into a fixed-size state — there is no length edit
# that un-folds a rejected token.
_SPEC_FAMILIES = ("dense", "moe", "vlm", "encdec")


def decode_verify(
    params: Params, cfg: ArchConfig, tokens: jax.Array, cache: Dict,
) -> Tuple[jax.Array, Dict]:
    """Score ``tokens`` (B, W) at positions ``length .. length+W-1``.

    The width-W analogue of :func:`decode_step` on a contiguous slot
    pool: queries attend the cached prefix (masked to
    ``kpos < length``) plus the causal in-flight suffix via
    :func:`_prefix_sdpa` — the same one-softmax construction the paged
    suffix prefill uses, so each position's logits are bit-exact with W
    sequential ``decode_step`` calls. K/V for all W positions are
    committed at ``length .. length+W-1``; ``cache["length"]`` is NOT
    advanced — the engine sets it to the accepted length afterwards
    (the rollback is exactly that length edit; rejected positions'
    K/V become junk above the length watermark, overwritten by the
    next round's writes and never attended).
    """
    if cfg.family not in _SPEC_FAMILIES:
        raise ValueError(
            f"decode_verify supports the pure KV-cache families "
            f"{_SPEC_FAMILIES}, got {cfg.family!r}"
        )
    q = cfg.quant
    acfg = attn_config(cfg)
    lengths = cache["length"]
    b, w = tokens.shape
    x = L.apply_embedding(params["embed"], tokens)
    positions = lengths[:, None] + jnp.arange(w)[None, :]
    has_cross = "cross" in cache

    def body(x_, xs):
        lp, k_l, v_l, cc = xs
        xin = L.apply_norm(cfg.norm_type, lp["norm1"], x_)
        qh, kh, vh, _ = attn_mod._project_qkv(lp["attn"], xin, acfg, q,
                                              positions)
        ctx = _prefix_sdpa(qh, kh, vh, k_l, v_l, lengths,
                           cfg.sliding_window)
        h, _ = apply_linear(lp["attn"]["wo"], ctx, q)
        x_ = x_ + h
        if has_cross:
            h, _ = attn_mod.decode_cross_attention(
                lp["cross"],
                L.apply_norm(cfg.norm_type, lp["norm_cross"], x_),
                cc, acfg, q,
            )
            x_ = x_ + h
        return (_ffn_block(lp, x_, cfg, q, per_token=True),
                (kh.astype(k_l.dtype), vh.astype(v_l.dtype)))

    xs = (params["blocks"], cache["kv"]["k"], cache["kv"]["v"],
          cache.get("cross", jnp.zeros((cfg.n_layers,))))
    x, (ks, vs) = layer_scan(body, x, xs, unroll=not cfg.scan_layers)
    x = L.apply_norm(cfg.norm_type, params["final_norm"], x)
    logits = L.apply_lm_head(params["embed"], x, params.get("lm_head"))
    new_cache = dict(cache)
    new_cache["kv"] = _commit_kv(
        cache["kv"], {"k_new": ks, "v_new": vs}, lengths)
    return logits, new_cache


def decode_propose(
    params: Params, cfg: ArchConfig, cache: Dict, last_tok: jax.Array,
    live: jax.Array, k_steps: int,
) -> Tuple[jax.Array, Dict]:
    """Run ``k_steps`` greedy draft steps; returns ((B, k_steps), cache).

    A ``lax.scan`` over masked :func:`decode_step` calls. Proposal 0
    extends the shared pending token, so the engine verifies proposals
    ``0 .. k-2`` and the LAST step exists only to commit its
    predecessor's K/V — after ``k_steps = K+1`` steps the draft cache
    holds every position a full acceptance needs, and any rollback
    target is a pure length edit. Non-live slots carry their token
    unchanged and their step is a cache no-op (``step_mask``).
    """
    def step(carry, _):
        c, tok = carry
        logits, c = decode_step(params, cfg, tok[:, None], c,
                                step_mask=live)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        nxt = jnp.where(live, nxt, tok)
        return (c, nxt), nxt

    (cache, _), toks = jax.lax.scan(
        step, (cache, last_tok.astype(jnp.int32)), None, length=k_steps)
    return jnp.moveaxis(toks, 0, 1), cache


def paged_verify_commit(
    kv: Dict, upd: Dict, lengths: jax.Array, block_tables: jax.Array,
    live: jax.Array,
) -> Dict:
    """Write a width-W verify's K/V into each live slot's pages.

    The width-W analogue of :func:`_commit_kv_paged`: position
    ``lengths[b] + j`` maps through slot ``b``'s block table (the engine
    pre-reserves all W positions via ``PagedKVManager.prepare_append``
    before the verify forward). Non-live slots are routed to the trash
    page — their tables may hold stale entries that now alias reallocated
    live pages, so masking by table contents alone is not enough.
    ``upd`` is the ``{"k", "v"}`` stacked (L, B, W, Hkv, D) pair from
    :func:`prefill_paged_suffix`.
    """
    bs = kv["k"].shape[2]
    mb = block_tables.shape[1]
    w = upd["k"].shape[2]
    pos = lengths[:, None] + jnp.arange(w)[None, :]            # (B, W)
    bi = jnp.minimum(pos // bs, mb - 1)
    blk = jnp.take_along_axis(block_tables, bi, axis=1)
    blk = jnp.where(live[:, None], blk, 0)
    off = pos % bs

    def wr(pool, new):
        return pool.at[:, blk, off].set(new.astype(pool.dtype))

    return {"k": wr(kv["k"], upd["k"]), "v": wr(kv["v"], upd["v"])}
