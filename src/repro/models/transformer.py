"""Model assembly: scan-over-stacked-layers for every family.

Homogeneous layer stacks are stored as *stacked* parameter pytrees
(leading dim = #layers) and executed with ``lax.scan`` — compile time is
O(1) in depth (an 81-layer zamba2 compiles as fast as a 3-layer one) and
activation rematerialization wraps the scan body. This is the standard
production layout (MaxText et al.).

Families
--------
dense/vlm — scan over identical GQA blocks (vlm prepends stub patches).
moe       — scan over attention+MoE blocks (arctic adds dense residual).
hybrid    — zamba2: scan over supersteps of (attn_every-1) Mamba2 blocks
            followed by ONE SHARED attention+MLP block (weight sharing),
            plus a tail of Mamba2 blocks.
ssm       — xLSTM: supersteps of (slstm_every-1) mLSTM + 1 sLSTM.
encdec    — whisper backbone: encoder scan + decoder scan w/ cross attn.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.attention import AttnConfig
from repro.parallel.sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# config helpers
# ---------------------------------------------------------------------------

def attn_config(cfg: ArchConfig, causal: bool = True) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qk_norm=cfg.qk_norm,
        sliding_window=cfg.sliding_window,
        rope_theta=cfg.rope_theta,
        use_bias=cfg.attn_bias,
        causal=causal,
        impl=cfg.attn_impl,
    )


def ssm_config(cfg: ArchConfig) -> ssm_mod.SSMConfig:
    return ssm_mod.SSMConfig(
        d_model=cfg.d_model, d_state=cfg.ssm_state,
        expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
    )


def xlstm_config(cfg: ArchConfig) -> xlstm_mod.XLSTMConfig:
    return xlstm_mod.XLSTMConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        proj_factor=cfg.xlstm_proj_factor,
    )


def layer_kinds(cfg: ArchConfig) -> List[str]:
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.family == "hybrid":
            kinds.append("shared_attn" if (i + 1) % cfg.attn_every == 0 else "mamba")
        elif cfg.family == "ssm":
            kinds.append(
                "slstm" if cfg.slstm_every and (i + 1) % cfg.slstm_every == 0
                else "mlstm"
            )
        elif cfg.family == "moe":
            kinds.append("moe")
        else:
            kinds.append("attn")
    return kinds


def stack_plan(cfg: ArchConfig) -> Dict[str, int]:
    """How many layers live in each stacked group."""
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        tail = cfg.n_layers % cfg.attn_every
        return {"groups": groups, "per_group": cfg.attn_every - 1, "tail": tail}
    if cfg.family == "ssm" and cfg.slstm_every:
        groups = cfg.n_layers // cfg.slstm_every
        tail = cfg.n_layers % cfg.slstm_every
        return {"groups": groups, "per_group": cfg.slstm_every - 1, "tail": tail}
    return {"groups": cfg.n_layers, "per_group": 1, "tail": 0}


# ---------------------------------------------------------------------------
# per-kind single blocks (init + forward)
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ArchConfig, cross: bool = False,
                     causal: bool = True) -> Params:
    ks = jax.random.split(key, 4)
    q = cfg.quant
    d = cfg.d_model
    p: Params = {
        "norm1": L.init_norm(cfg.norm_type, d),
        "attn": attn_mod.init_attention(ks[0], attn_config(cfg, causal), q),
        "norm2": L.init_norm(cfg.norm_type, d),
        "mlp": L.init_mlp(ks[1], d, cfg.d_ff, cfg.act, q, use_bias=cfg.attn_bias),
    }
    if cross:
        p["norm_cross"] = L.init_norm(cfg.norm_type, d)
        p["cross"] = attn_mod.init_attention(ks[2], attn_config(cfg), q)
    return p


def _init_moe_block(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 4)
    q = cfg.quant
    d = cfg.d_model
    e_ff = cfg.moe_d_ff or cfg.d_ff
    p: Params = {
        "norm1": L.init_norm(cfg.norm_type, d),
        "attn": attn_mod.init_attention(ks[0], attn_config(cfg), q),
        "norm2": L.init_norm(cfg.norm_type, d),
        "moe": moe_mod.init_moe(ks[1], d, e_ff, cfg.n_experts, cfg.moe_top_k,
                                q, act=cfg.act),
    }
    if cfg.dense_residual:
        p["mlp"] = L.init_mlp(ks[2], d, cfg.d_ff, cfg.act, q)
    return p


def _init_mamba_block(key, cfg: ArchConfig) -> Params:
    return {
        "norm1": L.init_norm(cfg.norm_type, cfg.d_model),
        "mamba": ssm_mod.init_mamba2(key, ssm_config(cfg), cfg.quant),
    }


def _init_mlstm_block(key, cfg: ArchConfig) -> Params:
    return {
        "norm1": L.init_norm(cfg.norm_type, cfg.d_model),
        "mlstm": xlstm_mod.init_mlstm(key, xlstm_config(cfg), cfg.quant),
    }


def _init_slstm_block(key, cfg: ArchConfig) -> Params:
    return {
        "norm1": L.init_norm(cfg.norm_type, cfg.d_model),
        "slstm": xlstm_mod.init_slstm(key, xlstm_config(cfg), cfg.quant),
    }


def _stacked(init_fn: Callable, key, n: int) -> Params:
    """vmap the per-block init over n split keys -> stacked param tree."""
    if n == 0:
        return None
    return jax.vmap(init_fn)(jax.random.split(key, n))


def layer_scan(body: Callable, carry, xs, *, unroll: bool = False):
    """``lax.scan`` over a stacked layer pytree, or the unrolled oracle.

    ``unroll=False`` (the production path, ``cfg.scan_layers=True``) is a
    plain ``jax.lax.scan``: one compiled block regardless of depth.
    ``unroll=True`` replays the exact same body as an explicit Python
    loop over ``xs``'s leading dim, restacking the per-layer outputs —
    compile cost linear in depth, but structurally identical math, which
    makes it the scan-vs-loop parity reference for the golden suite.
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if not ys or all(y is None for y in ys):
        return carry, None
    return carry, jax.tree.map(lambda *zs: jnp.stack(zs), *ys)


# block forwards ------------------------------------------------------------

def _merge_stats(agg: Dict, st: Dict):
    for k, v in st.items():
        if k in ("moe_aux_loss",):
            agg[k] = agg.get(k, 0.0) + v
        elif k == "p_zero_frac":
            agg["_pz_sum"] = agg.get("_pz_sum", 0.0) + v
            agg["_pz_n"] = agg.get("_pz_n", 0) + 1
        else:
            agg[k] = v


def _attn_block_fwd(lp: Params, x, cfg: ArchConfig,
                    enc_out=None, causal: bool = True) -> Tuple[jax.Array, Dict]:
    q = cfg.quant
    stats: Dict = {}
    h, st = attn_mod.apply_attention(
        lp["attn"], L.apply_norm(cfg.norm_type, lp["norm1"], x),
        attn_config(cfg, causal), q,
    )
    _merge_stats(stats, st)
    x = x + h
    if "cross" in lp and enc_out is not None:
        h, st = attn_mod.apply_attention(
            lp["cross"], L.apply_norm(cfg.norm_type, lp["norm_cross"], x),
            attn_config(cfg), q, xkv=enc_out,
        )
        _merge_stats(stats, st)
        x = x + h
    h, st = L.apply_mlp(
        lp["mlp"], L.apply_norm(cfg.norm_type, lp["norm2"], x), cfg.act, q
    )
    _merge_stats(stats, st)
    return constrain(x + h, "batch", "seq", "embed"), stats


def _moe_block_fwd(lp: Params, x, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    q = cfg.quant
    stats: Dict = {}
    h, st = attn_mod.apply_attention(
        lp["attn"], L.apply_norm(cfg.norm_type, lp["norm1"], x),
        attn_config(cfg), q,
    )
    _merge_stats(stats, st)
    x = x + h
    z = L.apply_norm(cfg.norm_type, lp["norm2"], x)
    h, st = moe_mod.apply_moe(
        lp["moe"], z, cfg.n_experts, cfg.moe_top_k, q,
        act=cfg.act, chunk_size=cfg.moe_chunk, impl=cfg.moe_impl,
    )
    _merge_stats(stats, st)
    if cfg.dense_residual:
        h2, st2 = L.apply_mlp(lp["mlp"], z, cfg.act, q)
        _merge_stats(stats, st2)
        h = h + h2
    return constrain(x + h, "batch", "seq", "embed"), stats


def _mamba_block_fwd(lp: Params, x, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    h, st = ssm_mod.apply_mamba2(
        lp["mamba"], L.apply_norm(cfg.norm_type, lp["norm1"], x),
        ssm_config(cfg), cfg.quant,
    )
    return constrain(x + h, "batch", "seq", "embed"), st


def _mlstm_block_fwd(lp: Params, x, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    h, st = xlstm_mod.apply_mlstm(
        lp["mlstm"], L.apply_norm(cfg.norm_type, lp["norm1"], x),
        xlstm_config(cfg), cfg.quant,
    )
    return constrain(x + h, "batch", "seq", "embed"), st


def _slstm_block_fwd(lp: Params, x, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    h, st = xlstm_mod.apply_slstm(
        lp["slstm"], L.apply_norm(cfg.norm_type, lp["norm1"], x),
        xlstm_config(cfg), cfg.quant,
    )
    return constrain(x + h, "batch", "seq", "embed"), st


def _shared_attn_fwd(params: Params, x, cfg: ArchConfig) -> Tuple[jax.Array, Dict]:
    q = cfg.quant
    stats: Dict = {}
    h, st = attn_mod.apply_attention(
        params["shared_attn"],
        L.apply_norm(cfg.norm_type, params["shared_norm"], x),
        attn_config(cfg), q,
    )
    _merge_stats(stats, st)
    x = x + h
    h, st = L.apply_mlp(
        params["shared_mlp"],
        L.apply_norm(cfg.norm_type, params["shared_mlp_norm"], x),
        cfg.act, q,
    )
    _merge_stats(stats, st)
    return constrain(x + h, "batch", "seq", "embed"), stats


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(key: jax.Array, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 8)
    params: Params = {
        "embed": L.init_embedding(ks[0], cfg.vocab_size, cfg.d_model),
        "final_norm": L.init_norm(cfg.norm_type, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_lm_head(ks[1], cfg.d_model, cfg.vocab_size)

    plan = stack_plan(cfg)
    if cfg.family in ("dense", "vlm"):
        params["blocks"] = _stacked(
            lambda k: _init_attn_block(k, cfg), ks[2], cfg.n_layers
        )
    elif cfg.family == "moe":
        params["blocks"] = _stacked(
            lambda k: _init_moe_block(k, cfg), ks[2], cfg.n_layers
        )
    elif cfg.family == "hybrid":
        n_mamba_grouped = plan["groups"] * plan["per_group"]
        params["mamba_groups"] = _stacked(
            lambda k: _init_mamba_block(k, cfg), ks[2], n_mamba_grouped
        )
        params["mamba_tail"] = _stacked(
            lambda k: _init_mamba_block(k, cfg), ks[3], plan["tail"]
        )
        sk = jax.random.split(ks[4], 2)
        params["shared_attn"] = attn_mod.init_attention(
            sk[0], attn_config(cfg), cfg.quant
        )
        params["shared_norm"] = L.init_norm(cfg.norm_type, cfg.d_model)
        params["shared_mlp_norm"] = L.init_norm(cfg.norm_type, cfg.d_model)
        params["shared_mlp"] = L.init_mlp(
            sk[1], cfg.d_model, cfg.d_ff, cfg.act, cfg.quant
        )
    elif cfg.family == "ssm":
        params["mlstm_groups"] = _stacked(
            lambda k: _init_mlstm_block(k, cfg), ks[2],
            plan["groups"] * plan["per_group"],
        )
        params["slstm_blocks"] = _stacked(
            lambda k: _init_slstm_block(k, cfg), ks[3], plan["groups"]
        )
        params["mlstm_tail"] = _stacked(
            lambda k: _init_mlstm_block(k, cfg), ks[4], plan["tail"]
        )
    elif cfg.family == "encdec":
        params["encoder"] = {
            "layers": _stacked(
                lambda k: _init_attn_block(k, cfg, causal=False),
                ks[2], cfg.n_enc_layers,
            ),
            "final_norm": L.init_norm(cfg.norm_type, cfg.d_model),
        }
        params["blocks"] = _stacked(
            lambda k: _init_attn_block(k, cfg, cross=True), ks[3], cfg.n_layers
        )
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _scan_blocks(
    stacked: Params, x: jax.Array, body: Callable, cfg: ArchConfig,
    stats: Dict,
):
    """lax.scan x -> body(layer_params, x) over the stacked leading dim."""
    if stacked is None:
        return x

    def one(carry, lp):
        x, aux, pz, pzn = carry
        if cfg.remat == "block":
            x2, st = jax.checkpoint(
                lambda p_, x_: body(p_, x_, cfg)
            )(lp, x)
        else:
            x2, st = body(lp, x, cfg)
        aux = aux + st.get("moe_aux_loss", 0.0)
        pz = pz + st.get("_pz_sum", st.get("p_zero_frac", 0.0))
        pzn = pzn + st.get("_pz_n", 1.0 if "p_zero_frac" in st else 0.0)
        return (x2, aux, pz, pzn), None

    (x, aux, pz, pzn), _ = layer_scan(
        one, (x, jnp.zeros(()), jnp.zeros(()), jnp.zeros(())), stacked,
        unroll=not cfg.scan_layers,
    )
    stats["moe_aux_loss"] = stats.get("moe_aux_loss", 0.0) + aux
    stats["_pz_sum"] = stats.get("_pz_sum", 0.0) + pz
    stats["_pz_n"] = stats.get("_pz_n", 0.0) + pzn
    return x


def encode(params: Params, cfg: ArchConfig, enc_embeds: jax.Array,
           stats: Optional[Dict] = None) -> jax.Array:
    stats = {} if stats is None else stats
    x = constrain(enc_embeds, "batch", "seq", "embed")
    x = _scan_blocks(
        params["encoder"]["layers"], x,
        lambda lp, x_, c: _attn_block_fwd(lp, x_, c, causal=False),
        cfg, stats,
    )
    return L.apply_norm(cfg.norm_type, params["encoder"]["final_norm"], x)


def backbone(params: Params, cfg: ArchConfig, x: jax.Array,
             enc_out: Optional[jax.Array], stats: Dict) -> jax.Array:
    plan = stack_plan(cfg)
    if cfg.family in ("dense", "vlm"):
        x = _scan_blocks(params["blocks"], x, _attn_block_fwd, cfg, stats)
    elif cfg.family == "moe":
        x = _scan_blocks(params["blocks"], x, _moe_block_fwd, cfg, stats)
    elif cfg.family == "encdec":
        x = _scan_blocks(
            params["blocks"], x,
            lambda lp, x_, c: _attn_block_fwd(lp, x_, c, enc_out=enc_out),
            cfg, stats,
        )
    elif cfg.family == "hybrid":
        g, pg = plan["groups"], plan["per_group"]
        if g > 0:
            grouped = jax.tree.map(
                lambda a: a.reshape(g, pg, *a.shape[1:]),
                params["mamba_groups"],
            )

            def superstep(carry, gp):
                x_, aux = carry
                st_: Dict = {}
                x_ = _scan_blocks(
                    gp, x_, _mamba_block_fwd,
                    dataclasses.replace(cfg, n_layers=pg), st_,
                )
                x_, st2 = _shared_attn_fwd(params, x_, cfg)
                return (x_, aux + st_.get("_pz_sum", 0.0)), None

            (x, _), _ = layer_scan(superstep, (x, jnp.zeros(())), grouped,
                                   unroll=not cfg.scan_layers)
        x = _scan_blocks(params["mamba_tail"], x, _mamba_block_fwd, cfg, stats)
    elif cfg.family == "ssm":
        g, pg = plan["groups"], plan["per_group"]
        if g > 0:
            grouped = jax.tree.map(
                lambda a: a.reshape(g, pg, *a.shape[1:]),
                params["mlstm_groups"],
            )

            def superstep(carry, inp):
                gp, sp = inp
                x_, = carry
                st_: Dict = {}
                x_ = _scan_blocks(
                    gp, x_, _mlstm_block_fwd,
                    dataclasses.replace(cfg, n_layers=pg), st_,
                )
                x_, _ = _slstm_block_fwd(sp, x_, cfg)
                return (x_,), None

            (x,), _ = layer_scan(
                superstep, (x,), (grouped, params["slstm_blocks"]),
                unroll=not cfg.scan_layers,
            )
        x = _scan_blocks(params["mlstm_tail"], x, _mlstm_block_fwd, cfg, stats)
    return x


def forward(
    params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
    last_only: bool = False,
) -> Tuple[jax.Array, Dict]:
    """Training / prefill forward -> (logits, stats).

    ``last_only=True`` applies the LM head to the final position only
    (serving prefill — avoids materializing S x vocab logits).
    """
    stats: Dict = {}
    x = L.apply_embedding(params["embed"], batch["tokens"])
    if cfg.compute_dtype == "bf16":
        x = x.astype(jnp.bfloat16)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(x.dtype), x], axis=1)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(params, cfg, batch["enc_embeds"].astype(x.dtype), stats)
    x = backbone(params, cfg, x, enc_out, stats)
    x = L.apply_norm(cfg.norm_type, params["final_norm"], x)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = x[:, batch["patch_embeds"].shape[1]:]
    if last_only:
        x = x[:, -1:]
    logits = L.apply_lm_head(params["embed"], x, params.get("lm_head"))
    logits = constrain(logits, "batch", "seq", "vocab")
    # static gate: presence of the sparsity stat must not depend on traced
    # values (forward runs under jit)
    if cfg.quant.collect_stats and cfg.quant.mode == "psq":
        stats["p_zero_frac"] = stats.pop("_pz_sum") / jnp.maximum(
            stats.pop("_pz_n", 1.0), 1.0
        )
    else:
        stats.pop("_pz_sum", None)
        stats.pop("_pz_n", None)
    return logits, stats


def loss_fn(
    params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict]:
    logits, stats = forward(params, cfg, batch)
    tgt = batch["targets"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask", jnp.ones_like(tgt, jnp.float32))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    aux = stats.get("moe_aux_loss")
    if aux is not None and cfg.family == "moe":
        loss = loss + 0.01 * aux
    stats["ce_loss"] = loss
    return loss, stats
