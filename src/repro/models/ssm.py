"""Mamba2 (SSD) block — used by the zamba2-7b hybrid architecture.

Chunked SSD implementation: within-chunk interactions use the quadratic
masked form, across-chunk state is carried by a scan — the standard
parallel training algorithm. A step-wise recurrence (exactly the same
math) serves decode; tests check scan == chunked == step.

Projections (in/out) are PSQLinear so the HCiM technique covers the SSM
family too (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import QuantConfig
from repro.core.psq_linear import apply_linear, init_linear
from repro.models.layers import apply_rmsnorm, init_rmsnorm
from repro.parallel.sharding import constrain

Params = Dict


class SSMConfig(NamedTuple):
    d_model: int
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        # conv runs over [x, B, C] as in Mamba2
        return self.d_inner + 2 * self.d_state


def init_mamba2(key: jax.Array, cfg: SSMConfig, quant: QuantConfig) -> Params:
    ks = jax.random.split(key, 5)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.d_state + cfg.n_heads
    p: Params = {
        "in_proj": init_linear(ks[0], cfg.d_model, d_in_proj, quant),
        "out_proj": init_linear(ks[1], cfg.d_inner, cfg.d_model, quant),
        "conv_w": jax.random.normal(ks[2], (cfg.conv_width, cfg.conv_dim)) * 0.2,
        "conv_b": jnp.zeros((cfg.conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads)),
        "D": jnp.ones((cfg.n_heads,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((cfg.n_heads,), 0.01))),
        "norm": init_rmsnorm(cfg.d_inner),
    }
    return p


def _split_proj(z_xbc_dt: jax.Array, cfg: SSMConfig):
    z, xbc, dt = jnp.split(
        z_xbc_dt,
        [cfg.d_inner, cfg.d_inner + cfg.conv_dim],
        axis=-1,
    )
    return z, xbc, dt


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, C); w: (W, C) depthwise causal kernel."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out + b


def conv_tail_window(stream: jax.Array, w: int,
                     lengths: Optional[jax.Array] = None) -> jax.Array:
    """Last-``w`` window of a ``(B, S, C)`` conv input stream, per row.

    With ``lengths=None`` this is the trailing window ``[S-w, S)``
    (zero-filled when ``S < w``) — the conv state a decode continuation
    needs after an unpadded prefill. With per-row ``lengths`` (B,), row
    ``b`` gets the window ``[lengths[b]-w, lengths[b])`` instead, so a
    RIGHT-padded prefill still hands decode the conv buffer of the last
    *true* tokens; positions before 0 read as zeros, matching a fresh
    conv cache.
    """
    b, s, c = stream.shape
    if lengths is None:
        return jnp.pad(stream, ((0, 0), (max(w - s, 0), 0), (0, 0)))[:, -w:]
    xp = jnp.pad(stream, ((0, 0), (w, 0), (0, 0)))
    return jax.vmap(
        lambda row, l: jax.lax.dynamic_slice(row, (l, 0), (w, c))
    )(xp, lengths)


def decode_constants(p: Params) -> Params:
    """Fold per-step-invariant decode terms into the param dict.

    ``A = -exp(A_log)`` is recomputed by every :func:`decode_mamba2`
    call (once per token step, per layer) even though it only depends on
    weights. Serving hoists it once at pack/load time; :func:`decode_mamba2`
    and :func:`apply_mamba2` pick up the precomputed leaf when present
    (bit-identical — the same elementwise expression, evaluated earlier).
    The softplus'd ``dt`` is NOT hoistable: ``dt_bias`` enters inside
    ``softplus(dtr + dt_bias)`` with the per-token projection.
    """
    return {**p, "A": -jnp.exp(p["A_log"])}


def _neg_A(p: Params) -> jax.Array:
    return p["A"] if "A" in p else -jnp.exp(p["A_log"])


def _ssd_chunked(
    xh: jax.Array,    # (B, S, H, P) inputs per head
    dt: jax.Array,    # (B, S, H)   softplus'd step sizes
    A: jax.Array,     # (H,)        negative decay rates
    Bm: jax.Array,    # (B, S, N)
    Cm: jax.Array,    # (B, S, N)
    chunk: int = 128,
) -> jax.Array:
    """Chunked SSD: y_t = C_t h_t, h_t = exp(A dt_t) h_{t-1} + dt_t x_t B_t."""
    b, s, h, pdim = xh.shape
    n = Bm.shape[-1]
    L = min(chunk, s)
    nc = math.ceil(s / L)
    pad = nc * L - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    # scan over chunks: only one (B, L, L, H) intra-chunk tensor is ever
    # live (shards over batch x heads), instead of an (B, NC, L, L, H)
    # monster — this is what keeps the zamba2 train_4k cell compilable.
    xh = jnp.moveaxis(xh.reshape(b, nc, L, h, pdim), 1, 0)   # (NC,B,L,H,P)
    dt = jnp.moveaxis(dt.reshape(b, nc, L, h), 1, 0)
    Bm = jnp.moveaxis(Bm.reshape(b, nc, L, n), 1, 0)
    Cm = jnp.moveaxis(Cm.reshape(b, nc, L, n), 1, 0)
    tri = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(hprev, inp):
        xc, dtc, bc, cc = inp                                # (B,L,...)
        loga = dtc * A[None, None, :]                        # (B,L,H) <= 0
        cum = jnp.cumsum(loga, axis=1)
        rel = cum[:, :, None, :] - cum[:, None, :, :]        # (B,L,L,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        cb = jnp.einsum("bln,bmn->blm", cc, bc)
        y_intra = jnp.einsum(
            "blm,blmh,bmh,bmhp->blhp", cb, decay, dtc, xc
        )
        y_inter = jnp.einsum(
            "bln,blh,bhnp->blhp", cc, jnp.exp(cum), hprev
        )
        last = jnp.exp(cum[:, -1, :])                        # (B,H)
        rem = jnp.exp(cum[:, -1:, :] - cum)                  # (B,L,H)
        inc = jnp.einsum("bln,blh,blhp->bhnp", bc, dtc * rem, xc)
        hnew = hprev * last[:, :, None, None] + inc
        return hnew, y_intra + y_inter

    h0 = jnp.zeros((b, h, n, pdim), xh.dtype)
    hfinal, ys = jax.lax.scan(chunk_step, h0, (xh, dt, Bm, Cm))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * L, h, pdim)
    return y[:, :s], hfinal


def apply_mamba2(
    p: Params, x: jax.Array, cfg: SSMConfig, quant: QuantConfig,
    chunk: int = 128, return_cache: bool = False,
    lengths: Optional[jax.Array] = None,
):
    """Parallel (chunked-SSD) forward. x: (B, S, d).

    ``lengths`` (B,) marks each row's TRUE token count in a RIGHT-padded
    batch: positions ``t >= lengths[b]`` become state no-ops (``dt = 0``
    — identity decay, zero injection) and the returned conv cache is the
    per-row window ending at the true length, so the final state equals
    an unpadded forward's bit for bit. Outputs at padded positions are
    unmasked junk; callers read logits at true positions only.
    """
    b, s, _ = x.shape
    zxd, stats = apply_linear(p["in_proj"], x, quant)
    z, xbc_raw, dtr = _split_proj(zxd, cfg)
    xbc = _causal_depthwise_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xin, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + cfg.d_state], -1)
    xh = xin.reshape(b, s, cfg.n_heads, cfg.head_dim)
    xh = constrain(xh, "batch", "seq", "ssm_inner", None)
    dt = jax.nn.softplus(dtr + p["dt_bias"])                # (B,S,H)
    if lengths is not None:
        valid = jnp.arange(s)[None, :] < lengths[:, None]   # (B,S)
        dt = jnp.where(valid[..., None], dt, 0.0)
    A = _neg_A(p)                                           # (H,) < 0
    y, hfinal = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=chunk)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = apply_rmsnorm(p["norm"], y * jax.nn.silu(z))
    out, st2 = apply_linear(p["out_proj"], y, quant)
    stats.update(st2)
    if return_cache:
        tail = conv_tail_window(xbc_raw, cfg.conv_width - 1, lengths)
        return out, stats, {"state": hfinal, "conv": tail}
    return out, stats


# ---------------------------------------------------------------------------
# Sequential reference + decode step
# ---------------------------------------------------------------------------

def ssd_sequential_reference(xh, dt, A, Bm, Cm):
    """Plain per-step recurrence (oracle for the chunked form)."""
    b, s, h, pdim = xh.shape
    n = Bm.shape[-1]

    def step(hst, inp):
        xt, dtt, bt, ct = inp
        a = jnp.exp(dtt * A)                                # (B,H)
        inc = jnp.einsum("bn,bh,bhp->bhnp", bt, dtt, xt)
        hst = hst * a[:, :, None, None] + inc
        yt = jnp.einsum("bn,bhnp->bhp", ct, hst)
        return hst, yt

    h0 = jnp.zeros((b, h, n, pdim), xh.dtype)
    _, ys = jax.lax.scan(
        step,
        h0,
        (
            jnp.moveaxis(xh, 1, 0),
            jnp.moveaxis(dt, 1, 0),
            jnp.moveaxis(Bm, 1, 0),
            jnp.moveaxis(Cm, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1)


def init_mamba2_cache(batch: int, cfg: SSMConfig, dtype=jnp.float32) -> Dict:
    return {
        "state": jnp.zeros(
            (batch, cfg.n_heads, cfg.d_state, cfg.head_dim), dtype
        ),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), dtype),
    }


def decode_mamba2(
    p: Params, x: jax.Array, cache: Dict, cfg: SSMConfig, quant: QuantConfig
) -> Tuple[jax.Array, Dict, Dict]:
    """One-token step. x: (B, 1, d)."""
    b = x.shape[0]
    zxd, stats = apply_linear(p["in_proj"], x, quant)
    z, xbc, dtr = _split_proj(zxd[:, 0], cfg)
    conv_buf = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)
    xbc = jnp.einsum("bwc,wc->bc", conv_buf, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(xbc)
    xin, Bm, Cm = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + cfg.d_state], -1)
    xh = xin.reshape(b, cfg.n_heads, cfg.head_dim)
    dt = jax.nn.softplus(dtr + p["dt_bias"])                # (B,H)
    A = _neg_A(p)                  # hoisted at serve time: decode_constants
    a = jnp.exp(dt * A)
    inc = jnp.einsum("bn,bh,bhp->bhnp", Bm, dt, xh)
    state = cache["state"] * a[:, :, None, None] + inc
    y = jnp.einsum("bn,bhnp->bhp", Cm, state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, cfg.d_inner)
    y = apply_rmsnorm(p["norm"], y * jax.nn.silu(z))
    out, st2 = apply_linear(p["out_proj"], y[:, None], quant)
    stats.update(st2)
    new_cache = {"state": state, "conv": conv_buf[:, 1:]}
    return out, new_cache, stats
