"""GQA attention: full/sliding-window causal, cross, and cached decode.

All projections are PSQLinear (the HCiM technique applies to every QKVO
matmul). The decode path consumes a KV cache laid out (B, S, H_kv, D)
so the sequence dim can be sharded across the data axis for 500k-context
serving (the softmax reduction over a sharded axis lowers to
collective-assisted reductions under pjit).
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import QuantConfig
from repro.core.psq_linear import apply_linear, init_linear
from repro.models.layers import apply_norm, apply_rope, init_norm
from repro.parallel.sharding import constrain

Params = Dict
NEG_INF = -1e9


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    sliding_window: int = 0        # 0 => full attention
    rope_theta: float = 10000.0
    use_bias: bool = False
    causal: bool = True
    impl: str = "naive"            # naive | flash (chunked online softmax)
    kv_block: int = 1024


def init_attention(key: jax.Array, cfg: AttnConfig, quant: QuantConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p: Params = {
        "wq": init_linear(ks[0], d, h * hd, quant, use_bias=cfg.use_bias),
        "wk": init_linear(ks[1], d, hk * hd, quant, use_bias=cfg.use_bias),
        "wv": init_linear(ks[2], d, hk * hd, quant, use_bias=cfg.use_bias),
        "wo": init_linear(ks[3], h * hd, d, quant, use_bias=cfg.use_bias),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm("rmsnorm", hd)
        p["k_norm"] = init_norm("rmsnorm", hd)
    return p


def _project_qkv(
    p: Params, x: jax.Array, cfg: AttnConfig, quant: QuantConfig,
    positions: jax.Array, xkv: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, Dict]:
    b, s, _ = x.shape
    src = x if xkv is None else xkv
    s_kv = src.shape[1]
    q, st1 = apply_linear(p["wq"], x, quant)
    k, st2 = apply_linear(p["wk"], src, quant)
    v, st3 = apply_linear(p["wv"], src, quant)
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s_kv, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s_kv, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = apply_norm("rmsnorm", p["q_norm"], q)
        k = apply_norm("rmsnorm", p["k_norm"], k)
    if cfg.rope_theta > 0 and xkv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    stats = {}
    for st in (st1, st2, st3):
        stats.update(st)
    return q, k, v, stats


def _sdpa(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, S_kv, Hk, D)
    v: jax.Array,
    causal: bool,
    sliding_window: int,
    q_offset: jax.Array | int = 0,
) -> jax.Array:
    b, s, h, d = q.shape
    s_kv = k.shape[1]
    groups = h // k.shape[2]
    qh = q.reshape(b, s, k.shape[2], groups, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qh, k) / math.sqrt(d)
    qpos = jnp.arange(s) + q_offset
    kpos = jnp.arange(s_kv)
    mask = jnp.ones((s, s_kv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if sliding_window > 0:
        mask &= kpos[None, :] > qpos[:, None] - sliding_window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h * d)


def _sdpa_flash(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, S_kv, Hk, D)
    v: jax.Array,
    causal: bool,
    sliding_window: int,
    kv_block: int = 1024,
) -> jax.Array:
    """Chunked online-softmax attention (flash-style, lax.scan over KV).

    Never materializes the (S, S_kv) score matrix in HBM: per KV block
    only an (B, Hk, G, S, L) tile is live, with running (m, l, acc)
    statistics carried in f32 — the memory-roofline fix for the 32k
    cells (§Perf). Bit-compatible with _sdpa up to fp reassociation.
    """
    b, s, h, d = q.shape
    s_kv = k.shape[1]
    hk = k.shape[2]
    g = h // hk
    L = min(kv_block, s_kv)
    nb = math.ceil(s_kv / L)
    pad = nb * L - s_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qh = q.reshape(b, s, hk, g, d)
    kb = jnp.moveaxis(k.reshape(b, nb, L, hk, d), 1, 0)   # (NB,B,L,Hk,D)
    vb = jnp.moveaxis(v.reshape(b, nb, L, hk, d), 1, 0)
    qpos = jnp.arange(s)
    scale = 1.0 / math.sqrt(d)

    def step(carry, inp):
        m, l, acc = carry                                  # (B,Hk,G,S), ..., (B,Hk,G,S,D)
        kc, vc, blk = inp
        logits = jnp.einsum(
            "bskgd,blkd->bkgsl", qh, kc
        ).astype(jnp.float32) * scale                      # (B,Hk,G,S,L)
        kpos = blk * L + jnp.arange(L)
        mask = jnp.ones((s, L), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if sliding_window > 0:
            mask &= kpos[None, :] > qpos[:, None] - sliding_window
        mask &= (kpos < s_kv)[None, :]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        corr = jnp.exp(m - m_new)
        p_blk = jnp.exp(logits - m_new[..., None])
        l_new = l * corr + jnp.sum(p_blk, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgsl,blkd->bkgsd", p_blk.astype(q.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, s), jnp.float32)
    a0 = jnp.zeros((b, hk, g, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kb, vb, jnp.arange(nb))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1)                          # (B,S,Hk,G,D)
    return out.reshape(b, s, h * d).astype(q.dtype)


def apply_attention(
    p: Params, x: jax.Array, cfg: AttnConfig, quant: QuantConfig,
    positions: Optional[jax.Array] = None,
    xkv: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict]:
    """Full (training/prefill) attention; cross-attention when xkv given."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v, stats = _project_qkv(p, x, cfg, quant, positions, xkv)
    causal = cfg.causal and xkv is None
    window = cfg.sliding_window if xkv is None else 0
    if cfg.impl == "flash":
        ctx = _sdpa_flash(q, k, v, causal, window, kv_block=cfg.kv_block)
    else:
        ctx = _sdpa(q, k, v, causal, window)
    ctx = constrain(ctx, "batch", "seq", "qkv_features")
    y, st = apply_linear(p["wo"], ctx, quant)
    stats.update(st)
    return y, stats


# ---------------------------------------------------------------------------
# Cached decode
# ---------------------------------------------------------------------------

def init_kv_cache(
    batch: int, max_len: int, n_kv_heads: int, head_dim: int,
    dtype=jnp.bfloat16, long_context: bool = False,
) -> Dict:
    seq_axis = "long_kv_seq" if long_context else "kv_seq"
    k = constrain(
        jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "batch", seq_axis, "kv_heads", "head_dim",
    )
    v = constrain(
        jnp.zeros((batch, max_len, n_kv_heads, head_dim), dtype),
        "batch", seq_axis, "kv_heads", "head_dim",
    )
    return {"k": k, "v": v, "length": jnp.zeros((), jnp.int32)}


def decode_attention(
    p: Params, x: jax.Array, cache: Dict, cfg: AttnConfig, quant: QuantConfig,
    defer_update: bool = False,
) -> Tuple[jax.Array, Dict, Dict]:
    """One-token decode step against a (possibly sequence-sharded) cache.

    x: (B, 1, d). Returns (y, new_cache, stats); with ``defer_update``
    returns (y, (k_new, v_new), stats) and NEVER writes the cache — the
    new token enters the softmax as an explicit extra column. Inside the
    layer scan this is essential: materializing an updated cache per
    layer compiles to a full stacked-cache copy every iteration
    (measured 40x the necessary decode traffic — EXPERIMENTS.md §Perf);
    the caller instead commits all layers' (k_new, v_new) with ONE tiny
    dynamic-update-slice after the scan.

    ``cache["length"]`` may be a scalar (uniform batch, the classic
    static path) or an ``(B,)`` vector of per-row lengths (the
    continuous-batching slot pool): masking, RoPE positions and cache
    writes are all per-row in the vector case.
    """
    b = x.shape[0]
    length = cache["length"]
    lv = jnp.broadcast_to(length, (b,)) if jnp.ndim(length) == 0 else length
    pos = lv[:, None]
    q, k_new, v_new, stats = _project_qkv(p, x, cfg, quant, pos)
    k, v = cache["k"], cache["v"]
    s_kv = k.shape[1]
    groups = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, 1, cfg.n_kv_heads, groups, cfg.head_dim)
    # compute in the cache dtype (bf16) with f32 accumulation: upcasting
    # `k` would convert (and loop-carry) the entire stacked cache in f32
    logits = jnp.einsum(
        "bskgd,btkd->bkgst", qh.astype(k.dtype), k,
        preferred_element_type=jnp.float32,
    )
    kpos = jnp.arange(s_kv)
    valid = kpos[None, :] < lv[:, None]              # past tokens only
    if cfg.sliding_window > 0:
        valid &= kpos[None, :] > lv[:, None] - cfg.sliding_window
    logits = jnp.where(valid[:, None, None, None], logits, NEG_INF)
    # the new token's own k as an explicit extra column
    logit_new = jnp.einsum(
        "bskgd,btkd->bkgst", qh.astype(k_new.dtype),
        k_new.astype(k_new.dtype), preferred_element_type=jnp.float32,
    )
    scale = 1.0 / math.sqrt(cfg.head_dim)
    full = jnp.concatenate([logits, logit_new], axis=-1) * scale
    probs = jax.nn.softmax(full.astype(jnp.float32), axis=-1)
    p_past, p_new = probs[..., :-1], probs[..., -1:]
    ctx = jnp.einsum(
        "bkgst,btkd->bskgd", p_past.astype(k.dtype), v,
        preferred_element_type=jnp.float32,
    ) + jnp.einsum(
        "bkgst,btkd->bskgd", p_new.astype(v_new.dtype), v_new,
        preferred_element_type=jnp.float32,
    )
    ctx = ctx.astype(q.dtype).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    y, st = apply_linear(p["wo"], ctx, quant)
    stats.update(st)
    if defer_update:
        return y, (k_new, v_new), stats
    if jnp.ndim(length) == 0:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k_new.astype(cache["k"].dtype), length, axis=1
        )
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v_new.astype(cache["v"].dtype), length, axis=1
        )
    else:
        write = jax.vmap(
            lambda c, u, l: jax.lax.dynamic_update_slice(c, u, (l, 0, 0))
        )
        k = write(cache["k"], k_new.astype(cache["k"].dtype), lv)
        v = write(cache["v"], v_new.astype(cache["v"].dtype), lv)
    new_cache = {"k": k, "v": v, "length": length + 1}
    return y, new_cache, stats


def cross_attention_cache(
    p: Params, enc_out: jax.Array, cfg: AttnConfig, quant: QuantConfig
) -> Dict:
    """Precompute encoder K/V for decode-time cross-attention."""
    b, s, _ = enc_out.shape
    k, _ = apply_linear(p["wk"], enc_out, quant)
    v, _ = apply_linear(p["wv"], enc_out, quant)
    return {
        "k": k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim),
        "v": v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim),
    }


def decode_cross_attention(
    p: Params, x: jax.Array, xcache: Dict, cfg: AttnConfig, quant: QuantConfig
) -> Tuple[jax.Array, Dict]:
    # x: (B, S, d) — S is 1 for classic decode, K+1 for spec verify.
    # Cross-attention has no causal mask and no positions, so any query
    # width attends the full encoder output identically.
    b, s, _ = x.shape
    q, stats = apply_linear(p["wq"], x, quant)
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = apply_norm("rmsnorm", p["q_norm"], q)
    k, v = xcache["k"], xcache["v"]
    groups = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, s, cfg.n_kv_heads, groups, cfg.head_dim)
    logits = jnp.einsum("bskgd,btkd->bkgst", qh, k.astype(q.dtype))
    logits = logits / math.sqrt(cfg.head_dim)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(q.dtype))
    ctx = ctx.reshape(b, s, cfg.n_heads * cfg.head_dim)
    y, st = apply_linear(p["wo"], ctx, quant)
    stats.update(st)
    return y, stats
