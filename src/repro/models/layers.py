"""Shared neural-net layers (norms, RoPE, MLPs, embeddings).

Every projection is a PSQLinear so the HCiM execution mode applies
uniformly across the zoo. Parameters are plain nested dicts.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import QuantConfig
from repro.core.psq_linear import apply_linear, init_linear
from repro.parallel.sharding import constrain

Params = Dict


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def apply_layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


def init_norm(kind: str, d: int) -> Params:
    return init_rmsnorm(d) if kind == "rmsnorm" else init_layernorm(d)


def apply_norm(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return apply_rmsnorm(p, x) if kind == "rmsnorm" else apply_layernorm(p, x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                     # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                    # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(
    key: jax.Array, d: int, d_ff: int, act: str, quant: QuantConfig,
    use_bias: bool = False,
) -> Params:
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "gate": init_linear(ks[0], d, d_ff, quant, use_bias=use_bias),
            "up": init_linear(ks[1], d, d_ff, quant, use_bias=use_bias),
            "down": init_linear(ks[2], d_ff, d, quant, use_bias=use_bias),
        }
    return {
        "fc": init_linear(ks[0], d, d_ff, quant, use_bias=use_bias),
        "proj": init_linear(ks[1], d_ff, d, quant, use_bias=use_bias),
    }


def apply_mlp(
    p: Params, x: jax.Array, act: str, quant: QuantConfig
) -> Tuple[jax.Array, Dict]:
    stats = {}
    if act == "swiglu":
        g, s1 = apply_linear(p["gate"], x, quant)
        u, s2 = apply_linear(p["up"], x, quant)
        h = jax.nn.silu(g) * u
        h = constrain(h, "batch", "seq", "ffn")
        y, s3 = apply_linear(p["down"], h, quant)
        stats = _merge(s1, s2, s3)
    else:
        h, s1 = apply_linear(p["fc"], x, quant)
        h = jax.nn.gelu(h)
        h = constrain(h, "batch", "seq", "ffn")
        y, s2 = apply_linear(p["proj"], h, quant)
        stats = _merge(s1, s2)
    return y, stats


def _merge(*stats: Dict) -> Dict:
    out: Dict = {}
    vals = [s["p_zero_frac"] for s in stats if "p_zero_frac" in s]
    if vals:
        out["p_zero_frac"] = sum(vals) / len(vals)
    return out


# ---------------------------------------------------------------------------
# Embedding / LM head (kept full-precision, standard PSQ practice)
# ---------------------------------------------------------------------------

def init_embedding(key: jax.Array, vocab: int, d: int) -> Params:
    return {"table": jax.random.normal(key, (vocab, d)) * 0.02}


def apply_embedding(p: Params, ids: jax.Array) -> jax.Array:
    return constrain(jnp.take(p["table"], ids, axis=0), "batch", "seq", "embed")


def apply_lm_head(
    p_emb: Params, x: jax.Array, head: Optional[Params] = None
) -> jax.Array:
    if head is not None:
        if "w_packed" in head:  # int4 deployment weights
            from repro.core.psq_linear import _unpack_int4_matmul

            return _unpack_int4_matmul(x, head["w_packed"], head["w_scale"])
        return x @ head["w"].astype(x.dtype)
    return x @ p_emb["table"].T.astype(x.dtype)


def init_lm_head(key: jax.Array, d: int, vocab: int) -> Params:
    return {"w": jax.random.normal(key, (d, vocab)) * (1.0 / math.sqrt(d))}
