"""Model zoo: every projection is a PSQLinear (HCiM-quantizable)."""
from repro.models.transformer import forward, init_model, loss_fn
from repro.models.decode import decode_step, init_cache, prefill
