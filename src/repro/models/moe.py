"""Mixture-of-Experts with capacity-based top-k dispatch (chunked).

Designed for both 40-expert (granite, top-8) and 128-expert (arctic,
top-2 + dense residual) configurations:

* the router runs in fp32 (standard practice; it is *not* PSQ-quantized
  — mirroring the paper's convention of keeping tiny accuracy-critical
  layers at full precision),
* tokens are processed in fixed-size chunks so the (E, C, d) gather
  intermediate stays small at any sequence length — this is what keeps
  the 1M-token arctic dry-run compilable,
* expert weights live as (E, d, ff) stacked tensors: expert-parallel
  (E over the model axis) when E divides the axis, otherwise the expert
  FFN dim shards (granite's 40 experts on a 16-way axis),
* an auxiliary load-balance loss (Switch-style) is returned in stats.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import QuantConfig
from repro.core import psq
from repro.core.psq_linear import init_linear
from repro.parallel.sharding import constrain

Params = Dict


def init_moe(
    key: jax.Array, d: int, d_ff: int, n_experts: int, top_k: int,
    quant: QuantConfig, act: str = "swiglu",
) -> Params:
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p: Params = {
        "router": jax.random.normal(ks[0], (d, n_experts)) * 0.02,
        "w_gate": jax.random.normal(ks[1], (n_experts, d, d_ff)) * std,
        "w_up": jax.random.normal(ks[2], (n_experts, d, d_ff)) * std,
        "w_down": jax.random.normal(ks[3], (n_experts, d_ff, d))
        * (1.0 / math.sqrt(d_ff)),
    }
    if quant.quantized:
        # one PSQ quantizer state per expert weight family (layer-level
        # scale factors per the paper; expert dim folds into the tile dim)
        for name, (kin, out) in {
            "w_gate": (d, d_ff), "w_up": (d, d_ff), "w_down": (d_ff, d)
        }.items():
            qp = psq.init_psq_params(key, kin, out, quant, w_std=std)
            p[f"{name}_q"] = qp
    return p


def _expert_ffn(
    p: Params, xs: jax.Array, quant: QuantConfig, act: str
) -> jax.Array:
    """xs: (E, C, d) gathered tokens -> (E, C, d) expert outputs."""
    if quant.quantized:
        # PSQ per expert: vmap the quantized matmul over the expert dim,
        # sharing the per-layer quantizer state (paper quantizes at layer
        # granularity; scale-factor tensors are per-layer here).
        def one(xe, wg, wu, wd):
            g, _ = psq.psq_matmul(xe, wg, p["w_gate_q"], quant)
            u, _ = psq.psq_matmul(xe, wu, p["w_up_q"], quant)
            h = jax.nn.silu(g) * u if act == "swiglu" else jax.nn.gelu(g)
            y, _ = psq.psq_matmul(h, wd, p["w_down_q"], quant)
            return y

        return jax.vmap(one)(xs, p["w_gate"], p["w_up"], p["w_down"])
    g = jnp.einsum("ecd,edf->ecf", xs, p["w_gate"])
    if act == "swiglu":
        u = jnp.einsum("ecd,edf->ecf", xs, p["w_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g)
    h = constrain(h, "experts", None, "expert_ffn")
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _moe_chunk(
    p: Params, x: jax.Array, n_experts: int, top_k: int,
    capacity: int, quant: QuantConfig, act: str,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Route one chunk of tokens. x: (T, d) -> (y, aux_loss, me_fraction)."""
    t, d = x.shape
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Switch-style load balance aux loss
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.zeros((n_experts,)).at[gate_idx.reshape(-1)].add(
        jnp.ones((t * top_k,)) / (t * top_k)
    )
    aux = n_experts * jnp.sum(me * ce)

    # per-expert token selection: score matrix (E, T) of assigned gates
    assign = jnp.zeros((t, n_experts), jnp.float32)
    assign = assign.at[jnp.arange(t)[:, None], gate_idx].set(gate_vals)
    # pick up to `capacity` highest-gate tokens per expert
    sel_gate, sel_idx = jax.lax.top_k(assign.T, capacity)    # (E, C)
    xs = jnp.take(x, sel_idx, axis=0)                        # (E, C, d)
    xs = xs * (sel_gate > 0.0)[..., None].astype(x.dtype)
    ys = _expert_ffn(p, xs, quant, act)                      # (E, C, d)
    ys = ys * sel_gate[..., None].astype(ys.dtype)
    y = jnp.zeros_like(x).at[sel_idx.reshape(-1)].add(
        ys.reshape(-1, d), mode="drop"
    )
    return y, aux, me


def apply_moe_dense(
    p: Params, x: jax.Array, n_experts: int, top_k: int,
    quant: QuantConfig, act: str = "swiglu",
) -> Tuple[jax.Array, Dict]:
    """Weighted-dense mixture: every expert computed, gated by top-k probs.

    For many-small-expert configs (granite: 40 experts of d_ff=512) the
    dispatch machinery costs far more than it saves — E/top_k extra
    expert FLOPs buy the removal of ALL gather/scatter/capacity traffic
    and turn the expert matmuls into two large TP-sharded einsums
    (EXPERIMENTS.md §Perf, granite hillclimb).
    """
    b, s, d = x.shape
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    gates = jnp.zeros_like(probs).at[
        jnp.arange(b)[:, None, None],
        jnp.arange(s)[None, :, None],
        gate_idx,
    ].set(gate_vals)                                          # (B,S,E) sparse

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1)) * (
        n_experts / top_k
    )
    aux = jnp.sum(me * ce)

    h_g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"].astype(x.dtype))
    if act == "swiglu":
        h_u = jnp.einsum("bsd,edf->bsef", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(h_g) * h_u
    else:
        h = jax.nn.gelu(h_g)
    h = constrain(h, "batch", "seq", None, "expert_ffn")
    y = jnp.einsum(
        "bsef,efd,bse->bsd", h, p["w_down"].astype(h.dtype),
        gates.astype(h.dtype),
    )
    return constrain(y, "batch", "seq", "embed"), {
        "moe_aux_loss": aux, "router_me": me,
    }


def apply_moe(
    p: Params, x: jax.Array, n_experts: int, top_k: int,
    quant: QuantConfig, act: str = "swiglu",
    capacity_factor: float = 1.25, chunk_size: int = 4096,
    impl: str = "dispatch",
) -> Tuple[jax.Array, Dict]:
    """x: (B, S, d). Locality-aware top-k routing with capacity dropping.

    Routing groups are formed *within* each batch row (sequence chunks of
    ``chunk_size``), so under batch->data sharding the gather/scatter of
    the dispatch never crosses devices — the expert compute itself is
    either expert-parallel (E % axis == 0) or TP over the expert FFN.
    (The original token-major chunking resharded the whole activation
    per chunk; see EXPERIMENTS.md §Perf granite hillclimb.)
    """
    if impl == "dense":
        return apply_moe_dense(p, x, n_experts, top_k, quant, act=act)
    b, s, d = x.shape
    chunk = max(1, min(chunk_size, s))
    n_chunks = math.ceil(s / chunk)
    pad = n_chunks * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    groups = x.reshape(b * n_chunks, chunk, d)
    capacity = min(chunk, max(1, int(capacity_factor * chunk * top_k / n_experts)))

    def route(xc):
        return _moe_chunk(p, xc, n_experts, top_k, capacity, quant, act)

    ys, aux, mes = jax.vmap(route)(groups)
    y = ys.reshape(b, n_chunks * chunk, d)[:, :s]
    y = constrain(y, "batch", "seq", "embed")
    stats = {
        "moe_aux_loss": jnp.mean(aux),
        "router_me": jnp.mean(mes, axis=0),
    }
    return y, stats
