"""Mixture-of-Experts with capacity-based top-k dispatch (chunked).

Designed for both 40-expert (granite, top-8) and 128-expert (arctic,
top-2 + dense residual) configurations:

* the router runs in fp32 (standard practice; it is *not* PSQ-quantized
  — mirroring the paper's convention of keeping tiny accuracy-critical
  layers at full precision),
* tokens are processed in fixed-size chunks so the (E, C, d) gather
  intermediate stays small at any sequence length — this is what keeps
  the 1M-token arctic dry-run compilable,
* expert weights live as (E, d, ff) stacked tensors: expert-parallel
  (E over the model axis) when E divides the axis, otherwise the expert
  FFN dim shards (granite's 40 experts on a 16-way axis),
* an auxiliary load-balance loss (Switch-style) is returned in stats.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.config import QuantConfig
from repro.core import psq
from repro.core.psq_linear import init_linear
from repro.parallel import sharding as shd
from repro.parallel.sharding import constrain

Params = Dict


def init_moe(
    key: jax.Array, d: int, d_ff: int, n_experts: int, top_k: int,
    quant: QuantConfig, act: str = "swiglu",
) -> Params:
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p: Params = {
        "router": jax.random.normal(ks[0], (d, n_experts)) * 0.02,
        "w_gate": jax.random.normal(ks[1], (n_experts, d, d_ff)) * std,
        "w_up": jax.random.normal(ks[2], (n_experts, d, d_ff)) * std,
        "w_down": jax.random.normal(ks[3], (n_experts, d_ff, d))
        * (1.0 / math.sqrt(d_ff)),
    }
    if quant.quantized:
        # one PSQ quantizer state per expert weight family (layer-level
        # scale factors per the paper; expert dim folds into the tile dim)
        for name, (kin, out) in {
            "w_gate": (d, d_ff), "w_up": (d, d_ff), "w_down": (d_ff, d)
        }.items():
            qp = psq.init_psq_params(key, kin, out, quant, w_std=std)
            p[f"{name}_q"] = qp
    return p


def _expert_ffn(
    p: Params, xs: jax.Array, quant: QuantConfig, act: str,
    constrained: bool = True,
) -> jax.Array:
    """xs: (E, C, d) gathered tokens -> (E, C, d) expert outputs.

    ``constrained=False`` drops the logical activation constraints —
    required inside the expert-parallel shard_map, where every mesh axis
    is manual and ``with_sharding_constraint`` would reject the spec.
    """
    if quant.quantized:
        # PSQ per expert: vmap the quantized matmul over the expert dim,
        # sharing the per-layer quantizer state (paper quantizes at layer
        # granularity; scale-factor tensors are per-layer here).
        def one(xe, wg, wu, wd):
            g, _ = psq.psq_matmul(xe, wg, p["w_gate_q"], quant)
            u, _ = psq.psq_matmul(xe, wu, p["w_up_q"], quant)
            h = jax.nn.silu(g) * u if act == "swiglu" else jax.nn.gelu(g)
            y, _ = psq.psq_matmul(h, wd, p["w_down_q"], quant)
            return y

        return jax.vmap(one)(xs, p["w_gate"], p["w_up"], p["w_down"])
    g = jnp.einsum("ecd,edf->ecf", xs, p["w_gate"])
    if act == "swiglu":
        u = jnp.einsum("ecd,edf->ecf", xs, p["w_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(g)
    if constrained:
        h = constrain(h, "experts", None, "expert_ffn")
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _route_chunk(
    router: jax.Array, x: jax.Array, n_experts: int, top_k: int,
    capacity: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Top-k routing for one chunk: x (T, d) -> (sel_gate, sel_idx, aux, me).

    Pure function of the (replicated) router weights, so the single
    device and every expert-parallel shard compute the identical
    ``(E, C)`` selection — the invariant that keeps the sharded combine
    bit-exact with the local scatter-add.
    """
    t = x.shape[0]
    logits = (x.astype(jnp.float32) @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)        # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Switch-style load balance aux loss
    me = jnp.mean(probs, axis=0)                             # (E,)
    ce = jnp.zeros((n_experts,)).at[gate_idx.reshape(-1)].add(
        jnp.ones((t * top_k,)) / (t * top_k)
    )
    aux = n_experts * jnp.sum(me * ce)

    # per-expert token selection: score matrix (E, T) of assigned gates
    assign = jnp.zeros((t, n_experts), jnp.float32)
    assign = assign.at[jnp.arange(t)[:, None], gate_idx].set(gate_vals)
    # pick up to `capacity` highest-gate tokens per expert
    sel_gate, sel_idx = jax.lax.top_k(assign.T, capacity)    # (E, C)
    return sel_gate, sel_idx, aux, me


def _combine_chunk(x: jax.Array, ys: jax.Array, sel_idx: jax.Array):
    """Scatter-add (E, C, d) gated expert outputs back to token order."""
    d = x.shape[-1]
    return jnp.zeros_like(x).at[sel_idx.reshape(-1)].add(
        ys.reshape(-1, d), mode="drop"
    )


def _moe_chunk(
    p: Params, x: jax.Array, n_experts: int, top_k: int,
    capacity: int, quant: QuantConfig, act: str,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Route one chunk of tokens. x: (T, d) -> (y, aux_loss, me_fraction)."""
    sel_gate, sel_idx, aux, me = _route_chunk(
        p["router"], x, n_experts, top_k, capacity
    )
    xs = jnp.take(x, sel_idx, axis=0)                        # (E, C, d)
    xs = xs * (sel_gate > 0.0)[..., None].astype(x.dtype)
    ys = _expert_ffn(p, xs, quant, act)                      # (E, C, d)
    ys = ys * sel_gate[..., None].astype(ys.dtype)
    y = _combine_chunk(x, ys, sel_idx)
    return y, aux, me


def _apply_moe_ep(
    p: Params, groups: jax.Array, n_experts: int, top_k: int,
    capacity: int, quant: QuantConfig, act: str, mesh, axis: str,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Expert-parallel dispatch over the ``axis`` mesh axis.

    Each device owns ``E / n`` expert FFN stacks (``w_gate``/``w_up``/
    ``w_down`` leading-dim sharded; router + PSQ quantizer state
    replicated) and computes only its local experts' gathered tokens.
    Routing is replicated — every shard derives the identical global
    ``(E, C)`` selection from the replicated router — so an
    ``all_gather`` of the local gated outputs reassembles the exact
    ``(E, C, d)`` tensor the single-device path feeds its scatter-add,
    and the combine is the identical op: bit-exact by construction for
    ANY top_k (a psum-of-partials combine would reassociate the
    per-token float sums across shards; the gather costs top_k x more
    bandwidth and buys determinism).

    ``groups`` (G, T, d) are the already-chunked token groups; the
    group dim follows the ``batch`` rule (dispatch never crosses the
    data axis), expert weights ride ``axis``.
    """
    n = mesh.shape[axis]
    e_local = n_experts // n

    pspecs = {
        k: (P(axis) if k in ("w_gate", "w_up", "w_down")
            else jax.tree.map(lambda _: P(), v))
        for k, v in p.items()
    }
    gspec = shd.data_pspec(groups.ndim, groups.shape, exclude=(axis,))
    g = groups.shape[0]
    aux_spec = shd.data_pspec(1, (g,), exclude=(axis,))
    me_spec = shd.data_pspec(2, (g, n_experts), exclude=(axis,))

    def local_fn(pl, gl):
        e_lo = jax.lax.axis_index(axis) * e_local

        def phase1(xc):
            sel_gate, sel_idx, aux, me = _route_chunk(
                pl["router"], xc, n_experts, top_k, capacity
            )
            sg = jax.lax.dynamic_slice_in_dim(sel_gate, e_lo, e_local, 0)
            si = jax.lax.dynamic_slice_in_dim(sel_idx, e_lo, e_local, 0)
            xs = jnp.take(xc, si, axis=0)                # (E/n, C, d)
            xs = xs * (sg > 0.0)[..., None].astype(xc.dtype)
            ys = _expert_ffn(pl, xs, quant, act, constrained=False)
            ys = ys * sg[..., None].astype(ys.dtype)
            return ys, sel_idx, aux, me

        ys_l, sel_idx, aux, me = jax.vmap(phase1)(gl)    # (G, E/n, C, d)
        ys = jax.lax.all_gather(ys_l, axis, axis=1, tiled=True)
        y = jax.vmap(_combine_chunk)(gl, ys, sel_idx)
        return y, aux, me

    fn = shard_map(
        local_fn, mesh=mesh, in_specs=(pspecs, gspec),
        out_specs=(gspec, aux_spec, me_spec), check_rep=False,
    )
    return fn(p, groups)


def apply_moe_dense(
    p: Params, x: jax.Array, n_experts: int, top_k: int,
    quant: QuantConfig, act: str = "swiglu",
) -> Tuple[jax.Array, Dict]:
    """Weighted-dense mixture: every expert computed, gated by top-k probs.

    For many-small-expert configs (granite: 40 experts of d_ff=512) the
    dispatch machinery costs far more than it saves — E/top_k extra
    expert FLOPs buy the removal of ALL gather/scatter/capacity traffic
    and turn the expert matmuls into two large TP-sharded einsums
    (EXPERIMENTS.md §Perf, granite hillclimb).
    """
    b, s, d = x.shape
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    gates = jnp.zeros_like(probs).at[
        jnp.arange(b)[:, None, None],
        jnp.arange(s)[None, :, None],
        gate_idx,
    ].set(gate_vals)                                          # (B,S,E) sparse

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean((gates > 0).astype(jnp.float32), axis=(0, 1)) * (
        n_experts / top_k
    )
    aux = jnp.sum(me * ce)

    h_g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"].astype(x.dtype))
    if act == "swiglu":
        h_u = jnp.einsum("bsd,edf->bsef", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(h_g) * h_u
    else:
        h = jax.nn.gelu(h_g)
    h = constrain(h, "batch", "seq", None, "expert_ffn")
    y = jnp.einsum(
        "bsef,efd,bse->bsd", h, p["w_down"].astype(h.dtype),
        gates.astype(h.dtype),
    )
    return constrain(y, "batch", "seq", "embed"), {
        "moe_aux_loss": aux, "router_me": me,
    }


def apply_moe(
    p: Params, x: jax.Array, n_experts: int, top_k: int,
    quant: QuantConfig, act: str = "swiglu",
    capacity_factor: float = 1.25, chunk_size: int = 4096,
    impl: str = "dispatch",
) -> Tuple[jax.Array, Dict]:
    """x: (B, S, d). Locality-aware top-k routing with capacity dropping.

    Routing groups are formed *within* each batch row (sequence chunks of
    ``chunk_size``), so under batch->data sharding the gather/scatter of
    the dispatch never crosses devices — the expert compute itself is
    either expert-parallel (E % axis == 0) or TP over the expert FFN.
    (The original token-major chunking resharded the whole activation
    per chunk; see EXPERIMENTS.md §Perf granite hillclimb.)

    Under active expert-parallel rules (``RULES_EXPERT`` + a mesh with
    an ``expert`` axis; see :func:`repro.parallel.sharding.expert_axes`)
    the dispatch runs as a shard_map with each device computing its
    local expert slab — bit-exact with the single-device path (see
    :func:`_apply_moe_ep`). Falls back to single-device dispatch when
    the expert count does not divide the axis. The ``dense`` impl stays
    on the TP (``expert_ffn -> model``) path.
    """
    if impl == "dense":
        return apply_moe_dense(p, x, n_experts, top_k, quant, act=act)
    b, s, d = x.shape
    chunk = max(1, min(chunk_size, s))
    n_chunks = math.ceil(s / chunk)
    pad = n_chunks * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    groups = x.reshape(b * n_chunks, chunk, d)
    capacity = min(chunk, max(1, int(capacity_factor * chunk * top_k / n_experts)))

    ep = shd.expert_axes()
    if ep is not None and n_experts % ep[0].shape[ep[1]] == 0:
        ys, aux, mes = _apply_moe_ep(
            p, groups, n_experts, top_k, capacity, quant, act, *ep
        )
    else:
        def route(xc):
            return _moe_chunk(p, xc, n_experts, top_k, capacity, quant, act)

        ys, aux, mes = jax.vmap(route)(groups)
    y = ys.reshape(b, n_chunks * chunk, d)[:, :s]
    y = constrain(y, "batch", "seq", "embed")
    stats = {
        "moe_aux_loss": jnp.mean(aux),
        "router_me": jnp.mean(mes, axis=0),
    }
    return y, stats
