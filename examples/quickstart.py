"""Quickstart: train a tiny PSQ-quantized LM end to end on CPU.

Shows the paper's pipeline in one file: an LM whose every matmul runs
through the HCiM crossbar model (ternary partial sums + learned
fixed-point scale factors), trained with PSQ-QAT, with the ternary
sparsity statistic the DCiM energy model consumes.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.config import QuantConfig
from repro.data import DataConfig, TokenStream
from repro.models import forward, init_model, loss_fn
from repro.train import OptConfig, adamw_update, init_opt_state


def main():
    cfg = get_config("tinyllama-1.1b").reduced()
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        quant=QuantConfig(mode="psq", psq_levels="ternary", xbar_rows=64,
                          collect_stats=True),
    )
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptConfig(lr=2e-3, warmup_steps=10, total_steps=60,
                        quant_lr_mult=0.2)
    opt = init_opt_state(params)
    stream = TokenStream(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))

    @jax.jit
    def step(params, opt, batch):
        (loss, stats), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        params, opt, _ = adamw_update(opt_cfg, params, grads, opt)
        return params, opt, loss, stats.get("p_zero_frac", 0.0)

    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
        params, opt, loss, pz = step(params, opt, batch)
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}  "
                  f"ternary-sparsity {float(pz):.2%}")
    print("\nPSQ-QAT works: loss decreased with 1.5-bit partial sums, and")
    print(f"~{float(pz):.0%} of comparator outputs are zero — the sparsity")
    print("HCiM's DCiM clock gating converts into the Fig. 5(a) energy win.")


if __name__ == "__main__":
    main()
