"""End-to-end training driver: fault-tolerant LM training with PSQ.

Defaults to a ~25M-parameter tinyllama-family model that trains a few
hundred steps in CPU-minutes; ``--preset 100m`` scales to the ~100M
configuration for real hardware. Demonstrates the full substrate:
deterministic data, AdamW + cosine schedule, atomic checkpointing with
auto-resume, failure injection + restart, straggler monitoring.

    PYTHONPATH=src python examples/train_lm_psq.py --steps 200
    PYTHONPATH=src python examples/train_lm_psq.py --quant psq --steps 100
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.core.config import PSQ_TERNARY, QuantConfig
from repro.data import DataConfig, TokenStream
from repro.train import FailureInjector, OptConfig, Trainer, TrainerConfig

PRESETS = {
    # (d_model, n_layers, n_heads, kv, d_ff, vocab, seq, batch)
    "tiny": (256, 4, 8, 4, 704, 2048, 256, 8),     # ~3M, CPU-seconds/step
    "25m": (512, 8, 8, 4, 1408, 8192, 256, 8),     # ~25M
    "100m": (768, 12, 12, 4, 2048, 32000, 1024, 32),  # ~100M (hardware)
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--quant", default="none", choices=["none", "psq", "binary"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=-1,
                    help="simulate a node failure at this step")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    d, L, h, kv, ff, vocab, seq, batch = PRESETS[args.preset]
    cfg = dataclasses.replace(
        get_config("tinyllama-1.1b"),
        d_model=d, n_layers=L, n_heads=h, n_kv_heads=kv, d_ff=ff,
        vocab_size=vocab, head_dim=d // h,
    )
    if args.quant != "none":
        q = PSQ_TERNARY if args.quant == "psq" else dataclasses.replace(
            PSQ_TERNARY, psq_levels="binary")
        cfg = cfg.with_quant(dataclasses.replace(q, xbar_rows=64))

    stream = TokenStream(DataConfig(vocab_size=vocab, seq_len=seq,
                                    global_batch=batch))
    injector = (FailureInjector(fail_at_steps=(args.inject_failure,))
                if args.inject_failure >= 0 else None)
    trainer = Trainer(
        cfg,
        OptConfig(lr=3e-4, warmup_steps=max(args.steps // 20, 5),
                  total_steps=args.steps),
        TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 10),
                      log_every=10, ckpt_dir=args.ckpt_dir,
                      compress_grads=args.compress_grads),
        data_fn=stream.batch_at,
        injector=injector,
    )
    trainer.train()
    h0, h1 = trainer.metrics_history[0], trainer.metrics_history[-1]
    print(f"\nloss {h0['loss']:.3f} -> {h1['loss']:.3f} over "
          f"{args.steps} steps ({args.preset}, quant={args.quant})")


if __name__ == "__main__":
    main()
