"""Paper-faithful QAT reproduction (Table 2 trends, CIFAR recipe).

Trains the synthetic CIFAR-shaped classifier with the exact §5.1 CIFAR
quantization recipe (a4/w4/sf4, ternary/binary partial sums, crossbar
128 vs 64) and prints the accuracy ladder next to the paper's reported
trend. Real CIFAR-10 is unavailable offline, so the claims validated
are *relative*: ternary ~ 4-bit ADC, binary ~2% lower, 64-row crossbars
degrade less (DESIGN.md §3).

    PYTHONPATH=src python examples/paper_repro_cifar.py [--steps 250]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import QuantConfig, adc_baseline
from benchmarks._qat_common import train_qat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    args = ap.parse_args()

    runs = [
        ("fp baseline        ", QuantConfig(mode="none")),
        ("7-bit ADC  (x128)  ", adc_baseline(7, 128)),
        ("4-bit ADC  (x128)  ", adc_baseline(4, 128)),
        ("ternary 1.5b (x128)", QuantConfig(mode="psq", psq_levels="ternary",
                                            xbar_rows=128)),
        ("ternary 1.5b (x64) ", QuantConfig(mode="psq", psq_levels="ternary",
                                            xbar_rows=64)),
        ("binary 1b   (x128) ", QuantConfig(mode="psq", psq_levels="binary",
                                            xbar_rows=128)),
    ]
    print("config                acc    (paper ResNet-20 trend: 92.3 / 90.2 /"
          " 88.8 / 89.8(x64) / 86.3)")
    for name, qc in runs:
        acc = train_qat(qc, steps=args.steps)
        print(f"{name} {acc:.3f}")


if __name__ == "__main__":
    main()
