"""Batched serving demo: prefill + decode with KV caches, across the
three deployment formats —

  * fp32 master weights,
  * int4-packed weights (two 4-bit codes per byte — the TPU analogue of
    HCiM's weight-stationary crossbars),
  * the full HCiM PSQ pipeline served from the PackedLayer cache:
    weights quantized, int4 planes packed and scale factors precomputed
    ONCE at load, reused across every request.

    PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses

import numpy as np
import jax

from repro.configs import get_config
from repro.core.config import PSQ_TERNARY
from repro.core.psq_linear import pack_tree_for_serving
from repro.models import init_model
from repro.serve import (
    EngineConfig, PackedModelCache, ServeEngine, pack_tree_psq,
    throughput_stats,
)


def run_engine(label, params, cfg, rng):
    eng = ServeEngine(params, cfg, EngineConfig(max_batch=4, max_len=64,
                                                temperature=0.7))
    for _ in range(8):
        prompt = rng.randint(0, cfg.vocab_size, size=rng.randint(4, 12))
        eng.submit(prompt, max_new_tokens=12)
    done = eng.run()
    stats = throughput_stats(done)
    nbytes = sum(x.nbytes for x in jax.tree.leaves(params))
    print(f"{label:22s}: {stats['requests']} reqs, "
          f"{stats['total_tokens']} tokens, "
          f"{stats['tokens_per_s']:.1f} tok/s, "
          f"weights {nbytes / 1e6:.1f} MB")


def main():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)

    run_engine("fp32 weights", params, cfg, rng)
    run_engine("int4-packed weights", pack_tree_for_serving(params), cfg, rng)

    # Full HCiM pipeline from the weight-stationary cache. The 'reference'
    # backend is the fast jnp path on CPU; on TPU pass 'pallas'.
    qcfg = dataclasses.replace(PSQ_TERNARY, kernel_backend="reference",
                               xbar_rows=64)
    psq_cfg = cfg.with_quant(qcfg)
    psq_params = init_model(jax.random.PRNGKey(0), psq_cfg)
    cache = PackedModelCache()
    packed = pack_tree_psq(psq_params, qcfg, cache)
    print(f"packed once at load: {cache.stats()}")
    run_engine("psq PackedLayer cache", packed, psq_cfg, rng)


if __name__ == "__main__":
    main()
