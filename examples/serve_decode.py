"""Batched serving demo: prefill + decode with KV caches, plus the int4
PSQ deployment path (weights packed to two 4-bit codes per byte — the
TPU analogue of HCiM's weight-stationary crossbars).

    PYTHONPATH=src python examples/serve_decode.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.core.psq_linear import pack_tree_for_serving
from repro.models import init_model
from repro.serve import EngineConfig, ServeEngine, throughput_stats


def main():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)

    for label, p in [
        ("fp32 weights", params),
        ("int4-packed weights", pack_tree_for_serving(params)),
    ]:
        eng = ServeEngine(p, cfg, EngineConfig(max_batch=4, max_len=64,
                                               temperature=0.7))
        for _ in range(8):
            prompt = rng.randint(0, cfg.vocab_size, size=rng.randint(4, 12))
            eng.submit(prompt, max_new_tokens=12)
        done = eng.run()
        stats = throughput_stats(done)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(p))
        print(f"{label:22s}: {stats['requests']} reqs, "
              f"{stats['total_tokens']} tokens, "
              f"{stats['tokens_per_s']:.1f} tok/s, "
              f"weights {nbytes / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
