"""Continuous-batching serving demo: slot-pool prefill + decode with KV
caches, across the three deployment formats —

  * fp32 master weights,
  * int4-packed weights (two 4-bit codes per byte — the TPU analogue of
    HCiM's weight-stationary crossbars),
  * the full HCiM PSQ pipeline served from the PackedLayer cache:
    weights quantized, int4 planes packed and scale factors precomputed
    ONCE at load, reused across every request.

Each engine runs the SAME mixed-length workload through the
continuous-batching scheduler (per-step retirement, mid-flight slot
admission — see docs/serving.md); pass mode="static" to EngineConfig for
the classic drain-the-queue loop. Demo timings include compilation —
benchmarks/serve_bench.py measures the warmed steady state.

    PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses

import numpy as np
import jax

from repro.configs import get_config
from repro.core.config import PSQ_TERNARY
from repro.core.psq_linear import pack_tree_for_serving
from repro.models import init_model
from repro.serve import (
    EngineConfig, PackedModelCache, ServeEngine, pack_tree_psq,
    throughput_stats,
)


def run_engine(label, params, cfg, mode="auto"):
    # fresh seeded RNG per engine: every format/scheduler decodes the
    # SAME workload, so the printed numbers compare apples to apples
    rng = np.random.RandomState(0)
    eng = ServeEngine(params, cfg, EngineConfig(max_batch=4, max_len=64,
                                                temperature=0.7, mode=mode))
    for _ in range(8):
        prompt = rng.randint(0, cfg.vocab_size, size=rng.randint(4, 12))
        eng.submit(prompt, max_new_tokens=int(rng.randint(4, 13)))
    done = eng.run()
    stats = throughput_stats(done)
    sched = eng.stats()
    nbytes = sum(x.nbytes for x in jax.tree.leaves(params))
    print(f"{label:26s}: {stats['requests']} reqs, "
          f"{stats['total_tokens']} tokens, "
          f"{stats['tokens_per_s']:.1f} tok/s, "
          f"occupancy {sched['mean_slot_occupancy']:.2f} "
          f"({sched['mode']}), weights {nbytes / 1e6:.1f} MB")


def main():
    cfg = get_config("tinyllama-1.1b").reduced()
    params = init_model(jax.random.PRNGKey(0), cfg)

    run_engine("fp32 weights", params, cfg)
    run_engine("fp32 weights (static)", params, cfg, mode="static")
    run_engine("int4-packed weights", pack_tree_for_serving(params), cfg)

    # Full HCiM pipeline from the weight-stationary cache. The 'reference'
    # backend is the fast jnp path on CPU; on TPU pass 'pallas'.
    qcfg = dataclasses.replace(PSQ_TERNARY, kernel_backend="reference",
                               xbar_rows=64)
    psq_cfg = cfg.with_quant(qcfg)
    psq_params = init_model(jax.random.PRNGKey(0), psq_cfg)
    cache = PackedModelCache()
    packed = pack_tree_psq(psq_params, qcfg, cache)
    print(f"packed once at load: {cache.stats()}")
    run_engine("psq PackedLayer cache", packed, psq_cfg)


if __name__ == "__main__":
    main()
